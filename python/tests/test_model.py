"""Model-level tests: shapes, parameter counts, learning, KAT-vs-ViT wiring."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train as T

jax.config.update("jax_platform_name", "cpu")

TINY = M.ModelConfig(
    name="tiny-test", img_size=8, patch=4, d=32, depth=2, heads=2,
    n_classes=5, s_block=8, drop_path=0.1,
)
TINY_VIT = M.ModelConfig(
    name="tiny-vit-test", img_size=8, patch=4, d=32, depth=2, heads=2,
    n_classes=5, ffn="mlp",
)


def test_forward_shapes():
    params = M.init_model(jax.random.PRNGKey(0), TINY)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 8, 8, 3))
    logits = M.forward(params, x, TINY)
    assert logits.shape == (3, 5)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_param_count_analytic_matches_init():
    for cfg in (TINY, TINY_VIT):
        params = M.init_model(jax.random.PRNGKey(0), cfg)
        assert M.count_params(params) == M.count_params_analytic(cfg)


@pytest.mark.parametrize(
    "name,expect_m",
    [("kat-t", 5.7), ("kat-s", 22.1), ("kat-b", 86.6),
     ("vit-t", 5.7), ("vit-s", 22.1), ("vit-b", 86.6)],
)
def test_paper_param_counts(name, expect_m):
    """Paper Tables 4/6: 5.7M / 22.1M / 86.6M parameters."""
    got = M.count_params_analytic(M.get_config(name)) / 1e6
    assert abs(got - expect_m) / expect_m < 0.01, got


def test_kat_and_vit_same_trunk_size():
    """KAT adds only the rational coefficients over ViT (paper Table 1)."""
    kat = M.count_params_analytic(M.get_config("kat-t"))
    vit = M.count_params_analytic(M.get_config("vit-t"))
    # 12 blocks x 2 rationals x 8 groups x 10 coeffs
    assert kat - vit == 12 * 2 * 8 * 10


def test_train_step_decreases_loss():
    params = M.init_model(jax.random.PRNGKey(0), TINY)
    m, v = T.init_opt_state(params)
    ts = jax.jit(T.make_train_step(TINY))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8, 3))
    y = jax.nn.one_hot(jnp.array([0, 1, 2, 3]), 5)
    key = jnp.zeros((2,), jnp.uint32)
    losses = []
    for step in range(1, 6):
        params, m, v, loss = ts(params, m, v, jnp.int32(step), jnp.float32(3e-3), key, x, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(losses))


def test_kat_backward_variant_grads_agree():
    """Both backward kernels produce (numerically close) model gradients.

    Note: comparing *post-AdamW params* instead would be flaky — at step 1
    AdamW reduces to lr*sign(g), amplifying ~1e-7 kernel differences on
    near-zero gradients to full-lr differences.
    """
    cfg_kat = M.ModelConfig(**{**TINY.__dict__, "name": "tiny-katbwd", "backward": "kat"})
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    y = jax.nn.one_hot(jnp.array([0, 1]), 5)
    grads = []
    for cfg in (TINY, cfg_kat):
        params = M.init_model(jax.random.PRNGKey(0), cfg)
        g = jax.grad(lambda p: T.loss_fn(p, x, y, cfg, jax.random.PRNGKey(0))[0])(params)
        grads.append(g)
    for a, b in zip(jax.tree.leaves(grads[0]), jax.tree.leaves(grads[1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-5)


def test_eval_deterministic_no_droppath():
    params = M.init_model(jax.random.PRNGKey(0), TINY)
    ev = jax.jit(T.make_eval_step(TINY))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    a = np.asarray(ev(params, x))
    b = np.asarray(ev(params, x))
    np.testing.assert_array_equal(a, b)


def test_droppath_changes_training_forward():
    params = M.init_model(jax.random.PRNGKey(0), TINY)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 8, 8, 3))
    k1 = jax.random.PRNGKey(2)
    k2 = jax.random.PRNGKey(3)
    a = np.asarray(M.forward(params, x, TINY, train=True, key=k1))
    b = np.asarray(M.forward(params, x, TINY, train=True, key=k2))
    assert not np.allclose(a, b)


def test_grkan_vs_mlp_forward_differs():
    pk = M.init_model(jax.random.PRNGKey(0), TINY)
    pv = M.init_model(jax.random.PRNGKey(0), TINY_VIT)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    a = np.asarray(M.forward(pk, x, TINY))
    b = np.asarray(M.forward(pv, x, TINY_VIT))
    assert not np.allclose(a, b)


def test_soft_xent_matches_hard_labels():
    logits = jnp.array([[2.0, 0.0, -1.0], [0.0, 1.0, 0.0]])
    hard = jax.nn.one_hot(jnp.array([0, 1]), 3)
    want = -jnp.mean(
        jnp.take_along_axis(jax.nn.log_softmax(logits), jnp.array([[0], [1]]), axis=1)
    )
    np.testing.assert_allclose(float(T.soft_xent(logits, hard)), float(want), rtol=1e-6)


def test_decay_mask_excludes_norms_and_rationals():
    params = M.init_model(jax.random.PRNGKey(0), TINY)
    mask = T.decay_mask(params)
    flat, _ = jax.tree_util.tree_flatten_with_path(mask)
    for path, val in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", ""))) for k in path)
        if any(t in name for t in ("ln1", "ln2", "ln_f", "cls", "pos", "a1", "b1", "a2", "b2")):
            assert val == 0.0, name
        if name.endswith(("fc1_w", "fc2_w", "head_w", "wq", "wk", "wv", "wo")):
            assert val == 1.0, name
