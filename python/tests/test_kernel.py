"""Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes / dtypes / block sizes; every property asserts
allclose against ``ref.py`` (and, for gradients, against jax autodiff of
the reference forward).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import rational as rk

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _tols(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


def make_case(seed, b, n_rows, n_g, d_g, m1, n, dtype):
    d = n_g * d_g
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = _rand(ks[0], (b, n_rows, d), dtype)
    do = _rand(ks[1], (b, n_rows, d), dtype)
    a = _rand(ks[2], (n_g, m1), dtype, 0.5)
    bco = _rand(ks[3], (n_g, n), dtype, 0.5)
    return x, do, a, bco


shape_strategy = st.tuples(
    st.integers(1, 3),       # batch
    st.integers(1, 9),       # rows (sequence)
    st.sampled_from([1, 2, 4, 8]),   # n_g
    st.sampled_from([1, 2, 8, 16]),  # d_g
    st.integers(2, 6),       # m+1
    st.integers(1, 4),       # n
)


@settings(max_examples=20, deadline=None)
@given(shape=shape_strategy, seed=st.integers(0, 2**16), s_block=st.sampled_from([1, 4, 8, 32]))
def test_fwd_matches_ref(shape, seed, s_block):
    b, rows, n_g, d_g, m1, n = shape
    x, _, a, bco = make_case(seed, b, rows, n_g, d_g, m1, n, jnp.float32)
    got = rk.rational_fwd(x, a, bco, s_block=s_block)
    want = ref.rational_fwd_ref(x, a, bco)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tols(jnp.float32))


@settings(max_examples=12, deadline=None)
@given(shape=shape_strategy, seed=st.integers(0, 2**16), s_block=st.sampled_from([1, 8, 32]))
def test_bwd_flash_matches_ref(shape, seed, s_block):
    b, rows, n_g, d_g, m1, n = shape
    x, do, a, bco = make_case(seed, b, rows, n_g, d_g, m1, n, jnp.float32)
    dx, da, db = rk.rational_bwd_flash(x, do, a, bco, s_block=s_block)
    dx_r, da_r, db_r = ref.rational_bwd_ref(x, do, a, bco)
    scale = max(1.0, float(jnp.max(jnp.abs(da_r))), float(jnp.max(jnp.abs(db_r))))
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_r), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(da) / scale, np.asarray(da_r) / scale, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(db) / scale, np.asarray(db_r) / scale, rtol=1e-3, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(shape=shape_strategy, seed=st.integers(0, 2**16))
def test_bwd_kat_matches_ref(shape, seed):
    b, rows, n_g, d_g, m1, n = shape
    x, do, a, bco = make_case(seed, b, rows, n_g, d_g, m1, n, jnp.float32)
    dx, da, db = rk.rational_bwd_kat(x, do, a, bco, s_rows=1)
    dx_r, da_r, db_r = ref.rational_bwd_ref(x, do, a, bco)
    scale = max(1.0, float(jnp.max(jnp.abs(da_r))), float(jnp.max(jnp.abs(db_r))))
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_r), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(da) / scale, np.asarray(da_r) / scale, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(db) / scale, np.asarray(db_r) / scale, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwd_dtypes(dtype):
    x, _, a, bco = make_case(7, 2, 5, 8, 16, 6, 4, dtype)
    got = rk.rational_fwd(x, a, bco, s_block=8)
    want = ref.rational_fwd_ref(x, a, bco)
    assert got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tols(dtype)
    )


def test_bwd_matches_autodiff():
    """Kernel backward == jax.grad of the reference forward."""
    x, do, a, bco = make_case(3, 2, 7, 4, 8, 6, 4, jnp.float32)
    dx, da, db = rk.rational_bwd_flash(x, do, a, bco, s_block=8)
    g = jax.grad(
        lambda x, a, b: jnp.vdot(ref.rational_fwd_ref(x, a, b), do), argnums=(0, 1, 2)
    )(x, a, bco)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(g[0]), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(da), np.asarray(g[1]), rtol=1e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(db), np.asarray(g[2]), rtol=1e-3, atol=2e-3)


def test_padding_rows_not_multiple_of_block():
    """Row counts that don't divide S_block exercise the zero-padding path."""
    x, do, a, bco = make_case(11, 1, 13, 4, 8, 6, 4, jnp.float32)  # 13 rows, s_block 8
    f = rk.rational_fwd(x, a, bco, s_block=8)
    np.testing.assert_allclose(
        np.asarray(f), np.asarray(ref.rational_fwd_ref(x, a, bco)), rtol=2e-4, atol=2e-4
    )
    dx, da, db = rk.rational_bwd_flash(x, do, a, bco, s_block=8)
    dx_r, da_r, db_r = ref.rational_bwd_ref(x, do, a, bco)
    np.testing.assert_allclose(np.asarray(da), np.asarray(da_r), rtol=1e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_r), rtol=1e-3, atol=2e-3)


def test_identity_init_is_identity():
    a, b = ref.identity_init_coeffs()
    a = jnp.tile(a[None], (8, 1))
    b = jnp.tile(b[None], (8, 1))
    x = jnp.linspace(-3, 3, 64, dtype=jnp.float32).reshape(1, 1, 64)
    np.testing.assert_allclose(
        np.asarray(rk.rational_fwd(x, a, b, s_block=1)), np.asarray(x), rtol=1e-6, atol=1e-6
    )


def test_swish_init_approximates_silu():
    a, b = ref.swish_init_coeffs()
    a = jnp.tile(a[None], (4, 1))
    b = jnp.tile(b[None], (4, 1))
    x = jnp.linspace(-3, 3, 128, dtype=jnp.float32).reshape(1, 1, 128)
    got = np.asarray(rk.rational_fwd(x, a, b, s_block=1))
    want = np.asarray(jax.nn.silu(x))
    assert np.max(np.abs(got - want)) < 0.12, np.max(np.abs(got - want))


def test_safe_pau_no_nan_at_poles():
    """Q = 1 + |A| >= 1 guarantees no poles — even at A(x) = 0 and huge x."""
    a = jnp.ones((2, 6), jnp.float32)
    b = jnp.ones((2, 4), jnp.float32) * -5.0
    x = jnp.array([[[-1e2, 0.0, 1e-30, 1e2, -1e-30, 2.0, -2.0, 0.5]]], jnp.float32)
    f = rk.rational_fwd(x, a, b, s_block=1)
    assert np.all(np.isfinite(np.asarray(f)))
    dx, da, db = rk.rational_bwd_flash(x, jnp.ones_like(x), a, b, s_block=1)
    assert np.all(np.isfinite(np.asarray(dx)))
    assert np.all(np.isfinite(np.asarray(da)))
    assert np.all(np.isfinite(np.asarray(db)))


def test_access_count_model():
    """The analytic access-count model matches the paper's §4 formulas and
    the claimed 1/(S_block*d_g) reduction factor."""
    bnd = 1024 * 197 * 768
    m1, n = 6, 4
    kat = rk.kat_global_accesses(bnd, m1, n)
    assert kat == 3 * (5 + 4 + 2) * bnd
    s_block, d_g = 128, 96
    fl = rk.flash_global_accesses(bnd, m1, n, s_block, d_g)
    expect = round(3 * (1 + (5 + 4 + 1) / (s_block * d_g)) * bnd)
    assert abs(fl - expect) <= 3 * (bnd // (s_block * d_g))
    assert kat / fl > 10.0  # an order of magnitude fewer accesses
