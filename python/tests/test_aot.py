"""AOT path tests: HLO text emission and manifest correctness.

These guard the L2->L3 interchange contract: manifest input order must be
the jax flatten order, dtypes/shapes must match, and the HLO must be text
(parsable header) — the exact properties the Rust runtime relies on.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M
from compile import train as T

jax.config.update("jax_platform_name", "cpu")

TINY = M.ModelConfig(
    name="tiny-aot", img_size=8, patch=4, d=32, depth=1, heads=2,
    n_classes=3, s_block=8,
)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build_model_artifacts(out, TINY, batch=2, tag="tiny")
    return out


def _manifest(out, name):
    with open(os.path.join(out, f"{name}.manifest.json")) as f:
        return json.load(f)


def test_hlo_is_text(built):
    with open(os.path.join(built, "tiny_train_step.hlo.txt")) as f:
        head = f.read(200)
    assert "HloModule" in head, head


def test_train_step_manifest_signature(built):
    man = _manifest(built, "tiny_train_step")
    params = M.init_model(jax.random.PRNGKey(0), TINY)
    n_p = len(jax.tree.leaves(params))
    assert len(man["inputs"]) == 3 * n_p + 5
    assert len(man["outputs"]) == 3 * n_p + 1
    # trailing inputs: step, lr, key, images, labels
    tail = man["inputs"][-5:]
    assert tail[0]["dtype"] == "i32" and tail[0]["shape"] == []
    assert tail[1]["dtype"] == "f32" and tail[1]["shape"] == []
    assert tail[2]["dtype"] == "u32" and tail[2]["shape"] == [2]
    assert tail[3]["shape"] == [2, 8, 8, 3]
    assert tail[4]["shape"] == [2, 3]
    # loss is the last output, scalar f32
    assert man["outputs"][-1]["shape"] == []
    assert man["outputs"][-1]["dtype"] == "f32"


def test_manifest_order_matches_flatten_order(built):
    man = _manifest(built, "tiny_init")
    params = M.init_model(jax.random.PRNGKey(0), TINY)
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    assert len(man["outputs"]) == len(flat)
    for spec, (path, leaf) in zip(man["outputs"], flat):
        assert spec["shape"] == list(leaf.shape), spec["name"]
        name = aot._path_str(path)
        assert spec["name"] == name


def test_metadata_fields(built):
    man = _manifest(built, "tiny_train_step")
    assert man["batch"] == 2
    assert man["img_size"] == 8
    assert man["n_classes"] == 3
    assert man["model"] == "tiny-aot"
    assert man["params"] == M.count_params_analytic(TINY)


def test_eval_manifest(built):
    man = _manifest(built, "tiny_eval")
    assert man["outputs"][0]["shape"] == [aot.EVAL_BATCH, 3]


def test_train_step_numerics_via_python_exec(built):
    """The exact lowered function reduces loss when iterated (the Rust
    trainer does the same through PJRT)."""
    params = M.init_model(jax.random.PRNGKey(0), TINY)
    m, v = T.init_opt_state(params)
    ts = jax.jit(T.make_train_step(TINY))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    y = jax.nn.one_hot(jnp.array([0, 1]), 3)
    key = jnp.zeros((2,), jnp.uint32)
    first = None
    for step in range(1, 9):
        params, m, v, loss = ts(params, m, v, jnp.int32(step), jnp.float32(3e-3), key, x, y)
        first = first if first is not None else float(loss)
    assert float(loss) < first
