"""TPU performance-model tests (L1 §Perf): VMEM footprint, HBM traffic,
S_block selection, and the paper's access-reduction formula."""

from compile.kernels import rational as rk


def test_vmem_footprint_fits_paper_dims():
    # Paper dims: d=768, 8 groups -> d_g=96; S_block=128.
    bytes_ = rk.flash_bwd_vmem_bytes(128, 96, 6, 4)
    assert bytes_ == 3 * 128 * 96 * 4 + 2 * 10 * 4
    assert bytes_ < rk.VMEM_BYTES // 4  # comfortable double-buffer headroom


def test_hbm_traffic_dominated_by_streaming():
    rows, d = 1024 * 197, 768
    total = rk.flash_bwd_hbm_bytes(rows, d, 6, 4, 8, 128)
    stream = 3 * rows * d * 4
    # the dA/dB revisit term is < 0.1% of traffic — Algorithm 2's point.
    assert (total - stream) / total < 1e-3


def test_access_reduction_factor_matches_paper():
    rows, d, n_g, s_block = 1024 * 197, 768, 8, 128
    d_g = d // n_g
    kat = rk.kat_global_accesses(rows * d, 6, 4)
    flash = rk.flash_global_accesses(rows * d, 6, 4, s_block, d_g)
    # paper §4: reduction ~ (m+n+2) / (1 + (m+n+1)/(S_block*d_g)) ~ 11x in
    # accesses, and the *atomic* count drops by S_block*d_g = 12288x.
    assert 10.5 < kat / flash < 11.5
    atomics_kat = rows * d * 10
    atomics_flash = -(-rows // s_block) * n_g * 10
    assert abs(atomics_kat / atomics_flash - s_block * d_g) / (s_block * d_g) < 0.01


def test_kernel_is_bandwidth_bound_on_tpu():
    # Arithmetic intensity << any TPU ridge point (~100+ FLOPs/byte).
    ai = rk.flash_bwd_arithmetic_intensity(1024 * 197, 768, 6, 4, 8, 128)
    assert ai < 10.0, ai


def test_pick_s_block_scales_with_vmem():
    # Small d_g -> huge blocks allowed; big d_g -> smaller blocks.
    s_small = rk.pick_s_block(rows=10_000, d=128, n_g=8)    # d_g=16
    s_big = rk.pick_s_block(rows=10_000, d=3072, n_g=8)     # d_g=384
    assert s_small >= s_big
    assert rk.flash_bwd_vmem_bytes(s_small, 16, 6, 4) <= rk.VMEM_BYTES // 4
    assert rk.flash_bwd_vmem_bytes(s_big, 384, 6, 4) <= rk.VMEM_BYTES // 4
    # never exceeds the row count
    assert rk.pick_s_block(rows=64, d=128, n_g=8) <= 64
