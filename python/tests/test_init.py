"""Initialization tests: variance preservation, mimetic attention, AdamW."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import attention as A
from compile import layers as L
from compile import train as T
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def test_variance_preserving_gain_identity():
    """F = identity => alpha = E[x^2] = 1."""
    assert abs(L._gain_for("identity") - 1.0) < 0.05


def test_variance_preserving_gain_swish():
    """F = swish => alpha = E[silu(x)^2] ~ 0.355 for x ~ N(0,1)."""
    x = np.random.RandomState(0).randn(200000).astype(np.float32)
    silu = x / (1 + np.exp(-x))
    want = float(np.mean(silu * silu))
    assert abs(L._gain_for("swish") - want) < 0.05, (L._gain_for("swish"), want)


def test_grkan_layer_preserves_variance():
    """With variance-preserving init, Var[GR-KAN fc1 output] ~ Var[input]."""
    key = jax.random.PRNGKey(0)
    d, dh, n_g = 64, 256, 8
    p = L.init_grkan_ffn(key, d, dh, n_g)
    x = jax.random.normal(jax.random.PRNGKey(1), (512, d))
    h = L.rational_op(x, p["a1"], p["b1"], "flash", 64)
    h = h @ p["fc1_w"]
    ratio = float(jnp.var(h) / jnp.var(x))
    assert 0.5 < ratio < 2.0, ratio


def test_mimetic_qk_product_near_identity_plus_noise():
    wq, wk = A.mimetic_qk(jax.random.PRNGKey(0), 64, alpha=0.7, beta=0.0)
    prod = np.asarray(wq @ wk.T)
    np.testing.assert_allclose(prod, 0.7 * np.eye(64), atol=1e-5)


def test_mimetic_qk_with_noise_has_positive_diagonal_bias():
    wq, wk = A.mimetic_qk(jax.random.PRNGKey(0), 64, alpha=0.7, beta=0.7)
    prod = np.asarray(wq @ wk.T)
    diag = np.mean(np.diag(prod))
    off = np.mean(np.abs(prod - np.diag(np.diag(prod))))
    assert diag > 3 * off, (diag, off)


def test_attention_output_shape_and_finite():
    p = A.init_attention(jax.random.PRNGKey(0), 32, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, 32))
    o = A.attention(p, x, 4)
    assert o.shape == (2, 9, 32)
    assert np.all(np.isfinite(np.asarray(o)))


def test_adamw_decoupled_weight_decay():
    """Weight decay applies even with zero gradient (decoupled)."""
    p = {"w": jnp.ones((4,)), "ln": jnp.ones((4,))}
    m = jax.tree.map(jnp.zeros_like, p)
    v = jax.tree.map(jnp.zeros_like, p)
    g = jax.tree.map(jnp.zeros_like, p)
    mask = {"w": 1.0, "ln": 0.0}
    p2, _, _ = T.adamw_update(p, m, v, g, jnp.int32(1), jnp.float32(0.1), mask)
    assert float(p2["w"][0]) < 1.0          # decayed
    assert float(p2["ln"][0]) == 1.0        # masked out


def test_adamw_step_direction():
    p = {"w": jnp.zeros((2,))}
    m = {"w": jnp.zeros((2,))}
    v = {"w": jnp.zeros((2,))}
    g = {"w": jnp.array([1.0, -1.0])}
    mask = {"w": 0.0}
    p2, m2, v2 = T.adamw_update(p, m, v, g, jnp.int32(1), jnp.float32(0.01), mask)
    got = np.asarray(p2["w"])
    assert got[0] < 0 and got[1] > 0
    np.testing.assert_allclose(np.abs(got), 0.01, rtol=1e-3)  # ~ lr * sign(g)


def test_drop_path_scales_kept_samples():
    x = jnp.ones((1000, 3))
    y = L.drop_path(jax.random.PRNGKey(0), x, 0.25, train=True)
    vals = np.unique(np.asarray(y).round(4))
    assert set(vals.tolist()) <= {0.0, np.float32(1 / 0.75).round(4)}
    # expectation preserved
    assert abs(float(jnp.mean(y)) - 1.0) < 0.1


def test_patch_embed_roundtrip_geometry():
    p = L.init_patch_embed(jax.random.PRNGKey(0), 4, 3, 16)
    img = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    t = L.patch_embed(p, img, 4)
    assert t.shape == (2, 4, 16)
    # identical patches map to identical tokens
    tile = jnp.tile(img[:, :4, :4, :], (1, 2, 2, 1))
    tt = L.patch_embed(p, tile, 4)
    np.testing.assert_allclose(np.asarray(tt[:, 0]), np.asarray(tt[:, 3]), rtol=1e-5)
