"""L2: full KAT / ViT models (paper Table 6 variants + a CPU-scale micro).

A model is (init_fn -> params pytree, forward_fn).  The feed-forward block
is either a GR-KAN (KAT) or an MLP (ViT); the GR-KAN's backward routes
through the FlashKAT or baseline-KAT Pallas kernel per config.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import attention as attn
from . import layers as L


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    img_size: int = 224
    patch: int = 16
    in_ch: int = 3
    d: int = 192
    depth: int = 12
    heads: int = 3
    mlp_ratio: int = 4
    n_classes: int = 1000
    ffn: str = "grkan"          # "grkan" (KAT) | "mlp" (ViT/DeiT)
    n_groups: int = 8           # paper: 8 groups
    backward: str = "flash"     # "flash" | "kat"
    s_block: int = 128
    drop_path: float = 0.1      # peak stochastic-depth rate
    mimetic: bool = True

    @property
    def n_tokens(self) -> int:
        return (self.img_size // self.patch) ** 2 + 1  # + cls

    @property
    def d_hidden(self) -> int:
        return self.d * self.mlp_ratio


# Paper Table 6 variants (identical trunk dims for KAT and ViT/DeiT).
def kat_tiny(**kw):
    return ModelConfig(name="kat-t", d=192, heads=3, **kw)


def kat_small(**kw):
    return ModelConfig(name="kat-s", d=384, heads=6, **kw)


def kat_base(**kw):
    kw.setdefault("drop_path", 0.4)
    return ModelConfig(name="kat-b", d=768, heads=12, **kw)


def vit_tiny(**kw):
    return ModelConfig(name="vit-t", d=192, heads=3, ffn="mlp", **kw)


def vit_small(**kw):
    return ModelConfig(name="vit-s", d=384, heads=6, ffn="mlp", **kw)


def vit_base(**kw):
    return ModelConfig(name="vit-b", d=768, heads=12, ffn="mlp", **kw)


# CPU-scale variants for the end-to-end driver (32x32 synthetic images).
# s_block=512 per the perf pass (EXPERIMENTS.md §Perf): 1.8x faster train
# step than 128 on CPU interpret (fewer grid iterations), VMEM-safe by
# kernels.rational.pick_s_block.
def kat_micro(**kw):
    return ModelConfig(
        name="kat-micro", img_size=32, patch=4, d=128, depth=4, heads=4,
        n_classes=10, s_block=512, drop_path=0.05, **kw,
    )


def vit_micro(**kw):
    return ModelConfig(
        name="vit-micro", img_size=32, patch=4, d=128, depth=4, heads=4,
        n_classes=10, ffn="mlp", drop_path=0.05, **kw,
    )


CONFIGS = {
    "kat-t": kat_tiny, "kat-s": kat_small, "kat-b": kat_base,
    "vit-t": vit_tiny, "vit-s": vit_small, "vit-b": vit_base,
    "kat-micro": kat_micro, "vit-micro": vit_micro,
}


def get_config(name: str, **kw) -> ModelConfig:
    return CONFIGS[name](**kw)


# ---------------------------------------------------------------------------
# Init.
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, dtype=jnp.float32):
    ka, kf = jax.random.split(key)
    p = {
        "ln1": L.init_layernorm(cfg.d, dtype),
        "attn": attn.init_attention(ka, cfg.d, cfg.heads, cfg.mimetic, dtype),
        "ln2": L.init_layernorm(cfg.d, dtype),
    }
    if cfg.ffn == "grkan":
        p["ffn"] = L.init_grkan_ffn(kf, cfg.d, cfg.d_hidden, cfg.n_groups, dtype)
    else:
        p["ffn"] = L.init_mlp_ffn(kf, cfg.d, cfg.d_hidden, dtype)
    return p


def init_model(key, cfg: ModelConfig, dtype=jnp.float32):
    keys = jax.random.split(key, cfg.depth + 3)
    blocks = [init_block(keys[i], cfg, dtype) for i in range(cfg.depth)]
    n_patches = (cfg.img_size // cfg.patch) ** 2
    return {
        "patch": L.init_patch_embed(keys[-3], cfg.patch, cfg.in_ch, cfg.d, dtype),
        "cls": jnp.zeros((1, 1, cfg.d), dtype),
        "pos": jax.random.normal(keys[-2], (1, n_patches + 1, cfg.d), dtype) * 0.02,
        "blocks": blocks,
        "ln_f": L.init_layernorm(cfg.d, dtype),
        "head_w": jax.random.normal(keys[-1], (cfg.d, cfg.n_classes), dtype)
        * (1.0 / cfg.d) ** 0.5,
        "head_b": jnp.zeros((cfg.n_classes,), dtype),
    }


# ---------------------------------------------------------------------------
# Forward.
# ---------------------------------------------------------------------------

def block_forward(p, x, cfg: ModelConfig, *, train: bool, key, dp_rate: float):
    k1, k2 = (jax.random.split(key) if key is not None else (None, None))
    h = attn.attention(p["attn"], L.layernorm(p["ln1"], x), cfg.heads)
    x = x + (L.drop_path(k1, h, dp_rate, train) if train else h)
    if cfg.ffn == "grkan":
        h = L.grkan_ffn(p["ffn"], L.layernorm(p["ln2"], x), cfg.backward, cfg.s_block)
    else:
        h = L.mlp_ffn(p["ffn"], L.layernorm(p["ln2"], x))
    x = x + (L.drop_path(k2, h, dp_rate, train) if train else h)
    return x


def forward(params, images, cfg: ModelConfig, *, train: bool = False, key=None):
    """images: (B, H, W, C) -> logits (B, n_classes)."""
    x = L.patch_embed(params["patch"], images, cfg.patch)
    B = x.shape[0]
    cls = jnp.broadcast_to(params["cls"], (B, 1, cfg.d)).astype(x.dtype)
    x = jnp.concatenate([cls, x], axis=1) + params["pos"]

    # Linearly ramped stochastic-depth rates, 0 -> cfg.drop_path (DeiT recipe).
    for i, bp in enumerate(params["blocks"]):
        dp = cfg.drop_path * i / max(1, cfg.depth - 1)
        bkey = jax.random.fold_in(key, i) if key is not None else None
        x = block_forward(bp, x, cfg, train=train, key=bkey, dp_rate=dp)

    x = L.layernorm(params["ln_f"], x)
    return x[:, 0, :] @ params["head_w"] + params["head_b"]


def count_params(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))


def count_params_analytic(cfg: ModelConfig) -> int:
    """Closed-form parameter count (cross-checked against init in tests and
    against the paper's 5.7M / 22.1M / 86.6M in Tables 4/6)."""
    d, dh = cfg.d, cfg.d_hidden
    n_patches = (cfg.img_size // cfg.patch) ** 2
    patch = (cfg.patch * cfg.patch * cfg.in_ch + 1) * d
    embed = d + (n_patches + 1) * d  # cls + pos
    attn_p = 4 * d * d + 4 * d
    ln = 2 * d
    ffn = d * dh + dh + dh * d + d
    if cfg.ffn == "grkan":
        ffn += 2 * cfg.n_groups * (6 + 4)  # two rationals per block
    block = ln + attn_p + ln + ffn
    head = d * cfg.n_classes + cfg.n_classes
    return patch + embed + cfg.depth * block + ln + head
