"""L2: training step (loss + AdamW) lowered whole into one HLO module.

The Rust coordinator owns the schedule (cosine LR, warmup), the data
pipeline and augmentations (mixup/cutmix produce *soft* labels, so the loss
here takes a full label distribution), EMA, and checkpointing.  Everything
that must be fast and differentiable — forward, backward (through the
Pallas rational kernels), and the AdamW update — lives in this one graph.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model as M

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
WEIGHT_DECAY = 0.05  # paper Table 7


def soft_xent(logits, soft_labels):
    """Cross-entropy against a label *distribution* (label smoothing and
    mixup/cutmix are applied by the coordinator, producing soft labels)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(soft_labels * logp, axis=-1))


def loss_fn(params, images, soft_labels, cfg, key):
    logits = M.forward(params, images, cfg, train=True, key=key)
    return soft_xent(logits, soft_labels), logits


def _no_decay(path_leaf) -> bool:
    """AdamW decay mask: no decay on norms, biases, cls/pos tokens, or the
    rational coefficients (they parameterize an activation, not a weight)."""
    path, _ = path_leaf
    names = {getattr(k, "key", getattr(k, "idx", None)) for k in path}
    if names & {"ln1", "ln2", "ln_f", "cls", "pos", "a1", "b1", "a2", "b2"}:
        return True
    last = path[-1]
    return getattr(last, "key", "") in {
        "b", "bias", "bq", "bk", "bv", "bo", "fc1_b", "fc2_b", "head_b"
    }


def decay_mask(params):
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree.structure(params)
    return jax.tree.unflatten(treedef, [0.0 if _no_decay(pl) else 1.0 for pl in flat])


def adamw_update(params, m, v, grads, step, lr, mask):
    """One decoupled-weight-decay Adam step (Loshchilov & Hutter 2017).

    ``step`` is the 1-based step count (int32 scalar), ``lr`` a f32 scalar.
    """
    step_f = step.astype(jnp.float32)
    bc1 = 1.0 - ADAM_B1**step_f
    bc2 = 1.0 - ADAM_B2**step_f

    def upd(p, m_, v_, g, wd):
        m2 = ADAM_B1 * m_ + (1.0 - ADAM_B1) * g
        v2 = ADAM_B2 * v_ + (1.0 - ADAM_B2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        p2 = p - lr * (mh / (jnp.sqrt(vh) + ADAM_EPS) + WEIGHT_DECAY * wd * p)
        return p2, m2, v2

    out = jax.tree.map(upd, params, m, v, grads, mask)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, new_m, new_v


def make_train_step(cfg: M.ModelConfig):
    """Returns train_step(params, m, v, step, lr, key_bits, images, labels)
    -> (params', m', v', loss).  ``key_bits`` is uint32[2]."""

    def train_step(params, m, v, step, lr, key_bits, images, soft_labels):
        key = jax.random.wrap_key_data(key_bits, impl="threefry2x32")
        mask = decay_mask(params)
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, images, soft_labels, cfg, key
        )
        new_p, new_m, new_v = adamw_update(params, m, v, grads, step, lr, mask)
        return new_p, new_m, new_v, loss

    return train_step


def make_eval_step(cfg: M.ModelConfig):
    """Returns eval_step(params, images) -> logits (no dropout/drop-path)."""

    def eval_step(params, images):
        return M.forward(params, images, cfg, train=False, key=None)

    return eval_step


def init_opt_state(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return zeros, jax.tree.map(jnp.zeros_like, params)
