"""AOT compile path: lower L2 functions to HLO *text* + JSON manifests.

HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Every artifact ``<name>.hlo.txt`` is accompanied by ``<name>.manifest.json``
describing the flat input/output signature (leaf paths, shapes, dtypes) so
the Rust runtime can marshal literals without guessing pytree order.

Run ``python -m compile.aot --out-dir ../artifacts`` (the Makefile does).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T
from .kernels import rational as rk

TRAIN_BATCH = 32
EVAL_BATCH = 32
# Paper kernel-benchmark dims are (1024, 197, 768); batch is scaled for CPU.
KERNEL_DIMS = (8, 197, 768)
KERNEL_GROUPS, KERNEL_M1, KERNEL_N = 8, 6, 4


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_str(dt) -> str:
    return {
        "float32": "f32", "float64": "f64", "int32": "i32", "int64": "i64",
        "uint32": "u32", "bfloat16": "bf16",
    }[jnp.dtype(dt).name]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _signature(tree):
    """Flatten a pytree of arrays/ShapeDtypeStructs into manifest entries."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [
        {"name": _path_str(p), "shape": list(v.shape), "dtype": _dtype_str(v.dtype)}
        for p, v in flat
    ]


def emit(out_dir: str, name: str, lowered, in_tree, out_tree, extra=None):
    os.makedirs(out_dir, exist_ok=True)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    text = to_hlo_text(lowered)
    with open(hlo_path, "w") as f:
        f.write(text)
    manifest = {
        "name": name,
        "inputs": _signature(in_tree),
        "outputs": _signature(out_tree),
    }
    if extra:
        manifest.update(extra)
    with open(os.path.join(out_dir, f"{name}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  {name}: {len(text)/1e6:.2f} MB hlo, {len(manifest['inputs'])} in / "
          f"{len(manifest['outputs'])} out")


def _spec_like(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


# ---------------------------------------------------------------------------
# Artifact builders.
# ---------------------------------------------------------------------------

def build_model_artifacts(out_dir: str, cfg: M.ModelConfig, batch: int, tag: str):
    """init / train_step / eval artifacts for one model config."""
    key = jax.random.PRNGKey(0)
    params = M.init_model(key, cfg)
    n_params = M.count_params(params)
    print(f"model {cfg.name} [{tag}]: {n_params/1e6:.2f}M params")

    cfg_extra = {
        "model": cfg.name, "params": n_params, "batch": batch,
        "img_size": cfg.img_size, "n_classes": cfg.n_classes,
        "backward": cfg.backward, "ffn": cfg.ffn,
    }

    # init: () -> params (seed baked in)
    def init_fn():
        return M.init_model(jax.random.PRNGKey(0), cfg)

    lowered = jax.jit(init_fn).lower()
    emit(out_dir, f"{tag}_init", lowered, (), params, cfg_extra)

    # train_step
    m, v = T.init_opt_state(params)
    step = jnp.zeros((), jnp.int32)
    lr = jnp.zeros((), jnp.float32)
    key_bits = jnp.zeros((2,), jnp.uint32)
    images = jax.ShapeDtypeStruct((batch, cfg.img_size, cfg.img_size, cfg.in_ch), jnp.float32)
    labels = jax.ShapeDtypeStruct((batch, cfg.n_classes), jnp.float32)

    args = (_spec_like(params), _spec_like(m), _spec_like(v),
            _spec_like(step), _spec_like(lr), _spec_like(key_bits), images, labels)
    ts = T.make_train_step(cfg)
    lowered = jax.jit(ts).lower(*args)
    loss_spec = jax.ShapeDtypeStruct((), jnp.float32)
    emit(out_dir, f"{tag}_train_step", lowered, args,
         (_spec_like(params), _spec_like(m), _spec_like(v), loss_spec), cfg_extra)

    # eval: (params, images) -> logits
    ev = T.make_eval_step(cfg)
    eimages = jax.ShapeDtypeStruct((EVAL_BATCH, cfg.img_size, cfg.img_size, cfg.in_ch), jnp.float32)
    lowered = jax.jit(ev).lower(_spec_like(params), eimages)
    logits = jax.ShapeDtypeStruct((EVAL_BATCH, cfg.n_classes), jnp.float32)
    emit(out_dir, f"{tag}_eval", lowered, (_spec_like(params), eimages), logits,
         dict(cfg_extra, batch=EVAL_BATCH))


def build_kernel_artifacts(out_dir: str, dims=KERNEL_DIMS):
    """Standalone rational-kernel artifacts at (scaled) paper dims."""
    b, n_rows, d = dims
    x = jax.ShapeDtypeStruct((b, n_rows, d), jnp.float32)
    do = jax.ShapeDtypeStruct((b, n_rows, d), jnp.float32)
    a = jax.ShapeDtypeStruct((KERNEL_GROUPS, KERNEL_M1), jnp.float32)
    bc = jax.ShapeDtypeStruct((KERNEL_GROUPS, KERNEL_N), jnp.float32)
    extra = {"dims": list(dims), "n_groups": KERNEL_GROUPS, "m1": KERNEL_M1, "n": KERNEL_N}

    lowered = jax.jit(lambda x, a, b: rk.rational_fwd(x, a, b)).lower(x, a, bc)
    emit(out_dir, "rational_fwd", lowered, (x, a, bc), x, extra)

    lowered = jax.jit(lambda x, do, a, b: rk.rational_bwd_flash(x, do, a, b)).lower(x, do, a, bc)
    emit(out_dir, "rational_bwd_flash", lowered, (x, do, a, bc), (x, a, bc), extra)

    lowered = jax.jit(
        lambda x, do, a, b: rk.rational_bwd_kat(x, do, a, b, s_rows=16)
    ).lower(x, do, a, bc)
    emit(out_dir, "rational_bwd_kat", lowered, (x, do, a, bc), (x, a, bc), extra)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=TRAIN_BATCH)
    ap.add_argument("--only", choices=["kernels", "models"], default=None)
    args = ap.parse_args()

    if args.only in (None, "kernels"):
        print("== kernel artifacts ==")
        build_kernel_artifacts(args.out_dir)
    if args.only in (None, "models"):
        print("== model artifacts ==")
        build_model_artifacts(args.out_dir, M.kat_micro(), args.batch, "kat_micro")
        build_model_artifacts(args.out_dir, M.vit_micro(), args.batch, "vit_micro")
        build_model_artifacts(
            args.out_dir, M.kat_micro(backward="kat"), args.batch, "kat_micro_katbwd"
        )
    # stamp file for `make`
    with open(os.path.join(args.out_dir, ".stamp"), "w") as f:
        f.write("ok\n")
    print("artifacts done")


if __name__ == "__main__":
    main()
