"""Multi-head self-attention with Mimetic initialization.

The paper's training recipe applies Mimetic initialization (Trockman &
Kolter 2023) to the attention layers: W_q W_k^T ~ alpha*I + beta*Z, which
makes random-init attention behave like a (noisy) identity/self-token
mixer and stabilizes early training.

Implementation note: the textbook construction factors the target matrix
with an SVD, but ``jnp.linalg.svd`` lowers to a typed-FFI LAPACK
custom-call that the AOT consumer (xla_extension 0.5.1) rejects.  We use
an SVD-free construction instead: W_q = W_k = sqrt(alpha)*I +
sqrt(beta/d)*G with shared Gaussian G, giving W_q W_k^T = alpha*I +
sqrt(alpha*beta/d)*(G+G^T) + (beta/d)*G G^T — diagonally dominant with a
shared symmetric noise term, which is the property mimetic init needs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mimetic_qk(key, d: int, alpha: float = 0.7, beta: float = 0.7, dtype=jnp.float32):
    """Return (W_q, W_k) with W_q W_k^T ≈ alpha*I + noise(beta) (SVD-free)."""
    g = jax.random.normal(key, (d, d), jnp.float32)
    w = (alpha**0.5) * jnp.eye(d, dtype=jnp.float32) + (beta / d) ** 0.5 * g
    return w.astype(dtype), w.astype(dtype)


def init_attention(key, d: int, heads: int, mimetic: bool = True, dtype=jnp.float32):
    assert d % heads == 0
    kq, kv, kp = jax.random.split(key, 3)
    if mimetic:
        wq, wk = mimetic_qk(kq, d, dtype=dtype)
    else:
        s = (1.0 / d) ** 0.5
        wq = jax.random.normal(kq, (d, d), dtype) * s
        wk = jax.random.normal(jax.random.fold_in(kq, 1), (d, d), dtype) * s
    s = (1.0 / d) ** 0.5
    return {
        "wq": wq,
        "wk": wk,
        "wv": jax.random.normal(kv, (d, d), dtype) * s,
        "wo": jax.random.normal(kp, (d, d), dtype) * s,
        "bq": jnp.zeros((d,), dtype),
        "bk": jnp.zeros((d,), dtype),
        "bv": jnp.zeros((d,), dtype),
        "bo": jnp.zeros((d,), dtype),
    }


def attention(p, x, heads: int):
    """x: (B, N, d) -> (B, N, d). Standard pre-softmax 1/sqrt(d_h) scaling."""
    B, N, d = x.shape
    dh = d // heads

    def split(t):
        return t.reshape(B, N, heads, dh).transpose(0, 2, 1, 3)  # (B, h, N, dh)

    q = split(x @ p["wq"] + p["bq"])
    k = split(x @ p["wk"] + p["bk"])
    v = split(x @ p["wv"] + p["bv"])

    logits = jnp.einsum("bhnd,bhmd->bhnm", q, k) / jnp.sqrt(jnp.asarray(dh, x.dtype))
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhnm,bhmd->bhnd", w, v)
    o = o.transpose(0, 2, 1, 3).reshape(B, N, d)
    return o @ p["wo"] + p["bo"]
