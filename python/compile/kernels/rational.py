"""Pallas kernels for the group-wise rational function (safe PAU).

Three kernels, mirroring the paper:

* ``rational_fwd``        — forward F(x) = P(x)/Q(x), grouped coefficients.
* ``rational_bwd_kat``    — the *baseline* backward pass with the access
  structure of paper Algorithm 1: a 1-D grid over rows where every grid step
  re-loads the full coefficient tensors and accumulates its contribution
  into the full ``dA``/``dB`` outputs.  On a GPU this accumulation is a
  per-element atomic add; on TPU (and in interpret mode) the sequential
  grid expresses the same long, contention-shaped accumulation chain.
* ``rational_bwd_flash``  — the FlashKAT backward pass (paper Algorithm 2):
  a 2-D grid ``(T, n_g)`` where each block loads *one* group's coefficients,
  reduces its ``(S_block, d_g)`` tile of contributions locally in VMEM, and
  performs a single accumulation into ``dA[j]``/``dB[j]`` per block.

Hardware adaptation (see DESIGN.md §2): CUDA threadblocks -> Pallas grid +
BlockSpec; shared-memory block reduction -> VMEM tile reduction
(``jnp.sum``); atomic adds -> revisiting the same output block across the
sequential TPU grid (``@pl.when(i == 0)`` initialize, else accumulate).

All kernels run with ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.  Correctness is anchored on
``ref.py`` via pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls; see module docstring.

DEFAULT_S_BLOCK = 128


# ---------------------------------------------------------------------------
# Shared in-kernel math (operates on one tile with one coefficient vector).
# ---------------------------------------------------------------------------

def _horner(coeffs_1d, x, k):
    """sum_i coeffs_1d[i] * x**i for i in [0, k) via Horner. coeffs_1d: (k,)."""
    acc = jnp.full_like(x, coeffs_1d[k - 1])
    for i in range(k - 2, -1, -1):
        acc = acc * x + coeffs_1d[i]
    return acc


def _pq_sign(x, a, b, m1, n):
    """P, Q, sign(A) for a tile x with coefficient vectors a:(m1,), b:(n,)."""
    p = _horner(a, x, m1)
    A = x * _horner(b, x, n)
    q = 1.0 + jnp.abs(A)
    return p, q, jnp.sign(A)


def _grads(x, do, a, b, m1, n):
    """Per-element dx plus *unreduced* coefficient contributions.

    Returns (dx, da_terms, db_terms) where da_terms[k] = do * x^k / Q and
    db_terms[j] = -do * x^(j+1) * sign(A) * P/Q^2, each with x's shape.
    """
    p, q, sgn = _pq_sign(x, a, b, m1, n)
    inv_q = 1.0 / q
    p_over_q2 = p * inv_q * inv_q

    # P'(x) and A'(x) by Horner on the derivative coefficients.
    if m1 > 1:
        dp = jnp.full_like(x, a[m1 - 1] * (m1 - 1))
        for i in range(m1 - 2, 0, -1):
            dp = dp * x + a[i] * i
    else:
        dp = jnp.zeros_like(x)
    acc = jnp.full_like(x, b[n - 1] * n)
    for j in range(n - 2, -1, -1):
        acc = acc * x + b[j] * (j + 1)
    dadx = acc

    dx = do * (dp * inv_q - sgn * dadx * p_over_q2)

    do_q = do * inv_q
    neg_do_spq2 = -do * sgn * p_over_q2
    da_terms = []
    db_terms = []
    pw = jnp.ones_like(x)
    for k in range(m1):
        da_terms.append(do_q * pw)
        pw = pw * x
    pw = x
    for j in range(n):
        db_terms.append(neg_do_spq2 * pw)
        pw = pw * x
    return dx, da_terms, db_terms


# ---------------------------------------------------------------------------
# Forward kernel.
# ---------------------------------------------------------------------------

def _fwd_kernel(x_ref, a_ref, b_ref, o_ref, *, m1, n):
    x = x_ref[...]
    a = a_ref[0, :]
    b = b_ref[0, :]
    p, q, _ = _pq_sign(x, a, b, m1, n)
    o_ref[...] = p / q


def _pad_rows(x2d, s_block):
    r = x2d.shape[0]
    t = -(-r // s_block)  # ceil div
    pad = t * s_block - r
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
    return x2d, t, r


@functools.partial(jax.jit, static_argnames=("s_block",))
def rational_fwd(x, a, b, s_block: int = DEFAULT_S_BLOCK):
    """Group-wise rational forward via Pallas.

    x: (..., d); a: (n_g, m+1); b: (n_g, n).  ``d % n_g == 0`` required.
    Rows (the flattened leading axes) are padded to a multiple of s_block.
    """
    n_g, m1 = a.shape
    n = b.shape[1]
    d = x.shape[-1]
    d_g = d // n_g
    assert d % n_g == 0, f"d={d} not divisible by n_g={n_g}"

    x2d = x.reshape(-1, d)
    x2d, t, r = _pad_rows(x2d, s_block)

    out = pl.pallas_call(
        functools.partial(_fwd_kernel, m1=m1, n=n),
        grid=(t, n_g),
        in_specs=[
            pl.BlockSpec((s_block, d_g), lambda i, j: (i, j)),
            pl.BlockSpec((1, m1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((s_block, d_g), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x.dtype),
        interpret=INTERPRET,
    )(x2d, a, b)
    return out[:r].reshape(x.shape)


# ---------------------------------------------------------------------------
# FlashKAT backward kernel (paper Algorithm 2).
# ---------------------------------------------------------------------------

def _bwd_flash_kernel(x_ref, do_ref, a_ref, b_ref, dx_ref, da_ref, db_ref, *, m1, n):
    i = pl.program_id(0)
    x = x_ref[...]
    do = do_ref[...]
    a = a_ref[0, :]
    b = b_ref[0, :]

    dx, da_terms, db_terms = _grads(x, do, a, b, m1, n)
    dx_ref[...] = dx

    # Block-local reduction in VMEM — the FlashKAT trick: one accumulation
    # per (S_block x d_g) tile instead of one atomic per element.
    da_local = jnp.stack([jnp.sum(t, dtype=x.dtype) for t in da_terms])[None, :]
    db_local = jnp.stack([jnp.sum(t, dtype=x.dtype) for t in db_terms])[None, :]

    @pl.when(i == 0)
    def _init():
        da_ref[...] = da_local
        db_ref[...] = db_local

    @pl.when(i > 0)
    def _accum():
        da_ref[...] += da_local
        db_ref[...] += db_local


@functools.partial(jax.jit, static_argnames=("s_block",))
def rational_bwd_flash(x, dout, a, b, s_block: int = DEFAULT_S_BLOCK):
    """FlashKAT backward pass (Algorithm 2): 2-D grid, block-local reduction.

    Returns (dx, da, db).
    """
    n_g, m1 = a.shape
    n = b.shape[1]
    d = x.shape[-1]
    d_g = d // n_g
    assert d % n_g == 0

    x2d = x.reshape(-1, d)
    do2d = dout.reshape(-1, d)
    x2d, t, r = _pad_rows(x2d, s_block)
    do2d, _, _ = _pad_rows(do2d, s_block)

    dx, da, db = pl.pallas_call(
        functools.partial(_bwd_flash_kernel, m1=m1, n=n),
        grid=(t, n_g),
        in_specs=[
            pl.BlockSpec((s_block, d_g), lambda i, j: (i, j)),
            pl.BlockSpec((s_block, d_g), lambda i, j: (i, j)),
            pl.BlockSpec((1, m1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((s_block, d_g), lambda i, j: (i, j)),
            pl.BlockSpec((1, m1), lambda i, j: (j, 0)),   # revisited over i
            pl.BlockSpec((1, n), lambda i, j: (j, 0)),    # revisited over i
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x2d.shape, x.dtype),
            jax.ShapeDtypeStruct(a.shape, x.dtype),
            jax.ShapeDtypeStruct(b.shape, x.dtype),
        ],
        interpret=INTERPRET,
    )(x2d, do2d, a, b)
    return dx[:r].reshape(x.shape), da, db


# ---------------------------------------------------------------------------
# KAT baseline backward kernel (paper Algorithm 1 access structure).
# ---------------------------------------------------------------------------

def _bwd_kat_kernel(x_ref, do_ref, a_ref, b_ref, dx_ref, da_ref, db_ref, *, m1, n, n_g):
    i = pl.program_id(0)
    x = x_ref[...]           # (s_rows, d)
    do = do_ref[...]
    a = a_ref[...]           # (n_g, m1) — the FULL coefficient tensor, re-read
    b = b_ref[...]           # every grid step, as Algorithm 1 re-reads per thread

    s_rows, d = x.shape
    d_g = d // n_g
    xg = x.reshape(s_rows, n_g, d_g)
    dog = do.reshape(s_rows, n_g, d_g)

    # Broadcast per-group coefficients over the tile: (1, n_g, 1) per power.
    def coeff(c, k):
        return c[:, k][None, :, None]

    p = jnp.broadcast_to(coeff(a, m1 - 1), xg.shape)
    for k in range(m1 - 2, -1, -1):
        p = p * xg + coeff(a, k)
    Ax = jnp.broadcast_to(coeff(b, n - 1), xg.shape)
    for k in range(n - 2, -1, -1):
        Ax = Ax * xg + coeff(b, k)
    A = xg * Ax
    q = 1.0 + jnp.abs(A)
    sgn = jnp.sign(A)
    inv_q = 1.0 / q
    p_over_q2 = p * inv_q * inv_q

    dp = jnp.broadcast_to(coeff(a, m1 - 1) * (m1 - 1), xg.shape)
    for k in range(m1 - 2, 0, -1):
        dp = dp * xg + coeff(a, k) * k
    dadx = jnp.broadcast_to(coeff(b, n - 1) * n, xg.shape)
    for k in range(n - 2, -1, -1):
        dadx = dadx * xg + coeff(b, k) * (k + 1)

    dx = dog * (dp * inv_q - sgn * dadx * p_over_q2)
    dx_ref[...] = dx.reshape(s_rows, d)

    do_q = dog * inv_q
    neg_do_spq2 = -dog * sgn * p_over_q2
    da_terms = []
    pw = jnp.ones_like(xg)
    for k in range(m1):
        da_terms.append(jnp.sum(do_q * pw, axis=(0, 2)))
        pw = pw * xg
    db_terms = []
    pw = xg
    for j in range(n):
        db_terms.append(jnp.sum(neg_do_spq2 * pw, axis=(0, 2)))
        pw = pw * xg
    da_local = jnp.stack(da_terms, axis=-1)   # (n_g, m1)
    db_local = jnp.stack(db_terms, axis=-1)   # (n_g, n)

    # Sequential accumulation into the full dA/dB every step — the long
    # contention-shaped chain of Algorithm 1's atomic adds.
    @pl.when(i == 0)
    def _init():
        da_ref[...] = da_local
        db_ref[...] = db_local

    @pl.when(i > 0)
    def _accum():
        da_ref[...] += da_local
        db_ref[...] += db_local


@functools.partial(jax.jit, static_argnames=("s_rows",))
def rational_bwd_kat(x, dout, a, b, s_rows: int = 1):
    """Baseline backward pass with Algorithm 1's access structure.

    1-D grid over row-blocks; the full coefficient tensors are re-read and
    the full dA/dB outputs re-accumulated at every grid step.  ``s_rows=1``
    gives one grid step per (token) row — the longest accumulation chain the
    sequential-grid adaptation can express.  Returns (dx, da, db).
    """
    n_g, m1 = a.shape
    n = b.shape[1]
    d = x.shape[-1]
    assert d % n_g == 0

    x2d = x.reshape(-1, d)
    do2d = dout.reshape(-1, d)
    x2d, t, r = _pad_rows(x2d, s_rows)
    do2d, _, _ = _pad_rows(do2d, s_rows)

    dx, da, db = pl.pallas_call(
        functools.partial(_bwd_kat_kernel, m1=m1, n=n, n_g=n_g),
        grid=(t,),
        in_specs=[
            pl.BlockSpec((s_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((s_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((n_g, m1), lambda i: (0, 0)),
            pl.BlockSpec((n_g, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((s_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((n_g, m1), lambda i: (0, 0)),   # revisited every step
            pl.BlockSpec((n_g, n), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x2d.shape, x.dtype),
            jax.ShapeDtypeStruct(a.shape, x.dtype),
            jax.ShapeDtypeStruct(b.shape, x.dtype),
        ],
        interpret=INTERPRET,
    )(x2d, do2d, a, b)
    return dx[:r].reshape(x.shape), da, db


# ---------------------------------------------------------------------------
# Analytic global-memory access model (paper Section 4).
# ---------------------------------------------------------------------------

def kat_global_accesses(bnd: int, m1: int, n: int) -> int:
    """Algorithm 1 access count: 3*(m+n+2) * B*N*d.

    ``bnd`` is B*N*d; ``m1`` is m+1.  Derivation in paper §4: 3*B*N*d for
    X/dO/dX plus 3*(m+n+1)*B*N*d for per-element coefficient reads and
    atomic read-modify-writes.
    """
    m_plus_n_plus_1 = (m1 - 1) + n + 1
    return 3 * (m_plus_n_plus_1 + 1) * bnd


def flash_global_accesses(bnd: int, m1: int, n: int, s_block: int, d_g: int) -> int:
    """Algorithm 2 access count: 3*((m+n+1)/(S_block*d_g) + 1) * B*N*d."""
    m_plus_n_plus_1 = (m1 - 1) + n + 1
    per_block = 3 * (s_block * d_g + m_plus_n_plus_1)
    blocks = bnd // (s_block * d_g)
    return blocks * per_block


# ---------------------------------------------------------------------------
# TPU performance model (interpret=True gives no TPU timing; the kernel's
# real-hardware efficiency is governed by the BlockSpec memory schedule).
# ---------------------------------------------------------------------------

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM on current TPU generations


def flash_bwd_vmem_bytes(s_block: int, d_g: int, m1: int, n: int, dtype_bytes: int = 4) -> int:
    """Resident VMEM per grid step of the FlashKAT backward kernel:
    X tile + dO tile + dX tile + coefficient rows + dA/dB accumulators.
    """
    tiles = 3 * s_block * d_g * dtype_bytes
    coeffs = 2 * (m1 + n) * dtype_bytes
    return tiles + coeffs


def flash_bwd_hbm_bytes(rows: int, d: int, m1: int, n: int, n_g: int,
                        s_block: int, dtype_bytes: int = 4) -> int:
    """Total HBM traffic of the FlashKAT backward: streams X, dO, dX once
    plus one dA/dB revisit per (T x n_g) block — the paper's §4 count in
    bytes."""
    d_g = d // n_g
    t = -(-rows // s_block)
    stream = 3 * rows * d * dtype_bytes
    acc = t * n_g * 2 * (m1 + n) * dtype_bytes
    return stream + acc


def flash_bwd_arithmetic_intensity(rows: int, d: int, m1: int, n: int, n_g: int,
                                   s_block: int) -> float:
    """FLOPs per HBM byte — the roofline coordinate.  The backward does
    ~(6m + 6n + 12) FLOPs/element; the kernel is bandwidth-bound on every
    current TPU (intensity << ridge), so minimizing HBM bytes (what
    Algorithm 2 does) IS the optimization."""
    flops = (6 * (m1 - 1) + 6 * n + 12) * rows * d
    return flops / flash_bwd_hbm_bytes(rows, d, m1, n, n_g, s_block)


def pick_s_block(rows: int, d: int, n_g: int, m1: int = 6, n: int = 4,
                 budget: int = VMEM_BYTES // 4) -> int:
    """Largest power-of-two S_block whose working set fits the VMEM budget
    (quarter of VMEM leaves room for double-buffering + compiler temps).
    Larger blocks amortize grid/dispatch overhead and shrink the dA/dB
    revisit traffic; the stream term is S_block-invariant."""
    d_g = d // n_g
    s = 8
    while s * 2 <= rows and flash_bwd_vmem_bytes(s * 2, d_g, m1, n) <= budget:
        s *= 2
    return s
