"""Pure-jnp oracle for the group-wise rational function (safe PAU).

This is the correctness anchor for the Pallas kernels in ``rational.py``:
every kernel output is compared against these functions by pytest.

The group-wise rational function (paper Eq. 6) is

    F(x) = P(x) / Q(x)
    P(x) = a_0 + a_1 x + ... + a_m x^m
    Q(x) = 1 + |b_1 x + ... + b_n x^n|

with one coefficient set per *group* of ``d_g = d / n_g`` consecutive
channels (paper Eq. 5).  The backward pass implements paper Eqs. 7-9:

    dF/da_i = x^i / Q(x)
    dF/db_j = -x^j * sign(A(x)) * P(x) / Q(x)^2          (A = b_1 x + ...)
    dF/dx   = P'(x)/Q(x) - sign(A(x)) A'(x) P(x)/Q(x)^2

and the coefficient gradients are accumulated over batch, sequence and the
group dimension (paper Eqs. 10-11).
"""

from __future__ import annotations

import jax.numpy as jnp


def group_view(x: jnp.ndarray, n_groups: int) -> jnp.ndarray:
    """Reshape (..., d) -> (..., n_groups, d_g)."""
    d = x.shape[-1]
    assert d % n_groups == 0, f"d={d} not divisible by n_groups={n_groups}"
    return x.reshape(*x.shape[:-1], n_groups, d // n_groups)


def polyval_ascending(coeffs: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Horner evaluation of ``sum_k coeffs[..., k] * x**k``.

    The polynomial axis of ``coeffs`` is last; its leading axes broadcast
    against ``x`` (e.g. coeffs (n_g, 1, K) against x (..., n_g, d_g)).
    """
    k = coeffs.shape[-1]
    out_shape = jnp.broadcast_shapes(coeffs[..., 0].shape, x.shape)
    acc = jnp.broadcast_to(coeffs[..., k - 1], out_shape).astype(x.dtype)
    for i in range(k - 2, -1, -1):
        acc = acc * x + coeffs[..., i]
    return acc


def rational_pq(xg: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray):
    """Return (P, Q, A, sign(A)) for grouped input.

    xg: (..., n_g, d_g); a: (n_g, m+1); b: (n_g, n).
    """
    p = polyval_ascending(a[:, None, :], xg)
    # A(x) = x * (b_1 + b_2 x + ... + b_n x^{n-1})
    A = xg * polyval_ascending(b[:, None, :], xg)
    q = 1.0 + jnp.abs(A)
    return p, q, A, jnp.sign(A)


def rational_fwd_ref(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Forward group-wise rational function.

    x: (..., d); a: (n_g, m+1); b: (n_g, n).  Returns F(x) with x's shape.
    """
    xg = group_view(x, a.shape[0])
    p, q, _, _ = rational_pq(xg, a, b)
    return (p / q).reshape(x.shape)


def rational_bwd_ref(x: jnp.ndarray, dout: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray):
    """Backward pass per paper Eqs. 7-11.

    Returns ``(dx, da, db)`` with dx of x's shape, da of a's shape, db of
    b's shape.  Coefficient gradients are reduced with a single ``jnp.sum``
    (deterministic tree-like reduction — the numerically 'good' ordering).
    """
    n_g, m_plus_1 = a.shape
    n = b.shape[1]
    xg = group_view(x, n_g)          # (..., n_g, d_g)
    dog = group_view(dout, n_g)

    p, q, A, sgn = rational_pq(xg, a, b)

    # P'(x) = a_1 + 2 a_2 x + ... + m a_m x^{m-1}
    dp_coeff = a[:, 1:] * jnp.arange(1, m_plus_1, dtype=x.dtype)  # (n_g, m)
    dp = polyval_ascending(dp_coeff[:, None, :], xg)
    # A'(x) = b_1 + 2 b_2 x + ... + n b_n x^{n-1}
    dA_coeff = b * jnp.arange(1, n + 1, dtype=x.dtype)            # (n_g, n)
    dAdx = polyval_ascending(dA_coeff[:, None, :], xg)

    inv_q = 1.0 / q
    p_over_q2 = p * inv_q * inv_q

    dx = dog * (dp * inv_q - sgn * dAdx * p_over_q2)

    # Powers x^i for i = 0..m and x^j for j = 1..n: (..., n_g, d_g, K).
    pows_a = jnp.stack([xg**i for i in range(m_plus_1)], axis=-1)
    pows_b = jnp.stack([xg**j for j in range(1, n + 1)], axis=-1)

    reduce_axes = tuple(range(xg.ndim - 2)) + (xg.ndim - 1,)  # batch dims + d_g
    da = jnp.sum(dog[..., None] * pows_a * inv_q[..., None], axis=reduce_axes)
    db = jnp.sum(
        dog[..., None] * (-pows_b) * (sgn * p_over_q2)[..., None], axis=reduce_axes
    )
    return dx.reshape(x.shape), da, db


def swish_init_coeffs(dtype=jnp.float32):
    """PAU coefficients approximating swish/SiLU.

    KAT's variance-preserving init (Yang & Wang 2024) initializes the second
    GR-KAN layer's rational to swish; these are the published safe-PAU fit
    coefficients for m=5, n=4 — the paper's 6/4 configuration.
    """
    a = jnp.array(
        [-0.0052296527, 0.5027744533, 0.4403392560, 0.5826427290,
         0.2196305065, 0.0256087044],
        dtype=dtype,
    )
    b = jnp.array(
        [0.3131766296, 1.0135363041, 0.0271426279, 0.0494586222], dtype=dtype
    )
    return a, b


def identity_init_coeffs(dtype=jnp.float32):
    """PAU coefficients realizing F(x) = x exactly (the paper initializes
    the first GR-KAN layer's rational to the identity)."""
    a = jnp.array([0.0, 1.0, 0.0, 0.0, 0.0, 0.0], dtype=dtype)
    b = jnp.array([0.0, 0.0, 0.0, 0.0], dtype=dtype)
    return a, b
