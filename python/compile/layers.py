"""L2 building blocks: GR-KAN, MLP, LayerNorm, patch embedding.

The GR-KAN layer (paper Eq. 5) wraps the L1 Pallas kernels with a
``jax.custom_vjp`` so the *whole model's* backward pass routes through
either the FlashKAT kernel (Algorithm 2) or the KAT baseline kernel
(Algorithm 1 structure), selected at model-build time.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from .kernels import rational as rk
from .kernels import ref as kref

Backward = Literal["flash", "kat"]


# ---------------------------------------------------------------------------
# Rational op with custom VJP (dispatches to the Pallas kernels).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def rational_op(x, a, b, backward: Backward = "flash", s_block: int = rk.DEFAULT_S_BLOCK):
    """Group-wise rational activation F(x) with kernel-backed fwd/bwd."""
    return rk.rational_fwd(x, a, b, s_block=s_block)


def _rational_fwd_rule(x, a, b, backward, s_block):
    return rk.rational_fwd(x, a, b, s_block=s_block), (x, a, b)


def _rational_bwd_rule(backward, s_block, res, dout):
    x, a, b = res
    if backward == "flash":
        dx, da, db = rk.rational_bwd_flash(x, dout, a, b, s_block=s_block)
    else:
        # Algorithm-1-structured baseline.  s_rows trades interpret-mode
        # speed against accumulation-chain fidelity; 16 keeps lowered HLO
        # loop counts tractable inside full-model train steps.
        dx, da, db = rk.rational_bwd_kat(x, dout, a, b, s_rows=16)
    return dx, da, db


rational_op.defvjp(_rational_fwd_rule, _rational_bwd_rule)


# ---------------------------------------------------------------------------
# Initializers.
# ---------------------------------------------------------------------------

def rational_gain(a: jnp.ndarray, b: jnp.ndarray, nsamples: int = 8192) -> float:
    """KAT's variance-preserving gain alpha = E[F(x)^2] / Var[x], x ~ N(0,1).

    Computed numerically from the coefficient init (paper §2, 'third').
    """
    x = jax.random.normal(jax.random.PRNGKey(0), (nsamples,), jnp.float32)
    n_g = a.shape[0] if a.ndim == 2 else 1
    a2 = a if a.ndim == 2 else a[None]
    b2 = b if b.ndim == 2 else b[None]
    f = kref.rational_fwd_ref(
        jnp.tile(x[:, None], (1, n_g)), a2, b2
    )
    return float(jnp.mean(f * f))


def variance_preserving_normal(key, shape, gain: float, d_in: int, dtype=jnp.float32):
    """W ~ N(0, alpha/d_in) per KAT (Yang & Wang 2024)."""
    std = (gain / d_in) ** 0.5
    return jax.random.normal(key, shape, dtype) * std


def init_rational_coeffs(kind: str, n_groups: int, dtype=jnp.float32):
    """Per-group coefficient tensors initialized to a named activation."""
    if kind == "identity":
        a, b = kref.identity_init_coeffs(dtype)
    elif kind == "swish":
        a, b = kref.swish_init_coeffs(dtype)
    else:
        raise ValueError(f"unknown rational init {kind!r}")
    return jnp.tile(a[None], (n_groups, 1)), jnp.tile(b[None], (n_groups, 1))


# ---------------------------------------------------------------------------
# GR-KAN feed-forward block (the KAT MLP replacement).
# ---------------------------------------------------------------------------

import functools as _ft


@_ft.lru_cache(maxsize=None)
def _gain_for(kind: str) -> float:
    """Concrete (non-traced) gain per named coefficient init, cached so
    ``init_grkan_ffn`` stays jit-traceable (no float() on tracers)."""
    a, b = init_rational_coeffs(kind, 1)
    import numpy as _np

    x = _np.asarray(
        jax.random.normal(jax.random.PRNGKey(0), (8192,), jnp.float32)
    )
    f = _np.asarray(kref.rational_fwd_ref(jnp.asarray(x)[:, None], a, b))
    return float(_np.mean(f * f))


def init_grkan_ffn(key, d: int, d_hidden: int, n_groups: int, dtype=jnp.float32):
    """Two stacked GR-KAN layers: rational(identity) -> fc1 -> rational(swish) -> fc2.

    Mirrors the paper: 'The first layer of GR-KAN has its group-wise rational
    function initialized to the identity function, and the second layer is
    initialized to a Swish function.'
    """
    k1, k2 = jax.random.split(key)
    a1, b1 = init_rational_coeffs("identity", n_groups, dtype)
    a2, b2 = init_rational_coeffs("swish", n_groups, dtype)
    g1 = _gain_for("identity")
    g2 = _gain_for("swish")
    return {
        "a1": a1,
        "b1": b1,
        "fc1_w": variance_preserving_normal(k1, (d, d_hidden), g1, d, dtype),
        "fc1_b": jnp.zeros((d_hidden,), dtype),
        "a2": a2,
        "b2": b2,
        "fc2_w": variance_preserving_normal(k2, (d_hidden, d), g2, d_hidden, dtype),
        "fc2_b": jnp.zeros((d,), dtype),
    }


def grkan_ffn(p, x, backward: Backward = "flash", s_block: int = rk.DEFAULT_S_BLOCK):
    """GR-KAN(x) = W2 F2(W1 F1(x) + b1) + b2 (paper Eq. 5, stacked twice)."""
    h = rational_op(x, p["a1"], p["b1"], backward, s_block)
    h = h @ p["fc1_w"] + p["fc1_b"]
    h = rational_op(h, p["a2"], p["b2"], backward, s_block)
    return h @ p["fc2_w"] + p["fc2_b"]


# ---------------------------------------------------------------------------
# Standard MLP feed-forward (the ViT baseline).
# ---------------------------------------------------------------------------

def init_mlp_ffn(key, d: int, d_hidden: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    # GELU baseline, trunc-normal-ish init as in ViT/DeiT.
    return {
        "fc1_w": jax.random.normal(k1, (d, d_hidden), dtype) * (2.0 / (d + d_hidden)) ** 0.5,
        "fc1_b": jnp.zeros((d_hidden,), dtype),
        "fc2_w": jax.random.normal(k2, (d_hidden, d), dtype) * (2.0 / (d + d_hidden)) ** 0.5,
        "fc2_b": jnp.zeros((d,), dtype),
    }


def mlp_ffn(p, x):
    h = jax.nn.gelu(x @ p["fc1_w"] + p["fc1_b"])
    return h @ p["fc2_w"] + p["fc2_b"]


# ---------------------------------------------------------------------------
# LayerNorm.
# ---------------------------------------------------------------------------

def init_layernorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]


# ---------------------------------------------------------------------------
# Patch embedding.
# ---------------------------------------------------------------------------

def init_patch_embed(key, patch: int, in_ch: int, d: int, dtype=jnp.float32):
    fan_in = patch * patch * in_ch
    return {
        "w": jax.random.normal(key, (fan_in, d), dtype) * (1.0 / fan_in) ** 0.5,
        "b": jnp.zeros((d,), dtype),
    }


def patch_embed(p, images, patch: int):
    """images: (B, H, W, C) -> tokens (B, H/p * W/p, d)."""
    B, H, W, C = images.shape
    gh, gw = H // patch, W // patch
    x = images.reshape(B, gh, patch, gw, patch, C)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(B, gh * gw, patch * patch * C)
    return x @ p["w"] + p["b"]


# ---------------------------------------------------------------------------
# Stochastic depth (drop-path) and dropout.
# ---------------------------------------------------------------------------

def drop_path(key, x, rate: float, train: bool):
    """Per-sample residual-branch drop (Huang et al. 2016)."""
    if not train or rate <= 0.0:
        return x
    keep = 1.0 - rate
    mask = jax.random.bernoulli(key, keep, (x.shape[0],) + (1,) * (x.ndim - 1))
    return x * mask.astype(x.dtype) / keep
