//! Host-side tensor values and Literal marshalling.

use anyhow::{bail, Context, Result};

use super::manifest::{DType, TensorSpec};

/// A host tensor paired with its dtype — the coordinator's currency for
/// feeding and reading XLA executables.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    U32 { shape: Vec<usize>, data: Vec<u32> },
}

impl HostTensor {
    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32 { shape: vec![], data: vec![v] }
    }

    pub fn key(bits: [u32; 2]) -> Self {
        HostTensor::U32 { shape: vec![2], data: bits.to_vec() }
    }

    pub fn zeros(spec: &TensorSpec) -> Result<Self> {
        let n = spec.elements();
        Ok(match spec.dtype {
            DType::F32 => HostTensor::F32 { shape: spec.shape.clone(), data: vec![0.0; n] },
            DType::I32 => HostTensor::I32 { shape: spec.shape.clone(), data: vec![0; n] },
            DType::U32 => HostTensor::U32 { shape: spec.shape.clone(), data: vec![0; n] },
            other => bail!("zeros: unsupported dtype {other:?}"),
        })
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. }
            | HostTensor::I32 { shape, .. }
            | HostTensor::U32 { shape, .. } => shape,
        }
    }

    pub fn elements(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
            HostTensor::U32 { data, .. } => data.len(),
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32 { .. } => DType::F32,
            HostTensor::I32 { .. } => DType::I32,
            HostTensor::U32 { .. } => DType::U32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            other => bail!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            other => bail!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    /// Build an xla Literal (reshaped to the tensor's shape).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data),
            HostTensor::U32 { data, .. } => xla::Literal::vec1(data),
        };
        lit.reshape(&dims).context("reshape literal")
    }

    /// Read a Literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Self> {
        Ok(match spec.dtype {
            DType::F32 => {
                HostTensor::F32 { shape: spec.shape.clone(), data: lit.to_vec::<f32>()? }
            }
            DType::I32 => {
                HostTensor::I32 { shape: spec.shape.clone(), data: lit.to_vec::<i32>()? }
            }
            DType::U32 => {
                HostTensor::U32 { shape: spec.shape.clone(), data: lit.to_vec::<u32>()? }
            }
            other => bail!("from_literal: unsupported dtype {other:?}"),
        })
    }

    /// Validate against a manifest slot.
    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        if self.shape() != spec.shape.as_slice() {
            bail!("slot {}: shape {:?} != manifest {:?}", spec.name, self.shape(), spec.shape);
        }
        if self.dtype() != spec.dtype {
            bail!("slot {}: dtype {:?} != manifest {:?}", spec.name, self.dtype(), spec.dtype);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(shape: &[usize], dtype: DType) -> TensorSpec {
        TensorSpec { name: "t".into(), shape: shape.to_vec(), dtype }
    }

    #[test]
    fn zeros_and_shapes() {
        let t = HostTensor::zeros(&spec(&[2, 3], DType::F32)).unwrap();
        assert_eq!(t.elements(), 6);
        assert_eq!(t.shape(), &[2, 3]);
        t.check(&spec(&[2, 3], DType::F32)).unwrap();
        assert!(t.check(&spec(&[3, 2], DType::F32)).is_err());
        assert!(t.check(&spec(&[2, 3], DType::I32)).is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::F32 { shape: vec![2, 2], data: vec![1.0, 2.0, 3.0, 4.0] };
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, &spec(&[2, 2], DType::F32)).unwrap();
        assert_eq!(back.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_roundtrip_scalar_and_key() {
        let s = HostTensor::scalar_i32(7);
        let lit = s.to_literal().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7]);
        let k = HostTensor::key([1, 2]);
        let lit = k.to_literal().unwrap();
        assert_eq!(lit.to_vec::<u32>().unwrap(), vec![1, 2]);
    }
}
