//! Artifact manifests: the flat input/output signature emitted by
//! `python/compile/aot.py` next to each `<name>.hlo.txt`.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    F64,
    I32,
    I64,
    U32,
    Bf16,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "f64" => DType::F64,
            "i32" => DType::I32,
            "i64" => DType::I64,
            "u32" => DType::U32,
            "bf16" => DType::Bf16,
            other => bail!("unsupported dtype {other:?}"),
        })
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            DType::F32 | DType::I32 | DType::U32 => 4,
            DType::F64 | DType::I64 => 8,
            DType::Bf16 => 2,
        }
    }
}

/// One flat input or output slot.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    /// Pytree key path, e.g. `0/blocks/3/ffn/fc1_w` (manifest order == HLO
    /// parameter order).
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Extra metadata (batch, model, img_size, ...) for coordinators.
    pub raw: Json,
}

fn parse_specs(v: &Json, which: &str) -> Result<Vec<TensorSpec>> {
    let arr = v
        .get(which)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("manifest missing {which:?} array"))?;
    arr.iter()
        .map(|e| {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("spec missing name"))?
                .to_string();
            let shape = e
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("spec {name} missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim in {name}")))
                .collect::<Result<Vec<_>>>()?;
            let dtype = DType::parse(
                e.get("dtype").and_then(Json::as_str).ok_or_else(|| anyhow!("missing dtype"))?,
            )?;
            Ok(TensorSpec { name, shape, dtype })
        })
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest missing name"))?
            .to_string();
        Ok(Self {
            name,
            inputs: parse_specs(&v, "inputs")?,
            outputs: parse_specs(&v, "outputs")?,
            raw: v,
        })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text)
    }

    /// Index of the input slot whose key path is exactly `name`.
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|s| s.name == name)
    }

    /// Metadata accessors.
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.raw.get(key).and_then(Json::as_usize)
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.raw.get(key).and_then(Json::as_str)
    }

    pub fn total_input_bytes(&self) -> usize {
        self.inputs.iter().map(|s| s.elements() * s.dtype.size_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "toy_train_step",
      "inputs": [
        {"name": "0/w", "shape": [4, 8], "dtype": "f32"},
        {"name": "3", "shape": [], "dtype": "i32"},
        {"name": "5", "shape": [2], "dtype": "u32"}
      ],
      "outputs": [
        {"name": "loss", "shape": [], "dtype": "f32"}
      ],
      "batch": 32,
      "model": "kat-micro"
    }"#;

    #[test]
    fn parse_roundtrip() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "toy_train_step");
        assert_eq!(m.inputs.len(), 3);
        assert_eq!(m.inputs[0].shape, vec![4, 8]);
        assert_eq!(m.inputs[0].dtype, DType::F32);
        assert_eq!(m.inputs[0].elements(), 32);
        assert_eq!(m.inputs[1].shape, Vec::<usize>::new());
        assert_eq!(m.inputs[2].dtype, DType::U32);
        assert_eq!(m.outputs.len(), 1);
        assert_eq!(m.meta_usize("batch"), Some(32));
        assert_eq!(m.meta_str("model"), Some("kat-micro"));
        assert_eq!(m.input_index("3"), Some(1));
        assert_eq!(m.input_index("nope"), None);
        assert_eq!(m.total_input_bytes(), 32 * 4 + 4 + 8);
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("\"f32\"", "\"f16\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"name":"x"}"#).is_err());
    }
}
