//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! client.  Python never runs on this path — the Rust binary is
//! self-contained once `make artifacts` has produced `artifacts/`.

pub mod manifest;
pub mod module;
pub mod values;

pub use manifest::{DType, Manifest, TensorSpec};
pub use module::{LoadedModule, ModuleExec, RowsAdapter, Runtime};
pub use values::HostTensor;
