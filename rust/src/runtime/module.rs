//! Loaded executables: HLO text -> PJRT compile -> execute.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::Manifest;
use super::values::HostTensor;

/// Shared PJRT CPU client + artifact directory.
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
    artifacts_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU runtime rooted at `artifacts_dir` (usually `artifacts/`).
    pub fn cpu(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client: Arc::new(client), artifacts_dir: artifacts_dir.into() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load `<name>.hlo.txt` + `<name>.manifest.json` and compile.
    pub fn load(&self, name: &str) -> Result<LoadedModule> {
        let hlo = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        let man = self.artifacts_dir.join(format!("{name}.manifest.json"));
        if !hlo.exists() {
            bail!(
                "artifact {} not found — run `make artifacts` first",
                hlo.display()
            );
        }
        let manifest = Manifest::load(&man)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            hlo.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {}", hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        Ok(LoadedModule { name: name.to_string(), exe, manifest, compile_secs: t0.elapsed().as_secs_f64() })
    }
}

/// A compiled module with its manifest-described signature.
pub struct LoadedModule {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
    pub compile_secs: f64,
}

impl LoadedModule {
    /// Execute with raw literals in manifest order; returns the flattened
    /// output literals (aot.py lowers with `return_tuple=True`).
    pub fn execute_literals(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.manifest.inputs.len() {
            bail!(
                "{}: {} inputs provided, manifest wants {}",
                self.name,
                inputs.len(),
                self.manifest.inputs.len()
            );
        }
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("{}: empty execution result", self.name))?
            .to_literal_sync()?;
        let outs = lit.to_tuple()?;
        if outs.len() != self.manifest.outputs.len() {
            bail!(
                "{}: {} outputs returned, manifest wants {}",
                self.name,
                outs.len(),
                self.manifest.outputs.len()
            );
        }
        Ok(outs)
    }

    /// Execute with host tensors (validated against the manifest).
    pub fn execute(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        for (t, spec) in inputs.iter().zip(&self.manifest.inputs) {
            t.check(spec).with_context(|| format!("input to {}", self.name))?;
        }
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let outs = self.execute_literals(&lits)?;
        outs.iter()
            .zip(&self.manifest.outputs)
            .map(|(l, spec)| HostTensor::from_literal(l, spec))
            .collect()
    }

    pub fn input_count(&self) -> usize {
        self.manifest.inputs.len()
    }

    pub fn output_count(&self) -> usize {
        self.manifest.outputs.len()
    }
}
