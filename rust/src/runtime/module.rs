//! Loaded executables: HLO text -> PJRT compile -> execute.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::Manifest;
use super::values::HostTensor;

/// Shared PJRT CPU client + artifact directory.
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
    artifacts_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU runtime rooted at `artifacts_dir` (usually `artifacts/`).
    pub fn cpu(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client: Arc::new(client), artifacts_dir: artifacts_dir.into() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load `<name>.hlo.txt` + `<name>.manifest.json` and compile.
    pub fn load(&self, name: &str) -> Result<LoadedModule> {
        let hlo = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        let man = self.artifacts_dir.join(format!("{name}.manifest.json"));
        if !hlo.exists() {
            bail!(
                "artifact {} not found — run `make artifacts` first",
                hlo.display()
            );
        }
        let manifest = Manifest::load(&man)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            hlo.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {}", hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        Ok(LoadedModule { name: name.to_string(), exe, manifest, compile_secs: t0.elapsed().as_secs_f64() })
    }
}

/// A compiled module with its manifest-described signature.
pub struct LoadedModule {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
    pub compile_secs: f64,
}

impl LoadedModule {
    /// Execute with raw literals in manifest order; returns the flattened
    /// output literals (aot.py lowers with `return_tuple=True`).
    pub fn execute_literals(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.manifest.inputs.len() {
            bail!(
                "{}: {} inputs provided, manifest wants {}",
                self.name,
                inputs.len(),
                self.manifest.inputs.len()
            );
        }
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("{}: empty execution result", self.name))?
            .to_literal_sync()?;
        let outs = lit.to_tuple()?;
        if outs.len() != self.manifest.outputs.len() {
            bail!(
                "{}: {} outputs returned, manifest wants {}",
                self.name,
                outs.len(),
                self.manifest.outputs.len()
            );
        }
        Ok(outs)
    }

    /// Execute with host tensors (validated against the manifest).
    pub fn execute(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.execute_refs(&refs)
    }

    /// [`Self::execute`] over borrowed tensors, so callers that combine a
    /// large fixed prefix (parameter leaves) with a per-call data tensor
    /// don't have to clone the prefix on every call.
    pub fn execute_refs(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        for (t, spec) in inputs.iter().zip(&self.manifest.inputs) {
            t.check(spec).with_context(|| format!("input to {}", self.name))?;
        }
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let outs = self.execute_literals(&lits)?;
        outs.iter()
            .zip(&self.manifest.outputs)
            .map(|(l, spec)| HostTensor::from_literal(l, spec))
            .collect()
    }

    pub fn input_count(&self) -> usize {
        self.manifest.inputs.len()
    }

    pub fn output_count(&self) -> usize {
        self.manifest.outputs.len()
    }
}

/// Anything that can execute one fixed-signature module call over host
/// tensors.  [`LoadedModule`] is the production implementation; tests and
/// examples provide pure-Rust modules so the layers above (the
/// batched-rows adapter, the serving pipeline executor) are exercised
/// without a PJRT runtime or artifacts on disk.
pub trait ModuleExec: Send {
    fn execute_batch(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>>;
}

impl ModuleExec for LoadedModule {
    fn execute_batch(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        self.execute_refs(inputs)
    }
}

/// How a [`RowsAdapter`] invokes its module (see the adapter docs).
enum RowsBackend {
    /// Generic host module: the fixed tensors are re-presented on every
    /// call (cheap — they are borrowed, not cloned).
    Host { module: Box<dyn ModuleExec>, fixed: Vec<HostTensor> },
    /// Loaded executable with the fixed prefix pre-serialized to
    /// literals once at construction; per chunk only the data slot is
    /// converted (`lits.last()` is the replace-in-place data literal).
    /// `Arc` so several adapters (autotune grid points, baselines) can
    /// share one compilation.
    Bound { module: Arc<LoadedModule>, lits: Vec<xla::Literal> },
}

/// Batched-rows adapter: presents a module whose data input has a fixed
/// leading batch dimension as a function over an arbitrary number of
/// flattened rows.
///
/// The serving stack coalesces requests along the row axis; an AOT
/// `<tag>_eval` module is compiled for one specific batch `B`.  This
/// adapter bridges the two: it slices `rows` flattened rows into chunks
/// of `B`, zero-pads the final partial chunk, prepends the fixed inputs
/// (parameter leaves), executes, and concatenates the first `take` rows
/// of each chunk's leading output.  The contract that makes this safe is
/// the same one the whole serve subsystem rests on: the module must be
/// **row-independent** (each output row a function of the matching input
/// row only), which holds for per-image eval models — so chunking and
/// padding cannot change any served row, bit for bit.
pub struct RowsAdapter {
    backend: RowsBackend,
    /// Data-slot shape: `[batch, per-row dims...]`.
    in_shape: Vec<usize>,
    out_shape: Vec<usize>,
    batch: usize,
    d_in: usize,
    d_out: usize,
    /// Reusable chunk buffer — the serving executor thread calls
    /// `execute_rows` once per coalesced batch, and the steady state
    /// should not allocate.
    scratch: Vec<f32>,
}

impl RowsAdapter {
    /// Wrap any module given explicit data-slot shapes.  `in_shape` and
    /// `out_shape` are `[batch, ...]` with matching batch dims.
    pub fn from_parts(
        module: Box<dyn ModuleExec>,
        fixed: Vec<HostTensor>,
        in_shape: Vec<usize>,
        out_shape: Vec<usize>,
    ) -> Result<Self> {
        Self::with_backend(RowsBackend::Host { module, fixed }, in_shape, out_shape)
    }

    fn with_backend(
        backend: RowsBackend,
        in_shape: Vec<usize>,
        out_shape: Vec<usize>,
    ) -> Result<Self> {
        if in_shape.is_empty() || out_shape.is_empty() {
            bail!("rows adapter needs batched (rank >= 1) input and output shapes");
        }
        if in_shape[0] != out_shape[0] {
            bail!(
                "rows adapter: input batch {} != output batch {}",
                in_shape[0],
                out_shape[0]
            );
        }
        let batch = in_shape[0];
        let d_in: usize = in_shape[1..].iter().product();
        let d_out: usize = out_shape[1..].iter().product();
        if batch == 0 || d_in == 0 || d_out == 0 {
            bail!("rows adapter: degenerate shapes in={in_shape:?} out={out_shape:?}");
        }
        Ok(Self { backend, in_shape, out_shape, batch, d_in, d_out, scratch: Vec::new() })
    }

    /// Wrap a loaded `<tag>_eval`-style module: every manifest input but
    /// the last is a fixed tensor supplied up front (parameter leaves, in
    /// manifest order), the last input is the per-row data slot, and
    /// output 0 is the per-row result.  The fixed tensors are validated
    /// and serialized to literals here, once — serving then pays only
    /// the data-slot conversion per chunk, not a full parameter copy.
    pub fn for_eval(module: LoadedModule, fixed: Vec<HostTensor>) -> Result<Self> {
        Self::for_eval_shared(Arc::new(module), fixed)
    }

    /// [`Self::for_eval`] over a shared compilation: callers building
    /// several adapters for the same module (an autotune sweep, a
    /// max-batch-1 baseline) compile once and clone the `Arc`.
    pub fn for_eval_shared(module: Arc<LoadedModule>, fixed: Vec<HostTensor>) -> Result<Self> {
        let n_in = module.manifest.inputs.len();
        if n_in == 0 {
            bail!("{}: module has no inputs, nothing to feed rows into", module.name);
        }
        if fixed.len() + 1 != n_in {
            bail!(
                "{}: {} fixed inputs + 1 data slot != manifest arity {}",
                module.name,
                fixed.len(),
                n_in
            );
        }
        let data_spec = &module.manifest.inputs[n_in - 1];
        let out_spec = module
            .manifest
            .outputs
            .first()
            .ok_or_else(|| anyhow!("{}: module has no outputs", module.name))?;
        if data_spec.dtype != super::manifest::DType::F32
            || out_spec.dtype != super::manifest::DType::F32
        {
            bail!(
                "{}: rows adapter serves f32 data/output slots, got {:?} -> {:?}",
                module.name,
                data_spec.dtype,
                out_spec.dtype
            );
        }
        let in_shape = data_spec.shape.clone();
        let out_shape = out_spec.shape.clone();
        let mut lits = Vec::with_capacity(n_in);
        for (t, spec) in fixed.iter().zip(&module.manifest.inputs[..n_in - 1]) {
            t.check(spec).with_context(|| format!("fixed input to {}", module.name))?;
            lits.push(t.to_literal()?);
        }
        // Placeholder for the data slot; replaced before every execute.
        lits.push(xla::Literal::vec1::<f32>(&[]));
        Self::with_backend(RowsBackend::Bound { module, lits }, in_shape, out_shape)
    }

    /// Execute one populated module-batch chunk.
    fn run_chunk(&mut self, data: &HostTensor) -> Result<Vec<HostTensor>> {
        match &mut self.backend {
            RowsBackend::Host { module, fixed } => {
                let mut inputs: Vec<&HostTensor> = fixed.iter().collect();
                inputs.push(data);
                module.execute_batch(&inputs)
            }
            RowsBackend::Bound { module, lits } => {
                let last = lits.len() - 1;
                lits[last] = data.to_literal()?;
                let outs = module.execute_literals(lits)?;
                outs.iter()
                    .zip(&module.manifest.outputs)
                    .map(|(l, spec)| HostTensor::from_literal(l, spec))
                    .collect()
            }
        }
    }

    /// Module batch size (the chunking granularity).
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Flattened per-row input width.
    pub fn d_in(&self) -> usize {
        self.d_in
    }

    /// Flattened per-row output width.
    pub fn d_out(&self) -> usize {
        self.d_out
    }

    /// Run `rows` flattened rows (`x.len() == rows * d_in`) through the
    /// module in batch-sized chunks; `out` is cleared and filled with
    /// `rows * d_out` values in row order.  `&mut self` so the chunk
    /// buffer persists across calls: the steady-state hot path clones
    /// the data-slot shape once per call and allocates nothing per
    /// chunk (the buffer is moved into the input tensor and reclaimed
    /// after each execute).
    pub fn execute_rows(&mut self, x: &[f32], rows: usize, out: &mut Vec<f32>) -> Result<()> {
        if x.len() != rows * self.d_in {
            bail!(
                "rows adapter: {} values for {} rows of d_in={}",
                x.len(),
                rows,
                self.d_in
            );
        }
        out.clear();
        out.reserve(rows * self.d_out);
        let mut chunk = std::mem::take(&mut self.scratch);
        chunk.resize(self.batch * self.d_in, 0.0);
        let mut shape = self.in_shape.clone();
        let mut r = 0usize;
        while r < rows {
            let take = (rows - r).min(self.batch);
            chunk[..take * self.d_in].copy_from_slice(&x[r * self.d_in..(r + take) * self.d_in]);
            // Zero the pad rows so a partial chunk's contents are a pure
            // function of the served rows (reproducible, and never NaN).
            chunk[take * self.d_in..].fill(0.0);
            let data = HostTensor::F32 { shape, data: chunk };
            // An error drops the moved buffers; the next call simply
            // reallocates them.
            let outs = self.run_chunk(&data)?;
            let HostTensor::F32 { shape: s, data: d } = data else { unreachable!() };
            shape = s;
            chunk = d;
            let first = outs
                .first()
                .ok_or_else(|| anyhow!("rows adapter: module returned no outputs"))?;
            let y = first.as_f32()?;
            if y.len() != self.batch * self.d_out {
                bail!(
                    "rows adapter: output has {} values, expected {} ({}x{} as {:?})",
                    y.len(),
                    self.batch * self.d_out,
                    self.batch,
                    self.d_out,
                    self.out_shape
                );
            }
            out.extend_from_slice(&y[..take * self.d_out]);
            r += take;
        }
        self.scratch = chunk;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pure-Rust stand-in for an eval module: one fixed weight vector
    /// `w[d_out]`, data `[batch, d_in]`, output `y[r][j] = x[r][j % d_in]
    /// * w[j]` — deliberately row-independent.
    struct ToyModule {
        batch: usize,
        d_in: usize,
        d_out: usize,
    }

    impl ModuleExec for ToyModule {
        fn execute_batch(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
            let w = inputs[0].as_f32()?;
            let x = inputs[1].as_f32()?;
            assert_eq!(x.len(), self.batch * self.d_in);
            let mut y = vec![0.0f32; self.batch * self.d_out];
            for r in 0..self.batch {
                for j in 0..self.d_out {
                    y[r * self.d_out + j] = x[r * self.d_in + j % self.d_in] * w[j];
                }
            }
            Ok(vec![HostTensor::F32 { shape: vec![self.batch, self.d_out], data: y }])
        }
    }

    fn adapter(batch: usize, d_in: usize, d_out: usize) -> RowsAdapter {
        let w = HostTensor::F32 {
            shape: vec![d_out],
            data: (0..d_out).map(|j| 1.0 + j as f32 * 0.5).collect(),
        };
        RowsAdapter::from_parts(
            Box::new(ToyModule { batch, d_in, d_out }),
            vec![w],
            vec![batch, d_in],
            vec![batch, d_out],
        )
        .unwrap()
    }

    #[test]
    fn rows_adapter_chunks_and_pads_bit_identically() {
        let mut a = adapter(4, 3, 5);
        assert_eq!((a.batch(), a.d_in(), a.d_out()), (4, 3, 5));
        // 10 rows = 2 full chunks + 1 partial (2 rows padded to 4).
        let x: Vec<f32> = (0..10 * 3).map(|i| i as f32 * 0.25 - 3.0).collect();
        let mut all = Vec::new();
        a.execute_rows(&x, 10, &mut all).unwrap();
        assert_eq!(all.len(), 10 * 5);
        // Per-request reference: each row served alone through the same
        // adapter must be bit-identical (row independence + zero pad).
        for r in 0..10 {
            let mut one = Vec::new();
            a.execute_rows(&x[r * 3..(r + 1) * 3], 1, &mut one).unwrap();
            assert_eq!(&all[r * 5..(r + 1) * 5], &one[..], "row {r}");
        }
    }

    #[test]
    fn rows_adapter_rejects_bad_shapes() {
        let mut a = adapter(4, 3, 5);
        let mut out = Vec::new();
        assert!(a.execute_rows(&[0.0; 7], 2, &mut out).is_err(), "7 != 2*3");
        assert!(RowsAdapter::from_parts(
            Box::new(ToyModule { batch: 2, d_in: 3, d_out: 5 }),
            vec![],
            vec![2, 3],
            vec![4, 5],
        )
        .is_err(), "batch mismatch");
        assert!(RowsAdapter::from_parts(
            Box::new(ToyModule { batch: 0, d_in: 3, d_out: 5 }),
            vec![],
            vec![0, 3],
            vec![0, 5],
        )
        .is_err(), "zero batch");
    }

    #[test]
    fn rows_adapter_zero_rows_is_empty_ok() {
        let mut a = adapter(4, 3, 5);
        let mut out = vec![1.0f32];
        a.execute_rows(&[], 0, &mut out).unwrap();
        assert!(out.is_empty());
    }
}
