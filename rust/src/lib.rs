//! FlashKAT reproduction library.
//!
//! Three-layer architecture (see DESIGN.md):
//! - **L1** (Pallas, build-time python): group-wise rational kernels.
//! - **L2** (JAX, build-time python): KAT / ViT models + AdamW train step,
//!   AOT-lowered to HLO text in `artifacts/`.
//! - **L3** (this crate): training coordinator, PJRT runtime, and every
//!   substrate the paper's evaluation needs — most notably a GPU
//!   memory-hierarchy simulator (`gpusim`) that reproduces the paper's
//!   Nsight-style measurements, a bit-faithful gradient-accumulation
//!   model (`rational`) for the rounding-error study, a dynamic
//!   micro-batching inference engine (`serve`) that turns the optimized
//!   host kernels into a traffic-handling system, and two zero-dependency
//!   network frontends exposing the sharded engine to external traffic:
//!   HTTP/JSON (`net`) and the flashwire length-prefixed binary protocol
//!   (`wire`) for float-heavy payloads where text JSON dominates request
//!   cost.

// Nightly-only lane types for the `simd` feature; the default stable
// build never sees this attribute (DESIGN.md §14).
#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod flops;
pub mod gpusim;
pub mod net;
pub mod probe;
pub mod rational;
pub mod report;
pub mod route;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod trace;
pub mod util;
pub mod wire;
