//! Pooled keep-alive [`WireClient`] connections, one idle list per
//! backend (DESIGN.md §18).
//!
//! The router's handler threads check a connection out, run one round
//! trip, and put it back — so steady-state forwarding pays zero
//! connection setup, which is the same economy the per-thread clients
//! buy the bench.  Poison discipline: a `WireClient` that failed
//! mid-frame marks itself broken ([`WireClient::is_broken`]); the pool
//! never returns a broken connection to the idle list, and checkout
//! runs the caller's op through [`WireClient::call_reconnecting`], so a
//! stale pooled connection (backend restarted, keep-alive dropped)
//! heals itself with one capped-backoff redial instead of surfacing as
//! a spurious failover.

use std::net::SocketAddr;
use std::sync::Mutex;

use anyhow::Result;

use crate::wire::{WireClient, WireLimits};

/// Idle connections kept per backend; beyond this, returned connections
/// are dropped (closed) rather than hoarded.
const MAX_IDLE_PER_BACKEND: usize = 32;

pub struct BackendPool {
    addrs: Vec<SocketAddr>,
    limits: WireLimits,
    idle: Vec<Mutex<Vec<WireClient>>>,
}

impl BackendPool {
    pub fn new(addrs: Vec<SocketAddr>, limits: WireLimits) -> BackendPool {
        let idle = addrs.iter().map(|_| Mutex::new(Vec::new())).collect();
        BackendPool { addrs, limits, idle }
    }

    pub fn addrs(&self) -> &[SocketAddr] {
        &self.addrs
    }

    /// Check out a pooled (or freshly dialed) connection to backend
    /// `backend`, run `op` through the reconnect helper with `attempts`
    /// total tries, and return the connection to the idle list if it is
    /// still healthy.  `Err` means the backend is unreachable as far as
    /// `attempts` redials could tell — the caller's cue to fail over.
    pub fn with_conn<T>(
        &self,
        backend: usize,
        attempts: usize,
        op: impl FnMut(&mut WireClient) -> Result<T>,
    ) -> Result<T> {
        let pooled = self.idle[backend].lock().unwrap().pop();
        let mut client = match pooled {
            Some(c) => c,
            None => WireClient::connect_with_limits(self.addrs[backend], self.limits)?,
        };
        let res = client.call_reconnecting(attempts, op);
        if res.is_ok() && !client.is_broken() {
            let mut idle = self.idle[backend].lock().unwrap();
            if idle.len() < MAX_IDLE_PER_BACKEND {
                idle.push(client);
            }
        }
        res
    }

    /// Idle connections currently pooled for `backend` (diagnostics).
    pub fn idle_count(&self, backend: usize) -> usize {
        self.idle[backend].lock().unwrap().len()
    }
}
