//! The flashroute frontend: one listening port fanning client traffic
//! out across N backend `serve-wire` processes (DESIGN.md §18).
//!
//! Thread layout is the same proven shape as both single-process
//! frontends (one accept thread → bounded [`ConnQueue`] → fixed handler
//! pool), with one addition: a prober thread driving the per-backend
//! [`HealthMachine`]s over Ping/Pong frames.  Each handler connection
//! is **protocol-sniffed**: the first two bytes decide flashwire
//! (`b"FW"` magic) vs HTTP, and the consumed bytes are replayed through
//! a rewind reader, so wire clients and HTTP clients share the front
//! port — the router hop is invisible to both.
//!
//! Forwarding relays frames *verbatim*: an `InferRequest` payload is
//! routed by [`InferRequest::peek_model`] (the leading name field) and
//! the backend's reply bytes are written back unmodified, so the
//! router can never perturb f32 bits — bit-identity through the extra
//! hop is structural, not re-proven per value.  Failover honors the
//! typed error taxonomy: `queue-full`/`backlog`/`draining`/`timeout`
//! frames from a backend mean "try the next healthy backend after the
//! retry hint"; every other typed error is deterministic (bad shape,
//! unknown model) and is relayed to the client at once, because a
//! replica would reject it identically.

use std::collections::HashMap;
use std::io::{BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::health::{HealthMachine, HealthState};
use super::pool::BackendPool;
use super::ring::HashRing;
use crate::net::http::{self, HttpResponse, ReadOutcome};
use crate::net::listener::{ConnQueue, HandlerTrace};
use crate::trace::TraceCollector;
use crate::util::json::Json;
use crate::wire::frame::{read_frame, write_frame, BadKind, FrameOutcome, MsgType, WireLimits};
use crate::wire::proto::{
    decode_ping, ErrCode, InferRequest, InferResponse, ShardLoad, StatsResponse, WireError,
};
use crate::wire::MAGIC;

/// Backend-selection policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Consistent-hash ring keyed by model name: one model's traffic
    /// lands on one backend (warm batcher, coalescible batches), and
    /// membership changes move ~1/N of the keyspace.
    Ring,
    /// Rank the ring's failover order by each backend's live load
    /// (queued + in-flight from the `StatsResponse` v2 tail, polled by
    /// the prober) — same candidates, least-loaded first.
    LeastLoaded,
}

impl RoutePolicy {
    pub fn parse(s: &str) -> Option<RoutePolicy> {
        match s {
            "ring" => Some(RoutePolicy::Ring),
            "least-loaded" => Some(RoutePolicy::LeastLoaded),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            RoutePolicy::Ring => "ring",
            RoutePolicy::LeastLoaded => "least-loaded",
        }
    }
}

/// Router tuning knobs (mirrors `WireOptions` plus the health/probe
/// layer).
#[derive(Clone)]
pub struct RouteOptions {
    /// Connection-handler threads (max concurrent client connections).
    pub conn_threads: usize,
    /// Accepted-but-unclaimed connections held before door shedding.
    pub backlog: usize,
    pub limits: WireLimits,
    pub policy: RoutePolicy,
    /// Prober cadence: one Ping round trip per backend per interval,
    /// and one cooldown tick for Down backends.
    pub probe_interval: Duration,
    /// Consecutive failures that open a backend's circuit.
    pub fail_threshold: u32,
    /// Probe intervals a Down backend sits out before its half-open
    /// trial.
    pub down_cooldown: u32,
    /// Optional collector: each handler thread registers a "route-{i}"
    /// track and every forwarded infer gets a span minted at the router
    /// admission edge, so the hop is visible in the same Perfetto
    /// timeline as everything else.
    pub tracer: Option<Arc<TraceCollector>>,
}

impl Default for RouteOptions {
    fn default() -> Self {
        Self {
            conn_threads: 8,
            backlog: 64,
            limits: WireLimits::default(),
            policy: RoutePolicy::Ring,
            probe_interval: Duration::from_millis(200),
            fail_threshold: 3,
            down_cooldown: 2,
            tracer: None,
        }
    }
}

/// Router-layer counters, all per-backend — the `flashkat_route_*`
/// Prometheus families.
pub struct RouteMetrics {
    pub connections: AtomicU64,
    forwarded: Vec<AtomicU64>,
    failed: Vec<AtomicU64>,
    retried: Vec<AtomicU64>,
    /// Health transitions by target state: [to_up, to_half_open, to_down].
    transitions: Vec<[AtomicU64; 3]>,
}

fn zeroed(n: usize) -> Vec<AtomicU64> {
    (0..n).map(|_| AtomicU64::new(0)).collect()
}

impl RouteMetrics {
    fn new(backends: usize) -> RouteMetrics {
        RouteMetrics {
            connections: AtomicU64::new(0),
            forwarded: zeroed(backends),
            failed: zeroed(backends),
            retried: zeroed(backends),
            transitions: (0..backends)
                .map(|_| std::array::from_fn(|_| AtomicU64::new(0)))
                .collect(),
        }
    }

    fn record_transition(&self, backend: usize, to: HealthState) {
        let slot = match to {
            HealthState::Up => 0,
            HealthState::HalfOpen => 1,
            HealthState::Down => 2,
        };
        self.transitions[backend][slot].fetch_add(1, Ordering::Relaxed);
    }

    /// Replies relayed from `backend` (success or deterministic typed
    /// error — anything the client got an answer for).
    pub fn forwarded(&self, backend: usize) -> u64 {
        self.forwarded[backend].load(Ordering::Relaxed)
    }

    /// Transport-level failures talking to `backend` (each one advanced
    /// the failover loop).
    pub fn failed(&self, backend: usize) -> u64 {
        self.failed[backend].load(Ordering::Relaxed)
    }

    /// Shed-class typed errors from `backend` that triggered a retry on
    /// the next candidate.
    pub fn retried(&self, backend: usize) -> u64 {
        self.retried[backend].load(Ordering::Relaxed)
    }

    /// Health transitions of `backend` as (to_up, to_half_open, to_down).
    pub fn health_transitions(&self, backend: usize) -> (u64, u64, u64) {
        let t = &self.transitions[backend];
        (
            t[0].load(Ordering::Relaxed),
            t[1].load(Ordering::Relaxed),
            t[2].load(Ordering::Relaxed),
        )
    }

    pub fn total_forwarded(&self) -> u64 {
        self.forwarded.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    pub fn total_failed(&self) -> u64 {
        self.failed.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Total failover events: shed-class typed errors plus transport
    /// failures — every time a request had to move to another backend.
    pub fn total_retried(&self) -> u64 {
        self.retried.iter().map(|c| c.load(Ordering::Relaxed)).sum::<u64>()
            + self.total_failed()
    }
}

/// Everything the handler threads and the prober share.
struct RouteCore {
    pool: BackendPool,
    ring: HashRing,
    policy: RoutePolicy,
    health: Vec<Mutex<HealthMachine>>,
    /// Last load sample per backend (queued + in-flight summed over its
    /// shards); `u64::MAX` = never sampled, ranks last.
    loads: Vec<AtomicU64>,
    /// Model name → d_in, learned from backend stats — lets the HTTP
    /// bridge default `rows` like the direct frontend does.
    widths: Mutex<HashMap<String, u32>>,
    metrics: Arc<RouteMetrics>,
}

impl RouteCore {
    fn backends(&self) -> usize {
        self.health.len()
    }

    fn on_success(&self, backend: usize) {
        if let Some(to) = self.health[backend].lock().unwrap().on_success() {
            self.metrics.record_transition(backend, to);
        }
    }

    fn on_failure(&self, backend: usize) {
        if let Some(to) = self.health[backend].lock().unwrap().on_failure() {
            self.metrics.record_transition(backend, to);
        }
    }

    fn available(&self, backend: usize) -> bool {
        self.health[backend].lock().unwrap().available()
    }

    /// Failover order for `model`: the ring's successor walk, filtered
    /// to available backends, least-loaded-first under that policy.
    /// When the filter empties the list (every circuit open), the full
    /// ring order is used instead — trying a probably-dead backend and
    /// relaying its typed answer beats inventing one.
    fn candidates(&self, model: &str) -> Vec<usize> {
        let ring_order = self.ring.successors(model);
        let mut order: Vec<usize> =
            ring_order.iter().copied().filter(|&b| self.available(b)).collect();
        if order.is_empty() {
            order = ring_order;
        }
        if self.policy == RoutePolicy::LeastLoaded {
            // Stable sort: ring position stays the tiebreak, so equal
            // loads degrade to plain ring routing.
            order.sort_by_key(|&b| self.loads[b].load(Ordering::Relaxed));
        }
        order
    }

    /// Record what a fresh stats snapshot teaches: the live load and
    /// every model's input width.
    fn learn(&self, backend: usize, stats: &StatsResponse) {
        self.loads[backend].store(stats.total_load(), Ordering::Relaxed);
        let mut widths = self.widths.lock().unwrap();
        for m in &stats.models {
            widths.entry(m.name.clone()).or_insert(m.d_in);
        }
    }
}

/// Final counters returned by [`RouteServer::shutdown`].
#[derive(Clone, Copy, Debug)]
pub struct RouteDrainStats {
    pub forwarded: u64,
    pub failed: u64,
    pub retried: u64,
    pub backends: usize,
}

pub struct RouteServer {
    addr: SocketAddr,
    core: Arc<RouteCore>,
    stop: Arc<AtomicBool>,
    queue: Arc<ConnQueue>,
    limits: WireLimits,
    threads: Mutex<Option<Vec<std::thread::JoinHandle<()>>>>,
}

impl RouteServer {
    /// Bind `addr` (port 0 → ephemeral) in front of `backends` and
    /// start the accept thread, the handler pool, and the prober.
    pub fn bind(
        addr: &str,
        backends: Vec<SocketAddr>,
        opts: RouteOptions,
    ) -> Result<RouteServer> {
        if backends.is_empty() {
            anyhow::bail!("router needs at least one backend");
        }
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr().context("reading bound address")?;
        listener.set_nonblocking(true).context("nonblocking listener")?;

        let n = backends.len();
        let metrics = Arc::new(RouteMetrics::new(n));
        let core = Arc::new(RouteCore {
            pool: BackendPool::new(backends, opts.limits),
            ring: HashRing::new(n),
            policy: opts.policy,
            health: (0..n)
                .map(|_| Mutex::new(HealthMachine::new(opts.fail_threshold, opts.down_cooldown)))
                .collect(),
            loads: (0..n).map(|_| AtomicU64::new(u64::MAX)).collect(),
            widths: Mutex::new(HashMap::new()),
            metrics,
        });
        let stop = Arc::new(AtomicBool::new(false));
        let queue = Arc::new(ConnQueue::new(opts.backlog));

        let mut threads = Vec::with_capacity(opts.conn_threads.max(1) + 2);
        {
            let (stop, queue, core) = (stop.clone(), queue.clone(), core.clone());
            threads.push(
                std::thread::Builder::new()
                    .name("flashkat-route-accept".into())
                    .spawn(move || accept_loop(&listener, &queue, &stop, &core))
                    .context("spawning accept thread")?,
            );
        }
        {
            let (stop, core) = (stop.clone(), core.clone());
            let interval = opts.probe_interval;
            let spawned = std::thread::Builder::new()
                .name("flashkat-route-probe".into())
                .spawn(move || probe_loop(&core, &stop, interval));
            match spawned {
                Ok(handle) => threads.push(handle),
                Err(e) => {
                    stop.store(true, Ordering::SeqCst);
                    for t in threads {
                        let _ = t.join();
                    }
                    anyhow::bail!("spawning prober thread: {e}");
                }
            }
        }
        for i in 0..opts.conn_threads.max(1) {
            let (stop_t, queue, core) = (stop.clone(), queue.clone(), core.clone());
            let limits = opts.limits;
            // One "route-{i}" track per handler thread — the serial-
            // writer discipline every frontend uses, so span IDs survive
            // the extra hop into the same trace timeline.
            let trace = opts.tracer.as_ref().map(|t| HandlerTrace {
                tracer: t.clone(),
                track: t.register_track(&format!("route-{i}")),
            });
            let tracer = opts.tracer.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("flashkat-route-{i}"))
                .spawn(move || {
                    handler_loop(&queue, &core, &limits, &stop_t, trace.as_ref(), tracer.as_ref())
                });
            match spawned {
                Ok(handle) => threads.push(handle),
                Err(e) => {
                    stop.store(true, Ordering::SeqCst);
                    for t in threads {
                        let _ = t.join();
                    }
                    anyhow::bail!("spawning handler thread {i}: {e}");
                }
            }
        }
        Ok(RouteServer {
            addr: local,
            core,
            stop,
            queue,
            limits: opts.limits,
            threads: Mutex::new(Some(threads)),
        })
    }

    /// The actually-bound address (resolves `--port 0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> &Arc<RouteMetrics> {
        &self.core.metrics
    }

    /// Current health state of each backend.
    pub fn backend_states(&self) -> Vec<HealthState> {
        self.core.health.iter().map(|h| h.lock().unwrap().state()).collect()
    }

    /// Graceful drain (idempotent): stop accepting, let in-flight
    /// exchanges finish, join every thread, answer stragglers in the
    /// hand-off queue, and return the final counters on the call that
    /// performed the drain.
    pub fn shutdown(&self) -> Option<RouteDrainStats> {
        let threads = self.threads.lock().unwrap().take()?;
        self.stop.store(true, Ordering::SeqCst);
        for t in threads {
            let _ = t.join();
        }
        while let Some(stream) = self.queue.pop(Duration::from_millis(1)) {
            handle_connection(stream, &self.core, &self.limits, &self.stop, None, None);
        }
        let m = &self.core.metrics;
        Some(RouteDrainStats {
            forwarded: m.total_forwarded(),
            failed: m.total_failed(),
            retried: m.total_retried(),
            backends: self.core.backends(),
        })
    }
}

impl Drop for RouteServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: &TcpListener, queue: &ConnQueue, stop: &AtomicBool, core: &RouteCore) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                core.metrics.connections.fetch_add(1, Ordering::Relaxed);
                if let Err(mut stream) = queue.push(stream) {
                    // Door shed.  The protocol is unknown pre-sniff, so
                    // the door speaks flashwire (the latency-critical
                    // clients); the retry hint is what the loadgen's
                    // Backlog-aware backoff consumes.
                    let err = WireError::new(ErrCode::Backlog, "router backlog full")
                        .with_retry_after(crate::wire::server::SHED_RETRY_AFTER_MILLIS);
                    let _ = write_frame(&mut stream, MsgType::Error, &err.encode());
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// One Ping round trip per backend per interval (single attempt — the
/// probe *is* the retry mechanism), driving each machine's transitions;
/// under least-loaded, a stats poll rides along to refresh the load
/// ranking.
fn probe_loop(core: &RouteCore, stop: &AtomicBool, interval: Duration) {
    let mut token: u64 = 0x0f1a_5470_0000_0000;
    while !stop.load(Ordering::SeqCst) {
        for b in 0..core.backends() {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            // Cooldown ticks only advance Down machines; Up/HalfOpen
            // get the actual ping.
            let due = {
                let mut m = core.health[b].lock().unwrap();
                if let Some(to) = m.tick() {
                    core.metrics.record_transition(b, to);
                }
                m.available()
            };
            if !due {
                continue;
            }
            token = token.wrapping_add(1);
            let t = token;
            match core.pool.with_conn(b, 1, |c| c.ping(t)) {
                Ok(()) => {
                    core.on_success(b);
                    if core.policy == RoutePolicy::LeastLoaded {
                        if let Ok(stats) = core.pool.with_conn(b, 1, |c| c.stats()) {
                            core.learn(b, &stats);
                        }
                    }
                }
                Err(_) => core.on_failure(b),
            }
        }
        // Sleep in short slices so drain is never stuck behind a long
        // probe interval.
        let mut left = interval;
        while left > Duration::ZERO && !stop.load(Ordering::SeqCst) {
            let nap = left.min(Duration::from_millis(20));
            std::thread::sleep(nap);
            left = left.saturating_sub(nap);
        }
    }
}

fn handler_loop(
    queue: &ConnQueue,
    core: &RouteCore,
    limits: &WireLimits,
    stop: &AtomicBool,
    trace: Option<&HandlerTrace>,
    tracer: Option<&Arc<TraceCollector>>,
) {
    loop {
        let Some(stream) = queue.pop(Duration::from_millis(50)) else {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            continue;
        };
        handle_connection(stream, core, limits, stop, trace, tracer);
        if stop.load(Ordering::SeqCst) {
            while let Some(stream) = queue.pop(Duration::from_millis(1)) {
                handle_connection(stream, core, limits, stop, trace, tracer);
            }
            return;
        }
    }
}

/// A reader that replays the sniffed prefix bytes before the live
/// stream — both protocol parsers see the byte stream from offset 0.
struct Rewind<R> {
    prefix: [u8; 2],
    pos: usize,
    inner: R,
}

impl<R: Read> Read for Rewind<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.pos < self.prefix.len() {
            let n = buf.len().min(self.prefix.len() - self.pos);
            buf[..n].copy_from_slice(&self.prefix[self.pos..self.pos + n]);
            self.pos += n;
            return Ok(n);
        }
        self.inner.read(buf)
    }
}

/// Read the two sniff bytes, tolerating read-timeout ticks like the
/// frame reader does.  `Ok(None)` = clean close before any byte.
fn sniff(
    stream: &mut TcpStream,
    stop: &AtomicBool,
    max_ticks: usize,
) -> std::io::Result<Option<[u8; 2]>> {
    let mut buf = [0u8; 2];
    let mut got = 0usize;
    let mut ticks = 0usize;
    while got < 2 {
        match stream.read(&mut buf[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => return Err(std::io::ErrorKind::UnexpectedEof.into()),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                ticks += 1;
                if ticks > max_ticks || stop.load(Ordering::SeqCst) {
                    return Err(e);
                }
            }
            Err(e) => return Err(e),
        }
    }
    Ok(Some(buf))
}

/// Serve one sniffed connection until close, protocol error, or drain.
fn handle_connection(
    stream: TcpStream,
    core: &RouteCore,
    limits: &WireLimits,
    stop: &AtomicBool,
    trace: Option<&HandlerTrace>,
    tracer: Option<&Arc<TraceCollector>>,
) {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_millis(50))).ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut stream = stream;
    let prefix = match sniff(&mut stream, stop, limits.max_stall_ticks) {
        Ok(Some(p)) => p,
        _ => return,
    };
    let mut reader = BufReader::new(Rewind { prefix, pos: 0, inner: stream });
    if prefix == MAGIC {
        serve_wire_conn(&mut reader, &mut writer, core, limits, stop, trace, tracer);
    } else {
        serve_http_conn(&mut reader, &mut writer, core, stop, trace, tracer);
    }
}

// ---- flashwire side ---------------------------------------------------

/// The relay's answer to one frame: the bytes to write back, whether
/// the connection survives, and the typed code (for tracing).
struct Relay {
    msg_type: MsgType,
    payload: Vec<u8>,
    keep: bool,
    code: Option<ErrCode>,
    span_id: Option<u64>,
}

impl Relay {
    fn err(e: WireError) -> Relay {
        Relay {
            msg_type: MsgType::Error,
            code: Some(e.code),
            payload: e.encode(),
            keep: true,
            span_id: None,
        }
    }

    fn fatal(e: WireError) -> Relay {
        Relay { keep: false, ..Relay::err(e) }
    }
}

fn serve_wire_conn(
    reader: &mut impl std::io::BufRead,
    writer: &mut impl Write,
    core: &RouteCore,
    limits: &WireLimits,
    stop: &AtomicBool,
    trace: Option<&HandlerTrace>,
    tracer: Option<&Arc<TraceCollector>>,
) {
    loop {
        let outcome = match read_frame(reader, limits, stop) {
            Ok(o) => o,
            Err(_) => return,
        };
        match outcome {
            FrameOutcome::Closed => return,
            FrameOutcome::Bad { kind, msg } => {
                let code = match kind {
                    BadKind::Malformed => ErrCode::BadFrame,
                    BadKind::Timeout => ErrCode::RequestTimeout,
                };
                let _ = write_frame(writer, MsgType::Error, &WireError::new(code, msg).encode());
                return;
            }
            FrameOutcome::Ok(frame) => {
                let msg_type = frame.msg_type;
                let t0 = trace.map(|tr| tr.tracer.now_us());
                let relay = dispatch_wire(frame.msg_type, &frame.payload, core, tracer);
                if let (Some(tr), Some(t0)) = (trace, t0) {
                    let status = relay.code.map(|c| c as u64).unwrap_or(0);
                    tr.record(format!("route {msg_type:?}"), t0, status, relay.span_id);
                }
                let keep = relay.keep && !stop.load(Ordering::SeqCst);
                if write_frame(writer, relay.msg_type, &relay.payload).is_err() || !keep {
                    return;
                }
            }
        }
    }
}

fn dispatch_wire(
    msg_type: MsgType,
    payload: &[u8],
    core: &RouteCore,
    tracer: Option<&Arc<TraceCollector>>,
) -> Relay {
    match msg_type {
        // The router answers pings itself: a client probing the front
        // port is asking about the tier it talks to, and the prober
        // owns backend liveness.
        MsgType::Ping => match decode_ping(payload) {
            Ok(token) => Relay {
                msg_type: MsgType::Pong,
                payload: token.to_vec(),
                keep: true,
                code: None,
                span_id: None,
            },
            Err(msg) => Relay::err(WireError::new(ErrCode::BadMsg, msg)),
        },
        MsgType::StatsRequest => {
            if !payload.is_empty() {
                let e = WireError::new(ErrCode::BadMsg, "StatsRequest carries no payload");
                return Relay::err(e);
            }
            match fanout_stats(core) {
                Some(stats) => Relay {
                    msg_type: MsgType::StatsResponse,
                    payload: stats.encode(),
                    keep: true,
                    code: None,
                    span_id: None,
                },
                None => Relay::err(WireError::new(
                    ErrCode::Draining,
                    "no healthy backend answered stats",
                )),
            }
        }
        MsgType::InferRequest => forward_infer(payload, core, tracer),
        MsgType::InferResponse | MsgType::StatsResponse | MsgType::Pong | MsgType::Error => {
            Relay::fatal(WireError::new(
                ErrCode::BadMsg,
                format!("{msg_type:?} is a server-to-client msg-type"),
            ))
        }
    }
}

/// Backoff before retrying on the next candidate after a shed-class
/// typed error: honor the backend's `retry_after_millis` hint, capped
/// so a handler thread is never parked long (the same 5ms cap as
/// `loadgen::shed_backoff`); no hint backs off a token 200µs.
fn failover_backoff(hint_millis: u32) -> Duration {
    const CAP: Duration = Duration::from_millis(5);
    if hint_millis > 0 {
        Duration::from_millis(hint_millis as u64).min(CAP)
    } else {
        Duration::from_micros(200)
    }
}

/// Is this typed error an invitation to try a replica?  Everything else
/// (bad shape, unknown model, bad frame...) is deterministic: a second
/// backend with the same registry would answer identically.
fn is_shed(code: ErrCode) -> bool {
    matches!(
        code,
        ErrCode::QueueFull | ErrCode::Backlog | ErrCode::Draining | ErrCode::Timeout
    )
}

/// Peek the rows field behind the leading name — only for span
/// annotations, so a short payload degrades to 0 instead of erroring
/// (the backend will reject it with the authoritative message).
fn peek_rows(payload: &[u8]) -> u32 {
    if payload.len() < 2 {
        return 0;
    }
    let n = u16::from_le_bytes([payload[0], payload[1]]) as usize;
    let off = 2 + n;
    match payload.get(off..off + 4) {
        Some(b) => u32::from_le_bytes([b[0], b[1], b[2], b[3]]),
        None => 0,
    }
}

/// The heart of the tier: route by model name, walk the failover order,
/// relay the first real answer verbatim.
fn forward_infer(payload: &[u8], core: &RouteCore, tracer: Option<&Arc<TraceCollector>>) -> Relay {
    let model = match InferRequest::peek_model(payload) {
        Ok(m) => m,
        Err(msg) => return Relay::err(WireError::new(ErrCode::BadMsg, msg)),
    };
    let span = tracer.map(|t| t.mint(&model, peek_rows(payload)));
    let span_id = span.as_ref().map(|s| s.span_id);
    let order = core.candidates(&model);
    let mut last_shed: Option<WireError> = None;
    let n = order.len();
    for (attempt, b) in order.into_iter().enumerate() {
        let res = core.pool.with_conn(b, 2, |c| c.round_trip(MsgType::InferRequest, payload));
        let frame = match res {
            Ok(f) => f,
            Err(_) => {
                // Transport failure: the backend never answered — feed
                // the health machine and move on.  The request is never
                // lost: either a replica answers or the client gets the
                // typed no-backend error below.
                core.on_failure(b);
                core.metrics.failed[b].fetch_add(1, Ordering::Relaxed);
                continue;
            }
        };
        core.on_success(b);
        if frame.msg_type == MsgType::Error {
            if let Ok(e) = WireError::decode(&frame.payload) {
                if is_shed(e.code) {
                    core.metrics.retried[b].fetch_add(1, Ordering::Relaxed);
                    if attempt + 1 < n {
                        std::thread::sleep(failover_backoff(e.retry_after_millis));
                    }
                    last_shed = Some(e);
                    continue;
                }
            }
            // Deterministic typed error (or an undecodable one): relay
            // the backend's bytes — it is the authoritative answer.
            core.metrics.forwarded[b].fetch_add(1, Ordering::Relaxed);
            let code = WireError::decode(&frame.payload).ok().map(|e| e.code);
            return Relay {
                msg_type: frame.msg_type,
                payload: frame.payload,
                keep: true,
                code,
                span_id,
            };
        }
        core.metrics.forwarded[b].fetch_add(1, Ordering::Relaxed);
        return Relay {
            msg_type: frame.msg_type,
            payload: frame.payload,
            keep: true,
            code: None,
            span_id,
        };
    }
    // Every candidate shed or failed: relay the last shed verdict (it
    // carries the freshest retry hint) or synthesize the no-backend one.
    let e = last_shed.unwrap_or_else(|| {
        WireError::new(ErrCode::Draining, format!("no reachable backend for model {model:?}"))
            .with_retry_after(crate::wire::server::SHED_RETRY_AFTER_MILLIS)
    });
    Relay { span_id, ..Relay::err(e) }
}

/// Fan a StatsRequest out to every available backend and merge, so a
/// client's stats view through the router covers the whole tier.
fn fanout_stats(core: &RouteCore) -> Option<StatsResponse> {
    let mut parts = Vec::new();
    for b in 0..core.backends() {
        if !core.available(b) {
            continue;
        }
        match core.pool.with_conn(b, 1, |c| c.stats()) {
            Ok(stats) => {
                core.on_success(b);
                core.learn(b, &stats);
                parts.push(stats);
            }
            Err(_) => core.on_failure(b),
        }
    }
    if parts.is_empty() {
        None
    } else {
        Some(merge_stats(parts))
    }
}

/// Merge per-backend stats: per-model counters sum by name (widths from
/// the first sighting), shard axes concatenate backend-major — N
/// backends of S shards read as N*S shards, which is what they are.
pub(crate) fn merge_stats(parts: Vec<StatsResponse>) -> StatsResponse {
    let mut models: Vec<crate::wire::StatsModel> = Vec::new();
    let mut shard_peaks: Vec<u64> = Vec::new();
    let mut shard_loads: Vec<ShardLoad> = Vec::new();
    for part in parts {
        for m in part.models {
            match models.iter_mut().find(|o| o.name == m.name) {
                Some(o) => {
                    o.requests += m.requests;
                    o.rows += m.rows;
                    o.batches += m.batches;
                    o.failed += m.failed;
                }
                None => models.push(m),
            }
        }
        shard_peaks.extend(part.shard_peaks);
        shard_loads.extend(part.shard_loads);
    }
    StatsResponse { models, shard_peaks, shard_loads }
}

// ---- HTTP side --------------------------------------------------------

fn serve_http_conn(
    reader: &mut impl std::io::BufRead,
    writer: &mut impl Write,
    core: &RouteCore,
    stop: &AtomicBool,
    trace: Option<&HandlerTrace>,
    tracer: Option<&Arc<TraceCollector>>,
) {
    let limits = http::Limits::default();
    loop {
        let outcome = match http::read_request(reader, &limits, stop) {
            Ok(o) => o,
            Err(_) => return,
        };
        match outcome {
            ReadOutcome::Closed => return,
            ReadOutcome::Bad { status, msg } => {
                let resp = HttpResponse::json(
                    status,
                    &Json::Obj(vec![("error".to_string(), Json::Str(msg))]),
                );
                let _ = resp.write(writer, false);
                return;
            }
            ReadOutcome::Ok(req) => {
                let t0 = trace.map(|tr| tr.tracer.now_us());
                let resp = handle_http(&req, core, tracer);
                if let (Some(tr), Some(t0)) = (trace, t0) {
                    tr.record(
                        format!("route {} {}", req.method, req.path()),
                        t0,
                        resp.status as u64,
                        resp.span_id,
                    );
                }
                let keep = req.keep_alive() && !stop.load(Ordering::SeqCst);
                if resp.write(writer, keep).is_err() || !keep {
                    return;
                }
            }
        }
    }
}

fn http_error(status: u16, msg: &str) -> HttpResponse {
    HttpResponse::json(status, &Json::Obj(vec![("error".to_string(), Json::Str(msg.to_string()))]))
}

fn handle_http(
    req: &http::Request,
    core: &RouteCore,
    tracer: Option<&Arc<TraceCollector>>,
) -> HttpResponse {
    let segments: Vec<&str> = req.path().split('/').filter(|s| !s.is_empty()).collect();
    match segments.as_slice() {
        ["healthz"] => match req.method.as_str() {
            "GET" => HttpResponse::text(200, "ok\n"),
            _ => http_error(405, "healthz supports GET"),
        },
        ["metrics"] => match req.method.as_str() {
            "GET" => HttpResponse::text(200, render_route_metrics(core)),
            _ => http_error(405, "metrics supports GET"),
        },
        ["v1", "models", name, "infer"] => match req.method.as_str() {
            "POST" => http_infer(req, core, name, tracer),
            _ => http_error(405, "infer supports POST"),
        },
        _ => http_error(404, &format!("no route for {}", req.path())),
    }
}

/// HTTP → wire bridge: parse the same JSON body the direct frontend
/// takes, encode a wire InferRequest, run it through the identical
/// failover path, and translate the typed outcome back to a status via
/// [`ErrCode::http_equiv`].  The JSON reply carries `y`/`batch_size`/
/// `cause` (the wire response has no per-request timing block — that
/// telemetry lives in the backend's own trace).
fn http_infer(
    req: &http::Request,
    core: &RouteCore,
    name: &str,
    tracer: Option<&Arc<TraceCollector>>,
) -> HttpResponse {
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t,
        Err(_) => return http_error(400, "body is not UTF-8"),
    };
    let body = match Json::parse(text) {
        Ok(v) => v,
        Err(e) => return http_error(400, &format!("bad JSON body: {e}")),
    };
    let Some(x_json) = body.get("x").and_then(Json::as_arr) else {
        return http_error(400, "body needs an \"x\" array of numbers");
    };
    let mut x = Vec::with_capacity(x_json.len());
    for v in x_json {
        match v.as_f64().map(|f| f as f32) {
            Some(f) if f.is_finite() => x.push(f),
            _ => return http_error(400, "\"x\" must contain only finite numbers"),
        }
    }
    let rows = match body.get("rows") {
        Some(v) => match v.as_usize().and_then(|n| u32::try_from(n).ok()) {
            Some(n) if n > 0 => n,
            _ => return http_error(400, "\"rows\" must be a positive integer"),
        },
        None => {
            // The router has no registry of its own; widths are learned
            // from backend stats (one lazy fan-out on first sight).
            let d_in = core.widths.lock().unwrap().get(name).copied();
            let d_in = match d_in {
                Some(w) => Some(w),
                None => {
                    fanout_stats(core);
                    core.widths.lock().unwrap().get(name).copied()
                }
            };
            let Some(d_in) = d_in else {
                return http_error(404, &format!("unknown model {name:?}"));
            };
            let d_in = d_in as usize;
            if x.is_empty() || x.len() % d_in != 0 {
                return http_error(
                    400,
                    &format!("x has {} values, not a positive multiple of d_in={d_in}", x.len()),
                );
            }
            (x.len() / d_in) as u32
        }
    };
    if x.len() % rows as usize != 0 {
        return http_error(400, &format!("x has {} values, not {rows} whole rows", x.len()));
    }
    let dim = (x.len() / rows as usize) as u32;
    let payload = InferRequest::encode_parts(name, rows, dim, &x);
    let relay = forward_infer(&payload, core, tracer);
    match relay.msg_type {
        MsgType::InferResponse => match InferResponse::decode(&relay.payload) {
            Ok(resp) => {
                if resp.y.iter().any(|v| !v.is_finite()) {
                    return http_error(500, "model produced non-finite values");
                }
                let y: Vec<Json> = resp.y.iter().map(|&v| Json::Num(v as f64)).collect();
                let mut fields = vec![
                    ("y".to_string(), Json::Arr(y)),
                    ("batch_size".to_string(), Json::Int(resp.batch_size as i64)),
                    ("cause".to_string(), Json::Str(resp.cause.label().to_string())),
                ];
                if let Some(id) = relay.span_id {
                    fields.push(("span_id".to_string(), Json::Int(id as i64)));
                }
                HttpResponse::json(200, &Json::Obj(fields)).with_span(relay.span_id)
            }
            Err(e) => http_error(502, &format!("bad InferResponse from backend: {e}")),
        },
        MsgType::Error => match WireError::decode(&relay.payload) {
            Ok(e) => {
                let mut resp = http_error(e.code.http_equiv(), &e.message);
                if e.retry_after_millis > 0 {
                    // HTTP Retry-After speaks whole seconds; round up.
                    let secs = e.retry_after_millis.div_ceil(1000).max(1);
                    resp = resp.with_header("retry-after", secs.to_string());
                }
                resp.with_span(relay.span_id)
            }
            Err(e) => http_error(502, &format!("bad Error frame from backend: {e}")),
        },
        other => http_error(502, &format!("unexpected {other:?} reply from backend")),
    }
}

fn render_route_metrics(core: &RouteCore) -> String {
    let m = &core.metrics;
    let mut out = String::new();
    out.push_str(&format!(
        "# TYPE flashkat_route_connections_total counter\nflashkat_route_connections_total {}\n",
        m.connections.load(Ordering::Relaxed)
    ));
    for (metric, help, pick) in [
        (
            "flashkat_route_forwarded_total",
            "replies relayed from each backend (answers, including deterministic typed errors)",
            RouteMetrics::forwarded as fn(&RouteMetrics, usize) -> u64,
        ),
        (
            "flashkat_route_failed_total",
            "transport failures per backend (connection refused/reset mid-exchange)",
            RouteMetrics::failed,
        ),
        (
            "flashkat_route_retried_total",
            "shed-class typed errors per backend that moved the request to the next candidate",
            RouteMetrics::retried,
        ),
    ] {
        out.push_str(&format!("# HELP {metric} {help}\n# TYPE {metric} counter\n"));
        for b in 0..core.backends() {
            out.push_str(&format!("{metric}{{backend=\"{b}\"}} {}\n", pick(m, b)));
        }
    }
    out.push_str(
        "# HELP flashkat_route_health_transitions_total backend circuit transitions by target state\n# TYPE flashkat_route_health_transitions_total counter\n",
    );
    for b in 0..core.backends() {
        let (up, half, down) = m.health_transitions(b);
        for (state, v) in [("up", up), ("half-open", half), ("down", down)] {
            out.push_str(&format!(
                "flashkat_route_health_transitions_total{{backend=\"{b}\",to=\"{state}\"}} {v}\n"
            ));
        }
    }
    out.push_str("# TYPE flashkat_route_backend_up gauge\n");
    for (b, h) in core.health.iter().enumerate() {
        let up = matches!(h.lock().unwrap().state(), HealthState::Up | HealthState::HalfOpen);
        out.push_str(&format!("flashkat_route_backend_up{{backend=\"{b}\"}} {}\n", up as u8));
    }
    out.push_str("# TYPE flashkat_route_backend_load gauge\n");
    for (b, l) in core.loads.iter().enumerate() {
        let v = l.load(Ordering::Relaxed);
        if v != u64::MAX {
            out.push_str(&format!("flashkat_route_backend_load{{backend=\"{b}\"}} {v}\n"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::StatsModel;

    #[test]
    fn policy_parses_its_two_names() {
        assert_eq!(RoutePolicy::parse("ring"), Some(RoutePolicy::Ring));
        assert_eq!(RoutePolicy::parse("least-loaded"), Some(RoutePolicy::LeastLoaded));
        assert_eq!(RoutePolicy::parse("round-robin"), None);
        assert_eq!(RoutePolicy::Ring.label(), "ring");
        assert_eq!(RoutePolicy::LeastLoaded.label(), "least-loaded");
    }

    #[test]
    fn failover_backoff_honors_and_caps_the_hint() {
        assert_eq!(failover_backoff(0), Duration::from_micros(200));
        assert_eq!(failover_backoff(2), Duration::from_millis(2));
        assert_eq!(failover_backoff(60_000), Duration::from_millis(5), "capped");
    }

    #[test]
    fn shed_classification_matches_the_failover_table() {
        for code in ErrCode::ALL {
            let shed = is_shed(code);
            let expect = matches!(
                code,
                ErrCode::QueueFull | ErrCode::Backlog | ErrCode::Draining | ErrCode::Timeout
            );
            assert_eq!(shed, expect, "{code:?}");
        }
    }

    #[test]
    fn merge_sums_models_and_concatenates_shard_axes() {
        let a = StatsResponse {
            models: vec![StatsModel {
                name: "m".into(),
                d_in: 8,
                d_out: 8,
                requests: 3,
                rows: 5,
                batches: 2,
                failed: 1,
            }],
            shard_peaks: vec![4],
            shard_loads: vec![ShardLoad { queued: 1, in_flight: 1 }],
        };
        let b = StatsResponse {
            models: vec![
                StatsModel {
                    name: "m".into(),
                    d_in: 8,
                    d_out: 8,
                    requests: 7,
                    rows: 9,
                    batches: 4,
                    failed: 0,
                },
                StatsModel {
                    name: "other".into(),
                    d_in: 4,
                    d_out: 4,
                    requests: 1,
                    rows: 1,
                    batches: 1,
                    failed: 0,
                },
            ],
            shard_peaks: vec![2, 0],
            shard_loads: vec![ShardLoad { queued: 0, in_flight: 2 }, ShardLoad::default()],
        };
        let merged = merge_stats(vec![a, b]);
        assert_eq!(merged.models.len(), 2);
        let m = merged.models.iter().find(|m| m.name == "m").unwrap();
        assert_eq!((m.requests, m.rows, m.batches, m.failed), (10, 14, 6, 1));
        assert_eq!(merged.shard_peaks, vec![4, 2, 0]);
        assert_eq!(merged.shard_loads.len(), 3);
        assert_eq!(merged.total_load(), 4);
    }

    #[test]
    fn peek_rows_degrades_to_zero_on_short_payloads() {
        let p = InferRequest::encode_parts("abc", 17, 2, &[0.0; 34]);
        assert_eq!(peek_rows(&p), 17);
        assert_eq!(peek_rows(&p[..4]), 0);
        assert_eq!(peek_rows(&[]), 0);
    }

    #[test]
    fn rewind_replays_the_prefix_then_the_stream() {
        let inner: &[u8] = b"cdef";
        let mut r = Rewind { prefix: [b'a', b'b'], pos: 0, inner };
        let mut out = Vec::new();
        r.read_to_end(&mut out).unwrap();
        assert_eq!(out, b"abcdef");
    }
}
