//! Per-backend health state machine (DESIGN.md §18): a circuit breaker
//! driven by two inputs — forwarding outcomes and the prober's
//! Ping/Pong round trips — with no clock of its own.
//!
//! ```text
//!             consecutive failures >= threshold
//!        Up ────────────────────────────────────► Down
//!        ▲                                          │
//!        │ success                                  │ `cooldown` probe
//!        │                                          │ ticks elapse
//!        └──────────── HalfOpen ◄───────────────────┘
//!            (one trial: success → Up, failure → Down)
//! ```
//!
//! Time is passed in by the caller as *probe ticks* ([`HealthMachine::tick`]
//! once per prober interval), so the machine is a pure value: every
//! transition is unit-testable without sleeping, and the router's
//! observed behavior is a deterministic function of the outcome
//! sequence.  Transitions are returned to the caller (not counted here)
//! so the router can feed its `flashkat_route_health_transitions_total`
//! counters without the machine knowing metrics exist.

/// Availability state of one backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    /// Serving traffic normally.
    Up,
    /// Circuit open: receives no traffic until the cooldown elapses.
    Down,
    /// Cooldown over: eligible for one trial (a probe ping or a real
    /// request) that decides Up vs back to Down.
    HalfOpen,
}

impl HealthState {
    /// Prometheus label value.
    pub fn label(self) -> &'static str {
        match self {
            HealthState::Up => "up",
            HealthState::Down => "down",
            HealthState::HalfOpen => "half-open",
        }
    }
}

#[derive(Clone, Debug)]
pub struct HealthMachine {
    state: HealthState,
    /// Consecutive failures while `Up`.
    fails: u32,
    /// Failures that open the circuit.
    threshold: u32,
    /// Probe ticks to sit `Down` before `HalfOpen`.
    cooldown: u32,
    /// Ticks spent `Down` so far.
    ticks_down: u32,
}

impl HealthMachine {
    /// Starts `Up` (optimistic: the first request is the first probe —
    /// a dead backend fails it and trips the threshold like any other
    /// failure run).  `threshold` and `cooldown` are clamped to ≥ 1.
    pub fn new(threshold: u32, cooldown: u32) -> HealthMachine {
        HealthMachine {
            state: HealthState::Up,
            fails: 0,
            threshold: threshold.max(1),
            cooldown: cooldown.max(1),
            ticks_down: 0,
        }
    }

    pub fn state(&self) -> HealthState {
        self.state
    }

    /// Whether the router may send this backend traffic: `Up` always,
    /// `HalfOpen` as the trial request.
    pub fn available(&self) -> bool {
        !matches!(self.state, HealthState::Down)
    }

    /// A successful round trip (forwarded request or probe pong).
    /// Returns the new state iff this changed it.
    pub fn on_success(&mut self) -> Option<HealthState> {
        self.fails = 0;
        match self.state {
            HealthState::Up => None,
            // A success while Down can only come from a request already
            // in flight when the circuit opened — it is still evidence
            // the backend lives, so it closes the circuit like a trial.
            HealthState::HalfOpen | HealthState::Down => {
                self.state = HealthState::Up;
                Some(HealthState::Up)
            }
        }
    }

    /// A failed round trip.  Returns the new state iff this changed it.
    pub fn on_failure(&mut self) -> Option<HealthState> {
        match self.state {
            HealthState::Up => {
                self.fails += 1;
                if self.fails >= self.threshold {
                    self.state = HealthState::Down;
                    self.ticks_down = 0;
                    Some(HealthState::Down)
                } else {
                    None
                }
            }
            // The trial failed: back to the start of the cooldown.
            HealthState::HalfOpen => {
                self.state = HealthState::Down;
                self.ticks_down = 0;
                Some(HealthState::Down)
            }
            HealthState::Down => {
                self.ticks_down = 0;
                None
            }
        }
    }

    /// One prober interval elapsed.  Advances `Down` toward `HalfOpen`;
    /// returns the new state iff this changed it.
    pub fn tick(&mut self) -> Option<HealthState> {
        if self.state == HealthState::Down {
            self.ticks_down += 1;
            if self.ticks_down >= self.cooldown {
                self.state = HealthState::HalfOpen;
                return Some(HealthState::HalfOpen);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failures_below_threshold_keep_the_backend_up() {
        let mut m = HealthMachine::new(3, 2);
        assert_eq!(m.on_failure(), None);
        assert_eq!(m.on_failure(), None);
        assert!(m.available());
        // A success resets the consecutive-failure run.
        assert_eq!(m.on_success(), None);
        assert_eq!(m.on_failure(), None);
        assert_eq!(m.on_failure(), None);
        assert_eq!(m.state(), HealthState::Up);
    }

    #[test]
    fn threshold_failures_open_the_circuit() {
        let mut m = HealthMachine::new(3, 2);
        m.on_failure();
        m.on_failure();
        assert_eq!(m.on_failure(), Some(HealthState::Down));
        assert!(!m.available());
        // Further failures while Down change nothing.
        assert_eq!(m.on_failure(), None);
        assert_eq!(m.state(), HealthState::Down);
    }

    #[test]
    fn cooldown_ticks_half_open_then_trial_decides() {
        let mut m = HealthMachine::new(1, 2);
        assert_eq!(m.on_failure(), Some(HealthState::Down));
        assert_eq!(m.tick(), None);
        assert_eq!(m.tick(), Some(HealthState::HalfOpen));
        assert!(m.available(), "half-open gets the trial request");
        // Trial failure: straight back down, cooldown restarts.
        assert_eq!(m.on_failure(), Some(HealthState::Down));
        assert_eq!(m.tick(), None);
        assert_eq!(m.tick(), Some(HealthState::HalfOpen));
        // Trial success: circuit closes.
        assert_eq!(m.on_success(), Some(HealthState::Up));
        assert_eq!(m.state(), HealthState::Up);
        // Ticks while Up are no-ops.
        assert_eq!(m.tick(), None);
    }

    #[test]
    fn late_success_while_down_closes_the_circuit() {
        let mut m = HealthMachine::new(1, 10);
        m.on_failure();
        assert_eq!(m.state(), HealthState::Down);
        assert_eq!(m.on_success(), Some(HealthState::Up));
    }

    #[test]
    fn degenerate_knobs_clamp_to_one() {
        let mut m = HealthMachine::new(0, 0);
        assert_eq!(m.on_failure(), Some(HealthState::Down), "threshold clamps to 1");
        assert_eq!(m.tick(), Some(HealthState::HalfOpen), "cooldown clamps to 1");
    }
}
