//! flashroute: a replicated multi-node serving tier over flashwire
//! (DESIGN.md §18).
//!
//! `flashkat route` binds ONE front port and fans client traffic out
//! across N backend `serve-wire` processes.  The tier exists for the
//! same reason the paper's kernels do: throughput past what one node's
//! memory bandwidth can serve, without changing what any byte means —
//! the router relays infer payloads and replies *verbatim*, so the
//! bit-identity gate (`serve-bench --nodes N`) holds through the hop by
//! construction.
//!
//! Four pieces, each independently testable:
//!
//! - [`ring`] — deterministic consistent-hash ring keyed by model name:
//!   near-uniform balance, ~1/N remapping on membership change, and a
//!   total failover order ([`HashRing::successors`]) per key.
//! - [`health`] — per-backend circuit breaker (Up → Down on consecutive
//!   failures, Down → HalfOpen after a probe-tick cooldown, one trial
//!   decides), a pure value driven by the prober and by forwarding
//!   outcomes.
//! - [`pool`] — keep-alive [`crate::wire::WireClient`] pools per
//!   backend with poison-aware checkout and reconnect-on-checkout.
//! - [`server`] — the frontend: protocol-sniffing accept path (flashwire
//!   magic vs HTTP on the same port), failover forwarding that honors
//!   the typed `queue-full`/`draining` shed taxonomy, a Ping prober, a
//!   merged stats view, `flashkat_route_*` Prometheus counters, and
//!   "route-N" Perfetto tracks.

pub mod health;
pub mod pool;
pub mod ring;
pub mod server;

pub use health::{HealthMachine, HealthState};
pub use pool::BackendPool;
pub use ring::HashRing;
pub use server::{
    RouteDrainStats, RouteMetrics, RouteOptions, RoutePolicy, RouteServer,
};
