//! Consistent-hash ring over backend indices (DESIGN.md §18).
//!
//! Each backend owns [`VNODES`] pseudo-random points on a `u64` ring;
//! a key routes to the backend owning the first point at or clockwise
//! of the key's own hash.  The classic consequences, both proven by the
//! property tests below:
//!
//! - **Balance**: with enough virtual nodes, every backend owns a
//!   near-equal arc of the ring, so random keys spread near-uniformly.
//! - **Minimal remapping**: adding or removing one backend only moves
//!   the keys whose successor point belonged to that backend — an
//!   expected `1/N` of the keyspace — while every other key keeps its
//!   assignment.  That is what makes the router's model → backend map
//!   stable across membership changes (a rehash-everything scheme would
//!   dump every model's warm batcher state on every join).
//!
//! The hash is FNV-1a/64 finished with a splitmix64 mix — deterministic
//! across runs and platforms (no `RandomState`), which the bit-identity
//! discipline requires: the same seeded workload must route the same
//! way on every machine.

/// Virtual nodes per backend.  64 keeps the worst observed share within
/// ~2x of fair for small clusters (see `keys_balance_across_backends`)
/// at a ring size of `64 * N` points — binary-searched, so lookup cost
/// is log2(64N).
pub const VNODES: usize = 64;

/// FNV-1a 64-bit over `s`, finished with splitmix64.  FNV alone is weak
/// in its low bits for short suffix-varying strings (exactly our
/// `"backend-3#17"` vnode labels); the splitmix finisher avalanches
/// every input bit across the word.
fn hash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in s.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    // splitmix64 finisher.
    h = h.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = h;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// The ring: `(point, backend)` pairs sorted by point.
#[derive(Clone, Debug)]
pub struct HashRing {
    points: Vec<(u64, usize)>,
    backends: usize,
}

impl HashRing {
    /// A ring over backends `0..backends`.  An empty ring is legal
    /// (routes nothing) so callers can build before discovery.
    pub fn new(backends: usize) -> HashRing {
        let mut points = Vec::with_capacity(backends * VNODES);
        for b in 0..backends {
            for v in 0..VNODES {
                points.push((hash(&format!("backend-{b}#{v}")), b));
            }
        }
        // Ties (a 64-bit collision) are broken by backend index purely
        // for determinism; they are astronomically unlikely.
        points.sort_unstable();
        HashRing { points, backends }
    }

    /// Number of backends on the ring.
    pub fn backends(&self) -> usize {
        self.backends
    }

    pub fn is_empty(&self) -> bool {
        self.backends == 0
    }

    /// The backend owning `key`, or `None` on an empty ring.
    pub fn route(&self, key: &str) -> Option<usize> {
        self.successors(key).first().copied()
    }

    /// Every backend in ring order starting at `key`'s owner — the
    /// failover sequence: the router tries index 0, then 1, ... so a
    /// dead owner's keys land deterministically on the next arc.
    pub fn successors(&self, key: &str) -> Vec<usize> {
        if self.points.is_empty() {
            return Vec::new();
        }
        let h = hash(key);
        let start = self.points.partition_point(|&(p, _)| p < h) % self.points.len();
        let mut seen = vec![false; self.backends];
        let mut out = Vec::with_capacity(self.backends);
        for i in 0..self.points.len() {
            let (_, b) = self.points[(start + i) % self.points.len()];
            if !seen[b] {
                seen[b] = true;
                out.push(b);
                if out.len() == self.backends {
                    break;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Random plausible model names, deterministic per seed.
    fn names(seed: u64, n: usize) -> Vec<String> {
        let mut rng = Pcg64::new(seed);
        (0..n)
            .map(|i| {
                let len = 3 + rng.below(12) as usize;
                let tail: String = (0..len)
                    .map(|_| char::from(b'a' + rng.below(26) as u8))
                    .collect();
                format!("{tail}-{i}")
            })
            .collect()
    }

    #[test]
    fn keys_balance_across_backends() {
        // Property: over random model-name sets, every backend's share
        // stays within [mean/2, 2*mean] — the bound VNODES buys.
        for seed in [3, 17, 92] {
            for backends in [2usize, 3, 5, 8] {
                let ring = HashRing::new(backends);
                let keys = names(seed, 8000);
                let mut counts = vec![0usize; backends];
                for k in &keys {
                    counts[ring.route(k).unwrap()] += 1;
                }
                let mean = keys.len() / backends;
                for (b, &c) in counts.iter().enumerate() {
                    assert!(
                        c >= mean / 2 && c <= mean * 2,
                        "seed {seed}: backend {b}/{backends} got {c} of {} keys (mean {mean})",
                        keys.len()
                    );
                }
            }
        }
    }

    #[test]
    fn join_moves_only_about_one_nth_of_keys() {
        // Property: growing N → N+1 backends moves ~1/(N+1) of keys —
        // all onto the new backend — and every unmoved key keeps its
        // owner exactly.
        for n in [2usize, 4, 7] {
            let before = HashRing::new(n);
            let after = HashRing::new(n + 1);
            let keys = names(41, 6000);
            let mut moved = 0usize;
            for k in &keys {
                let (a, b) = (before.route(k).unwrap(), after.route(k).unwrap());
                if a != b {
                    moved += 1;
                    assert_eq!(b, n, "a moved key must land on the joining backend");
                }
            }
            let expected = keys.len() / (n + 1);
            assert!(
                moved <= expected * 2,
                "join {n}->{}: {moved} keys moved, expected ~{expected}",
                n + 1
            );
            assert!(moved >= expected / 3, "join {n}->{}: only {moved} moved", n + 1);
        }
    }

    #[test]
    fn leave_strands_only_the_leavers_keys() {
        // Property: shrinking N → N-1 (dropping the last backend) only
        // remaps keys the leaver owned; survivors keep every key.
        for n in [3usize, 5, 8] {
            let before = HashRing::new(n);
            let after = HashRing::new(n - 1);
            let keys = names(77, 6000);
            let mut remapped = 0usize;
            for k in &keys {
                let a = before.route(k).unwrap();
                let b = after.route(k).unwrap();
                if a == n - 1 {
                    remapped += 1;
                    assert_ne!(b, n - 1);
                } else {
                    assert_eq!(a, b, "a survivor's key must not move on leave");
                }
            }
            let expected = keys.len() / n;
            assert!(
                remapped <= expected * 2 && remapped >= expected / 3,
                "leave {n}->{}: {remapped} keys remapped, expected ~{expected}",
                n - 1
            );
        }
    }

    #[test]
    fn successors_start_at_the_owner_and_cover_every_backend() {
        let ring = HashRing::new(5);
        for k in names(9, 200) {
            let succ = ring.successors(&k);
            assert_eq!(succ.len(), 5);
            assert_eq!(succ[0], ring.route(&k).unwrap());
            let mut sorted = succ.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3, 4], "distinct cover of all backends");
        }
    }

    #[test]
    fn empty_and_single_backend_rings_are_well_defined() {
        assert!(HashRing::new(0).route("m").is_none());
        assert!(HashRing::new(0).successors("m").is_empty());
        let one = HashRing::new(1);
        assert_eq!(one.route("anything"), Some(0));
        assert_eq!(one.successors("anything"), vec![0]);
    }

    #[test]
    fn routing_is_deterministic_across_ring_instances() {
        let a = HashRing::new(4);
        let b = HashRing::new(4);
        for k in names(123, 500) {
            assert_eq!(a.route(&k), b.route(&k));
            assert_eq!(a.successors(&k), b.successors(&k));
        }
    }
}
