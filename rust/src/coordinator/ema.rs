//! Exponential moving average of model parameters (paper: decay 0.9999).
//!
//! Kept on the host (L3) — the coordinator owns parameter lifecycle; the
//! device graph only computes the step.

use anyhow::{bail, Result};

use crate::runtime::HostTensor;

pub struct Ema {
    pub decay: f32,
    shadow: Vec<HostTensor>,
}

impl Ema {
    pub fn new(params: &[HostTensor], decay: f32) -> Self {
        Self { decay, shadow: params.to_vec() }
    }

    /// shadow = decay*shadow + (1-decay)*params  (f32 leaves only).
    pub fn update(&mut self, params: &[HostTensor]) -> Result<()> {
        if params.len() != self.shadow.len() {
            bail!("EMA: {} leaves vs shadow {}", params.len(), self.shadow.len());
        }
        let d = self.decay;
        for (s, p) in self.shadow.iter_mut().zip(params) {
            let (s_data, p_data) = (s.as_f32_mut()?, p.as_f32()?);
            if s_data.len() != p_data.len() {
                bail!("EMA leaf size mismatch");
            }
            for (a, &b) in s_data.iter_mut().zip(p_data) {
                *a = d * *a + (1.0 - d) * b;
            }
        }
        Ok(())
    }

    pub fn shadow(&self) -> &[HostTensor] {
        &self.shadow
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn leaf(v: f32, n: usize) -> HostTensor {
        HostTensor::F32 { shape: vec![n], data: vec![v; n] }
    }

    #[test]
    fn ema_tracks_target() {
        let init = vec![leaf(0.0, 4)];
        let mut ema = Ema::new(&init, 0.99);
        let target = vec![leaf(1.0, 4)];
        for _ in 0..1000 {
            ema.update(&target).unwrap();
        }
        let v = ema.shadow()[0].as_f32().unwrap()[0];
        assert!((v - 1.0).abs() < 1e-3, "{v}");
    }

    #[test]
    fn single_update_formula() {
        let mut ema = Ema::new(&[leaf(1.0, 1)], 0.9);
        ema.update(&[leaf(2.0, 1)]).unwrap();
        let v = ema.shadow()[0].as_f32().unwrap()[0];
        assert!((v - 1.1).abs() < 1e-6);
    }

    #[test]
    fn mismatched_leaves_error() {
        let mut ema = Ema::new(&[leaf(0.0, 2)], 0.9);
        assert!(ema.update(&[leaf(0.0, 2), leaf(0.0, 2)]).is_err());
        assert!(ema.update(&[leaf(0.0, 3)]).is_err());
    }
}
