//! Data augmentation (paper Table 7 recipe): label smoothing, Mixup,
//! CutMix (with 0.5 switch probability), and Random Erasing.  All operate
//! on flat (B,H,W,C) image buffers and produce *soft* label distributions,
//! which is why the L2 loss takes a full distribution per sample.

use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct AugmentConfig {
    pub n_classes: usize,
    pub img_size: usize,
    pub channels: usize,
    pub label_smoothing: f64,
    pub mixup_alpha: f64,
    pub cutmix_alpha: f64,
    /// Probability of choosing CutMix over Mixup when mixing (paper: 0.5).
    pub switch_prob: f64,
    /// Probability of applying any mix at all.
    pub mix_prob: f64,
    pub erase_prob: f64,
}

impl AugmentConfig {
    pub fn from_paper(n_classes: usize, img_size: usize) -> Self {
        Self {
            n_classes,
            img_size,
            channels: 3,
            label_smoothing: 0.1,
            mixup_alpha: 0.8,
            cutmix_alpha: 1.0,
            switch_prob: 0.5,
            mix_prob: 1.0,
            erase_prob: 0.25,
        }
    }

    fn img_elems(&self) -> usize {
        self.img_size * self.img_size * self.channels
    }
}

/// Smooth hard labels into a distribution: 1-eps on the target,
/// eps/(K-1) elsewhere.
pub fn smooth_labels(labels: &[usize], n_classes: usize, eps: f64) -> Vec<f32> {
    let off = (eps / (n_classes - 1) as f64) as f32;
    let on = (1.0 - eps) as f32;
    let mut out = vec![off; labels.len() * n_classes];
    for (b, &y) in labels.iter().enumerate() {
        debug_assert!(y < n_classes);
        out[b * n_classes + y] = on;
    }
    out
}

/// Mixup (Zhang et al. 2017): convex combination of sample pairs.
/// Pairs sample b with `perm[b]`; labels mix with the same lambda.
pub fn mixup(
    images: &mut [f32],
    soft_labels: &mut [f32],
    n_classes: usize,
    img_elems: usize,
    perm: &[usize],
    lam: f32,
) {
    let b = perm.len();
    let src_img = images.to_vec();
    let src_lab = soft_labels.to_vec();
    for i in 0..b {
        let j = perm[i];
        for k in 0..img_elems {
            images[i * img_elems + k] =
                lam * src_img[i * img_elems + k] + (1.0 - lam) * src_img[j * img_elems + k];
        }
        for k in 0..n_classes {
            soft_labels[i * n_classes + k] =
                lam * src_lab[i * n_classes + k] + (1.0 - lam) * src_lab[j * n_classes + k];
        }
    }
}

/// CutMix (Yun et al. 2019): paste a random rectangle from the paired
/// sample; label weight = pasted-area fraction.  Returns the box used.
#[allow(clippy::too_many_arguments)]
pub fn cutmix(
    images: &mut [f32],
    soft_labels: &mut [f32],
    n_classes: usize,
    img_size: usize,
    channels: usize,
    perm: &[usize],
    lam: f32,
    rng: &mut Pcg64,
) -> (usize, usize, usize, usize) {
    let b = perm.len();
    let img_elems = img_size * img_size * channels;
    // Box with area (1-lam), centered uniformly (the paper's recipe).
    let cut = ((1.0 - lam) as f64).sqrt();
    let ch = ((img_size as f64 * cut).round() as usize).min(img_size);
    let cw = ch;
    let cy = rng.below(img_size.max(1));
    let cx = rng.below(img_size.max(1));
    let y0 = cy.saturating_sub(ch / 2);
    let y1 = (cy + ch.div_ceil(2)).min(img_size);
    let x0 = cx.saturating_sub(cw / 2);
    let x1 = (cx + cw.div_ceil(2)).min(img_size);
    let area = ((y1 - y0) * (x1 - x0)) as f32;
    let lam_adj = 1.0 - area / (img_size * img_size) as f32;

    let src_img = images.to_vec();
    let src_lab = soft_labels.to_vec();
    for i in 0..b {
        let j = perm[i];
        for y in y0..y1 {
            for x in x0..x1 {
                for c in 0..channels {
                    let off = (y * img_size + x) * channels + c;
                    images[i * img_elems + off] = src_img[j * img_elems + off];
                }
            }
        }
        for k in 0..n_classes {
            soft_labels[i * n_classes + k] = lam_adj * src_lab[i * n_classes + k]
                + (1.0 - lam_adj) * src_lab[j * n_classes + k];
        }
    }
    (y0, y1, x0, x1)
}

/// Random Erasing (Zhong et al. 2020): per-image, with probability p,
/// replace a random rectangle with Gaussian noise.
pub fn random_erase(
    images: &mut [f32],
    batch: usize,
    img_size: usize,
    channels: usize,
    prob: f64,
    rng: &mut Pcg64,
) -> usize {
    let img_elems = img_size * img_size * channels;
    let mut erased = 0;
    for i in 0..batch {
        if !rng.bernoulli(prob) {
            continue;
        }
        erased += 1;
        let area = rng.uniform_range(0.02, 0.33);
        let aspect = rng.uniform_range(0.3, 3.3);
        let h = (((img_size * img_size) as f64 * area * aspect).sqrt().round() as usize)
            .clamp(1, img_size);
        let w = (((img_size * img_size) as f64 * area / aspect).sqrt().round() as usize)
            .clamp(1, img_size);
        let y0 = rng.below(img_size - h + 1);
        let x0 = rng.below(img_size - w + 1);
        for y in y0..y0 + h {
            for x in x0..x0 + w {
                for c in 0..channels {
                    images[i * img_elems + (y * img_size + x) * channels + c] =
                        rng.normal_f32();
                }
            }
        }
    }
    erased
}

/// Apply the paper's full augmentation recipe to a batch in place;
/// returns the soft labels.
pub fn apply(
    cfg: &AugmentConfig,
    images: &mut [f32],
    labels: &[usize],
    rng: &mut Pcg64,
) -> Vec<f32> {
    let b = labels.len();
    let mut soft = smooth_labels(labels, cfg.n_classes, cfg.label_smoothing);

    if b > 1 && rng.bernoulli(cfg.mix_prob) {
        let mut perm: Vec<usize> = (0..b).collect();
        rng.shuffle(&mut perm);
        if rng.bernoulli(cfg.switch_prob) {
            let lam = rng.beta_symmetric(cfg.cutmix_alpha) as f32;
            cutmix(
                images,
                &mut soft,
                cfg.n_classes,
                cfg.img_size,
                cfg.channels,
                &perm,
                lam,
                rng,
            );
        } else {
            let lam = rng.beta_symmetric(cfg.mixup_alpha) as f32;
            mixup(images, &mut soft, cfg.n_classes, cfg.img_elems(), &perm, lam);
        }
    }
    random_erase(images, b, cfg.img_size, cfg.channels, cfg.erase_prob, rng);
    soft
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoothing_sums_to_one() {
        let soft = smooth_labels(&[0, 3], 5, 0.1);
        for b in 0..2 {
            let s: f32 = soft[b * 5..(b + 1) * 5].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        assert!((soft[0] - 0.9).abs() < 1e-6);
        assert!((soft[1] - 0.025).abs() < 1e-6);
        assert!((soft[5 + 3] - 0.9).abs() < 1e-6);
    }

    #[test]
    fn mixup_preserves_label_mass_and_mixes_pixels() {
        let mut images = vec![0.0f32; 2 * 4]; // 2 samples, 4 "pixels"
        images[4..].fill(1.0);
        let mut soft = smooth_labels(&[0, 1], 2, 0.0);
        mixup(&mut images, &mut soft, 2, 4, &[1, 0], 0.25);
        // sample 0 = 0.25*zeros + 0.75*ones
        assert!((images[0] - 0.75).abs() < 1e-6);
        assert!((images[4] - 0.25).abs() < 1e-6);
        for b in 0..2 {
            let s: f32 = soft[b * 2..(b + 1) * 2].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        assert!((soft[0] - 0.25).abs() < 1e-6); // P(class0 | sample0)
    }

    #[test]
    fn cutmix_label_weight_matches_area() {
        let mut rng = Pcg64::new(3);
        let img_size = 8;
        let mut images = vec![0.0f32; 2 * 8 * 8 * 1];
        images[64..].fill(1.0);
        let mut soft = smooth_labels(&[0, 1], 2, 0.0);
        let (y0, y1, x0, x1) =
            cutmix(&mut images, &mut soft, 2, img_size, 1, &[1, 0], 0.5, &mut rng);
        let area = ((y1 - y0) * (x1 - x0)) as f32 / 64.0;
        // sample 0's pasted pixels came from sample 1 (ones)
        let pasted: f32 = images[..64].iter().sum();
        assert!((pasted - area * 64.0).abs() < 1e-4);
        assert!((soft[1] - area).abs() < 1e-5); // P(class1 | sample0)
    }

    #[test]
    fn erase_respects_probability_extremes() {
        let mut rng = Pcg64::new(5);
        let mut images = vec![0.5f32; 4 * 8 * 8 * 3];
        assert_eq!(random_erase(&mut images, 4, 8, 3, 0.0, &mut rng), 0);
        assert!(images.iter().all(|&v| v == 0.5));
        let n = random_erase(&mut images, 4, 8, 3, 1.0, &mut rng);
        assert_eq!(n, 4);
        assert!(images.iter().any(|&v| v != 0.5));
    }

    #[test]
    fn apply_full_recipe_outputs_valid_distributions() {
        let cfg = AugmentConfig::from_paper(10, 8);
        let mut rng = Pcg64::new(7);
        let mut images = vec![0.1f32; 4 * 8 * 8 * 3];
        let soft = apply(&cfg, &mut images, &[0, 1, 2, 3], &mut rng);
        assert_eq!(soft.len(), 4 * 10);
        for b in 0..4 {
            let s: f32 = soft[b * 10..(b + 1) * 10].iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "{s}");
            assert!(soft[b * 10..(b + 1) * 10].iter().all(|&p| p >= 0.0));
        }
        assert!(images.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn apply_without_mixing_keeps_smoothed_labels() {
        let cfg = AugmentConfig {
            mix_prob: 0.0,
            erase_prob: 0.0,
            ..AugmentConfig::from_paper(5, 4)
        };
        let mut rng = Pcg64::new(11);
        let mut images = vec![0.0f32; 2 * 4 * 4 * 3];
        let soft = apply(&cfg, &mut images, &[2, 4], &mut rng);
        assert_eq!(soft, smooth_labels(&[2, 4], 5, 0.1));
    }
}
