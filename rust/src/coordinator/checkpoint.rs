//! Checkpointing: parameters + step to a simple self-describing binary
//! format (magic, version, tensor table).  No external serde available in
//! this environment, so the format is defined here and round-trip tested.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::HostTensor;

const MAGIC: &[u8; 8] = b"FLSHKAT\x01";

/// Upper bounds on header-declared sizes.  Every length in the header is
/// corruption- (or attacker-) controlled until the payload reads succeed,
/// so nothing from the header may reach an allocation or a multiplication
/// unchecked: a forged dim table must fail with an error, not a huge
/// `Vec` reservation or an overflow panic.
const MAX_LEAVES: usize = 1 << 20;
/// Max elements per tensor leaf (2^28 f32 = 1 GiB of payload).
const MAX_ELEMS: usize = 1 << 28;

pub struct Checkpoint {
    pub step: u64,
    pub params: Vec<(String, HostTensor)>,
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = std::io::BufWriter::new(f);
        w.write_all(MAGIC)?;
        write_u64(&mut w, self.step)?;
        write_u64(&mut w, self.params.len() as u64)?;
        for (name, t) in &self.params {
            let data = t.as_f32().context("checkpoint supports f32 leaves")?;
            write_u64(&mut w, name.len() as u64)?;
            w.write_all(name.as_bytes())?;
            write_u64(&mut w, t.shape().len() as u64)?;
            for &d in t.shape() {
                write_u64(&mut w, d as u64)?;
            }
            for &v in data {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let f =
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let mut r = std::io::BufReader::new(f);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{} is not a FlashKAT checkpoint", path.display());
        }
        let step = read_u64(&mut r)?;
        let count = read_u64(&mut r)? as usize;
        if count > MAX_LEAVES {
            bail!("corrupt checkpoint: {count} parameter leaves (max {MAX_LEAVES})");
        }
        let mut params = Vec::with_capacity(count);
        for _ in 0..count {
            let name_len = read_u64(&mut r)? as usize;
            if name_len > 1 << 16 {
                bail!("corrupt checkpoint: name length {name_len}");
            }
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let ndim = read_u64(&mut r)? as usize;
            if ndim > 16 {
                bail!("corrupt checkpoint: ndim {ndim}");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                let dim = usize::try_from(read_u64(&mut r)?)
                    .ok()
                    .filter(|&d| d <= MAX_ELEMS)
                    .with_context(|| format!("corrupt checkpoint: dim exceeds {MAX_ELEMS}"))?;
                shape.push(dim);
            }
            let n = shape
                .iter()
                .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                .filter(|&n| n <= MAX_ELEMS)
                .with_context(|| {
                    format!("corrupt checkpoint: shape {shape:?} exceeds {MAX_ELEMS} elements")
                })?;
            let bytes = n.checked_mul(4).context("corrupt checkpoint: byte count overflow")?;
            let mut data = vec![0f32; n];
            let mut buf = vec![0u8; bytes];
            r.read_exact(&mut buf)?;
            for (i, c) in buf.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            params.push((String::from_utf8(name)?, HostTensor::F32 { shape, data }));
        }
        Ok(Self { step, params })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("fk_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.ckpt");
        let ck = Checkpoint {
            step: 123,
            params: vec![
                ("a/w".into(), HostTensor::F32 { shape: vec![2, 3], data: vec![1.5; 6] }),
                ("b".into(), HostTensor::F32 { shape: vec![], data: vec![-2.0] }),
            ],
        };
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 123);
        assert_eq!(back.params.len(), 2);
        assert_eq!(back.params[0].0, "a/w");
        assert_eq!(back.params[0].1.shape(), &[2, 3]);
        assert_eq!(back.params[0].1.as_f32().unwrap(), &[1.5; 6]);
        assert_eq!(back.params[1].1.as_f32().unwrap(), &[-2.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_corrupt_dims() {
        let dir = std::env::temp_dir().join(format!("fk_ckpt_d_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("dims.ckpt");

        // Valid prologue up to one leaf named "w", then a forged dim table.
        let header = |dims: &[u64], count: u64| {
            let mut buf = Vec::new();
            buf.extend_from_slice(MAGIC);
            buf.extend_from_slice(&0u64.to_le_bytes()); // step
            buf.extend_from_slice(&count.to_le_bytes()); // leaf count
            buf.extend_from_slice(&1u64.to_le_bytes()); // name len
            buf.push(b'w');
            buf.extend_from_slice(&(dims.len() as u64).to_le_bytes());
            for &d in dims {
                buf.extend_from_slice(&d.to_le_bytes());
            }
            buf
        };

        // A single dim beyond the element bound: rejected per-dimension.
        std::fs::write(&path, header(&[1 << 30], 1)).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("corrupt checkpoint"), "{err:#}");
        std::fs::write(&path, header(&[1 << 40, 1 << 40], 1)).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("corrupt checkpoint"), "{err:#}");

        // Every dim individually legal but the product exceeds the
        // element bound: must trip the checked product, not allocate 4 GiB.
        std::fs::write(&path, header(&[1 << 15, 1 << 15], 1)).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("shape"), "{err:#}");

        // Dims legal, product overflows usize entirely: `checked_mul`
        // must catch the wrap, not fold it into a small bogus count.
        std::fs::write(&path, header(&[1 << 28, 1 << 28, 1 << 28], 1)).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("shape"), "{err:#}");

        // Absurd leaf count: rejected before `Vec::with_capacity`.
        std::fs::write(&path, header(&[2], u64::MAX)).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("parameter leaves"), "{err:#}");

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("fk_ckpt_g_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
