//! The training loop: drives the AOT `*_train_step` executable with the
//! paper's recipe and measures throughput the way Table 4 does
//! (images/second, mean ± 95% CI over step samples, loader excluded —
//! here the loader is prefetched on a worker thread and timed separately).

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::augment::{self, AugmentConfig};
use super::checkpoint::Checkpoint;
use super::ema::Ema;
use super::schedule::CosineSchedule;
use crate::config::TrainConfig;
use crate::data::loader::Prefetcher;
use crate::data::SynthSpec;
use crate::runtime::{HostTensor, LoadedModule, Runtime};
use crate::util::rng::Pcg64;
use crate::util::stats::OnlineStats;

pub struct Trainer {
    pub cfg: TrainConfig,
    init: LoadedModule,
    step_mod: LoadedModule,
    eval_mod: LoadedModule,
    /// Artifact tag, e.g. "kat_micro".
    pub tag: String,
    img_size: usize,
    n_classes: usize,
    batch: usize,
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub tag: String,
    pub steps: usize,
    pub losses: Vec<f32>,
    /// images/second, step time only (paper's metric).
    pub throughput_mean: f64,
    pub throughput_ci95: f64,
    /// Fraction of wall time spent outside device execution (marshal+aug).
    pub host_overhead: f64,
    /// Held-out accuracy of the final raw parameters.
    pub final_eval_acc: Option<f64>,
    /// Held-out accuracy of the EMA shadow.  NOTE: at the paper's decay
    /// (0.9999) the shadow needs >> 10k steps to move away from init —
    /// for short runs judge `final_eval_acc`.
    pub ema_eval_acc: Option<f64>,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }

    pub fn first_loss(&self) -> f32 {
        *self.losses.first().unwrap_or(&f32::NAN)
    }
}

/// Index of the largest logit in `row` (ties resolve to the last maximum,
/// matching `Iterator::max_by`).
///
/// A diverged model emits NaN logits; the seed compared with
/// `partial_cmp(..).unwrap()`, which panicked deep inside the comparator.
/// NaN now surfaces as an `Err` the caller can report, and finite
/// comparisons use the total order (`f32::total_cmp`), which cannot fail.
pub fn predict_top1(row: &[f32]) -> Result<usize> {
    if row.is_empty() {
        bail!("empty logit row");
    }
    if let Some(i) = row.iter().position(|v| v.is_nan()) {
        bail!("NaN logit at class {i} — model diverged?");
    }
    Ok(row
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .expect("non-empty row"))
}

impl Trainer {
    /// Load the `<tag>_init` / `<tag>_train_step` / `<tag>_eval` artifacts.
    pub fn new(rt: &Runtime, tag: &str, cfg: TrainConfig) -> Result<Self> {
        let init = rt.load(&format!("{tag}_init"))?;
        let step_mod = rt.load(&format!("{tag}_train_step"))?;
        let eval_mod = rt.load(&format!("{tag}_eval"))?;
        let n_p = init.output_count();
        if step_mod.input_count() != 3 * n_p + 5 {
            bail!(
                "{tag}: train_step has {} inputs, expected 3*{n_p}+5 (params,m,v,step,lr,key,x,y)",
                step_mod.input_count()
            );
        }
        let img_size = step_mod.manifest.meta_usize("img_size").context("img_size meta")?;
        let n_classes = step_mod.manifest.meta_usize("n_classes").context("n_classes meta")?;
        let batch = step_mod.manifest.meta_usize("batch").context("batch meta")?;
        Ok(Self { cfg, init, step_mod, eval_mod, tag: tag.to_string(), img_size, n_classes, batch })
    }

    pub fn param_leaves(&self) -> usize {
        self.init.output_count()
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Initialize parameters on device (executes the `_init` artifact) and
    /// zeroed optimizer state.
    pub fn init_state(&self) -> Result<(Vec<HostTensor>, Vec<HostTensor>, Vec<HostTensor>)> {
        let params = self.init.execute(&[])?;
        let zeros: Vec<HostTensor> = self
            .init
            .manifest
            .outputs
            .iter()
            .map(HostTensor::zeros)
            .collect::<Result<_>>()?;
        Ok((params, zeros.clone(), zeros))
    }

    /// One optimizer step; returns (new params, m, v, loss).
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        params: Vec<HostTensor>,
        m: Vec<HostTensor>,
        v: Vec<HostTensor>,
        step: i32,
        lr: f32,
        key: [u32; 2],
        images: Vec<f32>,
        soft_labels: Vec<f32>,
    ) -> Result<(Vec<HostTensor>, Vec<HostTensor>, Vec<HostTensor>, f32)> {
        let n_p = self.param_leaves();
        let mut inputs = Vec::with_capacity(3 * n_p + 5);
        inputs.extend(params);
        inputs.extend(m);
        inputs.extend(v);
        inputs.push(HostTensor::scalar_i32(step));
        inputs.push(HostTensor::scalar_f32(lr));
        inputs.push(HostTensor::key(key));
        inputs.push(HostTensor::F32 {
            shape: vec![self.batch, self.img_size, self.img_size, 3],
            data: images,
        });
        inputs.push(HostTensor::F32 { shape: vec![self.batch, self.n_classes], data: soft_labels });

        let mut outs = self.step_mod.execute(&inputs)?;
        let loss = match outs.pop().context("loss output")? {
            HostTensor::F32 { data, .. } => data[0],
            other => bail!("loss has dtype {:?}", other.dtype()),
        };
        let v_new = outs.split_off(2 * n_p);
        let m_new = outs.split_off(n_p);
        Ok((outs, m_new, v_new, loss))
    }

    /// Top-1 accuracy of `params` on `n_batches` held-out synthetic batches.
    ///
    /// The dataset seed must match training (it defines the *classes*:
    /// blob layouts and textures); held-out-ness comes from a sample-index
    /// range no training run can reach.
    pub fn evaluate(&self, params: &[HostTensor], n_batches: usize) -> Result<f64> {
        const HELD_OUT_BASE: u64 = 1 << 40;
        let eval_batch = self.eval_mod.manifest.meta_usize("batch").context("eval batch")?;
        let ds = crate::data::SynthDataset::new(SynthSpec {
            img_size: self.img_size,
            n_classes: self.n_classes,
            seed: self.cfg.seed,
            ..Default::default()
        });
        let mut correct = 0usize;
        let mut total = 0usize;
        for bi in 0..n_batches {
            let (images, labels) = ds.batch(HELD_OUT_BASE + (bi * eval_batch) as u64, eval_batch);
            let mut inputs: Vec<HostTensor> = params.to_vec();
            inputs.push(HostTensor::F32 {
                shape: vec![eval_batch, self.img_size, self.img_size, 3],
                data: images,
            });
            let outs = self.eval_mod.execute(&inputs)?;
            let logits = outs[0].as_f32()?;
            for (b, &y) in labels.iter().enumerate() {
                let row = &logits[b * self.n_classes..(b + 1) * self.n_classes];
                let pred = predict_top1(row)
                    .with_context(|| format!("{}: eval batch {bi} sample {b}", self.tag))?;
                correct += usize::from(pred == y);
                total += 1;
            }
        }
        Ok(correct as f64 / total as f64)
    }

    /// Run the full training loop.  `ckpt_path` saves final params if set.
    pub fn train(&self, ckpt_path: Option<&Path>) -> Result<TrainReport> {
        let cfg = &self.cfg;
        let sched = CosineSchedule::new(cfg.base_lr, cfg.warmup_steps, cfg.steps);
        let aug = AugmentConfig {
            label_smoothing: cfg.label_smoothing,
            mixup_alpha: cfg.mixup_alpha,
            cutmix_alpha: cfg.cutmix_alpha,
            switch_prob: cfg.mix_switch_prob,
            erase_prob: cfg.erase_prob,
            ..AugmentConfig::from_paper(self.n_classes, self.img_size)
        };
        let mut rng = Pcg64::new(cfg.seed);
        let prefetch = Prefetcher::new(
            SynthSpec { img_size: self.img_size, n_classes: self.n_classes, seed: cfg.seed, ..Default::default() },
            self.batch,
            2,
        );

        let (mut params, mut m, mut v) = self.init_state()?;
        let mut ema = Ema::new(&params, cfg.ema_decay as f32);

        let mut losses = Vec::with_capacity(cfg.steps);
        let mut thp = OnlineStats::new();
        let mut host_secs = 0.0f64;
        let mut total_secs = 0.0f64;

        for step in 1..=cfg.steps {
            let t_host = Instant::now();
            let mut batch = prefetch.next();
            let soft = augment::apply(&aug, &mut batch.images, &batch.labels, &mut rng);
            let lr = sched.lr(step) as f32;
            let key = [rng.next_u32(), rng.next_u32()];
            host_secs += t_host.elapsed().as_secs_f64();

            let t_step = Instant::now();
            let (p2, m2, v2, loss) =
                self.step(params, m, v, step as i32, lr, key, batch.images, soft)?;
            let dt = t_step.elapsed().as_secs_f64();
            total_secs += dt;
            thp.push(self.batch as f64 / dt);

            params = p2;
            m = m2;
            v = v2;
            if !loss.is_finite() {
                bail!("{}: loss diverged at step {step}", self.tag);
            }
            losses.push(loss);

            let t_host = Instant::now();
            ema.update(&params)?;
            host_secs += t_host.elapsed().as_secs_f64();

            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                eprintln!(
                    "[{}] step {step:>5}/{} loss {loss:.4} lr {lr:.2e} {:.1} img/s",
                    self.tag,
                    cfg.steps,
                    self.batch as f64 / dt
                );
            }
        }

        let final_eval_acc = Some(self.evaluate(&params, 4)?);
        let ema_eval_acc = Some(self.evaluate(ema.shadow(), 4)?);

        if let Some(path) = ckpt_path {
            let named: Vec<(String, HostTensor)> = self
                .init
                .manifest
                .outputs
                .iter()
                .zip(ema.shadow())
                .map(|(s, t)| (s.name.clone(), t.clone()))
                .collect();
            Checkpoint { step: cfg.steps as u64, params: named }.save(path)?;
        }

        Ok(TrainReport {
            tag: self.tag.clone(),
            steps: cfg.steps,
            losses,
            throughput_mean: thp.mean(),
            throughput_ci95: thp.ci95(),
            host_overhead: host_secs / (host_secs + total_secs).max(1e-9),
            final_eval_acc,
            ema_eval_acc,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predict_top1_picks_max() {
        assert_eq!(predict_top1(&[0.1, 3.0, -2.0]).unwrap(), 1);
        assert_eq!(predict_top1(&[5.0]).unwrap(), 0);
        // Infinities are ordinary values under total_cmp.
        assert_eq!(predict_top1(&[f32::NEG_INFINITY, -1.0]).unwrap(), 1);
        assert_eq!(predict_top1(&[2.0, f32::INFINITY, 3.0]).unwrap(), 1);
        // Ties resolve to the last maximum (max_by semantics).
        assert_eq!(predict_top1(&[1.0, 1.0, 0.0]).unwrap(), 1);
    }

    #[test]
    fn predict_top1_nan_is_error_not_panic() {
        // Regression: the seed panicked inside the comparator on NaN
        // logits from a diverged model; it must be a reportable error.
        assert!(predict_top1(&[0.0, f32::NAN, 1.0]).is_err());
        assert!(predict_top1(&[f32::NAN]).is_err());
        assert!(predict_top1(&[]).is_err());
        let err = predict_top1(&[f32::NAN, 0.5]).unwrap_err();
        assert!(format!("{err}").contains("NaN logit"), "{err}");
    }
}
