//! Learning-rate schedule: linear warmup then cosine decay (paper Table 7).

#[derive(Clone, Copy, Debug)]
pub struct CosineSchedule {
    pub base_lr: f64,
    pub warmup_steps: usize,
    pub total_steps: usize,
    pub min_lr: f64,
}

impl CosineSchedule {
    pub fn new(base_lr: f64, warmup_steps: usize, total_steps: usize) -> Self {
        Self { base_lr, warmup_steps, total_steps, min_lr: base_lr * 1e-2 }
    }

    /// LR at 1-based step `step`.
    pub fn lr(&self, step: usize) -> f64 {
        if self.total_steps == 0 {
            return self.base_lr;
        }
        if step <= self.warmup_steps && self.warmup_steps > 0 {
            return self.base_lr * step as f64 / self.warmup_steps as f64;
        }
        let t = (step - self.warmup_steps) as f64;
        let total = (self.total_steps.saturating_sub(self.warmup_steps)).max(1) as f64;
        let frac = (t / total).min(1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * frac).cos());
        self.min_lr + (self.base_lr - self.min_lr) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = CosineSchedule::new(1e-3, 10, 100);
        assert!((s.lr(1) - 1e-4).abs() < 1e-12);
        assert!((s.lr(5) - 5e-4).abs() < 1e-12);
        assert!((s.lr(10) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn cosine_decays_to_min() {
        let s = CosineSchedule::new(1e-3, 10, 100);
        assert!(s.lr(11) > s.lr(50));
        assert!(s.lr(50) > s.lr(99));
        assert!((s.lr(100) - s.min_lr).abs() < 1e-9);
        // beyond the horizon stays at min
        assert!((s.lr(200) - s.min_lr).abs() < 1e-9);
    }

    #[test]
    fn halfway_is_half_amplitude() {
        let s = CosineSchedule::new(2e-3, 0, 100);
        let mid = s.lr(50);
        let expect = s.min_lr + (2e-3 - s.min_lr) * 0.5;
        assert!((mid - expect).abs() < 1e-9);
    }

    #[test]
    fn zero_warmup_no_nan() {
        let s = CosineSchedule::new(1e-3, 0, 10);
        for step in 1..=10 {
            assert!(s.lr(step).is_finite());
        }
    }
}
