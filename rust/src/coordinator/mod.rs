//! L3 training coordinator: the orchestration layer that drives the AOT
//! train-step executable with the paper's full training recipe — data
//! pipeline, Mixup/CutMix/Random-Erasing augmentation producing soft
//! labels, label smoothing, cosine LR schedule with warmup, EMA of
//! parameters, checkpointing, and throughput metrics with 95% CIs
//! (paper Tables 4/7).

pub mod augment;
pub mod checkpoint;
pub mod ema;
pub mod schedule;
pub mod trainer;

pub use trainer::{TrainReport, Trainer};
