//! Per-request span tracing for the serving stack (DESIGN.md §15).
//!
//! FlashKAT's kernel-level lesson was that aggregate counters hid the
//! real bottleneck until time was *attributed* — the same applies one
//! level up.  BENCH_serve's p50/p99 histograms say how slow requests
//! were, not where the time went; this module gives every request an
//! explicit [`SpanCtx`] minted at its admission point, threads it
//! through batching and execution, and renders the result as a
//! [Perfetto](https://ui.perfetto.dev) trace: one track per shard with
//! a slice per executed batch (annotated with flush cause and size),
//! a companion track with a slice per request, and one track per
//! network handler thread.
//!
//! The collector is deliberately lock-light so tracing cannot perturb
//! the p99 it is measuring: every track has exactly one writer thread,
//! events land in that track's own fixed-capacity ring behind an
//! uncontended `Mutex`, and rendering happens once, at shutdown, off
//! the hot path.  When the ring fills, new events are dropped and
//! counted — a bounded trace beats an unbounded stall.

pub mod perfetto;

pub use perfetto::{stat, stat_by_track, TraceStat};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// Per-track event capacity.  At ~100 bytes/event this bounds a track
/// at a few MB; serve-bench's default 2000-request runs use a fraction
/// of it, and overflow drops (counted) rather than blocks.
pub const TRACK_CAPACITY: usize = 1 << 16;

/// Per-request span context, minted at the admission point (in-process
/// `submit*`, the HTTP infer route, or the wire infer handler) and
/// carried with the request through batching and execution.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanCtx {
    /// Globally unique across shards and transports for one collector.
    pub span_id: u64,
    /// Mint time on the collector's clock (µs since its epoch).
    pub t_admit_us: u64,
    pub model: String,
    pub rows: u32,
}

/// Where one request's time went, on the serving clock (µs).  Recorded
/// on every [`crate::serve::Response`] whether or not a trace collector
/// is attached — the marks are four monotonic-clock reads per batch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Timing {
    /// Admission (batcher enqueue) to batch release.
    pub queue_wait_us: u64,
    /// Batch release to executor call (input assembly).
    pub batch_form_us: u64,
    /// Executor call duration (shared by all requests of the batch).
    pub exec_us: u64,
    /// Executor return to this request's reply send.
    pub reply_us: u64,
}

/// Handle to one registered track (index into the collector).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TrackId(pub usize);

/// One annotation value on a slice.
#[derive(Clone, Debug, PartialEq)]
pub enum AnnValue {
    U64(u64),
    Str(String),
}

/// One slice on one track: `[t0_us, t1_us]` on the collector's clock,
/// with debug annotations.  Slices recorded on a track must nest or be
/// disjoint (each track has a single writer working serially), which
/// is what lets [`perfetto::render`] lay them out as a slice stack.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub track: TrackId,
    pub name: String,
    pub t0_us: u64,
    pub t1_us: u64,
    pub args: Vec<(&'static str, AnnValue)>,
}

struct Ring {
    events: Vec<TraceEvent>,
    dropped: u64,
}

struct TrackBuf {
    name: String,
    ring: Mutex<Ring>,
}

/// Handle to one registered counter track (index into the collector's
/// counter registry — a separate id space from [`TrackId`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CounterId(pub usize);

struct CounterRing {
    /// `(t_us, value)` samples; rendered as Perfetto TYPE_COUNTER events.
    samples: Vec<(u64, u64)>,
    dropped: u64,
}

struct CounterBuf {
    name: String,
    ring: Mutex<CounterRing>,
}

/// Ring-buffered trace collector shared by the server shards and the
/// network handler threads.  Also owns the span-id counter and the
/// clock epoch, so span ids are unique across every admission point
/// and all timestamps are comparable.
pub struct TraceCollector {
    epoch: Instant,
    next_span: AtomicU64,
    /// Tracks are registered up-front (server start / frontend bind);
    /// recording takes the read side, so concurrent writers on
    /// different tracks never contend with each other.
    tracks: RwLock<Vec<Arc<TrackBuf>>>,
    /// Counter tracks (queue depth, cache bytes, traffic) — a separate
    /// registry so slice-track consumers ([`Self::snapshot`], the
    /// per-request accounting tests) never see counter series.
    counters: RwLock<Vec<Arc<CounterBuf>>>,
}

impl Default for TraceCollector {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceCollector {
    pub fn new() -> Self {
        Self {
            epoch: Instant::now(),
            next_span: AtomicU64::new(1),
            tracks: RwLock::new(Vec::new()),
            counters: RwLock::new(Vec::new()),
        }
    }

    /// The collector's clock epoch.  A server built with this collector
    /// adopts it, so span, batch, and handler timestamps all share one
    /// µs timeline.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Microseconds since the collector's epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Mint a new span at an admission point.  Ids are allocated from
    /// one atomic counter, so they are unique across shards and
    /// transports (batcher ticket ids are per-shard and are not).
    pub fn mint(&self, model: &str, rows: u32) -> SpanCtx {
        SpanCtx {
            span_id: self.next_span.fetch_add(1, Ordering::Relaxed),
            t_admit_us: self.now_us(),
            model: model.to_string(),
            rows,
        }
    }

    /// Register a named track.  Call once per writer thread at setup
    /// time, before traffic; the returned id is what events carry.
    pub fn register_track(&self, name: &str) -> TrackId {
        let mut tracks = self.tracks.write().expect("trace track registry poisoned");
        tracks.push(Arc::new(TrackBuf {
            name: name.to_string(),
            ring: Mutex::new(Ring { events: Vec::new(), dropped: 0 }),
        }));
        TrackId(tracks.len() - 1)
    }

    /// Record a batch of events.  The track registry is read-locked
    /// once and each event takes only its own track's (single-writer,
    /// uncontended) mutex, so this stays off every other thread's path.
    pub fn record_many(&self, events: Vec<TraceEvent>) {
        let tracks = self.tracks.read().expect("trace track registry poisoned");
        for ev in events {
            let Some(track) = tracks.get(ev.track.0) else {
                debug_assert!(false, "event on unregistered track {}", ev.track.0);
                continue;
            };
            let mut ring = track.ring.lock().expect("trace ring poisoned");
            if ring.events.len() < TRACK_CAPACITY {
                ring.events.push(ev);
            } else {
                ring.dropped += 1;
            }
        }
    }

    pub fn record(&self, event: TraceEvent) {
        self.record_many(vec![event]);
    }

    /// Register a named counter track.  Same setup-time discipline as
    /// [`Self::register_track`]; samples carry the returned id.
    pub fn register_counter_track(&self, name: &str) -> CounterId {
        let mut counters = self.counters.write().expect("trace counter registry poisoned");
        counters.push(Arc::new(CounterBuf {
            name: name.to_string(),
            ring: Mutex::new(CounterRing { samples: Vec::new(), dropped: 0 }),
        }));
        CounterId(counters.len() - 1)
    }

    /// Record one counter sample: the track's value at `t_us`.  Bounded
    /// like slice rings — overflow drops (counted) rather than grows.
    pub fn record_counter(&self, id: CounterId, t_us: u64, value: u64) {
        let counters = self.counters.read().expect("trace counter registry poisoned");
        let Some(track) = counters.get(id.0) else {
            debug_assert!(false, "sample on unregistered counter track {}", id.0);
            return;
        };
        let mut ring = track.ring.lock().expect("trace counter ring poisoned");
        if ring.samples.len() < TRACK_CAPACITY {
            ring.samples.push((t_us, value));
        } else {
            ring.dropped += 1;
        }
    }

    /// Total events dropped to ring overflow, across all slice and
    /// counter tracks.
    pub fn dropped(&self) -> u64 {
        let tracks = self.tracks.read().expect("trace track registry poisoned");
        let slices: u64 =
            tracks.iter().map(|t| t.ring.lock().expect("trace ring poisoned").dropped).sum();
        let counters = self.counters.read().expect("trace counter registry poisoned");
        let counter_drops: u64 = counters
            .iter()
            .map(|t| t.ring.lock().expect("trace counter ring poisoned").dropped)
            .sum();
        slices + counter_drops
    }

    /// `(track name, dropped count)` for every registered track — slice
    /// tracks first, then counter tracks.  Feeds the per-track
    /// `flashkat_trace_dropped_total{track=...}` metrics.
    pub fn dropped_by_track(&self) -> Vec<(String, u64)> {
        let tracks = self.tracks.read().expect("trace track registry poisoned");
        let mut out: Vec<(String, u64)> = tracks
            .iter()
            .map(|t| (t.name.clone(), t.ring.lock().expect("trace ring poisoned").dropped))
            .collect();
        let counters = self.counters.read().expect("trace counter registry poisoned");
        out.extend(counters.iter().map(|t| {
            (t.name.clone(), t.ring.lock().expect("trace counter ring poisoned").dropped)
        }));
        out
    }

    /// Clone out every track's name and events (test/render seam).
    pub fn snapshot(&self) -> Vec<(String, Vec<TraceEvent>)> {
        let tracks = self.tracks.read().expect("trace track registry poisoned");
        tracks
            .iter()
            .map(|t| (t.name.clone(), t.ring.lock().expect("trace ring poisoned").events.clone()))
            .collect()
    }

    /// Clone out every counter track's name and samples.
    pub fn counters_snapshot(&self) -> Vec<(String, Vec<(u64, u64)>)> {
        let counters = self.counters.read().expect("trace counter registry poisoned");
        counters
            .iter()
            .map(|t| {
                (
                    t.name.clone(),
                    t.ring.lock().expect("trace counter ring poisoned").samples.clone(),
                )
            })
            .collect()
    }

    /// Render the collected events (slices + counters) as a serialized
    /// Perfetto trace.
    pub fn render(&self) -> Vec<u8> {
        perfetto::render_with_counters(&self.snapshot(), &self.counters_snapshot())
    }

    /// Render and write the trace to `path`.
    pub fn write_file(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_ids_unique_across_threads() {
        let c = Arc::new(TraceCollector::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                (0..500).map(|_| c.mint("m", 1).span_id).collect::<Vec<_>>()
            }));
        }
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "span ids collided");
    }

    #[test]
    fn rings_are_bounded_and_count_drops() {
        let c = TraceCollector::new();
        let t = c.register_track("t");
        let ev = |n: usize| TraceEvent {
            track: t,
            name: format!("e{n}"),
            t0_us: n as u64,
            t1_us: n as u64 + 1,
            args: Vec::new(),
        };
        c.record_many((0..TRACK_CAPACITY + 10).map(ev).collect());
        assert_eq!(c.snapshot()[0].1.len(), TRACK_CAPACITY);
        assert_eq!(c.dropped(), 10);
    }

    #[test]
    fn counter_rings_are_bounded_and_separate_from_slices() {
        let c = TraceCollector::new();
        let _slice = c.register_track("shard 0");
        let q = c.register_counter_track("shard 0 queue");
        for i in 0..TRACK_CAPACITY + 7 {
            c.record_counter(q, i as u64, (i % 5) as u64);
        }
        // Counter series never leak into the slice snapshot (the
        // per-request accounting tests count snapshot events exactly).
        assert_eq!(c.snapshot().len(), 1);
        assert!(c.snapshot()[0].1.is_empty());
        let counters = c.counters_snapshot();
        assert_eq!(counters.len(), 1);
        assert_eq!(counters[0].0, "shard 0 queue");
        assert_eq!(counters[0].1.len(), TRACK_CAPACITY);
        assert_eq!(c.dropped(), 7);
        let by_track = c.dropped_by_track();
        assert_eq!(by_track.len(), 2);
        assert_eq!(by_track[0], ("shard 0".to_string(), 0));
        assert_eq!(by_track[1], ("shard 0 queue".to_string(), 7));
    }

    #[test]
    fn render_includes_counter_tracks() {
        let c = TraceCollector::new();
        let t = c.register_track("shard 0");
        let q = c.register_counter_track("shard 0 queue");
        c.record(TraceEvent {
            track: t,
            name: "batch m".into(),
            t0_us: 5,
            t1_us: 9,
            args: Vec::new(),
        });
        c.record_counter(q, 5, 2);
        c.record_counter(q, 9, 0);
        let st = stat(&c.render()).unwrap();
        assert_eq!(st.track_descriptors, 3); // process + slice + counter
        assert_eq!(st.slice_begins, 1);
        assert_eq!(st.slice_ends, 1);
        assert_eq!(st.counters, 2);
    }

    #[test]
    fn snapshot_and_render_round_trip() {
        let c = TraceCollector::new();
        let a = c.register_track("shard 0");
        let b = c.register_track("shard 0 req");
        c.record(TraceEvent {
            track: a,
            name: "batch m".into(),
            t0_us: 5,
            t1_us: 9,
            args: vec![("cause", AnnValue::Str("full".into())), ("batch_size", AnnValue::U64(2))],
        });
        c.record(TraceEvent {
            track: b,
            name: "req m".into(),
            t0_us: 6,
            t1_us: 9,
            args: vec![("span_id", AnnValue::U64(42))],
        });
        let st = stat(&c.render()).unwrap();
        assert_eq!(st.slice_begins, 2);
        assert_eq!(st.slice_ends, 2);
        assert_eq!(st.track_descriptors, 3); // process + 2 tracks
    }
}
