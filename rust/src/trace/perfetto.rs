//! Hand-rolled Perfetto protobuf writer and scanner (DESIGN.md §15).
//!
//! A Perfetto trace is the simplest possible protobuf: a root `Trace`
//! message that is nothing but `repeated TracePacket packet = 1`.  The
//! packets we emit use four fields, all stable since the format was
//! frozen:
//!
//! | message           | field                        | number | wire type |
//! |-------------------|------------------------------|--------|-----------|
//! | TracePacket       | timestamp (ns)               | 8      | varint    |
//! | TracePacket       | trusted_packet_sequence_id   | 10     | varint    |
//! | TracePacket       | track_event                  | 11     | len-delim |
//! | TracePacket       | sequence_flags               | 13     | varint    |
//! | TracePacket       | track_descriptor             | 60     | len-delim |
//! | TrackDescriptor   | uuid                         | 1      | varint    |
//! | TrackDescriptor   | name                         | 2      | string    |
//! | TrackDescriptor   | process                      | 3      | len-delim |
//! | TrackDescriptor   | parent_uuid                  | 5      | varint    |
//! | TrackDescriptor   | counter                      | 8      | len-delim |
//! | ProcessDescriptor | pid                          | 1      | varint    |
//! | ProcessDescriptor | process_name                 | 6      | string    |
//! | TrackEvent        | debug_annotations            | 4      | len-delim |
//! | TrackEvent        | type (1=begin 2=end 3=inst,  | 9      | varint    |
//! |                   |  4=counter)                  |        |           |
//! | TrackEvent        | track_uuid                   | 11     | varint    |
//! | TrackEvent        | name                         | 23     | string    |
//! | TrackEvent        | counter_value                | 30     | varint    |
//! | DebugAnnotation   | uint_value                   | 3      | varint    |
//! | DebugAnnotation   | string_value                 | 6      | string    |
//! | DebugAnnotation   | name                         | 10     | string    |
//!
//! Like `util/json`, everything is written by hand against the wire
//! format instead of pulling in a protobuf crate: the writer is a page
//! of varint arithmetic, and owning it keeps the serving stack
//! zero-dependency.  [`stat`] is the matching minimal scanner — enough
//! protobuf decoding to count packets and slices so `flashkat
//! trace-stat` (and CI) can assert a dump is well-formed without
//! shipping the trace to ui.perfetto.dev first.

use super::{AnnValue, TraceEvent};

/// Sequence id for every packet we emit.  All events come from one
/// in-process collector drained at shutdown, so a single synthetic
/// sequence (id 1, state cleared on the first packet) is sufficient.
const SEQUENCE_ID: u64 = 1;

/// TracePacket.sequence_flags: SEQ_INCREMENTAL_STATE_CLEARED.
const SEQ_CLEARED: u64 = 1;

/// Track uuid of the synthetic process that parents every track.
const PROCESS_UUID: u64 = 1;

// ---------------- encoding primitives ----------------

fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Field key: (field_number << 3) | wire_type.
fn put_key(out: &mut Vec<u8>, field: u64, wire: u64) {
    put_varint(out, (field << 3) | wire);
}

fn put_u64(out: &mut Vec<u8>, field: u64, v: u64) {
    put_key(out, field, 0);
    put_varint(out, v);
}

fn put_str(out: &mut Vec<u8>, field: u64, s: &str) {
    put_key(out, field, 2);
    put_varint(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn put_msg(out: &mut Vec<u8>, field: u64, inner: &[u8]) {
    put_key(out, field, 2);
    put_varint(out, inner.len() as u64);
    out.extend_from_slice(inner);
}

// ---------------- packet builders ----------------

fn packet(out: &mut Vec<u8>, body: &[u8]) {
    put_msg(out, 1, body); // Trace.packet = 1
}

fn descriptor_packet(out: &mut Vec<u8>, desc: &[u8], first: bool) {
    let mut p = Vec::with_capacity(desc.len() + 16);
    put_u64(&mut p, 10, SEQUENCE_ID);
    if first {
        put_u64(&mut p, 13, SEQ_CLEARED);
    }
    put_msg(&mut p, 60, desc);
    packet(out, &p);
}

fn annotation(name: &str, value: &AnnValue) -> Vec<u8> {
    let mut a = Vec::with_capacity(name.len() + 16);
    match value {
        AnnValue::U64(v) => put_u64(&mut a, 3, *v),
        AnnValue::Str(s) => put_str(&mut a, 6, s),
    }
    put_str(&mut a, 10, name);
    a
}

/// TYPE_SLICE_BEGIN carries the name and annotations; TYPE_SLICE_END
/// closes whatever is on top of the track's slice stack.
fn event_packet(
    out: &mut Vec<u8>,
    t_us: u64,
    track_uuid: u64,
    ty: u64,
    name: Option<&str>,
    args: &[(&'static str, AnnValue)],
) {
    let mut ev = Vec::with_capacity(64);
    for (k, v) in args {
        put_msg(&mut ev, 4, &annotation(k, v));
    }
    put_u64(&mut ev, 9, ty);
    put_u64(&mut ev, 11, track_uuid);
    if let Some(n) = name {
        put_str(&mut ev, 23, n);
    }
    let mut p = Vec::with_capacity(ev.len() + 16);
    put_u64(&mut p, 8, t_us.saturating_mul(1000)); // µs clock -> ns
    put_u64(&mut p, 10, SEQUENCE_ID);
    put_msg(&mut p, 11, &ev);
    packet(out, &p);
}

/// Render named tracks of slice events into a Perfetto trace.
///
/// Slices on one track form a stack, so packets must appear in
/// timestamp order with proper nesting.  The collector guarantees
/// slices on a track either nest or are disjoint (shard execution and
/// connection handling are serial per track); here we interleave the
/// BEGIN/END packets accordingly: at equal timestamps ENDs come first,
/// ties among BEGINs open the longest slice first, and ties among ENDs
/// close the innermost (latest-begun) slice first.
pub fn render(tracks: &[(String, Vec<TraceEvent>)]) -> Vec<u8> {
    render_with_counters(tracks, &[])
}

/// [`render`] plus counter tracks: each `(name, samples)` entry becomes
/// one counter-typed track (TrackDescriptor with an empty
/// CounterDescriptor sub-message) whose `(t_us, value)` samples are
/// emitted as TYPE_COUNTER track events in timestamp order.  With an
/// empty `counters` slice the output is byte-identical to [`render`].
pub fn render_with_counters(
    tracks: &[(String, Vec<TraceEvent>)],
    counters: &[(String, Vec<(u64, u64)>)],
) -> Vec<u8> {
    let mut out = Vec::with_capacity(4096);

    // Synthetic process track parenting every real track.
    let mut proc_desc = Vec::new();
    put_u64(&mut proc_desc, 1, 1); // pid
    put_str(&mut proc_desc, 6, "flashkat-serve");
    let mut desc = Vec::new();
    put_u64(&mut desc, 1, PROCESS_UUID);
    put_msg(&mut desc, 3, &proc_desc);
    descriptor_packet(&mut out, &desc, true);

    for (i, (name, _)) in tracks.iter().enumerate() {
        let mut desc = Vec::new();
        put_u64(&mut desc, 1, track_uuid(i));
        put_str(&mut desc, 2, name);
        put_u64(&mut desc, 5, PROCESS_UUID);
        descriptor_packet(&mut out, &desc, false);
    }

    // Counter tracks take the uuid range after the slice tracks.
    for (i, (name, _)) in counters.iter().enumerate() {
        let mut desc = Vec::new();
        put_u64(&mut desc, 1, track_uuid(tracks.len() + i));
        put_str(&mut desc, 2, name);
        put_u64(&mut desc, 5, PROCESS_UUID);
        // Empty CounterDescriptor: presence is what marks the track as
        // a counter track in the Perfetto UI.
        put_msg(&mut desc, 8, &[]);
        descriptor_packet(&mut out, &desc, false);
    }

    for (i, (_, events)) in tracks.iter().enumerate() {
        let uuid = track_uuid(i);
        // (timestamp, end_rank, tiebreak, event index, is_begin):
        // ENDs (rank 0) before BEGINs (rank 1) at the same timestamp;
        // BEGIN ties open the longest slice first (descending t1);
        // END ties close the innermost slice first (descending t0).
        let mut marks: Vec<(u64, u8, u64, usize, bool)> = Vec::with_capacity(events.len() * 2);
        for (j, e) in events.iter().enumerate() {
            let t1 = e.t1_us.max(e.t0_us);
            marks.push((e.t0_us, 1, u64::MAX - t1, j, true));
            marks.push((t1, 0, u64::MAX - e.t0_us, j, false));
        }
        marks.sort();
        for (ts, _, _, j, is_begin) in marks {
            let e = &events[j];
            if is_begin {
                event_packet(&mut out, ts, uuid, 1, Some(&e.name), &e.args);
            } else {
                event_packet(&mut out, ts, uuid, 2, None, &[]);
            }
        }
    }

    for (i, (_, samples)) in counters.iter().enumerate() {
        let uuid = track_uuid(tracks.len() + i);
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        for (t_us, value) in sorted {
            counter_packet(&mut out, t_us, uuid, value);
        }
    }
    out
}

/// TYPE_COUNTER event: the track's value at `t_us`.
fn counter_packet(out: &mut Vec<u8>, t_us: u64, track_uuid: u64, value: u64) {
    let mut ev = Vec::with_capacity(16);
    put_u64(&mut ev, 9, 4); // TYPE_COUNTER
    put_u64(&mut ev, 11, track_uuid);
    put_u64(&mut ev, 30, value); // counter_value
    let mut p = Vec::with_capacity(ev.len() + 16);
    put_u64(&mut p, 8, t_us.saturating_mul(1000)); // µs clock -> ns
    put_u64(&mut p, 10, SEQUENCE_ID);
    put_msg(&mut p, 11, &ev);
    packet(out, &p);
}

fn track_uuid(index: usize) -> u64 {
    PROCESS_UUID + 1 + index as u64
}

// ---------------- scanner ----------------

/// Counts from a minimal decode of a serialized trace — enough to
/// assert a dump is non-empty and well-formed (`flashkat trace-stat`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceStat {
    pub packets: u64,
    pub track_descriptors: u64,
    pub slice_begins: u64,
    pub slice_ends: u64,
    pub instants: u64,
    pub counters: u64,
}

struct Scanner<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Scanner<'a> {
    fn varint(&mut self) -> Result<u64, String> {
        let mut v: u64 = 0;
        let mut shift = 0u32;
        loop {
            let byte = *self.b.get(self.i).ok_or("truncated varint")?;
            self.i += 1;
            if shift >= 64 {
                return Err("varint overflow".into());
            }
            v |= u64::from(byte & 0x7f) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    /// Read a field key; `None` at a clean end of the buffer.
    fn key(&mut self) -> Result<Option<(u64, u64)>, String> {
        if self.i == self.b.len() {
            return Ok(None);
        }
        let key = self.varint()?;
        Ok(Some((key >> 3, key & 7)))
    }

    /// Length-delimited payload (wire type 2).
    fn bytes(&mut self) -> Result<&'a [u8], String> {
        let len = self.varint()? as usize;
        let end = self.i.checked_add(len).filter(|&e| e <= self.b.len());
        let end = end.ok_or("length-delimited field past end of buffer")?;
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn skip(&mut self, wire: u64) -> Result<(), String> {
        match wire {
            0 => {
                self.varint()?;
            }
            1 => {
                self.i = self
                    .i
                    .checked_add(8)
                    .filter(|&e| e <= self.b.len())
                    .ok_or("truncated fixed64")?;
            }
            2 => {
                self.bytes()?;
            }
            5 => {
                self.i = self
                    .i
                    .checked_add(4)
                    .filter(|&e| e <= self.b.len())
                    .ok_or("truncated fixed32")?;
            }
            w => return Err(format!("unsupported wire type {w}")),
        }
        Ok(())
    }
}

/// Scan a serialized trace and count packets / descriptors / slices.
pub fn stat(bytes: &[u8]) -> Result<TraceStat, String> {
    let mut s = Scanner { b: bytes, i: 0 };
    let mut st = TraceStat::default();
    while let Some((field, wire)) = s.key()? {
        if field != 1 || wire != 2 {
            return Err(format!("unexpected top-level field {field} (wire {wire})"));
        }
        st.packets += 1;
        let mut p = Scanner { b: s.bytes()?, i: 0 };
        while let Some((pf, pw)) = p.key()? {
            match (pf, pw) {
                (60, 2) => {
                    st.track_descriptors += 1;
                    p.bytes()?;
                }
                (11, 2) => {
                    let mut ev = Scanner { b: p.bytes()?, i: 0 };
                    while let Some((ef, ew)) = ev.key()? {
                        if (ef, ew) == (9, 0) {
                            match ev.varint()? {
                                1 => st.slice_begins += 1,
                                2 => st.slice_ends += 1,
                                3 => st.instants += 1,
                                4 => st.counters += 1,
                                t => return Err(format!("unknown track event type {t}")),
                            }
                        } else {
                            ev.skip(ew)?;
                        }
                    }
                }
                (_, w) => p.skip(w)?,
            }
        }
    }
    Ok(st)
}

/// Per-track event counts from a serialized trace: one `(name, events)`
/// entry per *named* track descriptor, in descriptor order.  The
/// synthetic process descriptor has no name and is skipped; events on a
/// uuid without a named descriptor are ignored (use [`stat`] first —
/// it rejects structurally broken traces).
pub fn stat_by_track(bytes: &[u8]) -> Result<Vec<(String, u64)>, String> {
    let mut s = Scanner { b: bytes, i: 0 };
    let mut tracks: Vec<(u64, String, u64)> = Vec::new();
    while let Some((field, wire)) = s.key()? {
        if field != 1 || wire != 2 {
            return Err(format!("unexpected top-level field {field} (wire {wire})"));
        }
        let mut p = Scanner { b: s.bytes()?, i: 0 };
        while let Some((pf, pw)) = p.key()? {
            match (pf, pw) {
                (60, 2) => {
                    let mut d = Scanner { b: p.bytes()?, i: 0 };
                    let (mut uuid, mut name) = (None, None);
                    while let Some((df, dw)) = d.key()? {
                        match (df, dw) {
                            (1, 0) => uuid = Some(d.varint()?),
                            (2, 2) => {
                                name = Some(String::from_utf8_lossy(d.bytes()?).into_owned());
                            }
                            (_, w) => d.skip(w)?,
                        }
                    }
                    if let (Some(u), Some(n)) = (uuid, name) {
                        tracks.push((u, n, 0));
                    }
                }
                (11, 2) => {
                    let mut ev = Scanner { b: p.bytes()?, i: 0 };
                    let mut uuid = None;
                    while let Some((ef, ew)) = ev.key()? {
                        if (ef, ew) == (11, 0) {
                            uuid = Some(ev.varint()?);
                        } else {
                            ev.skip(ew)?;
                        }
                    }
                    if let Some(u) = uuid {
                        if let Some(t) = tracks.iter_mut().find(|(tu, _, _)| *tu == u) {
                            t.2 += 1;
                        }
                    }
                }
                (_, w) => p.skip(w)?,
            }
        }
    }
    Ok(tracks.into_iter().map(|(_, n, c)| (n, c)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TrackId;

    fn ev(name: &str, t0: u64, t1: u64) -> TraceEvent {
        TraceEvent {
            track: TrackId(0),
            name: name.to_string(),
            t0_us: t0,
            t1_us: t1,
            args: vec![("size", AnnValue::U64(3)), ("cause", AnnValue::Str("full".into()))],
        }
    }

    #[test]
    fn varint_round_trip() {
        for &v in &[0u64, 1, 127, 128, 300, 16_383, 16_384, u64::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut s = Scanner { b: &buf, i: 0 };
            assert_eq!(s.varint().unwrap(), v);
            assert_eq!(s.i, buf.len(), "trailing bytes for {v}");
        }
    }

    #[test]
    fn render_then_stat_counts_match() {
        let tracks = vec![
            ("shard 0".to_string(), vec![ev("batch a", 10, 20), ev("batch b", 30, 40)]),
            ("shard 0 req".to_string(), vec![ev("req a", 10, 18)]),
        ];
        let bytes = render(&tracks);
        let st = stat(&bytes).unwrap();
        // 1 process + 2 track descriptors, 3 slices => 3 + 6 packets.
        assert_eq!(st.track_descriptors, 3);
        assert_eq!(st.slice_begins, 3);
        assert_eq!(st.slice_ends, 3);
        assert_eq!(st.instants, 0);
        assert_eq!(st.packets, 9);
    }

    #[test]
    fn counter_tracks_render_and_stat() {
        let tracks = vec![("shard 0".to_string(), vec![ev("batch a", 10, 20)])];
        let counters =
            vec![("shard 0 queue".to_string(), vec![(12u64, 1u64), (5, 3), (9, 2)])];
        let bytes = render_with_counters(&tracks, &counters);
        let st = stat(&bytes).unwrap();
        // process + slice track + counter track descriptors.
        assert_eq!(st.track_descriptors, 3);
        assert_eq!(st.slice_begins, 1);
        assert_eq!(st.slice_ends, 1);
        assert_eq!(st.counters, 3);
        assert_eq!(st.packets, 3 + 2 + 3);
        // Counter samples are emitted in timestamp order regardless of
        // recording order: decode the counter packets' timestamps.
        let mut ts_seen = Vec::new();
        let mut s = Scanner { b: &bytes, i: 0 };
        while let Some((_, _)) = s.key().unwrap() {
            let mut p = Scanner { b: s.bytes().unwrap(), i: 0 };
            let (mut ts, mut is_counter) = (0u64, false);
            while let Some((pf, pw)) = p.key().unwrap() {
                match (pf, pw) {
                    (8, 0) => ts = p.varint().unwrap(),
                    (11, 2) => {
                        let mut ev = Scanner { b: p.bytes().unwrap(), i: 0 };
                        while let Some((ef, ew)) = ev.key().unwrap() {
                            if (ef, ew) == (9, 0) {
                                is_counter = ev.varint().unwrap() == 4;
                            } else {
                                ev.skip(ew).unwrap();
                            }
                        }
                    }
                    (_, w) => p.skip(w).unwrap(),
                }
            }
            if is_counter {
                ts_seen.push(ts);
            }
        }
        assert_eq!(ts_seen, vec![5_000, 9_000, 12_000]);
    }

    #[test]
    fn stat_by_track_splits_events_per_named_track() {
        let tracks = vec![
            ("shard 0".to_string(), vec![ev("batch a", 10, 20), ev("batch b", 30, 40)]),
            ("shard 0 req".to_string(), vec![ev("req a", 10, 18)]),
        ];
        let counters = vec![("shard 0 queue".to_string(), vec![(5u64, 3u64), (9, 2)])];
        let by_track = stat_by_track(&render_with_counters(&tracks, &counters)).unwrap();
        // Slice tracks count begin + end marks; counter tracks count samples.
        assert_eq!(
            by_track,
            vec![
                ("shard 0".to_string(), 4),
                ("shard 0 req".to_string(), 2),
                ("shard 0 queue".to_string(), 2),
            ]
        );
        assert_eq!(stat_by_track(&[]).unwrap(), vec![]);
    }

    #[test]
    fn render_with_no_counters_is_byte_identical_to_render() {
        let tracks = vec![
            ("shard 0".to_string(), vec![ev("batch a", 10, 20), ev("batch b", 30, 40)]),
            ("shard 0 req".to_string(), vec![ev("req a", 10, 18)]),
        ];
        assert_eq!(render_with_counters(&tracks, &[]), render(&tracks));
    }

    #[test]
    fn stat_rejects_garbage_and_truncation() {
        assert!(stat(&[0xff]).is_err(), "truncated varint");
        let mut ok = render(&[("t".to_string(), vec![ev("e", 0, 1)])]);
        assert!(stat(&ok).is_ok());
        ok.pop();
        assert!(stat(&ok).is_err(), "truncated packet");
        assert!(stat(&[0x12, 0x00]).is_err(), "wrong top-level field");
        assert_eq!(stat(&[]).unwrap(), TraceStat::default());
    }

    /// Same-timestamp marks must interleave as a proper slice stack:
    /// END before BEGIN, outer slices open first and close last.
    #[test]
    fn render_orders_nested_slices_as_a_stack() {
        let tracks = vec![(
            "t".to_string(),
            // Outer [10,20], inner [10,15], then adjacent [15,18]:
            // stack order must be B(outer) B(inner) E(inner) B(adj) E(adj) E(outer).
            vec![ev("adj", 15, 18), ev("outer", 10, 20), ev("inner", 10, 15)],
        )];
        let bytes = render(&tracks);
        // Decode just the (timestamp, type) sequence of track events.
        let mut seq = Vec::new();
        let mut s = Scanner { b: &bytes, i: 0 };
        while let Some((_, _)) = s.key().unwrap() {
            let mut p = Scanner { b: s.bytes().unwrap(), i: 0 };
            let (mut ts, mut ty) = (None, None);
            while let Some((pf, pw)) = p.key().unwrap() {
                match (pf, pw) {
                    (8, 0) => ts = Some(p.varint().unwrap()),
                    (11, 2) => {
                        let mut ev = Scanner { b: p.bytes().unwrap(), i: 0 };
                        while let Some((ef, ew)) = ev.key().unwrap() {
                            if (ef, ew) == (9, 0) {
                                ty = Some(ev.varint().unwrap());
                            } else {
                                ev.skip(ew).unwrap();
                            }
                        }
                    }
                    (_, w) => p.skip(w).unwrap(),
                }
            }
            if let (Some(ts), Some(ty)) = (ts, ty) {
                seq.push((ts, ty));
            }
        }
        assert_eq!(
            seq,
            vec![
                (10_000, 1), // outer begins first (longest at t=10)
                (10_000, 1), // inner
                (15_000, 2), // inner ends before adj begins
                (15_000, 1),
                (18_000, 2),
                (20_000, 2), // outer closes last
            ]
        );
    }
}
