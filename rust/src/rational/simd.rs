//! SIMD-wide GR-KAN rational kernel (`--features simd`, nightly
//! `portable_simd`).
//!
//! FlashKAT's lesson — restructure data movement, don't shave FLOPs —
//! applied one level below PR 1's tile accumulators: the per-core vector
//! units.  The scalar `NativeFloat` path in [`super::kernel`] stays the
//! bit-exactness oracle; this module restructures the same expression
//! trees into explicit wide lanes (DESIGN.md §14):
//!
//! - **Element lanes** (`f32x8` / `f64x4`): the Horner numerator /
//!   denominator evaluations, `sign(A)`/abs handling, and every fused
//!   backward expression (`1/Q`, `P/Q²`, `P'`, `A'`, `dx`) run one
//!   element per lane.  Each lane executes exactly the scalar kernel's
//!   op sequence — one rounded IEEE op per step, no FMA contraction —
//!   so every per-element output (forward `y`, backward `dx`, and the
//!   per-element `dout/Q`, `-dout·sign(A)·P/Q²` factors) is bit-identical
//!   to the scalar fast path for **both** f32 and f64.
//! - **Coefficient lanes** ([`MAX_M1`]` = `[`MAX_N`]` = 8` wide): the
//!   register-resident gradient accumulator holds its running dA / dB
//!   sums as one SIMD vector each and folds in one element per step —
//!   `seq += splat(factor) * powers(x)` — in the exact element order of
//!   the scalar [`TileAcc`].  Per coefficient, the add chain sees the
//!   same operands in the same order, so the accumulated partials are
//!   bit-identical too.  Lane-*transposed* element accumulators (one
//!   element stream per lane, horizontally reduced at segment
//!   boundaries) would be a different summation order — in this
//!   codebase's own vocabulary, a different accumulation
//!   [`Strategy`](super::accumulate::Strategy) — and could never meet
//!   the f64 bitwise acceptance bar; see DESIGN.md §14.  The vector
//!   state is reduced into the scalar pairwise carry stacks only at run
//!   boundaries ([`RUN`] elements) and at tile finish, mirroring
//!   Algorithm 2's fast-memory tile reduction.
//! - **Masked tails**: widths that are not a lane multiple compute the
//!   final tile vector-wide on a zero-padded load and then store / fold
//!   only the live lanes.  A scalar-loop fallback is banned: live lanes
//!   must take the same code path (hence the same rounding story and the
//!   same NaN/±0/subnormal handling) regardless of where the segment
//!   ends.  Padding lanes are computed but never stored and never
//!   pushed into the accumulator, for two reasons: a zero-padded lane
//!   can evaluate to NaN even when every live lane is finite (e.g.
//!   `0 · Inf` inside Q when a coefficient is non-finite) and would
//!   poison the running sums, and folding it would advance the
//!   [`RUN`] counter, shifting every later flush boundary and
//!   regrouping the pairwise carry stacks — a different summation
//!   tree, hence different bits.
//!
//! NaN caveat: IEEE-754 does not pin NaN payloads, and scalar vs vector
//! instructions may canonicalize them differently.  The bit-identity
//! contract (and the tests) therefore treat any-NaN == any-NaN; all
//! non-NaN values compare by exact bits.

use std::simd::prelude::*;

use super::accumulate::PairwiseAcc;
use super::kernel::{SegAccum, MAX_M1, MAX_N, RUN};

/// Element lanes per tile for f32 (256-bit AVX2-native; portable SIMD
/// legalizes to narrower hardware transparently).
pub const LANES_F32: usize = 8;
/// Element lanes per tile for f64.
pub const LANES_F64: usize = 4;
/// Coefficient-axis vector width; the compile-time guard keeps it in
/// lock-step with the scalar register caps.
const CW: usize = 8;
const _: () = assert!(MAX_M1 == CW && MAX_N == CW);

macro_rules! simd_kernel {
    ($t:ident, $lanes:expr, $m:ident) => {
        pub mod $m {
            use super::*;

            /// Element-lane count for this scalar type.
            pub const LANES: usize = $lanes;
            /// Element-lane vector.
            pub type V = Simd<$t, LANES>;
            /// Coefficient-axis vector (dA / dB accumulator rows).
            type C = Simd<$t, CW>;

            /// Lane-wise `sign` with `signum0(±0) == signum0(NaN) == 0`,
            /// matching [`crate::rational::Float::signum0`]: the `>`/`<`
            /// comparisons are false for NaN in both scalar and vector
            /// forms, so NaN lanes select 0.
            #[inline]
            fn signum0(v: V) -> V {
                let zero = V::splat(0.0);
                v.simd_gt(zero)
                    .select(V::splat(1.0), v.simd_lt(zero).select(V::splat(-1.0), zero))
            }

            /// Lane-wise `(P, Q, sign(A))` — op-for-op the mirror of
            /// [`crate::rational::kernel::pq_elem_native`]: every step is
            /// one rounded IEEE op per lane (mul then add, never a fused
            /// mul-add), so each lane is bit-identical to the scalar fast
            /// path.
            #[inline]
            pub fn pq_vec(x: V, a: &[$t], b: &[$t]) -> (V, V, V) {
                let m1 = a.len();
                let mut p = V::splat(a[m1 - 1]);
                for i in (0..m1 - 1).rev() {
                    p = p * x + V::splat(a[i]);
                }
                let n = b.len();
                let mut h = V::splat(b[n - 1]);
                for j in (0..n - 1).rev() {
                    h = h * x + V::splat(b[j]);
                }
                let abig = x * h;
                let q = V::splat(1.0) + abig.abs();
                (p, q, signum0(abig))
            }

            /// Lane-wise forward value `F(x) = P(x) / (1 + |A(x)|)`.
            #[inline]
            pub fn forward_vec(x: V, a: &[$t], b: &[$t]) -> V {
                let (p, q, _) = pq_vec(x, a, b);
                p / q
            }

            /// Forward over one contiguous `(row, group)` segment (all
            /// elements share `a`/`b`).  Full tiles use vector
            /// loads/stores; the ragged tail computes vector-wide on a
            /// zero-padded tile and stores only the live lanes (masked
            /// tail — see the module docs for why there is no scalar
            /// fallback).
            pub fn forward_seg(xs: &[$t], out: &mut [$t], a: &[$t], b: &[$t]) {
                debug_assert_eq!(xs.len(), out.len());
                let full = xs.len() - xs.len() % LANES;
                let mut k = 0;
                while k < full {
                    let x = V::from_slice(&xs[k..]);
                    forward_vec(x, a, b).copy_to_slice(&mut out[k..k + LANES]);
                    k += LANES;
                }
                let rem = xs.len() - full;
                if rem > 0 {
                    crate::probe::on_masked_tail((LANES - rem) as u64);
                    let mut pad = [0.0 as $t; LANES];
                    pad[..rem].copy_from_slice(&xs[full..]);
                    let y = forward_vec(V::from_array(pad), a, b).to_array();
                    out[full..].copy_from_slice(&y[..rem]);
                }
            }

            /// Vector stage of the fused backward: per-lane `dx` plus the
            /// two per-element coefficient-gradient factors (`dout/Q` and
            /// `-dout·sign(A)·P/Q²`) — the mirror of
            /// [`crate::rational::kernel::backward_elem_native`] up to,
            /// but not including, the contribution fills.  The lane-
            /// invariant degree products (`a[i]·i`, `b[j]·(j+1)`) are
            /// computed in scalar and splatted: one rounded op either
            /// way.
            #[inline]
            fn backward_vec(x: V, dout: V, a: &[$t], b: &[$t]) -> (V, V, V) {
                let m1 = a.len();
                let n = b.len();
                let (p, q, sgn) = pq_vec(x, a, b);
                let inv_q = V::splat(1.0) / q;
                let p_over_q2 = p * inv_q * inv_q;

                let mut dp = V::splat(0.0);
                if m1 > 1 {
                    dp = V::splat(a[m1 - 1] * (m1 - 1) as $t);
                    for i in (1..m1 - 1).rev() {
                        dp = dp * x + V::splat(a[i] * i as $t);
                    }
                }
                let mut dadx = V::splat(b[n - 1] * n as $t);
                for j in (0..n - 1).rev() {
                    dadx = dadx * x + V::splat(b[j] * (j + 1) as $t);
                }

                let dx = dout * (dp * inv_q - sgn * dadx * p_over_q2);
                let do_q = dout * inv_q;
                let neg_do_spq2 = -dout * sgn * p_over_q2;
                (dx, do_q, neg_do_spq2)
            }

            /// Register-resident SIMD tile accumulator for one
            /// `(block, group)` tile — the lane-parallel twin of
            /// [`crate::rational::kernel::TileAcc`], bit-identical to it
            /// by construction (coefficient-axis lanes, element-sequential
            /// fold; see the module docs).
            pub struct SegAcc {
                m1: usize,
                n: usize,
                tree: bool,
                run: usize,
                seq_a: C,
                seq_b: C,
                tree_a: [PairwiseAcc<$t>; MAX_M1],
                tree_b: [PairwiseAcc<$t>; MAX_N],
            }

            impl SegAcc {
                /// Fold one element's contributions: `da_e[i] = do_q·xⁱ`
                /// and `db_e[j] = neg_do_spq2·x^(j+1)` become two vector
                /// mul+adds over the coefficient axis.  The power ladder
                /// is the same left-to-right `pw *= x` chain as the
                /// scalar fill loops, so every lane's product — and the
                /// per-coefficient running sum it feeds — rounds
                /// identically to the scalar path.  Lanes at or above
                /// `m1`/`n` accumulate garbage that [`Self::finish`]
                /// masks off (lane arithmetic cannot contaminate
                /// neighbours).
                #[inline]
                fn push_elem(&mut self, x: $t, do_q: $t, neg_do_spq2: $t) {
                    let mut pows = [1.0 as $t; CW + 1];
                    for k in 1..=CW {
                        pows[k] = pows[k - 1] * x;
                    }
                    let pa = C::from_slice(&pows[..CW]);
                    let pb = C::from_slice(&pows[1..]);
                    self.seq_a = self.seq_a + C::splat(do_q) * pa;
                    self.seq_b = self.seq_b + C::splat(neg_do_spq2) * pb;
                    self.run += 1;
                    if self.tree && self.run == RUN {
                        self.flush_run();
                    }
                }

                /// Horizontal hand-off point: the vector running sums are
                /// pushed into the per-coefficient pairwise carry stacks
                /// only here — at [`RUN`]-element boundaries — and at
                /// [`SegAccum::finish`], never per element.
                fn flush_run(&mut self) {
                    crate::probe::on_run_flush();
                    let sa = self.seq_a.to_array();
                    let sb = self.seq_b.to_array();
                    for i in 0..self.m1 {
                        self.tree_a[i].push(sa[i]);
                    }
                    for j in 0..self.n {
                        self.tree_b[j].push(sb[j]);
                    }
                    self.seq_a = C::splat(0.0);
                    self.seq_b = C::splat(0.0);
                    self.run = 0;
                }
            }

            impl SegAccum<$t> for SegAcc {
                fn new(m1: usize, n: usize, tree: bool) -> Self {
                    assert!(
                        m1 <= MAX_M1 && n <= MAX_N,
                        "SegAcc: m1={m1} n={n} exceed register caps ({MAX_M1}, {MAX_N})"
                    );
                    Self {
                        m1,
                        n,
                        tree,
                        run: 0,
                        seq_a: C::splat(0.0),
                        seq_b: C::splat(0.0),
                        tree_a: std::array::from_fn(|_| PairwiseAcc::default()),
                        tree_b: std::array::from_fn(|_| PairwiseAcc::default()),
                    }
                }

                fn row_seg(
                    &mut self,
                    x: &[$t],
                    dout: &[$t],
                    dx: &mut [$t],
                    a: &[$t],
                    b: &[$t],
                ) {
                    debug_assert_eq!(x.len(), dout.len());
                    debug_assert_eq!(x.len(), dx.len());
                    debug_assert_eq!(a.len(), self.m1);
                    debug_assert_eq!(b.len(), self.n);
                    let len = x.len();
                    let full = len - len % LANES;
                    let mut k = 0;
                    while k < full {
                        let xv = V::from_slice(&x[k..]);
                        let dov = V::from_slice(&dout[k..]);
                        let (dxv, do_q, neg) = backward_vec(xv, dov, a, b);
                        dxv.copy_to_slice(&mut dx[k..k + LANES]);
                        let xa = xv.to_array();
                        let qa = do_q.to_array();
                        let na = neg.to_array();
                        for l in 0..LANES {
                            self.push_elem(xa[l], qa[l], na[l]);
                        }
                        k += LANES;
                    }
                    let rem = len - full;
                    if rem > 0 {
                        crate::probe::on_masked_tail((LANES - rem) as u64);
                        // Masked tail: vector-wide compute on zero padding,
                        // then store / fold the live lanes only.  Dead
                        // lanes never reach dx or the accumulator: their
                        // contributions can be NaN (0·Inf against
                        // non-finite coefficients) and folding them would
                        // advance the RUN counter, moving every later
                        // flush boundary (see the module docs).
                        let mut xp = [0.0 as $t; LANES];
                        let mut dp = [0.0 as $t; LANES];
                        xp[..rem].copy_from_slice(&x[full..]);
                        dp[..rem].copy_from_slice(&dout[full..]);
                        let (dxv, do_q, neg) =
                            backward_vec(V::from_array(xp), V::from_array(dp), a, b);
                        let dxa = dxv.to_array();
                        dx[full..].copy_from_slice(&dxa[..rem]);
                        let qa = do_q.to_array();
                        let na = neg.to_array();
                        for l in 0..rem {
                            self.push_elem(xp[l], qa[l], na[l]);
                        }
                    }
                }

                fn finish(mut self) -> ([$t; MAX_M1], [$t; MAX_N]) {
                    let mut da = [0.0 as $t; MAX_M1];
                    let mut db = [0.0 as $t; MAX_N];
                    if self.tree {
                        if self.run > 0 {
                            self.flush_run();
                        }
                        for i in 0..self.m1 {
                            da[i] = self.tree_a[i].finish();
                        }
                        for j in 0..self.n {
                            db[j] = self.tree_b[j].finish();
                        }
                    } else {
                        let sa = self.seq_a.to_array();
                        let sb = self.seq_b.to_array();
                        da[..self.m1].copy_from_slice(&sa[..self.m1]);
                        db[..self.n].copy_from_slice(&sb[..self.n]);
                    }
                    (da, db)
                }
            }
        }
    };
}

simd_kernel!(f32, LANES_F32, k32);
simd_kernel!(f64, LANES_F64, k64);

pub use k32::SegAcc as SimdSegAcc32;
pub use k64::SegAcc as SimdSegAcc64;

#[cfg(test)]
mod tests {
    use super::super::kernel::{backward_row_seg, SegAccum, TileAcc};
    use super::super::{forward_elem, Float};
    use super::*;
    use crate::util::rng::Pcg64;

    fn bits_eq32(a: f32, b: f32) -> bool {
        a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
    }

    fn bits_eq64(a: f64, b: f64) -> bool {
        a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
    }

    #[test]
    fn forward_seg_bitwise_matches_scalar_all_widths_f32() {
        let mut rng = Pcg64::new(11);
        let a: Vec<f32> = (0..6).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..4).map(|_| rng.normal_f32()).collect();
        for w in 1..=(3 * LANES_F32 + 1) {
            let xs: Vec<f32> = (0..w).map(|_| rng.normal_f32()).collect();
            let mut out = vec![0f32; w];
            k32::forward_seg(&xs, &mut out, &a, &b);
            for (k, &x) in xs.iter().enumerate() {
                assert!(bits_eq32(out[k], forward_elem(x, &a, &b)), "w={w} k={k}");
            }
        }
    }

    #[test]
    fn forward_seg_bitwise_matches_scalar_all_widths_f64() {
        let mut rng = Pcg64::new(12);
        let a: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
        for w in 1..=(3 * LANES_F64 + 1) {
            let xs: Vec<f64> = (0..w).map(|_| rng.normal()).collect();
            let mut out = vec![0f64; w];
            k64::forward_seg(&xs, &mut out, &a, &b);
            for (k, &x) in xs.iter().enumerate() {
                assert!(bits_eq64(out[k], forward_elem(x, &a, &b)), "w={w} k={k}");
            }
        }
    }

    #[test]
    fn seg_acc_bitwise_matches_tile_acc_across_runs_and_tails() {
        // Same segments through the SIMD accumulator and the scalar
        // TileAcc oracle: dx and the finished dA/dB partials must match
        // bit for bit, across run-boundary remainders, ragged tails, and
        // both tree variants.
        let mut rng = Pcg64::new(13);
        for &count in &[1usize, 3, 7, 8, 9, 63, 64, 65, 130, 1024 + 5] {
            for &tree in &[true, false] {
                let (m1, n) = (6, 4);
                let a: Vec<f32> = (0..m1).map(|_| rng.normal_f32()).collect();
                let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
                let x: Vec<f32> = (0..count).map(|_| rng.normal_f32()).collect();
                let dout: Vec<f32> = (0..count).map(|_| rng.normal_f32()).collect();
                let mut dx_s = vec![0f32; count];
                let mut dx_v = vec![0f32; count];
                let mut oracle = TileAcc::<f32>::new(m1, n, tree);
                backward_row_seg(&x, &dout, &mut dx_s, &a, &b, &mut oracle);
                let mut acc = <SimdSegAcc32 as SegAccum<f32>>::new(m1, n, tree);
                acc.row_seg(&x, &dout, &mut dx_v, &a, &b);
                for k in 0..count {
                    assert!(bits_eq32(dx_v[k], dx_s[k]), "dx count={count} k={k}");
                }
                let (da_s, db_s) = oracle.finish();
                let (da_v, db_v) = acc.finish();
                for i in 0..m1 {
                    assert!(bits_eq32(da_v[i], da_s[i]), "da[{i}] count={count} tree={tree}");
                }
                for j in 0..n {
                    assert!(bits_eq32(db_v[j], db_s[j]), "db[{j}] count={count} tree={tree}");
                }
            }
        }
    }

    #[test]
    fn seg_acc_persists_run_state_across_row_segs() {
        // The run counter spans rows within a tile: feeding the same
        // elements as one 96-element segment or as rows of 13 must land
        // identical bits (flush points depend only on cumulative count).
        let mut rng = Pcg64::new(14);
        let (m1, n) = (6, 4);
        let a: Vec<f64> = (0..m1).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let total = 96usize;
        let x: Vec<f64> = (0..total).map(|_| rng.normal()).collect();
        let dout: Vec<f64> = (0..total).map(|_| rng.normal()).collect();
        let mut dx_one = vec![0f64; total];
        let mut acc_one = <SimdSegAcc64 as SegAccum<f64>>::new(m1, n, true);
        acc_one.row_seg(&x, &dout, &mut dx_one, &a, &b);
        let mut dx_rows = vec![0f64; total];
        let mut acc_rows = <SimdSegAcc64 as SegAccum<f64>>::new(m1, n, true);
        let mut s = 0;
        while s < total {
            let e = (s + 13).min(total);
            acc_rows.row_seg(&x[s..e], &dout[s..e], &mut dx_rows[s..e], &a, &b);
            s = e;
        }
        assert_eq!(dx_one, dx_rows);
        let (da1, db1) = acc_one.finish();
        let (da2, db2) = acc_rows.finish();
        for i in 0..m1 {
            assert_eq!(da1[i].to_bits(), da2[i].to_bits());
        }
        for j in 0..n {
            assert_eq!(db1[j].to_bits(), db2[j].to_bits());
        }
    }

    #[test]
    fn masked_tail_dead_lanes_never_poison_the_accumulator() {
        // The discriminating case for fold-vs-skip on padding lanes: with
        // a non-finite denominator coefficient, a zero-padded lane
        // evaluates 0·Inf = NaN inside Q while every *live* lane stays
        // finite (q = Inf, so do_q = dout/Inf = ±0).  Folding a dead lane
        // would turn the dA running sum into NaN; skipping it keeps the
        // bit-exact zero the scalar oracle produces.  Exercised at every
        // tail raggedness and both accumulator variants.
        let (m1, n) = (2, 1);
        let a = [0.5f32, 0.25];
        let b = [f32::INFINITY];
        for count in 1..=(2 * LANES_F32 + 1) {
            for &tree in &[true, false] {
                let x = vec![1.0f32; count];
                let dout = vec![1.0f32; count];
                let mut dx_s = vec![0f32; count];
                let mut dx_v = vec![0f32; count];
                let mut oracle = TileAcc::<f32>::new(m1, n, tree);
                backward_row_seg(&x, &dout, &mut dx_s, &a, &b, &mut oracle);
                let mut acc = <SimdSegAcc32 as SegAccum<f32>>::new(m1, n, tree);
                acc.row_seg(&x, &dout, &mut dx_v, &a, &b);
                for k in 0..count {
                    assert!(bits_eq32(dx_v[k], dx_s[k]), "dx count={count} k={k}");
                }
                let (da_s, db_s) = oracle.finish();
                let (da_v, db_v) = acc.finish();
                for i in 0..m1 {
                    assert!(da_s[i].is_finite(), "oracle premise da[{i}]");
                    assert_eq!(da_v[i].to_bits(), da_s[i].to_bits(), "count={count} da[{i}]");
                }
                for j in 0..n {
                    assert_eq!(db_v[j].to_bits(), db_s[j].to_bits(), "count={count} db[{j}]");
                }
            }
        }
    }

    #[test]
    fn signum0_handles_nan_and_signed_zero() {
        let v = k32::V::from_array([f32::NAN, 0.0, -0.0, 1.5, -2.0, f32::INFINITY, f32::NEG_INFINITY, -0.0]);
        let expect = [0.0f32, 0.0, 0.0, 1.0, -1.0, 1.0, -1.0, 0.0];
        let (_, _, sgn) = k32::pq_vec(v, &[0.0, 1.0], &[1.0]);
        // pq_vec's sign is sign(x·H(x)) with H = b[0] = 1, i.e. sign(x).
        let got = sgn.to_array();
        for l in 0..8 {
            assert!(bits_eq32(got[l], expect[l]), "lane {l}: {} vs {}", got[l], expect[l]);
        }
        // and the scalar oracle agrees lane-for-lane
        for l in 0..8 {
            let s = <f32 as Float>::signum0(v.to_array()[l]);
            assert!(bits_eq32(got[l], s), "lane {l} vs scalar");
        }
    }
}
