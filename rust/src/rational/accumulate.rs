//! Gradient-accumulation strategies — the experiment variable behind the
//! paper's rounding-error study (Tables 5/8).
//!
//! Algorithm 1 (KAT) accumulates every element's coefficient-gradient
//! contribution with an individual atomic add: a summation chain of length
//! B*N*d_g per coefficient.  Algorithm 2 (FlashKAT) reduces each
//! (S_block x d_g) tile in fast memory (a tree reduction) and performs one
//! global add per block: chain length ~ T + log2(S_block*d_g).  Floating-
//! point summation error grows with chain length, hence the ~2 orders of
//! magnitude MAE gap the paper reports.

use super::{backward_elem, Coeffs, Float};
use crate::util::parallel::par_map;

/// How coefficient-gradient contributions are reduced into dA / dB.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Per-element global adds in flat memory order (paper Algorithm 1's
    /// atomic-add schedule; GPU order is nondeterministic, this is a
    /// representative member of the same error class).
    Sequential,
    /// FlashKAT: pairwise-tree reduction within each block of
    /// `s_block` rows, then one global add per block (paper Algorithm 2).
    BlockTree { s_block: usize },
    /// Ablation: block-local *sequential* reduction, then one global add
    /// per block.  Isolates "fewer global adds" from "tree reduction".
    BlockSequential { s_block: usize },
    /// Ablation: full pairwise-tree reduction over every contribution —
    /// the best ordering a reduction could achieve.
    PairwiseFull,
}

/// Full backward over (rows, d): returns (dx, dA, dB) with the coefficient
/// gradients accumulated per `strategy`.
pub fn backward<T: Float>(
    x: &[T],
    dout: &[T],
    rows: usize,
    d: usize,
    c: &Coeffs<T>,
    strategy: Strategy,
) -> (Vec<T>, Vec<T>, Vec<T>) {
    assert_eq!(x.len(), rows * d);
    assert_eq!(dout.len(), rows * d);
    assert_eq!(d % c.n_groups, 0);
    match strategy {
        Strategy::Sequential => backward_sequential(x, dout, rows, d, c),
        Strategy::BlockTree { s_block } => backward_block(x, dout, rows, d, c, s_block, true),
        Strategy::BlockSequential { s_block } => {
            backward_block(x, dout, rows, d, c, s_block, false)
        }
        Strategy::PairwiseFull => backward_pairwise_full(x, dout, rows, d, c),
    }
}

fn backward_sequential<T: Float>(
    x: &[T],
    dout: &[T],
    rows: usize,
    d: usize,
    c: &Coeffs<T>,
) -> (Vec<T>, Vec<T>, Vec<T>) {
    let d_g = d / c.n_groups;
    let (m1, n) = (c.m1, c.n);
    let mut dx = vec![T::ZERO; x.len()];
    let mut da = vec![T::ZERO; c.n_groups * m1];
    let mut db = vec![T::ZERO; c.n_groups * n];
    let mut da_e = vec![T::ZERO; m1];
    let mut db_e = vec![T::ZERO; n];
    for r in 0..rows {
        for g in 0..c.n_groups {
            let a = c.a_row(g);
            let b = c.b_row(g);
            for k in 0..d_g {
                let idx = r * d + g * d_g + k;
                dx[idx] = backward_elem(x[idx], dout[idx], a, b, &mut da_e, &mut db_e);
                // one "atomic add" per coefficient per element
                for i in 0..m1 {
                    da[g * m1 + i] = T::from_f64(da[g * m1 + i].to_f64() + da_e[i].to_f64());
                }
                for j in 0..n {
                    db[g * n + j] = T::from_f64(db[g * n + j].to_f64() + db_e[j].to_f64());
                }
            }
        }
    }
    (dx, da, db)
}

/// Streaming pairwise (tree) accumulator: maintains a carry stack of
/// power-of-two partial sums, O(log n) state, no materialized buffer.
/// This is the register-level shape of a block tree reduction (§Perf: it
/// replaced a materialize-then-reduce implementation, 1.8x faster, and is
/// numerically a pairwise tree like the kernel's `tl.sum`).
#[derive(Clone, Debug)]
pub struct PairwiseAcc<T: Float> {
    stack: [(T, u32); 48],
    len: usize,
}

impl<T: Float> Default for PairwiseAcc<T> {
    fn default() -> Self {
        Self { stack: [(T::ZERO, 0); 48], len: 0 }
    }
}

impl<T: Float> PairwiseAcc<T> {
    #[inline]
    pub fn push(&mut self, v: T) {
        let mut v = v;
        let mut count = 1u32;
        while self.len > 0 && self.stack[self.len - 1].1 == count {
            self.len -= 1;
            v = T::from_f64(v.to_f64() + self.stack[self.len].0.to_f64());
            count *= 2;
        }
        self.stack[self.len] = (v, count);
        self.len += 1;
    }

    /// Fold remaining partials (smallest first) into the total.
    pub fn finish(&self) -> T {
        let mut s = T::ZERO;
        for i in (0..self.len).rev() {
            s = T::from_f64(s.to_f64() + self.stack[i].0.to_f64());
        }
        s
    }
}

/// Pairwise-tree sum of a scratch buffer (in T precision), in place.
pub fn tree_sum<T: Float>(buf: &mut [T]) -> T {
    let mut len = buf.len();
    if len == 0 {
        return T::ZERO;
    }
    while len > 1 {
        let half = len / 2;
        for i in 0..half {
            buf[i] = T::from_f64(buf[i].to_f64() + buf[len - 1 - i].to_f64());
        }
        len -= half;
    }
    buf[0]
}

fn backward_block<T: Float>(
    x: &[T],
    dout: &[T],
    rows: usize,
    d: usize,
    c: &Coeffs<T>,
    s_block: usize,
    tree: bool,
) -> (Vec<T>, Vec<T>, Vec<T>) {
    let d_g = d / c.n_groups;
    let (m1, n, n_g) = (c.m1, c.n, c.n_groups);
    let s_block = s_block.max(1);
    let n_blocks = rows.div_ceil(s_block);

    // Per-(block, group) partials computed in parallel (mirrors the 2-D
    // grid of Algorithm 2), then accumulated over blocks in block order
    // (the serialized atomic adds).
    let jobs: Vec<(usize, usize)> = (0..n_blocks)
        .flat_map(|blk| (0..n_g).map(move |g| (blk, g)))
        .collect();

    struct Partial<T> {
        blk: usize,
        g: usize,
        da: Vec<T>,
        db: Vec<T>,
        dx: Vec<T>, // tile dx, (rows_in_block * d_g)
    }

    let partials: Vec<Partial<T>> = par_map(&jobs, |&(blk, g)| {
        let a = c.a_row(g);
        let b = c.b_row(g);
        let r0 = blk * s_block;
        let r1 = (r0 + s_block).min(rows);
        let tile = (r1 - r0) * d_g;
        let mut dx_tile = Vec::with_capacity(tile);
        let mut da_e = vec![T::ZERO; m1];
        let mut db_e = vec![T::ZERO; n];
        // Streaming accumulation, O(log) state per coefficient: pairwise
        // carry-stacks for the tree variant, plain sums for the ablation.
        let mut tree_a: Vec<PairwiseAcc<T>> = vec![PairwiseAcc::default(); m1];
        let mut tree_b: Vec<PairwiseAcc<T>> = vec![PairwiseAcc::default(); n];
        let mut seq_a = vec![T::ZERO; m1];
        let mut seq_b = vec![T::ZERO; n];
        // Chunked pairwise (numpy-style): sequential runs of RUN elements
        // feed the carry stack — register-speed, tree-class rounding.
        const RUN: usize = 64;
        let mut run = 0usize;
        for r in r0..r1 {
            for k in 0..d_g {
                let idx = r * d + g * d_g + k;
                let dxv = backward_elem(x[idx], dout[idx], a, b, &mut da_e, &mut db_e);
                dx_tile.push(dxv);
                for i in 0..m1 {
                    seq_a[i] = T::from_f64(seq_a[i].to_f64() + da_e[i].to_f64());
                }
                for j in 0..n {
                    seq_b[j] = T::from_f64(seq_b[j].to_f64() + db_e[j].to_f64());
                }
                run += 1;
                if tree && run == RUN {
                    for i in 0..m1 {
                        tree_a[i].push(seq_a[i]);
                        seq_a[i] = T::ZERO;
                    }
                    for j in 0..n {
                        tree_b[j].push(seq_b[j]);
                        seq_b[j] = T::ZERO;
                    }
                    run = 0;
                }
            }
        }
        let (da, db) = if tree {
            if run > 0 {
                for i in 0..m1 {
                    tree_a[i].push(seq_a[i]);
                }
                for j in 0..n {
                    tree_b[j].push(seq_b[j]);
                }
            }
            (
                tree_a.iter().map(PairwiseAcc::finish).collect(),
                tree_b.iter().map(PairwiseAcc::finish).collect(),
            )
        } else {
            (seq_a, seq_b)
        };
        Partial { blk, g, da, db, dx: dx_tile }
    });

    // Scatter dx tiles and accumulate the per-block partials in block order.
    let mut dx = vec![T::ZERO; x.len()];
    let mut da = vec![T::ZERO; n_g * m1];
    let mut db = vec![T::ZERO; n_g * n];
    for p in &partials {
        let r0 = p.blk * s_block;
        let r1 = (r0 + s_block).min(rows);
        for (t, r) in (r0..r1).enumerate() {
            let src = &p.dx[t * d_g..(t + 1) * d_g];
            let dst = &mut dx[r * d + p.g * d_g..r * d + (p.g + 1) * d_g];
            dst.copy_from_slice(src);
        }
    }
    let mut ordered: Vec<&Partial<T>> = partials.iter().collect();
    ordered.sort_by_key(|p| (p.g, p.blk));
    for p in ordered {
        for i in 0..m1 {
            da[p.g * m1 + i] = T::from_f64(da[p.g * m1 + i].to_f64() + p.da[i].to_f64());
        }
        for j in 0..n {
            db[p.g * n + j] = T::from_f64(db[p.g * n + j].to_f64() + p.db[j].to_f64());
        }
    }
    (dx, da, db)
}

fn backward_pairwise_full<T: Float>(
    x: &[T],
    dout: &[T],
    rows: usize,
    d: usize,
    c: &Coeffs<T>,
) -> (Vec<T>, Vec<T>, Vec<T>) {
    let d_g = d / c.n_groups;
    let (m1, n, n_g) = (c.m1, c.n, c.n_groups);
    let mut dx = vec![T::ZERO; x.len()];
    let mut da = vec![T::ZERO; n_g * m1];
    let mut db = vec![T::ZERO; n_g * n];
    let mut da_e = vec![T::ZERO; m1];
    let mut db_e = vec![T::ZERO; n];
    for g in 0..n_g {
        let a = c.a_row(g);
        let b = c.b_row(g);
        let tile = rows * d_g;
        let mut contrib_a: Vec<Vec<T>> = (0..m1).map(|_| Vec::with_capacity(tile)).collect();
        let mut contrib_b: Vec<Vec<T>> = (0..n).map(|_| Vec::with_capacity(tile)).collect();
        for r in 0..rows {
            for k in 0..d_g {
                let idx = r * d + g * d_g + k;
                dx[idx] = backward_elem(x[idx], dout[idx], a, b, &mut da_e, &mut db_e);
                for i in 0..m1 {
                    contrib_a[i].push(da_e[i]);
                }
                for j in 0..n {
                    contrib_b[j].push(db_e[j]);
                }
            }
        }
        for i in 0..m1 {
            da[g * m1 + i] = tree_sum(&mut contrib_a[i]);
        }
        for j in 0..n {
            db[g * n + j] = tree_sum(&mut contrib_b[j]);
        }
    }
    (dx, da, db)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn case(rows: usize, d: usize, n_g: usize, seed: u64) -> (Vec<f64>, Vec<f64>, Coeffs<f64>) {
        let mut rng = Pcg64::new(seed);
        let x: Vec<f64> = (0..rows * d).map(|_| rng.normal()).collect();
        let dout: Vec<f64> = (0..rows * d).map(|_| rng.normal()).collect();
        let c = Coeffs::<f64>::randn(n_g, 6, 4, &mut rng);
        (x, dout, c)
    }

    #[test]
    fn tree_sum_matches_sequential_in_f64() {
        let mut rng = Pcg64::new(1);
        let vals: Vec<f64> = (0..1000).map(|_| rng.normal()).collect();
        let seq: f64 = vals.iter().sum();
        let mut buf = vals.clone();
        assert!((tree_sum(&mut buf) - seq).abs() < 1e-9);
    }

    #[test]
    fn all_strategies_agree_in_f64() {
        let (x, dout, c) = case(37, 32, 4, 2);
        let (dx0, da0, db0) = backward(&x, &dout, 37, 32, &c, Strategy::Sequential);
        for strat in [
            Strategy::BlockTree { s_block: 8 },
            Strategy::BlockSequential { s_block: 8 },
            Strategy::PairwiseFull,
        ] {
            let (dx, da, db) = backward(&x, &dout, 37, 32, &c, strat);
            for (u, v) in dx.iter().zip(&dx0) {
                assert!((u - v).abs() < 1e-12);
            }
            for (u, v) in da.iter().zip(&da0) {
                assert!((u - v).abs() * 1e9 < da0.iter().map(|z| z.abs()).fold(1.0, f64::max), "{strat:?}");
            }
            for (u, v) in db.iter().zip(&db0) {
                assert!((u - v).abs() * 1e9 < db0.iter().map(|z| z.abs()).fold(1.0, f64::max), "{strat:?}");
            }
        }
    }

    #[test]
    fn f32_block_tree_closer_to_f64_than_sequential() {
        // The paper's Table 5/8 effect, in miniature.
        let rows = 2048;
        let d = 64;
        let n_g = 8;
        let (x, dout, c) = case(rows, d, n_g, 3);
        let (_, da64, _) = backward(&x, &dout, rows, d, &c, Strategy::Sequential);

        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let dof: Vec<f32> = dout.iter().map(|&v| v as f32).collect();
        let cf = c.cast::<f32>();
        let (_, da_seq, _) = backward(&xf, &dof, rows, d, &cf, Strategy::Sequential);
        let (_, da_blk, _) = backward(&xf, &dof, rows, d, &cf, Strategy::BlockTree { s_block: 64 });

        let mae = |da: &[f32]| -> f64 {
            da.iter().zip(&da64).map(|(&a, &b)| (a as f64 - b).abs()).sum::<f64>() / da.len() as f64
        };
        let (e_seq, e_blk) = (mae(&da_seq), mae(&da_blk));
        assert!(e_blk < e_seq, "block {e_blk} !< seq {e_seq}");
    }

    #[test]
    fn block_sizes_cover_remainders() {
        let (x, dout, c) = case(13, 16, 2, 4);
        for s_block in [1, 2, 5, 13, 64] {
            let (_, da, _) = backward(&x, &dout, 13, 16, &c, Strategy::BlockTree { s_block });
            let (_, da0, _) = backward(&x, &dout, 13, 16, &c, Strategy::Sequential);
            for (u, v) in da.iter().zip(&da0) {
                assert!((u - v).abs() < 1e-9, "s_block={s_block}");
            }
        }
    }

    #[test]
    fn dx_identical_across_strategies_f32() {
        // dx has no accumulation — strategies must not change it at all.
        let (x, dout, c) = case(19, 32, 4, 5);
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let dof: Vec<f32> = dout.iter().map(|&v| v as f32).collect();
        let cf = c.cast::<f32>();
        let (dx_a, _, _) = backward(&xf, &dof, 19, 32, &cf, Strategy::Sequential);
        let (dx_b, _, _) = backward(&xf, &dof, 19, 32, &cf, Strategy::BlockTree { s_block: 4 });
        assert_eq!(dx_a, dx_b);
    }
}
