//! Gradient-accumulation strategies — the experiment variable behind the
//! paper's rounding-error study (Tables 5/8).
//!
//! Algorithm 1 (KAT) accumulates every element's coefficient-gradient
//! contribution with an individual atomic add: a summation chain of length
//! B*N*d_g per coefficient.  Algorithm 2 (FlashKAT) reduces each
//! (S_block x d_g) tile in fast memory (a tree reduction) and performs one
//! global add per block: chain length ~ T + log2(S_block*d_g).  Floating-
//! point summation error grows with chain length, hence the ~2 orders of
//! magnitude MAE gap the paper reports.

use super::kernel::{self, SegAccum, SpillAcc};
use super::{backward_elem, Coeffs, Float};
use crate::util::parallel::{default_threads, par_map, par_map_capped, SendPtr};

/// How coefficient-gradient contributions are reduced into dA / dB.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Per-element global adds in flat memory order (paper Algorithm 1's
    /// atomic-add schedule; GPU order is nondeterministic, this is a
    /// representative member of the same error class).
    Sequential,
    /// FlashKAT: pairwise-tree reduction within each block of
    /// `s_block` rows, then one global add per block (paper Algorithm 2).
    BlockTree { s_block: usize },
    /// Ablation: block-local *sequential* reduction, then one global add
    /// per block.  Isolates "fewer global adds" from "tree reduction".
    BlockSequential { s_block: usize },
    /// Ablation: full pairwise-tree reduction over every contribution —
    /// the best ordering a reduction could achieve.
    PairwiseFull,
}

/// Full backward over (rows, d): returns (dx, dA, dB) with the coefficient
/// gradients accumulated per `strategy`.
pub fn backward<T: Float>(
    x: &[T],
    dout: &[T],
    rows: usize,
    d: usize,
    c: &Coeffs<T>,
    strategy: Strategy,
) -> (Vec<T>, Vec<T>, Vec<T>) {
    assert_eq!(x.len(), rows * d);
    assert_eq!(dout.len(), rows * d);
    assert_eq!(d % c.n_groups, 0);
    match strategy {
        Strategy::Sequential => backward_sequential(x, dout, rows, d, c),
        Strategy::BlockTree { s_block } => backward_block(x, dout, rows, d, c, s_block, true),
        Strategy::BlockSequential { s_block } => {
            backward_block(x, dout, rows, d, c, s_block, false)
        }
        Strategy::PairwiseFull => backward_pairwise_full(x, dout, rows, d, c),
    }
}

/// Algorithm 1's schedule.  Deliberately serial: this strategy *is* the
/// bit-exact global-ordering reference the experiment measures against.
/// The element math and the single-rounded adds go through the fast-path
/// hooks (bit-identical to the seed's f64 round-trips for f32/f64 adds).
fn backward_sequential<T: Float>(
    x: &[T],
    dout: &[T],
    rows: usize,
    d: usize,
    c: &Coeffs<T>,
) -> (Vec<T>, Vec<T>, Vec<T>) {
    let d_g = d / c.n_groups;
    let (m1, n) = (c.m1, c.n);
    let mut dx = vec![T::ZERO; x.len()];
    let mut da = vec![T::ZERO; c.n_groups * m1];
    let mut db = vec![T::ZERO; c.n_groups * n];
    let mut da_stack = [T::ZERO; kernel::MAX_M1];
    let mut db_stack = [T::ZERO; kernel::MAX_N];
    let mut da_heap;
    let mut db_heap;
    let (da_e, db_e): (&mut [T], &mut [T]) = if kernel::fits_registers(m1, n) {
        (&mut da_stack[..m1], &mut db_stack[..n])
    } else {
        da_heap = vec![T::ZERO; m1];
        db_heap = vec![T::ZERO; n];
        (&mut da_heap, &mut db_heap)
    };
    let elem = std::mem::size_of::<T>() as u64;
    for r in 0..rows {
        for g in 0..c.n_groups {
            let a = c.a_row(g);
            let b = c.b_row(g);
            // Traffic probes, aggregated per (row, group) segment: x and
            // dout stream in once, dx streams out once, coefficients are
            // fetched once — and, Algorithm-1 style, every element does a
            // read-modify-write of all (m1+n) global partials.
            {
                use crate::probe::{on_load, on_store, Phase, Stream};
                let seg = d_g as u64 * elem;
                let coef = (m1 + n) as u64 * elem;
                on_load(Phase::Backward, Stream::X, seg);
                on_load(Phase::Backward, Stream::Dout, seg);
                on_load(Phase::Backward, Stream::Coeffs, coef);
                on_store(Phase::Backward, Stream::Dx, seg);
                on_load(Phase::Backward, Stream::Partials, coef * d_g as u64);
                on_store(Phase::Backward, Stream::Partials, coef * d_g as u64);
            }
            for k in 0..d_g {
                let idx = r * d + g * d_g + k;
                dx[idx] = backward_elem(x[idx], dout[idx], a, b, da_e, db_e);
                // one "atomic add" per coefficient per element
                for i in 0..m1 {
                    da[g * m1 + i] = da[g * m1 + i].add_r(da_e[i]);
                }
                for j in 0..n {
                    db[g * n + j] = db[g * n + j].add_r(db_e[j]);
                }
            }
        }
    }
    (dx, da, db)
}

/// Streaming pairwise (tree) accumulator: maintains a carry stack of
/// power-of-two partial sums, O(log n) state, no materialized buffer.
/// This is the register-level shape of a block tree reduction (§Perf: it
/// replaced a materialize-then-reduce implementation, 1.8x faster, and is
/// numerically a pairwise tree like the kernel's `tl.sum`).
#[derive(Clone, Debug)]
pub struct PairwiseAcc<T: Float> {
    stack: [(T, u32); 48],
    len: usize,
}

impl<T: Float> Default for PairwiseAcc<T> {
    fn default() -> Self {
        Self { stack: [(T::ZERO, 0); 48], len: 0 }
    }
}

impl<T: Float> PairwiseAcc<T> {
    #[inline]
    pub fn push(&mut self, v: T) {
        let mut v = v;
        let mut count = 1u32;
        while self.len > 0 && self.stack[self.len - 1].1 == count {
            self.len -= 1;
            v = T::from_f64(v.to_f64() + self.stack[self.len].0.to_f64());
            count *= 2;
        }
        self.stack[self.len] = (v, count);
        self.len += 1;
    }

    /// Fold remaining partials (smallest first) into the total.
    pub fn finish(&self) -> T {
        let mut s = T::ZERO;
        for i in (0..self.len).rev() {
            s = T::from_f64(s.to_f64() + self.stack[i].0.to_f64());
        }
        s
    }
}

/// Pairwise-tree sum of a scratch buffer (in T precision), in place.
pub fn tree_sum<T: Float>(buf: &mut [T]) -> T {
    let mut len = buf.len();
    if len == 0 {
        return T::ZERO;
    }
    while len > 1 {
        let half = len / 2;
        for i in 0..half {
            buf[i] = T::from_f64(buf[i].to_f64() + buf[len - 1 - i].to_f64());
        }
        len -= half;
    }
    buf[0]
}

fn backward_block<T: Float>(
    x: &[T],
    dout: &[T],
    rows: usize,
    d: usize,
    c: &Coeffs<T>,
    s_block: usize,
    tree: bool,
) -> (Vec<T>, Vec<T>, Vec<T>) {
    let d_g = d / c.n_groups;
    let (m1, n, n_g) = (c.m1, c.n, c.n_groups);
    let s_block = s_block.max(1);
    let n_blocks = rows.div_ceil(s_block);

    // Per-(block, group) partials computed in parallel (mirrors the 2-D
    // grid of Algorithm 2), then accumulated over blocks in block order
    // (the serialized atomic adds).  Each tile streams its x/dout exactly
    // once and writes its dx span directly into the output buffer; the
    // register accumulators live in `kernel::TileAcc` (spill twin for
    // coefficient counts above the caps — bit-identical ordering).
    let jobs: Vec<(usize, usize)> = (0..n_blocks)
        .flat_map(|blk| (0..n_g).map(move |g| (blk, g)))
        .collect();

    struct Partial<T> {
        blk: usize,
        g: usize,
        da: Vec<T>,
        db: Vec<T>,
    }

    let mut dx = vec![T::ZERO; x.len()];
    let dx_base = SendPtr(dx.as_mut_ptr());
    let use_registers = kernel::fits_registers(m1, n);

    let partials: Vec<Partial<T>> = par_map(&jobs, |&(blk, g)| {
        let a = c.a_row(g);
        let b = c.b_row(g);
        let r0 = blk * s_block;
        let r1 = (r0 + s_block).min(rows);
        // Traffic probes, aggregated per (block, group) tile: each tile
        // streams its x/dout spans once, writes its dx spans once,
        // fetches the coefficients once, and emits one set of (m1+n)
        // partials — Algorithm 2's per-block global add.  This sits
        // above the `Float::Acc` seam, so it covers the scalar TileAcc
        // and the SIMD twin alike.
        {
            use crate::probe::{on_load, on_store, Phase, Stream};
            let tile = ((r1 - r0) * d_g) as u64 * std::mem::size_of::<T>() as u64;
            let coef = ((m1 + n) * std::mem::size_of::<T>()) as u64;
            on_load(Phase::Backward, Stream::X, tile);
            on_load(Phase::Backward, Stream::Dout, tile);
            on_load(Phase::Backward, Stream::Coeffs, coef);
            on_store(Phase::Backward, Stream::Dx, tile);
            on_store(Phase::Backward, Stream::Partials, coef);
        }
        if use_registers {
            // The accumulator is the type's `Float::Acc`: scalar TileAcc
            // by default, the SIMD twin for f32/f64 under the `simd`
            // feature — bit-identical either way (DESIGN.md §14).
            let mut acc = <T::Acc as SegAccum<T>>::new(m1, n, tree);
            for r in r0..r1 {
                let base = r * d + g * d_g;
                // SAFETY: each (blk, g) job owns a disjoint set of dx
                // indices (rows r0..r1 of group g's columns) and the dx
                // Vec outlives par_map.
                let dx_seg =
                    unsafe { std::slice::from_raw_parts_mut(dx_base.0.add(base), d_g) };
                acc.row_seg(&x[base..base + d_g], &dout[base..base + d_g], dx_seg, a, b);
            }
            let (da, db) = acc.finish();
            Partial { blk, g, da: da[..m1].to_vec(), db: db[..n].to_vec() }
        } else {
            let mut acc = SpillAcc::new(m1, n, tree);
            for r in r0..r1 {
                let base = r * d + g * d_g;
                // SAFETY: as above — disjoint dx spans per job.
                let dx_seg =
                    unsafe { std::slice::from_raw_parts_mut(dx_base.0.add(base), d_g) };
                acc.row_seg(&x[base..base + d_g], &dout[base..base + d_g], dx_seg, a, b);
            }
            let (da, db) = acc.finish();
            Partial { blk, g, da, db }
        }
    });

    // Accumulate the per-block partials in block order (the serialized
    // global adds of Algorithm 2).
    let mut da = vec![T::ZERO; n_g * m1];
    let mut db = vec![T::ZERO; n_g * n];
    let mut ordered: Vec<&Partial<T>> = partials.iter().collect();
    ordered.sort_by_key(|p| (p.g, p.blk));
    let coef = ((m1 + n) * std::mem::size_of::<T>()) as u64;
    for p in ordered {
        // Reduce-phase traffic: each per-block partial is read once and
        // read-modify-written into the global dA/dB rows.
        crate::probe::on_load(crate::probe::Phase::Reduce, crate::probe::Stream::Partials, coef);
        crate::probe::on_store(crate::probe::Phase::Reduce, crate::probe::Stream::Partials, coef);
        for i in 0..m1 {
            da[p.g * m1 + i] = da[p.g * m1 + i].add_r(p.da[i]);
        }
        for j in 0..n {
            db[p.g * n + j] = db[p.g * n + j].add_r(p.db[j]);
        }
    }
    (dx, da, db)
}

/// Best-case ordering ablation: full pairwise tree over every
/// contribution.  Groups are independent, so they run in parallel on the
/// worker pool (deterministic: each group's materialize-then-reduce is
/// self-contained and dx spans are disjoint).
fn backward_pairwise_full<T: Float>(
    x: &[T],
    dout: &[T],
    rows: usize,
    d: usize,
    c: &Coeffs<T>,
) -> (Vec<T>, Vec<T>, Vec<T>) {
    let d_g = d / c.n_groups;
    let (m1, n, n_g) = (c.m1, c.n, c.n_groups);
    let mut dx = vec![T::ZERO; x.len()];
    let dx_base = SendPtr(dx.as_mut_ptr());
    let groups: Vec<usize> = (0..n_g).collect();
    // Each in-flight group materializes (m1+n) buffers of rows*d_g
    // contributions; cap concurrency so the total stays around ~1 GiB
    // regardless of scalar width (the seed held one group at a time — at
    // paper dims this degrades to that, while small ablation dims use the
    // full pool).
    let per_group_bytes = rows * d_g * (m1 + n) * std::mem::size_of::<T>();
    let cap = ((1usize << 30) / per_group_bytes.max(1)).clamp(1, default_threads());
    let per_group: Vec<(Vec<T>, Vec<T>)> = par_map_capped(&groups, cap, |&g| {
        let a = c.a_row(g);
        let b = c.b_row(g);
        let tile = rows * d_g;
        let mut da_e = vec![T::ZERO; m1];
        let mut db_e = vec![T::ZERO; n];
        let mut contrib_a: Vec<Vec<T>> = (0..m1).map(|_| Vec::with_capacity(tile)).collect();
        let mut contrib_b: Vec<Vec<T>> = (0..n).map(|_| Vec::with_capacity(tile)).collect();
        for r in 0..rows {
            let base = r * d + g * d_g;
            // SAFETY: group g owns a disjoint set of dx columns; the Vec
            // outlives par_map.
            let dx_seg = unsafe { std::slice::from_raw_parts_mut(dx_base.0.add(base), d_g) };
            for k in 0..d_g {
                dx_seg[k] =
                    backward_elem(x[base + k], dout[base + k], a, b, &mut da_e, &mut db_e);
                for i in 0..m1 {
                    contrib_a[i].push(da_e[i]);
                }
                for j in 0..n {
                    contrib_b[j].push(db_e[j]);
                }
            }
        }
        (
            contrib_a.iter_mut().map(|buf| tree_sum(buf)).collect(),
            contrib_b.iter_mut().map(|buf| tree_sum(buf)).collect(),
        )
    });
    let mut da = vec![T::ZERO; n_g * m1];
    let mut db = vec![T::ZERO; n_g * n];
    for (g, (da_g, db_g)) in per_group.iter().enumerate() {
        da[g * m1..(g + 1) * m1].copy_from_slice(da_g);
        db[g * n..(g + 1) * n].copy_from_slice(db_g);
    }
    (dx, da, db)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn case(rows: usize, d: usize, n_g: usize, seed: u64) -> (Vec<f64>, Vec<f64>, Coeffs<f64>) {
        let mut rng = Pcg64::new(seed);
        let x: Vec<f64> = (0..rows * d).map(|_| rng.normal()).collect();
        let dout: Vec<f64> = (0..rows * d).map(|_| rng.normal()).collect();
        let c = Coeffs::<f64>::randn(n_g, 6, 4, &mut rng);
        (x, dout, c)
    }

    #[test]
    fn tree_sum_matches_sequential_in_f64() {
        let mut rng = Pcg64::new(1);
        let vals: Vec<f64> = (0..1000).map(|_| rng.normal()).collect();
        let seq: f64 = vals.iter().sum();
        let mut buf = vals.clone();
        assert!((tree_sum(&mut buf) - seq).abs() < 1e-9);
    }

    #[test]
    fn all_strategies_agree_in_f64() {
        let (x, dout, c) = case(37, 32, 4, 2);
        let (dx0, da0, db0) = backward(&x, &dout, 37, 32, &c, Strategy::Sequential);
        for strat in [
            Strategy::BlockTree { s_block: 8 },
            Strategy::BlockSequential { s_block: 8 },
            Strategy::PairwiseFull,
        ] {
            let (dx, da, db) = backward(&x, &dout, 37, 32, &c, strat);
            for (u, v) in dx.iter().zip(&dx0) {
                assert!((u - v).abs() < 1e-12);
            }
            for (u, v) in da.iter().zip(&da0) {
                assert!((u - v).abs() * 1e9 < da0.iter().map(|z| z.abs()).fold(1.0, f64::max), "{strat:?}");
            }
            for (u, v) in db.iter().zip(&db0) {
                assert!((u - v).abs() * 1e9 < db0.iter().map(|z| z.abs()).fold(1.0, f64::max), "{strat:?}");
            }
        }
    }

    #[test]
    fn f32_block_tree_closer_to_f64_than_sequential() {
        // The paper's Table 5/8 effect, in miniature.
        let rows = 2048;
        let d = 64;
        let n_g = 8;
        let (x, dout, c) = case(rows, d, n_g, 3);
        let (_, da64, _) = backward(&x, &dout, rows, d, &c, Strategy::Sequential);

        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let dof: Vec<f32> = dout.iter().map(|&v| v as f32).collect();
        let cf = c.cast::<f32>();
        let (_, da_seq, _) = backward(&xf, &dof, rows, d, &cf, Strategy::Sequential);
        let (_, da_blk, _) = backward(&xf, &dof, rows, d, &cf, Strategy::BlockTree { s_block: 64 });

        let mae = |da: &[f32]| -> f64 {
            da.iter().zip(&da64).map(|(&a, &b)| (a as f64 - b).abs()).sum::<f64>() / da.len() as f64
        };
        let (e_seq, e_blk) = (mae(&da_seq), mae(&da_blk));
        assert!(e_blk < e_seq, "block {e_blk} !< seq {e_seq}");
    }

    #[test]
    fn block_sizes_cover_remainders() {
        let (x, dout, c) = case(13, 16, 2, 4);
        for s_block in [1, 2, 5, 13, 64] {
            let (_, da, _) = backward(&x, &dout, 13, 16, &c, Strategy::BlockTree { s_block });
            let (_, da0, _) = backward(&x, &dout, 13, 16, &c, Strategy::Sequential);
            for (u, v) in da.iter().zip(&da0) {
                assert!((u - v).abs() < 1e-9, "s_block={s_block}");
            }
        }
    }

    #[test]
    fn dx_identical_across_strategies_f32() {
        // dx has no accumulation — strategies must not change it at all.
        let (x, dout, c) = case(19, 32, 4, 5);
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let dof: Vec<f32> = dout.iter().map(|&v| v as f32).collect();
        let cf = c.cast::<f32>();
        let (dx_a, _, _) = backward(&xf, &dof, 19, 32, &cf, Strategy::Sequential);
        let (dx_b, _, _) = backward(&xf, &dof, 19, 32, &cf, Strategy::BlockTree { s_block: 4 });
        assert_eq!(dx_a, dx_b);
    }
}
