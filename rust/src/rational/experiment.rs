//! The rounding-error experiment (paper Tables 5/8).
//!
//! Generate X, dO ~ N(0,1) and coefficients ~ N(0,1); compute dA/dB with
//! the KAT schedule (f32, sequential atomic order), the FlashKAT schedule
//! (f32, block tree reduction), and the f64 oracle; report the MAE between
//! each f32 result and the oracle over `passes` independent passes, with
//! 95% confidence intervals and variances — the exact columns of Table 8.
//!
//! Both the per-pass loop and the f64 oracle use deterministic parallel
//! schedules (worker pool): passes are RNG-independent, and in f64 the
//! oracle's ordering noise (~1e-16 relative) is invisible next to the
//! ~1e-6 f32 accumulation errors under study.

use super::accumulate::{backward, Strategy};
use super::Coeffs;
use crate::util::parallel::{default_threads, par_map_capped};
use crate::util::rng::Pcg64;
use crate::util::stats::OnlineStats;

/// Upper bound on concurrently-running passes: each in-flight pass holds
/// ~6 `rows*d` buffers, so full pool width would multiply peak memory by
/// 16x at the paper-scale dims.  Inner backwards nested inside a pass
/// worker fall back to serial automatically (see util::parallel).
const MAX_PASS_WIDTH: usize = 4;

#[derive(Clone, Debug)]
pub struct RoundingConfig {
    pub rows: usize,      // B*N collapsed (paper: 1024*197)
    pub d: usize,         // paper: 768
    pub n_groups: usize,  // paper: 8
    pub m1: usize,        // paper: 6
    pub n: usize,         // paper: 4
    pub s_block: usize,   // FlashKAT block rows
    pub passes: usize,    // paper: 100
    pub seed: u64,
}

impl Default for RoundingConfig {
    fn default() -> Self {
        // CPU-scaled dims (paper used 1024x197x768 on a 4060 Ti); the MAE
        // *ratio* between schedules is what must reproduce.
        Self { rows: 96 * 197, d: 768, n_groups: 8, m1: 6, n: 4, s_block: 128, passes: 10, seed: 0 }
    }
}

#[derive(Clone, Debug)]
pub struct GradError {
    pub mae_mean: f64,
    pub mae_ci95: f64,
    pub variance: f64,
}

#[derive(Clone, Debug)]
pub struct RoundingReport {
    pub cfg_desc: String,
    pub kat_da: GradError,
    pub kat_db: GradError,
    pub flash_da: GradError,
    pub flash_db: GradError,
}

impl RoundingReport {
    /// Ratio of KAT to FlashKAT dA MAE — the paper's "~2 orders" headline.
    pub fn improvement_da(&self) -> f64 {
        self.kat_da.mae_mean / self.flash_da.mae_mean
    }

    pub fn improvement_db(&self) -> f64 {
        self.kat_db.mae_mean / self.flash_db.mae_mean
    }
}

fn mae(f32s: &[f32], f64s: &[f64]) -> f64 {
    f32s.iter().zip(f64s).map(|(&a, &b)| (a as f64 - b).abs()).sum::<f64>() / f32s.len() as f64
}

fn grad_error(maes: &[f64]) -> GradError {
    let mut st = OnlineStats::new();
    for &m in maes {
        st.push(m);
    }
    GradError { mae_mean: st.mean(), mae_ci95: st.ci95(), variance: st.var() }
}

/// Run the full experiment.  Returns the per-strategy MAE statistics.
///
/// Passes are independent (each derives its own RNG stream from
/// `seed + pass`) and run on the worker pool with a deterministic
/// schedule — results are identical to the serial loop at any width.
/// The f64 oracle uses the block-tree schedule: in f64 the
/// ordering-induced difference vs. the sequential order is ~1e-16
/// relative — far below the f32 effects being measured.  Its 2-D job
/// grid parallelizes when the oracle runs outside pass-level
/// parallelism (passes == 1); with multiple passes in flight the
/// nested backward serializes inside each pass worker and the
/// parallelism comes from the pass level instead.
pub fn run(cfg: &RoundingConfig) -> RoundingReport {
    let pass_ids: Vec<usize> = (0..cfg.passes).collect();
    let width = default_threads().min(MAX_PASS_WIDTH);
    let maes: Vec<[f64; 4]> = par_map_capped(&pass_ids, width, |&pass| {
        let mut rng = Pcg64::new(cfg.seed.wrapping_add(pass as u64));
        let n_el = cfg.rows * cfg.d;
        let x64: Vec<f64> = (0..n_el).map(|_| rng.normal()).collect();
        let do64: Vec<f64> = (0..n_el).map(|_| rng.normal()).collect();
        let c64 = Coeffs::<f64>::randn(cfg.n_groups, cfg.m1, cfg.n, &mut rng);

        let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
        let do32: Vec<f32> = do64.iter().map(|&v| v as f32).collect();
        let c32 = c64.cast::<f32>();

        // f64 oracle (the paper computes the KAT method in float64).
        let (_, da64, db64) = backward(
            &x64,
            &do64,
            cfg.rows,
            cfg.d,
            &c64,
            Strategy::BlockTree { s_block: cfg.s_block },
        );

        let (_, da_kat, db_kat) =
            backward(&x32, &do32, cfg.rows, cfg.d, &c32, Strategy::Sequential);
        let (_, da_fl, db_fl) = backward(
            &x32,
            &do32,
            cfg.rows,
            cfg.d,
            &c32,
            Strategy::BlockTree { s_block: cfg.s_block },
        );

        [
            mae(&da_kat, &da64),
            mae(&db_kat, &db64),
            mae(&da_fl, &da64),
            mae(&db_fl, &db64),
        ]
    });
    let col = |i: usize| -> Vec<f64> { maes.iter().map(|m| m[i]).collect() };
    let (kat_da_maes, kat_db_maes) = (col(0), col(1));
    let (flash_da_maes, flash_db_maes) = (col(2), col(3));

    RoundingReport {
        cfg_desc: format!(
            "X,dO in R^({}x{}), A in R^({}x{}), B in R^({}x{}), {} passes",
            cfg.rows, cfg.d, cfg.n_groups, cfg.m1, cfg.n_groups, cfg.n, cfg.passes
        ),
        kat_da: grad_error(&kat_da_maes),
        kat_db: grad_error(&kat_db_maes),
        flash_da: grad_error(&flash_da_maes),
        flash_db: grad_error(&flash_db_maes),
    }
}

/// Low-precision extension (the paper's Appendix hypothesis): rerun the
/// study with **bfloat16** gradients, where accumulation order matters far
/// more (8-bit mantissa).  Returns (kat_da, flash_da) MAE statistics.
pub fn run_bf16(cfg: &RoundingConfig) -> (GradError, GradError) {
    use super::Bf16;
    use crate::tensor::Scalar;
    let pass_ids: Vec<usize> = (0..cfg.passes).collect();
    let width = default_threads().min(MAX_PASS_WIDTH);
    let maes: Vec<[f64; 2]> = par_map_capped(&pass_ids, width, |&pass| {
        let mut rng = Pcg64::new(cfg.seed.wrapping_add(0xbf16 + pass as u64));
        let n_el = cfg.rows * cfg.d;
        let x64: Vec<f64> = (0..n_el).map(|_| rng.normal()).collect();
        let do64: Vec<f64> = (0..n_el).map(|_| rng.normal()).collect();
        let c64 = Coeffs::<f64>::randn(cfg.n_groups, cfg.m1, cfg.n, &mut rng);
        let (_, da64, _) = backward(
            &x64,
            &do64,
            cfg.rows,
            cfg.d,
            &c64,
            Strategy::BlockTree { s_block: cfg.s_block },
        );

        let xb: Vec<Bf16> = x64.iter().map(|&v| Bf16::from_f32(v as f32)).collect();
        let dob: Vec<Bf16> = do64.iter().map(|&v| Bf16::from_f32(v as f32)).collect();
        let cb = c64.cast::<Bf16>();
        let (_, da_kat, _) = backward(&xb, &dob, cfg.rows, cfg.d, &cb, Strategy::Sequential);
        let (_, da_fl, _) = backward(
            &xb,
            &dob,
            cfg.rows,
            cfg.d,
            &cb,
            Strategy::BlockTree { s_block: cfg.s_block },
        );
        let mae_b = |da: &[Bf16]| -> f64 {
            da.iter().zip(&da64).map(|(&a, &b)| (a.to_f64() - b).abs()).sum::<f64>()
                / da.len() as f64
        };
        [mae_b(&da_kat), mae_b(&da_fl)]
    });
    let kat_maes: Vec<f64> = maes.iter().map(|m| m[0]).collect();
    let flash_maes: Vec<f64> = maes.iter().map(|m| m[1]).collect();
    (grad_error(&kat_maes), grad_error(&flash_maes))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flash_reduces_rounding_error_by_an_order_of_magnitude() {
        // Scaled-down Table 8: the effect direction and scale must hold.
        let cfg = RoundingConfig {
            rows: 4096,
            d: 96,
            n_groups: 8,
            m1: 6,
            n: 4,
            s_block: 64,
            passes: 3,
            seed: 7,
        };
        let rep = run(&cfg);
        assert!(
            rep.improvement_da() > 5.0,
            "dA improvement only {:.2}x (kat {:.3e} flash {:.3e})",
            rep.improvement_da(),
            rep.kat_da.mae_mean,
            rep.flash_da.mae_mean
        );
        // dB carries heavy-tailed P/Q^2 * x^j terms whose element-level f32
        // error is a shared floor; the accumulation-order gap grows with
        // chain length (see benches/table5_rounding at larger dims: >14x).
        assert!(rep.improvement_db() > 1.1, "dB improvement {:.2}x", rep.improvement_db());
        // sanity: errors are positive and finite
        for e in [&rep.kat_da, &rep.kat_db, &rep.flash_da, &rep.flash_db] {
            assert!(e.mae_mean.is_finite() && e.mae_mean > 0.0);
        }
    }

    #[test]
    fn bf16_rounding_gap_persists_at_low_precision() {
        // Paper Appendix hypothesis: the ordering benefit should matter for
        // low-precision training.  In bf16 both schedules get worse, and
        // tree accumulation remains meaningfully better.
        let cfg = RoundingConfig {
            rows: 2048,
            d: 96,
            n_groups: 8,
            m1: 6,
            n: 4,
            s_block: 64,
            passes: 3,
            seed: 11,
        };
        let (kat, flash) = run_bf16(&cfg);
        assert!(kat.mae_mean.is_finite() && flash.mae_mean.is_finite());
        assert!(kat.mae_mean > 1.5 * flash.mae_mean, "kat {} flash {}", kat.mae_mean, flash.mae_mean);
        // and bf16 errors dwarf the f32 ones at the same dims
        let f32rep = run(&cfg);
        assert!(kat.mae_mean > 5.0 * f32rep.kat_da.mae_mean);
    }

    #[test]
    fn bf16_scalar_semantics() {
        use crate::rational::{Bf16, Float};
        use crate::tensor::Scalar;
        assert_eq!(Bf16::from_f32(1.0).to_f32(), 1.0);
        assert_eq!(Bf16::ONE.to_f32(), 1.0);
        assert_eq!(Bf16::from_f32(-2.5).abs().to_f32(), 2.5);
        assert_eq!(Bf16::from_f32(0.0).signum0().to_f32(), 0.0);
        assert_eq!(Bf16::from_f32(-7.0).signum0().to_f32(), -1.0);
        // round-to-nearest-even: 1 + 2^-9 rounds back to 1 in bf16
        let x = 1.0f32 + 2f32.powi(-9);
        assert_eq!(Bf16::from_f32(x).to_f32(), 1.0);
        // ~3 decimal digits of precision survive
        let y = Bf16::from_f32(3.14159).to_f32();
        assert!((y - 3.14159).abs() < 0.01);
    }
}
