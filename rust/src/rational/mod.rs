//! Group-wise rational function (safe PAU) on the host — the Rust-side
//! oracle and the substrate for the rounding-error study (paper Tables 5/8).
//!
//! The math mirrors `python/compile/kernels/ref.py` (paper Eqs. 6-11); the
//! *accumulation strategies* in [`accumulate`] mirror the memory schedules
//! of paper Algorithms 1 and 2, whose floating-point summation orders are
//! what produce the paper's rounding-error gap.
//!
//! Element math is layered (DESIGN.md §4): the `*_ref` functions here are
//! the generic semantics oracle (every op rounded into `T` by a f64
//! round-trip, which is what lets [`Bf16`] and any future software format
//! run the experiment), while [`kernel`] provides monomorphized f32/f64
//! fast paths that the [`Float`] trait hooks dispatch to.

pub mod accumulate;
pub mod experiment;
pub mod kernel;
#[cfg(feature = "simd")]
pub mod simd;

use crate::tensor::Scalar;

/// Per-group PAU coefficients: `a` has m+1 entries (x^0..x^m), `b` has n
/// entries (x^1..x^n).  The paper's configuration is m+1 = 6, n = 4.
#[derive(Clone, Debug)]
pub struct Coeffs<T: Scalar> {
    pub n_groups: usize,
    pub a: Vec<T>, // [n_groups][m1] row-major
    pub b: Vec<T>, // [n_groups][n]
    pub m1: usize,
    pub n: usize,
}

impl<T: Scalar> Coeffs<T> {
    pub fn new(n_groups: usize, m1: usize, n: usize, a: Vec<T>, b: Vec<T>) -> Self {
        assert_eq!(a.len(), n_groups * m1);
        assert_eq!(b.len(), n_groups * n);
        Self { n_groups, a, b, m1, n }
    }

    pub fn randn(n_groups: usize, m1: usize, n: usize, rng: &mut crate::util::rng::Pcg64) -> Self {
        let a = (0..n_groups * m1).map(|_| T::from_f64(rng.normal())).collect();
        let b = (0..n_groups * n).map(|_| T::from_f64(rng.normal())).collect();
        Self { n_groups, a, b, m1, n }
    }

    #[inline]
    pub fn a_row(&self, g: usize) -> &[T] {
        &self.a[g * self.m1..(g + 1) * self.m1]
    }

    #[inline]
    pub fn b_row(&self, g: usize) -> &[T] {
        &self.b[g * self.n..(g + 1) * self.n]
    }

    /// Check that a feature width can be served by this table: positive
    /// and an exact multiple of the group count.  [`forward_into`]
    /// asserts the same invariant; executors (`serve::RationalExecutor`)
    /// call this at registration time so a bad width is a clean `Err` at
    /// model-load instead of a panic on the serving thread.
    pub fn validate_width(&self, d: usize) -> anyhow::Result<()> {
        if d == 0 || d % self.n_groups != 0 {
            anyhow::bail!(
                "width {d} is not a positive multiple of n_groups={}",
                self.n_groups
            );
        }
        Ok(())
    }

    pub fn cast<U: Scalar>(&self) -> Coeffs<U> {
        Coeffs {
            n_groups: self.n_groups,
            a: self.a.iter().map(|x| U::from_f64(x.to_f64())).collect(),
            b: self.b.iter().map(|x| U::from_f64(x.to_f64())).collect(),
            m1: self.m1,
            n: self.n,
        }
    }
}

/// Arithmetic needed beyond `Scalar` for the rational math.
pub trait Float: Scalar {
    /// Tile accumulator driving `backward_block`'s register path: the
    /// scalar [`kernel::TileAcc`] everywhere, except f32/f64 under
    /// `--features simd`, which name the lane-parallel twin in [`simd`]
    /// (bit-identical by construction — DESIGN.md §14).
    type Acc: kernel::SegAccum<Self>;

    fn abs(self) -> Self;
    fn signum0(self) -> Self; // sign with signum0(0) == 0, matching jnp.sign
    fn mul_add2(self, a: Self, b: Self) -> Self;

    /// Per-element forward fast path.  The default is the generic
    /// round-trip reference; f32/f64 override with the monomorphized
    /// native kernel in [`kernel`] (f64: bit-identical, f32: bit-identical
    /// — every forward step is a single rounded op in both versions).
    #[inline]
    fn forward_elem_fast(x: Self, a: &[Self], b: &[Self]) -> Self {
        forward_elem_ref(x, a, b)
    }

    /// Per-element fused backward fast path; default = reference.  The
    /// f32 override differs from the reference by ≤ ~1 ulp on fused
    /// multi-op expressions (dx, dB); dA contributions stay bit-identical
    /// (see tests/kernel_parity.rs for the enforced bounds).
    #[inline]
    fn backward_elem_fast(
        x: Self,
        dout: Self,
        a: &[Self],
        b: &[Self],
        da_out: &mut [Self],
        db_out: &mut [Self],
    ) -> Self {
        backward_elem_ref(x, dout, a, b, da_out, db_out)
    }

    /// Forward over one contiguous `(row, group)` segment (all elements
    /// share `a`/`b`).  The default is the per-element fast path in a
    /// loop; f32/f64 under `--features simd` override with the
    /// lane-parallel kernel (bit-identical per element — DESIGN.md §14).
    #[inline]
    fn forward_seg_fast(xs: &[Self], out: &mut [Self], a: &[Self], b: &[Self]) {
        debug_assert_eq!(xs.len(), out.len());
        for (o, &x) in out.iter_mut().zip(xs) {
            *o = Self::forward_elem_fast(x, a, b);
        }
    }
}

impl Float for f32 {
    #[cfg(not(feature = "simd"))]
    type Acc = kernel::TileAcc<f32>;
    #[cfg(feature = "simd")]
    type Acc = simd::SimdSegAcc32;

    #[inline]
    fn abs(self) -> Self {
        self.abs()
    }
    #[inline]
    fn signum0(self) -> Self {
        if self > 0.0 {
            1.0
        } else if self < 0.0 {
            -1.0
        } else {
            0.0
        }
    }
    #[inline]
    fn mul_add2(self, a: Self, b: Self) -> Self {
        self * a + b
    }
    #[inline]
    fn forward_elem_fast(x: Self, a: &[Self], b: &[Self]) -> Self {
        kernel::forward_elem_native(x, a, b)
    }
    #[inline]
    fn backward_elem_fast(
        x: Self,
        dout: Self,
        a: &[Self],
        b: &[Self],
        da_out: &mut [Self],
        db_out: &mut [Self],
    ) -> Self {
        kernel::backward_elem_native(x, dout, a, b, da_out, db_out)
    }

    #[cfg(feature = "simd")]
    #[inline]
    fn forward_seg_fast(xs: &[Self], out: &mut [Self], a: &[Self], b: &[Self]) {
        simd::k32::forward_seg(xs, out, a, b)
    }
}

impl Float for f64 {
    #[cfg(not(feature = "simd"))]
    type Acc = kernel::TileAcc<f64>;
    #[cfg(feature = "simd")]
    type Acc = simd::SimdSegAcc64;

    #[inline]
    fn abs(self) -> Self {
        self.abs()
    }
    #[inline]
    fn signum0(self) -> Self {
        if self > 0.0 {
            1.0
        } else if self < 0.0 {
            -1.0
        } else {
            0.0
        }
    }
    #[inline]
    fn mul_add2(self, a: Self, b: Self) -> Self {
        self * a + b
    }
    #[inline]
    fn forward_elem_fast(x: Self, a: &[Self], b: &[Self]) -> Self {
        kernel::forward_elem_native(x, a, b)
    }
    #[inline]
    fn backward_elem_fast(
        x: Self,
        dout: Self,
        a: &[Self],
        b: &[Self],
        da_out: &mut [Self],
        db_out: &mut [Self],
    ) -> Self {
        kernel::backward_elem_native(x, dout, a, b, da_out, db_out)
    }

    #[cfg(feature = "simd")]
    #[inline]
    fn forward_seg_fast(xs: &[Self], out: &mut [Self], a: &[Self], b: &[Self]) {
        simd::k64::forward_seg(xs, out, a, b)
    }
}

/// Software bfloat16 (round-to-nearest-even via f32 truncation with carry),
/// used to test the paper's low-precision hypothesis: "the reduction in
/// rounding errors from FlashKAT could be helpful for low-precision
/// training where gradient updates are more unstable" (Appendix).
#[derive(Clone, Copy, Debug, Default, PartialEq, PartialOrd)]
pub struct Bf16(pub u16);

impl Bf16 {
    #[inline]
    pub fn from_f32(x: f32) -> Self {
        let bits = x.to_bits();
        // Non-finite values (exponent all ones) must bypass the rounding
        // carry: adding 0x7fff to a NaN whose payload lives in the low
        // bits can overflow the mantissa into the Inf encoding, and plain
        // truncation of such a NaN silently produces Inf.  Keep Inf exact
        // and force the quiet bit so every NaN stays a NaN.
        if bits & 0x7f80_0000 == 0x7f80_0000 {
            let mut hi = (bits >> 16) as u16;
            if bits & 0x007f_ffff != 0 {
                hi |= 0x0040;
            }
            return Bf16(hi);
        }
        // round-to-nearest-even on the truncated 16 bits
        let rounded = bits.wrapping_add(0x7fff + ((bits >> 16) & 1));
        Bf16((rounded >> 16) as u16)
    }

    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }
}

impl crate::tensor::Scalar for Bf16 {
    fn from_f64(x: f64) -> Self {
        Bf16::from_f32(x as f32)
    }
    fn to_f64(self) -> f64 {
        self.to_f32() as f64
    }
    const ZERO: Self = Bf16(0);
    const ONE: Self = Bf16(0x3f80);
}

impl Float for Bf16 {
    type Acc = kernel::TileAcc<Bf16>;

    #[inline]
    fn abs(self) -> Self {
        Bf16(self.0 & 0x7fff)
    }
    #[inline]
    fn signum0(self) -> Self {
        let f = self.to_f32();
        if f > 0.0 {
            Bf16::from_f32(1.0)
        } else if f < 0.0 {
            Bf16::from_f32(-1.0)
        } else {
            Bf16(0)
        }
    }
    #[inline]
    fn mul_add2(self, a: Self, b: Self) -> Self {
        Bf16::from_f32(self.to_f32() * a.to_f32() + b.to_f32())
    }
}

/// Forward value F(x) = P(x) / (1 + |A(x)|) for one element.  Dispatches
/// to the type's fast path (native monomorphized kernel for f32/f64, the
/// round-trip reference otherwise).
#[inline]
pub fn forward_elem<T: Float>(x: T, a: &[T], b: &[T]) -> T {
    T::forward_elem_fast(x, a, b)
}

/// Reference forward value: every op rounded into `T` via the f64
/// round-trip.  This is the semantics oracle the fast paths are tested
/// against.
#[inline]
pub fn forward_elem_ref<T: Float>(x: T, a: &[T], b: &[T]) -> T {
    let (p, q, _) = pq_elem(x, a, b);
    T::from_f64(p.to_f64() / q.to_f64())
}

/// (P, Q, sign(A)) for one element; Horner throughout.
#[inline]
pub fn pq_elem<T: Float>(x: T, a: &[T], b: &[T]) -> (T, T, T) {
    let m1 = a.len();
    let mut p = a[m1 - 1];
    for i in (0..m1 - 1).rev() {
        p = p.mul_add2(x, a[i]);
    }
    let n = b.len();
    let mut h = b[n - 1];
    for j in (0..n - 1).rev() {
        h = h.mul_add2(x, b[j]);
    }
    let abig = T::from_f64(x.to_f64() * h.to_f64());
    let q = T::from_f64(1.0 + abig.abs().to_f64());
    (p, q, abig.signum0())
}

/// Per-element gradients (paper Eqs. 7-9), scaled by the upstream grad.
///
/// Returns `dx` and writes the m+1 dA contributions and n dB contributions
/// into the provided buffers (unreduced — accumulation order is the
/// experiment variable, see [`accumulate`]).  Dispatches to the type's
/// fast path.
#[inline]
pub fn backward_elem<T: Float>(
    x: T,
    dout: T,
    a: &[T],
    b: &[T],
    da_out: &mut [T],
    db_out: &mut [T],
) -> T {
    T::backward_elem_fast(x, dout, a, b, da_out, db_out)
}

/// Reference per-element backward: every op rounded into `T` via the f64
/// round-trip (semantics oracle; see [`backward_elem`]).
#[inline]
pub fn backward_elem_ref<T: Float>(
    x: T,
    dout: T,
    a: &[T],
    b: &[T],
    da_out: &mut [T],
    db_out: &mut [T],
) -> T {
    let m1 = a.len();
    let n = b.len();
    debug_assert_eq!(da_out.len(), m1);
    debug_assert_eq!(db_out.len(), n);

    let (p, q, sgn) = pq_elem(x, a, b);
    let inv_q = T::from_f64(1.0 / q.to_f64());
    let p_over_q2 = T::from_f64(p.to_f64() * inv_q.to_f64() * inv_q.to_f64());

    // P'(x)
    let mut dp = T::ZERO;
    if m1 > 1 {
        dp = T::from_f64(a[m1 - 1].to_f64() * (m1 - 1) as f64);
        for i in (1..m1 - 1).rev() {
            dp = T::from_f64(dp.to_f64() * x.to_f64() + a[i].to_f64() * i as f64);
        }
    }
    // A'(x)
    let mut dadx = T::from_f64(b[n - 1].to_f64() * n as f64);
    for j in (0..n - 1).rev() {
        dadx = T::from_f64(dadx.to_f64() * x.to_f64() + b[j].to_f64() * (j + 1) as f64);
    }

    let dx = T::from_f64(
        dout.to_f64() * (dp.to_f64() * inv_q.to_f64() - sgn.to_f64() * dadx.to_f64() * p_over_q2.to_f64()),
    );

    let do_q = T::from_f64(dout.to_f64() * inv_q.to_f64());
    let neg_do_spq2 = T::from_f64(-dout.to_f64() * sgn.to_f64() * p_over_q2.to_f64());
    let mut pw = T::ONE;
    for item in da_out.iter_mut().take(m1) {
        *item = T::from_f64(do_q.to_f64() * pw.to_f64());
        pw = T::from_f64(pw.to_f64() * x.to_f64());
    }
    let mut pw = x;
    for item in db_out.iter_mut().take(n) {
        *item = T::from_f64(neg_do_spq2.to_f64() * pw.to_f64());
        pw = T::from_f64(pw.to_f64() * x.to_f64());
    }
    dx
}

/// Forward over a (rows, d) buffer with grouped coefficients.  Rows are
/// independent, so the loop runs on the worker pool (elementwise — the
/// schedule cannot change any value).
pub fn forward<T: Float>(x: &[T], rows: usize, d: usize, c: &Coeffs<T>) -> Vec<T> {
    let mut out = Vec::new();
    forward_into(x, rows, d, c, &mut out);
    out
}

/// [`forward`] into a caller-owned buffer (cleared and resized to fit).
/// Serving-path variant: the executor reuses one output buffer across
/// batches instead of allocating per call.  Values are identical to
/// [`forward`] bit for bit.
pub fn forward_into<T: Float>(x: &[T], rows: usize, d: usize, c: &Coeffs<T>, out: &mut Vec<T>) {
    assert_eq!(x.len(), rows * d);
    assert_eq!(d % c.n_groups, 0);
    let d_g = d / c.n_groups;
    out.clear();
    out.resize(x.len(), T::ZERO);
    // Row-aligned parallel chunks: a lane tile never crosses a `(row,
    // group)` segment boundary, so aligning splits to whole rows (align =
    // d) is strictly stronger than lane alignment — no parallel split can
    // bisect a tile, for any lane width.
    crate::util::parallel::par_chunks_mut_aligned(out, d, d, |offset, chunk| {
        use crate::probe::{on_load, on_store, Phase, Stream};
        let elem = std::mem::size_of::<T>() as u64;
        for (row_i, out_row) in chunk.chunks_mut(d).enumerate() {
            let r = offset / d + row_i;
            let row = &x[r * d..(r + 1) * d];
            // Traffic probes count what this row's evaluation logically
            // touches: the x row once, each group's coefficient rows
            // once, the output row once (no-ops unless `--features
            // probe`; never read or written by the kernel math).
            on_load(Phase::Forward, Stream::X, d as u64 * elem);
            on_load(Phase::Forward, Stream::Coeffs, (c.n_groups * (c.m1 + c.n)) as u64 * elem);
            for g in 0..c.n_groups {
                let s = g * d_g;
                T::forward_seg_fast(&row[s..s + d_g], &mut out_row[s..s + d_g], c.a_row(g), c.b_row(g));
            }
            on_store(Phase::Forward, Stream::Y, d as u64 * elem);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn swish_coeffs() -> (Vec<f64>, Vec<f64>) {
        (
            vec![-0.0052296527, 0.5027744533, 0.4403392560, 0.5826427290, 0.2196305065, 0.0256087044],
            vec![0.3131766296, 1.0135363041, 0.0271426279, 0.0494586222],
        )
    }

    #[test]
    fn identity_coeffs_give_identity() {
        let a = [0.0f64, 1.0, 0.0, 0.0, 0.0, 0.0];
        let b = [0.0f64, 0.0, 0.0, 0.0];
        for x in [-3.0, -0.5, 0.0, 0.7, 2.0] {
            assert!((forward_elem(x, &a, &b) - x).abs() < 1e-12);
        }
    }

    #[test]
    fn swish_coeffs_approximate_silu() {
        let (a, b) = swish_coeffs();
        for i in 0..61 {
            let x = -3.0 + 0.1 * i as f64;
            let silu = x / (1.0 + (-x).exp());
            assert!((forward_elem(x, &a, &b) - silu).abs() < 0.02, "x={x}");
        }
    }

    #[test]
    fn q_is_always_at_least_one() {
        let mut rng = Pcg64::new(0);
        let a: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
        for _ in 0..1000 {
            let x = rng.normal() * 10.0;
            let (_, q, _) = pq_elem(x, &a, &b);
            assert!(q >= 1.0);
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        let mut rng = Pcg64::new(3);
        let a: Vec<f64> = (0..6).map(|_| rng.normal() * 0.5).collect();
        let b: Vec<f64> = (0..4).map(|_| rng.normal() * 0.5).collect();
        let mut da = [0.0f64; 6];
        let mut db = [0.0f64; 4];
        let eps = 1e-6;
        for _ in 0..50 {
            let x = rng.normal();
            let dout = rng.normal();
            let dx = backward_elem(x, dout, &a, &b, &mut da, &mut db);

            // d/dx
            let fd = (forward_elem(x + eps, &a, &b) - forward_elem(x - eps, &a, &b)) / (2.0 * eps);
            assert!((dx - dout * fd).abs() < 1e-5, "dx {dx} vs {}", dout * fd);

            // d/da_i
            for i in 0..6 {
                let mut ap = a.clone();
                ap[i] += eps;
                let mut am = a.clone();
                am[i] -= eps;
                let fd = (forward_elem(x, &ap, &b) - forward_elem(x, &am, &b)) / (2.0 * eps);
                assert!((da[i] - dout * fd).abs() < 1e-5, "da[{i}]");
            }
            // d/db_j
            for j in 0..4 {
                let mut bp = b.clone();
                bp[j] += eps;
                let mut bm = b.clone();
                bm[j] -= eps;
                let fd = (forward_elem(x, &a, &bp) - forward_elem(x, &a, &bm)) / (2.0 * eps);
                assert!((db[j] - dout * fd).abs() < 2e-5, "db[{j}] {} vs {}", db[j], dout * fd);
            }
        }
    }

    #[test]
    fn grouped_forward_uses_right_group() {
        // two groups: identity and 2x (a1=2)
        let c = Coeffs::<f64>::new(
            2,
            2,
            1,
            vec![0.0, 1.0, /* g1 */ 0.0, 2.0],
            vec![0.0, /* g1 */ 0.0],
        );
        let x = vec![1.0, 2.0, 3.0, 4.0]; // one row, d=4, d_g=2
        let out = forward(&x, 1, 4, &c);
        assert_eq!(out, vec![1.0, 2.0, 6.0, 8.0]);
    }

    #[test]
    fn validate_width_accepts_multiples_only() {
        let mut rng = Pcg64::new(2);
        let c = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
        assert!(c.validate_width(64).is_ok());
        assert!(c.validate_width(8).is_ok());
        assert!(c.validate_width(0).is_err());
        assert!(c.validate_width(12).is_err(), "12 % 8 != 0");
    }

    #[test]
    fn forward_into_matches_forward_and_reuses_buffer() {
        let mut rng = Pcg64::new(4);
        let c = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
        let x: Vec<f32> = (0..4 * 64).map(|_| rng.normal_f32()).collect();
        let want = forward(&x, 4, 64, &c);
        let mut out = Vec::new();
        forward_into(&x, 4, 64, &c, &mut out);
        assert_eq!(out, want);
        // Second call into the same buffer: no reallocation, same values.
        let cap = out.capacity();
        forward_into(&x, 4, 64, &c, &mut out);
        assert_eq!(out.capacity(), cap);
        assert_eq!(out, want);
    }

    #[test]
    fn bf16_nonfinite_conversions() {
        // +/-Inf survive exactly.
        assert_eq!(Bf16::from_f32(f32::INFINITY).to_f32(), f32::INFINITY);
        assert_eq!(Bf16::from_f32(f32::NEG_INFINITY).to_f32(), f32::NEG_INFINITY);
        // Every NaN stays a NaN — including ones whose payload lives
        // entirely in the low 16 bits (truncation alone would yield Inf,
        // and the seed's rounding carry could too).
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
        let low_payload_nan = f32::from_bits(0x7f80_0001);
        assert!(low_payload_nan.is_nan());
        assert!(Bf16::from_f32(low_payload_nan).to_f32().is_nan());
        let neg_nan = f32::from_bits(0xff80_0001);
        assert!(Bf16::from_f32(neg_nan).to_f32().is_nan());
        // Sign of NaN is preserved.
        assert_eq!(Bf16::from_f32(neg_nan).0 & 0x8000, 0x8000);
        // Finite values just over bf16's max round to Inf (normal RNE),
        // and the max finite f32 does too — but stays finite in f32 land.
        assert_eq!(Bf16::from_f32(f32::MAX).to_f32(), f32::INFINITY);
        // A value representable in bf16 is exact.
        assert_eq!(Bf16::from_f32(-0.5).to_f32(), -0.5);
    }

    #[test]
    fn sign_zero_at_a_zero() {
        let a = [1.0f64, 1.0, 0.0, 0.0, 0.0, 0.0];
        let b = [1.0f64, 0.0, 0.0, 0.0];
        let (_, q, sgn) = pq_elem(0.0, &a, &b);
        assert_eq!(q, 1.0);
        assert_eq!(sgn, 0.0);
    }
}
