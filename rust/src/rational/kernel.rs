//! Restructured host kernel for the group-wise rational (GR-KAN) layer.
//!
//! The paper's diagnosis is that GR-KAN's slowdown is memory traffic and
//! gradient-accumulation structure, not FLOPs; this module applies the
//! same lesson to the CPU substrate (DESIGN.md §4):
//!
//! - **Monomorphized native-precision fast paths** for f32/f64.  The
//!   generic `T: Float` reference in [`super`] rounds every op by
//!   round-tripping through f64 (`from_f64(to_f64() op to_f64())`) so it
//!   can model arbitrary precisions (e.g. [`super::Bf16`]).  For f32 and
//!   f64 that round-trip is pure overhead: each single `+`, `*`, `/` via
//!   f64 is bit-identical to the native op (exact f64 sums/products of
//!   f32 values; Figueroa's theorem for division), so the hot path can
//!   run entirely in the scalar's native type.  f64 fast paths are
//!   bit-identical to the reference everywhere; f32 fused expressions
//!   that the reference rounds once (e.g. `p*inv_q*inv_q`) round per-op
//!   here and may differ by ~1 ulp per op (bounds in tests/kernel_parity).
//! - **Register-resident coefficient-gradient accumulation**: fixed-size
//!   `[T; MAX_M1]` / `[T; MAX_N]` accumulators ([`TileAcc`]) replace the
//!   seed's per-element heap scratch, mirroring Algorithm 2's fast-memory
//!   tile reduction.
//! - **Tile streaming**: [`backward_row_seg`] fuses dx computation and
//!   gradient accumulation over one `(row, group)` segment so each tile
//!   of `x`/`dout` is streamed exactly once.

use super::accumulate::PairwiseAcc;
use super::Float;

/// Register-accumulator capacity for a-coefficients (paper config m+1=6).
pub const MAX_M1: usize = 8;
/// Register-accumulator capacity for b-coefficients (paper config n=4).
pub const MAX_N: usize = 8;
/// Sequential run length between pairwise carry-stack pushes.  Must stay
/// in lock-step with the accumulation semantics documented in
/// [`super::accumulate`]: changing it changes the rounding experiment.
pub const RUN: usize = 64;

/// Native arithmetic for the monomorphized fast paths.  Implemented for
/// f32/f64 only; software formats (Bf16) stay on the generic reference.
pub trait NativeFloat:
    Float
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::ops::Neg<Output = Self>
{
    /// Exact conversion of a small integer (coefficient degrees).
    fn from_usize(k: usize) -> Self;
}

impl NativeFloat for f32 {
    #[inline]
    fn from_usize(k: usize) -> Self {
        k as f32
    }
}

impl NativeFloat for f64 {
    #[inline]
    fn from_usize(k: usize) -> Self {
        k as f64
    }
}

/// Native-precision `(P, Q, sign(A))`; op-for-op the same expression tree
/// as [`super::pq_elem`], so the f64 instantiation is bit-identical and
/// the f32 instantiation is bit-identical too (every step is a single
/// rounded op in both versions).
#[inline]
pub fn pq_elem_native<T: NativeFloat>(x: T, a: &[T], b: &[T]) -> (T, T, T) {
    let m1 = a.len();
    let mut p = a[m1 - 1];
    for i in (0..m1 - 1).rev() {
        p = p * x + a[i];
    }
    let n = b.len();
    let mut h = b[n - 1];
    for j in (0..n - 1).rev() {
        h = h * x + b[j];
    }
    let abig = x * h;
    let q = T::ONE + abig.abs();
    (p, q, abig.signum0())
}

/// Native-precision forward value F(x) = P(x) / (1 + |A(x)|).
#[inline]
pub fn forward_elem_native<T: NativeFloat>(x: T, a: &[T], b: &[T]) -> T {
    let (p, q, _) = pq_elem_native(x, a, b);
    p / q
}

/// Native-precision fused per-element backward; mirrors
/// [`super::backward_elem_ref`] expression-for-expression (f64: bitwise
/// identical; f32: ≤ ~1 ulp per fused expression, and the dA
/// contributions are bit-identical because they are pure single-product
/// chains).
#[inline]
pub fn backward_elem_native<T: NativeFloat>(
    x: T,
    dout: T,
    a: &[T],
    b: &[T],
    da_out: &mut [T],
    db_out: &mut [T],
) -> T {
    let m1 = a.len();
    let n = b.len();
    debug_assert_eq!(da_out.len(), m1);
    debug_assert_eq!(db_out.len(), n);

    let (p, q, sgn) = pq_elem_native(x, a, b);
    let inv_q = T::ONE / q;
    let p_over_q2 = p * inv_q * inv_q;

    // P'(x)
    let mut dp = T::ZERO;
    if m1 > 1 {
        dp = a[m1 - 1] * T::from_usize(m1 - 1);
        for i in (1..m1 - 1).rev() {
            dp = dp * x + a[i] * T::from_usize(i);
        }
    }
    // A'(x)
    let mut dadx = b[n - 1] * T::from_usize(n);
    for j in (0..n - 1).rev() {
        dadx = dadx * x + b[j] * T::from_usize(j + 1);
    }

    let dx = dout * (dp * inv_q - sgn * dadx * p_over_q2);

    let do_q = dout * inv_q;
    let neg_do_spq2 = -dout * sgn * p_over_q2;
    let mut pw = T::ONE;
    for item in da_out.iter_mut() {
        *item = do_q * pw;
        pw = pw * x;
    }
    let mut pw = x;
    for item in db_out.iter_mut() {
        *item = neg_do_spq2 * pw;
        pw = pw * x;
    }
    dx
}

/// Register-resident tile accumulator for one `(block, group)` tile.
///
/// Reproduces the accumulation semantics of the seed implementation
/// bit-for-bit: sequential single-rounded adds within runs of [`RUN`]
/// elements, each run pushed into a pairwise carry stack (tree variant),
/// or one plain sequential sum (block-sequential ablation).  The state is
/// fixed-size stack storage — no per-element heap traffic.
pub struct TileAcc<T: Float> {
    m1: usize,
    n: usize,
    tree: bool,
    run: usize,
    seq_a: [T; MAX_M1],
    seq_b: [T; MAX_N],
    tree_a: [PairwiseAcc<T>; MAX_M1],
    tree_b: [PairwiseAcc<T>; MAX_N],
}

impl<T: Float> TileAcc<T> {
    /// Panics if the coefficient counts exceed the register caps; callers
    /// check [`fits_registers`] and take the heap spill path instead.
    pub fn new(m1: usize, n: usize, tree: bool) -> Self {
        assert!(
            m1 <= MAX_M1 && n <= MAX_N,
            "TileAcc: m1={m1} n={n} exceed register caps ({MAX_M1}, {MAX_N})"
        );
        Self {
            m1,
            n,
            tree,
            run: 0,
            seq_a: [T::ZERO; MAX_M1],
            seq_b: [T::ZERO; MAX_N],
            tree_a: std::array::from_fn(|_| PairwiseAcc::default()),
            tree_b: std::array::from_fn(|_| PairwiseAcc::default()),
        }
    }

    /// Fold in one element's contributions (first `m1` / `n` entries).
    #[inline]
    pub fn push(&mut self, da_e: &[T; MAX_M1], db_e: &[T; MAX_N]) {
        for i in 0..self.m1 {
            self.seq_a[i] = self.seq_a[i].add_r(da_e[i]);
        }
        for j in 0..self.n {
            self.seq_b[j] = self.seq_b[j].add_r(db_e[j]);
        }
        self.run += 1;
        if self.tree && self.run == RUN {
            self.flush_run();
        }
    }

    #[inline]
    fn flush_run(&mut self) {
        crate::probe::on_run_flush();
        for i in 0..self.m1 {
            self.tree_a[i].push(self.seq_a[i]);
            self.seq_a[i] = T::ZERO;
        }
        for j in 0..self.n {
            self.tree_b[j].push(self.seq_b[j]);
            self.seq_b[j] = T::ZERO;
        }
        self.run = 0;
    }

    /// Reduce to the tile's dA / dB partials (entries past `m1`/`n` are
    /// zero).
    pub fn finish(mut self) -> ([T; MAX_M1], [T; MAX_N]) {
        if self.tree {
            if self.run > 0 {
                self.flush_run();
            }
            let mut da = [T::ZERO; MAX_M1];
            let mut db = [T::ZERO; MAX_N];
            for i in 0..self.m1 {
                da[i] = self.tree_a[i].finish();
            }
            for j in 0..self.n {
                db[j] = self.tree_b[j].finish();
            }
            (da, db)
        } else {
            (self.seq_a, self.seq_b)
        }
    }
}

/// Do the coefficient counts fit the register-resident tile path?
#[inline]
pub fn fits_registers(m1: usize, n: usize) -> bool {
    m1 <= MAX_M1 && n <= MAX_N
}

/// Which kernel variant this build dispatches f32/f64 hot paths to.
/// Recorded by `serve-bench` into every transport benchmark artifact so
/// cross-run comparisons are not silently confounded by the feature flag.
pub const fn variant() -> &'static str {
    if cfg!(feature = "simd") {
        "simd"
    } else {
        "scalar"
    }
}

/// Register-resident tile accumulation over row segments — the seam where
/// the `simd` feature swaps implementations.  [`TileAcc`] (via
/// [`backward_row_seg`]) is the scalar bit-exactness oracle; the SIMD
/// twin in [`super::simd`] must match it bit for bit (DESIGN.md §14).
/// `backward_block` drives whichever accumulator the element type's
/// [`Float::Acc`](super::Float::Acc) names.
pub trait SegAccum<T: Float> {
    /// Fresh accumulator for one `(block, group)` tile.  Panics if the
    /// coefficient counts exceed the register caps ([`fits_registers`]);
    /// callers route those to the heap [`SpillAcc`] instead.
    fn new(m1: usize, n: usize, tree: bool) -> Self;
    /// Fused backward over one contiguous row segment: write `dx` in
    /// place, fold every dA/dB contribution into the tile state.
    fn row_seg(&mut self, x: &[T], dout: &[T], dx: &mut [T], a: &[T], b: &[T]);
    /// Reduce to the tile's dA / dB partials (entries past `m1`/`n` zero).
    fn finish(self) -> ([T; MAX_M1], [T; MAX_N]);
}

impl<T: Float> SegAccum<T> for TileAcc<T> {
    fn new(m1: usize, n: usize, tree: bool) -> Self {
        TileAcc::new(m1, n, tree)
    }

    #[inline]
    fn row_seg(&mut self, x: &[T], dout: &[T], dx: &mut [T], a: &[T], b: &[T]) {
        backward_row_seg(x, dout, dx, a, b, self);
    }

    fn finish(self) -> ([T; MAX_M1], [T; MAX_N]) {
        TileAcc::finish(self)
    }
}

/// Fused backward over one contiguous row segment (one row × one group,
/// `d_g` elements): writes `dx` in place and folds every contribution
/// into `acc`.  The segment's `x`/`dout` are streamed exactly once.
#[inline]
pub fn backward_row_seg<T: Float>(
    x: &[T],
    dout: &[T],
    dx: &mut [T],
    a: &[T],
    b: &[T],
    acc: &mut TileAcc<T>,
) {
    debug_assert_eq!(x.len(), dout.len());
    debug_assert_eq!(x.len(), dx.len());
    let (m1, n) = (a.len(), b.len());
    let mut da_e = [T::ZERO; MAX_M1];
    let mut db_e = [T::ZERO; MAX_N];
    for k in 0..x.len() {
        dx[k] = T::backward_elem_fast(x[k], dout[k], a, b, &mut da_e[..m1], &mut db_e[..n]);
        acc.push(&da_e, &db_e);
    }
}

/// Heap-accumulator twin of [`TileAcc`] + [`backward_row_seg`] for
/// coefficient counts above the register caps.  Accumulation order is
/// identical (sequential runs of [`RUN`] feeding pairwise carry stacks),
/// so results match the register path bit-for-bit where both apply.
pub struct SpillAcc<T: Float> {
    tree: bool,
    run: usize,
    seq_a: Vec<T>,
    seq_b: Vec<T>,
    tree_a: Vec<PairwiseAcc<T>>,
    tree_b: Vec<PairwiseAcc<T>>,
    da_e: Vec<T>,
    db_e: Vec<T>,
}

impl<T: Float> SpillAcc<T> {
    pub fn new(m1: usize, n: usize, tree: bool) -> Self {
        crate::probe::on_spill_fall();
        Self {
            tree,
            run: 0,
            seq_a: vec![T::ZERO; m1],
            seq_b: vec![T::ZERO; n],
            tree_a: vec![PairwiseAcc::default(); m1],
            tree_b: vec![PairwiseAcc::default(); n],
            da_e: vec![T::ZERO; m1],
            db_e: vec![T::ZERO; n],
        }
    }

    /// Fused backward over one row segment, spill-accumulator variant.
    pub fn row_seg(&mut self, x: &[T], dout: &[T], dx: &mut [T], a: &[T], b: &[T]) {
        for k in 0..x.len() {
            dx[k] =
                T::backward_elem_fast(x[k], dout[k], a, b, &mut self.da_e, &mut self.db_e);
            for i in 0..self.seq_a.len() {
                self.seq_a[i] = self.seq_a[i].add_r(self.da_e[i]);
            }
            for j in 0..self.seq_b.len() {
                self.seq_b[j] = self.seq_b[j].add_r(self.db_e[j]);
            }
            self.run += 1;
            if self.tree && self.run == RUN {
                self.flush_run();
            }
        }
    }

    fn flush_run(&mut self) {
        crate::probe::on_run_flush();
        for i in 0..self.seq_a.len() {
            self.tree_a[i].push(self.seq_a[i]);
            self.seq_a[i] = T::ZERO;
        }
        for j in 0..self.seq_b.len() {
            self.tree_b[j].push(self.seq_b[j]);
            self.seq_b[j] = T::ZERO;
        }
        self.run = 0;
    }

    pub fn finish(mut self) -> (Vec<T>, Vec<T>) {
        if self.tree {
            if self.run > 0 {
                self.flush_run();
            }
            (
                self.tree_a.iter().map(PairwiseAcc::finish).collect(),
                self.tree_b.iter().map(PairwiseAcc::finish).collect(),
            )
        } else {
            (self.seq_a, self.seq_b)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn caps_cover_paper_config() {
        assert!(fits_registers(6, 4), "paper config must take the register path");
        assert!(!fits_registers(MAX_M1 + 1, 1));
    }

    #[test]
    fn tile_and_spill_accumulators_agree_bitwise() {
        // Same pushes through both accumulators — totals must be
        // bit-identical (same adds in the same order), tree and
        // sequential variants, across run-boundary remainders.
        let mut rng = Pcg64::new(42);
        for &count in &[1usize, 63, 64, 65, 200, 1024] {
            for &tree in &[true, false] {
                let (m1, n) = (6, 4);
                let mut reg = TileAcc::<f32>::new(m1, n, tree);
                let mut spill = SpillAcc::<f32>::new(m1, n, tree);
                let a: Vec<f32> = (0..m1).map(|_| rng.normal_f32()).collect();
                let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
                let mut dx1 = vec![0.0f32; count];
                let mut dx2 = vec![0.0f32; count];
                let x: Vec<f32> = (0..count).map(|_| rng.normal_f32()).collect();
                let dout: Vec<f32> = (0..count).map(|_| rng.normal_f32()).collect();
                backward_row_seg(&x, &dout, &mut dx1, &a, &b, &mut reg);
                spill.row_seg(&x, &dout, &mut dx2, &a, &b);
                assert_eq!(dx1, dx2);
                let (ra, rb) = reg.finish();
                let (sa, sb) = spill.finish();
                for i in 0..m1 {
                    assert_eq!(ra[i].to_bits(), sa[i].to_bits(), "count={count} tree={tree}");
                }
                for j in 0..n {
                    assert_eq!(rb[j].to_bits(), sb[j].to_bits(), "count={count} tree={tree}");
                }
            }
        }
    }
}
