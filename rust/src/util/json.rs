//! Minimal JSON parser / serializer (offline environment: no serde).
//!
//! Supports the full JSON grammar; numbers are stored as f64 with an i64
//! fast path for integers.  Object key order is preserved (artifact
//! manifests rely on input ordering).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------- accessors ----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object view as a map (loses duplicate keys; fine for manifests).
    pub fn as_map(&self) -> Option<BTreeMap<&str, &Json>> {
        match self {
            Json::Obj(kv) => Some(kv.iter().map(|(k, v)| (k.as_str(), v)).collect()),
            _ => None,
        }
    }

    // ---------------- parse ----------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---------------- serialize ----------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => out.push_str(&i.to_string()),
            Json::Num(f) => {
                if f.is_finite() {
                    out.push_str(&format!("{f}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // (surrogate pairs unsupported; manifests are ASCII)
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            is_float = true;
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"name":"m","inputs":[{"name":"a/b","shape":[2,3],"dtype":"f32"}],"batch":32,"lr":0.001,"neg":-5,"flag":true,"none":null}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("m"));
        assert_eq!(v.get("batch").unwrap().as_usize(), Some(32));
        assert_eq!(v.get("neg").unwrap().as_i64(), Some(-5));
        assert!(v.get("lr").unwrap().as_f64().unwrap() - 0.001 < 1e-12);
        assert_eq!(v.get("flag").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("none"), Some(&Json::Null));
        let inputs = v.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].get("shape").unwrap().as_arr().unwrap().len(), 2);
        // re-serialize and re-parse
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_nested_and_whitespace() {
        let v = Json::parse(" { \"a\" : [ 1 , [ 2.5 , \"x\\n\" ] , { } ] } ").unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_i64(), Some(1));
        let inner = a[1].as_arr().unwrap();
        assert_eq!(inner[0].as_f64(), Some(2.5));
        assert_eq!(inner[1].as_str(), Some("x\n"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn escape_roundtrip() {
        let v = Json::Str("line\n\"quote\"\t\\".to_string());
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn big_ints_fall_back_to_float() {
        let v = Json::parse("99999999999999999999").unwrap();
        assert!(matches!(v, Json::Num(_)));
    }
}
