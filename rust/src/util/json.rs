//! Minimal JSON parser / serializer (offline environment: no serde).
//!
//! Supports the full JSON grammar; numbers are stored as f64 with an i64
//! fast path for integers.  Object key order is preserved (artifact
//! manifests rely on input ordering).
//!
//! Strings are handled strictly in both directions — HTTP bodies now
//! flow through here, so inputs are untrusted: the serializer escapes
//! every control character (U+0000–U+001F), and the parser rejects raw
//! (unescaped) control bytes inside strings per RFC 8259, so any string
//! a `Json` value can hold round-trips byte-exactly.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------- accessors ----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object view as a map (loses duplicate keys; fine for manifests).
    pub fn as_map(&self) -> Option<BTreeMap<&str, &Json>> {
        match self {
            Json::Obj(kv) => Some(kv.iter().map(|(k, v)| (k.as_str(), v)).collect()),
            _ => None,
        }
    }

    // ---------------- parse ----------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---------------- serialize ----------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => out.push_str(&i.to_string()),
            Json::Num(f) => {
                if f.is_finite() {
                    out.push_str(&format!("{f}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Containers deeper than this are rejected.  The parser recurses per
/// nesting level and HTTP bodies are untrusted, so without a cap a few
/// kilobytes of `[` would overflow the handler thread's stack and abort
/// the process; 128 is far beyond any artifact or API payload.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting deeper than 128 levels"));
        }
        self.depth += 1;
        let v = self.value_inner();
        self.depth -= 1;
        v
    }

    fn value_inner(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Strict surrogate handling (untrusted HTTP
                            // bodies; standard encoders emit non-BMP
                            // chars as \uD800-range pairs): decode a
                            // valid pair, reject a lone half instead of
                            // silently corrupting it to U+FFFD.
                            let c = match cp {
                                0xD800..=0xDBFF => {
                                    if self.b.get(self.i + 1) != Some(&b'\\')
                                        || self.b.get(self.i + 2) != Some(&b'u')
                                        || self.i + 6 >= self.b.len()
                                    {
                                        return Err(self.err("unpaired surrogate in \\u escape"));
                                    }
                                    let hex =
                                        std::str::from_utf8(&self.b[self.i + 3..self.i + 7])
                                            .map_err(|_| self.err("bad \\u escape"))?;
                                    let lo = u32::from_str_radix(hex, 16)
                                        .map_err(|_| self.err("bad \\u escape"))?;
                                    if !(0xDC00..=0xDFFF).contains(&lo) {
                                        return Err(self.err("unpaired surrogate in \\u escape"));
                                    }
                                    self.i += 6;
                                    let combined =
                                        0x1_0000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                        .ok_or_else(|| self.err("bad \\u escape"))?
                                }
                                0xDC00..=0xDFFF => {
                                    return Err(self.err("unpaired surrogate in \\u escape"))
                                }
                                cp => char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad \\u escape"))?,
                            };
                            s.push(c);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                // RFC 8259: control characters (U+0000–U+001F) MUST be
                // escaped inside strings.  HTTP bodies carry untrusted
                // bytes, so a raw control byte is a parse error, not
                // something to smuggle through (the serializer always
                // escapes them, so round-trips are unaffected).
                Some(c) if c < 0x20 => {
                    return Err(self.err("unescaped control character in string"))
                }
                Some(_) => {
                    // copy a UTF-8 run
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' || c < 0x20 {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            is_float = true;
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                // "-0" must stay a float: Int(0) would drop the sign
                // bit, and the serving layer's bit-exact f32 round-trip
                // contract distinguishes -0.0 from +0.0.
                if i != 0 || !text.starts_with('-') {
                    return Ok(Json::Int(i));
                }
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"name":"m","inputs":[{"name":"a/b","shape":[2,3],"dtype":"f32"}],"batch":32,"lr":0.001,"neg":-5,"flag":true,"none":null}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("m"));
        assert_eq!(v.get("batch").unwrap().as_usize(), Some(32));
        assert_eq!(v.get("neg").unwrap().as_i64(), Some(-5));
        assert!(v.get("lr").unwrap().as_f64().unwrap() - 0.001 < 1e-12);
        assert_eq!(v.get("flag").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("none"), Some(&Json::Null));
        let inputs = v.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].get("shape").unwrap().as_arr().unwrap().len(), 2);
        // re-serialize and re-parse
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_nested_and_whitespace() {
        let v = Json::parse(" { \"a\" : [ 1 , [ 2.5 , \"x\\n\" ] , { } ] } ").unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_i64(), Some(1));
        let inner = a[1].as_arr().unwrap();
        assert_eq!(inner[0].as_f64(), Some(2.5));
        assert_eq!(inner[1].as_str(), Some("x\n"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn escape_roundtrip() {
        let v = Json::Str("line\n\"quote\"\t\\".to_string());
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn all_control_characters_serialize_escaped_and_round_trip() {
        for cp in 0u32..0x20 {
            let c = char::from_u32(cp).unwrap();
            let v = Json::Str(format!("a{c}b"));
            let text = v.to_string();
            // The wire form must not contain the raw control byte.
            assert!(
                !text.bytes().any(|b| (b as u32) < 0x20),
                "U+{cp:04X} leaked raw into {text:?}"
            );
            assert_eq!(Json::parse(&text).unwrap(), v, "U+{cp:04X}");
        }
    }

    #[test]
    fn parser_rejects_raw_control_bytes_but_accepts_escapes() {
        // Raw control bytes inside a string are RFC 8259 violations.
        assert!(Json::parse("\"a\u{0}b\"").is_err(), "raw NUL");
        assert!(Json::parse("\"a\nb\"").is_err(), "raw newline");
        assert!(Json::parse("\"a\tb\"").is_err(), "raw tab");
        assert!(Json::parse("{\"k\u{1f}\":1}").is_err(), "raw control in key");
        // The escaped forms are fine.
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".to_string()));
        assert_eq!(Json::parse("\"a\\u0000b\"").unwrap(), Json::Str("a\u{0}b".to_string()));
        // Control bytes outside strings (whitespace) keep working.
        assert!(Json::parse("{\n\t\"a\": 1\r\n}").is_ok());
    }

    /// Property-style round trip over byte-noise strings: whatever UTF-8
    /// string a seeded fuzzer produces — control bytes, quotes,
    /// backslashes, multi-byte runs — `parse(to_string(s)) == s`.
    #[test]
    fn byte_noise_strings_round_trip() {
        let mut rng = crate::util::rng::Pcg64::new(0x1e57);
        for case in 0..200 {
            let len = rng.below(64);
            let mut bytes = Vec::with_capacity(len);
            for _ in 0..len {
                // Bias toward the interesting ranges: controls, ASCII
                // punctuation (quotes/backslashes), and high bytes that
                // form (or break into) multi-byte UTF-8 sequences.
                let b = match rng.below(4) {
                    0 => rng.below(0x20) as u8,
                    1 => b"\"\\/{}[]:,"[rng.below(9)],
                    2 => rng.below(128) as u8,
                    _ => rng.below(256) as u8,
                };
                bytes.push(b);
            }
            // from_utf8_lossy folds invalid sequences to U+FFFD, giving a
            // valid but adversarial string.
            let s = String::from_utf8_lossy(&bytes).into_owned();
            let v = Json::Str(s.clone());
            let text = v.to_string();
            let back = Json::parse(&text)
                .unwrap_or_else(|e| panic!("case {case}: {e} for {text:?}"));
            assert_eq!(back, v, "case {case}: {s:?}");
            // And nested inside a document, as HTTP bodies will carry it.
            let doc = Json::Obj(vec![("k".to_string(), v)]);
            assert_eq!(Json::parse(&doc.to_string()).unwrap(), doc, "case {case} nested");
        }
    }

    #[test]
    fn big_ints_fall_back_to_float() {
        let v = Json::parse("99999999999999999999").unwrap();
        assert!(matches!(v, Json::Num(_)));
    }

    #[test]
    fn negative_zero_keeps_its_sign_bit() {
        // Parse side: "-0" must not collapse into Int(0) (= +0.0).
        let v = Json::parse("-0").unwrap();
        let f = v.as_f64().unwrap();
        assert!(f == 0.0 && f.is_sign_negative(), "parsed {v:?}");
        // Full wire round trip, f32 bit-exact (the serving contract).
        let sent = -0.0f32;
        let wire = Json::Num(sent as f64).to_string();
        let back = Json::parse(&wire).unwrap().as_f64().unwrap() as f32;
        assert_eq!(back.to_bits(), sent.to_bits(), "wire {wire:?}");
        // Plain zero and negative ints are untouched.
        assert_eq!(Json::parse("0").unwrap(), Json::Int(0));
        assert_eq!(Json::parse("-7").unwrap(), Json::Int(-7));
    }

    #[test]
    fn surrogate_pairs_decode_and_lone_halves_are_rejected() {
        // Standard encoders (e.g. json.dumps with ensure_ascii) emit
        // non-BMP characters as surrogate pairs.
        assert_eq!(
            Json::parse("\"\\ud83d\\ude00\"").unwrap(),
            Json::Str("\u{1F600}".to_string())
        );
        // Lone halves are corruption, not data.
        assert!(Json::parse("\"\\ud83d\"").is_err());
        assert!(Json::parse("\"\\ud83d x\"").is_err());
        assert!(Json::parse("\"\\ude00\"").is_err());
        assert!(Json::parse("\"\\ud83d\\u0041\"").is_err(), "high half + non-low half");
        // BMP escapes are unaffected.
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".to_string()));
    }

    #[test]
    fn nesting_depth_is_capped_not_a_stack_overflow() {
        // 100k opening brackets: must error cleanly, not abort the
        // process (this parser sees untrusted HTTP bodies).
        let bomb = "[".repeat(100_000);
        assert!(Json::parse(&bomb).is_err());
        let bomb = "{\"a\":".repeat(50_000);
        assert!(Json::parse(&bomb).is_err());
        // Reasonable nesting still parses.
        let deep = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&deep).is_ok());
        let too_deep = format!("{}1{}", "[".repeat(129), "]".repeat(129));
        assert!(Json::parse(&too_deep).is_err());
    }
}
