//! Small self-contained substrates (this build environment is offline, so
//! JSON, RNG, statistics, and parallel helpers are implemented in-repo).

pub mod json;
pub mod parallel;
pub mod rng;
pub mod stats;
