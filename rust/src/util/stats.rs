//! Summary statistics: mean / variance (Welford), 95% confidence intervals.
//!
//! Used for the paper's ± CI columns (Tables 4, 5/8) and the bench harness.

/// Online mean/variance accumulator (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Half-width of the 95% confidence interval on the mean.
    /// Uses the t-distribution critical value (Welch-style, df = n-1).
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            return f64::NAN;
        }
        t_crit95((self.n - 1) as usize) * self.std() / (self.n as f64).sqrt()
    }

    pub fn summary(&self) -> String {
        format!("{:.4} (± {:.4})", self.mean(), self.ci95())
    }
}

/// Two-sided 95% t critical values; converges to 1.96 for large df.
pub fn t_crit95(df: usize) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228,
        2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086,
        2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    match df {
        0 => f64::NAN,
        d if d <= 30 => TABLE[d - 1],
        d if d <= 60 => 2.000,
        d if d <= 120 => 1.980,
        _ => 1.960,
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Percentile of an ascending-sorted slice with linear interpolation
/// between the two nearest ranks (numpy's default method).
///
/// The seed used nearest-rank, which collapses p50/p95/p99 onto the same
/// sample at small `n` and quantizes tail latencies; interpolation is
/// monotone in `p` and exact at the sample points.  `p` outside
/// `[0, 100]` clamps to the extremes.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = (p / 100.0).clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = (lo + 1).min(sorted.len() - 1);
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Log-bucketed histogram over `u64` microsecond samples.
///
/// The serving stack records a queue-wait and an exec sample per
/// request; keeping raw vectors per model would make `ExecStats::merge`
/// and the live `/metrics` path O(requests).  Instead samples land in
/// logarithmic buckets with [`SUB_BITS`] sub-buckets per octave
/// (8/octave ⇒ ≤ 12.5% relative error), so the whole histogram is a
/// few hundred counters regardless of traffic, merge is element-wise
/// addition, and percentiles are a cumulative walk.  Values below
/// `2^SUB_BITS` are exact.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LogHist {
    counts: Vec<u64>,
    total: u64,
    sum: u64,
}

/// Sub-buckets per octave (as a power of two): 3 ⇒ 8 sub-buckets.
const SUB_BITS: u32 = 3;

impl LogHist {
    /// Bucket index of `v`: identity below `2^SUB_BITS`, then the top
    /// `SUB_BITS` bits after the MSB select the sub-bucket.
    fn bucket(v: u64) -> usize {
        if v < (1 << SUB_BITS) {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros();
        let sub = (v >> (msb - SUB_BITS)) & ((1 << SUB_BITS) - 1);
        (((msb - SUB_BITS + 1) << SUB_BITS) | sub as u32) as usize
    }

    /// Lower bound of bucket `b` (the value `percentile` reports).
    fn bucket_lo(b: usize) -> u64 {
        if b < (1 << SUB_BITS) {
            return b as u64;
        }
        let sub = (b as u64) & ((1 << SUB_BITS) - 1);
        let shift = (b >> SUB_BITS) as u32 - 1;
        ((1 << SUB_BITS) | sub) << shift
    }

    pub fn record(&mut self, v: u64) {
        let b = Self::bucket(v);
        if self.counts.len() <= b {
            self.counts.resize(b + 1, 0);
        }
        self.counts[b] += 1;
        self.total += 1;
        self.sum = self.sum.saturating_add(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Sum of all recorded values (exact, unlike the bucketed counts).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn merge(&mut self, other: &LogHist) {
        if self.counts.len() < other.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum = self.sum.saturating_add(other.sum);
    }

    /// `(upper_bound, cumulative_count)` per occupied bucket, in
    /// ascending order — the Prometheus histogram exposition shape.
    /// Bucket `b` spans `[bucket_lo(b), bucket_lo(b+1))`, so its `le`
    /// upper bound is the *next* bucket's lower bound; every recorded
    /// value in the bucket is `< bucket_lo(b + 1)`, making the
    /// cumulative counts exact for these boundaries.
    pub fn cumulative_buckets(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            out.push((Self::bucket_lo(b + 1), cum));
        }
        out
    }

    /// p-th percentile as the lower bound of the bucket holding the
    /// nearest-rank sample; `NaN` when empty.  Within-bucket position is
    /// unknown, so the answer under-reads by at most one sub-bucket
    /// width (≤ 12.5%).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let rank = ((p / 100.0).clamp(0.0, 1.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_lo(b) as f64;
            }
        }
        // Unreachable while `total` matches the counts; a defensive max.
        Self::bucket_lo(self.counts.len().saturating_sub(1)) as f64
    }
}

/// Human formatting for big counts: 11.3M, 2.4T, ...
pub fn human_count(x: f64) -> String {
    let ax = x.abs();
    if ax >= 1e12 {
        format!("{:.1}T", x / 1e12)
    } else if ax >= 1e9 {
        format!("{:.1}G", x / 1e9)
    } else if ax >= 1e6 {
        format!("{:.1}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.1}K", x / 1e3)
    } else {
        format!("{x:.0}")
    }
}

/// Human formatting for seconds: 7.33 ms, 1.03 s ...
pub fn human_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2} s")
    } else if secs >= 1e-3 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.2} us", secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 5.0) * (x - 5.0)).sum::<f64>() / 7.0;
        assert!((s.var() - direct_var).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let mut small = OnlineStats::new();
        let mut big = OnlineStats::new();
        let mut rng = crate::util::rng::Pcg64::new(1);
        for i in 0..1000 {
            let x = rng.normal();
            if i < 10 {
                small.push(x);
            }
            big.push(x);
        }
        assert!(big.ci95() < small.ci95());
        // 95% CI of 1000 N(0,1) samples ~ 1.96/sqrt(1000) ~ 0.062
        assert!((big.ci95() - 0.062).abs() < 0.02, "{}", big.ci95());
    }

    #[test]
    fn t_table_monotone() {
        assert!(t_crit95(1) > t_crit95(5));
        assert!(t_crit95(5) > t_crit95(100));
        assert_eq!(t_crit95(10_000), 1.960);
    }

    #[test]
    fn percentile_interpolates_between_ranks() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
        assert!((percentile(&xs, 75.0) - 32.5).abs() < 1e-12);
        // Exact at the sample points.
        assert!((percentile(&xs, 100.0 / 3.0) - 20.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_edge_cases() {
        assert!(percentile(&[], 50.0).is_nan());
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
        // Out-of-range p clamps instead of indexing out of bounds.
        assert_eq!(percentile(&[1.0, 2.0], -5.0), 1.0);
        assert_eq!(percentile(&[1.0, 2.0], 150.0), 2.0);
    }

    #[test]
    fn percentile_monotone_in_p() {
        let mut rng = crate::util::rng::Pcg64::new(2);
        let mut v: Vec<f64> = (0..101).map(|_| rng.uniform()).collect();
        v.sort_by(|a, b| a.total_cmp(b));
        let mut prev = f64::NEG_INFINITY;
        for p in 0..=100 {
            let q = percentile(&v, p as f64);
            assert!(q >= prev, "p={p}: {q} < {prev}");
            prev = q;
        }
    }

    #[test]
    fn loghist_exact_below_one_octave() {
        let mut h = LogHist::default();
        for v in 0..8 {
            h.record(v);
        }
        assert_eq!(h.count(), 8);
        // Values below 2^SUB_BITS land in identity buckets, so the
        // percentile walk recovers them exactly.
        assert_eq!(h.percentile(100.0 / 8.0), 0.0);
        assert_eq!(h.percentile(100.0), 7.0);
    }

    #[test]
    fn loghist_bucket_bounds_round_trip() {
        // bucket_lo(bucket(v)) is the largest bucket boundary <= v, and
        // the relative error is bounded by one sub-bucket width.
        for &v in &[0u64, 1, 7, 8, 9, 15, 16, 17, 100, 1023, 1024, 123_456, u64::MAX / 3] {
            let lo = LogHist::bucket_lo(LogHist::bucket(v));
            assert!(lo <= v, "lo {lo} > v {v}");
            assert!(v - lo <= v / 8, "v {v} lo {lo}: error beyond one sub-bucket");
        }
        // Bucket index is monotone in the value.
        let mut prev = 0;
        for v in 0..10_000u64 {
            let b = LogHist::bucket(v);
            assert!(b >= prev, "bucket not monotone at {v}");
            prev = b;
        }
    }

    #[test]
    fn loghist_percentile_and_merge() {
        let mut a = LogHist::default();
        let mut b = LogHist::default();
        for v in 1..=100u64 {
            if v <= 50 {
                a.record(v)
            } else {
                b.record(v)
            }
        }
        assert!(a.percentile(50.0) <= 25.0 + 4.0);
        a.merge(&b);
        assert_eq!(a.count(), 100);
        let p50 = a.percentile(50.0);
        assert!((44.0..=50.0).contains(&p50), "p50 {p50}");
        let p99 = a.percentile(99.0);
        assert!((88.0..=99.0).contains(&p99), "p99 {p99}");
        // Monotone in p.
        assert!(a.percentile(99.0) >= a.percentile(50.0));
        assert!(LogHist::default().percentile(50.0).is_nan());
    }

    #[test]
    fn loghist_sum_and_cumulative_buckets() {
        let mut h = LogHist::default();
        for v in [1u64, 2, 2, 100, 1000] {
            h.record(v);
        }
        assert_eq!(h.sum(), 1105);
        let buckets = h.cumulative_buckets();
        let (mut prev_le, mut prev_c) = (0u64, 0u64);
        for &(le, c) in &buckets {
            assert!(le > prev_le, "le bounds not increasing: {le} after {prev_le}");
            assert!(c >= prev_c, "cumulative counts decreased");
            (prev_le, prev_c) = (le, c);
        }
        assert_eq!(prev_c, h.count());
        // The boundaries are exact: exactly 3 samples are <= 8 (the
        // first octave boundary above 2), and all 5 are <= the top.
        assert!(buckets.iter().any(|&(le, c)| le <= 8 && c == 3));
    }

    #[test]
    fn human_formats() {
        assert_eq!(human_count(11_300_000.0), "11.3M");
        assert_eq!(human_count(2.4e12), "2.4T");
        assert_eq!(human_time(1.03), "1.03 s");
        assert_eq!(human_time(0.00733), "7.33 ms");
    }
}
