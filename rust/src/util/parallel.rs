//! Parallel helpers built on a persistent worker pool (offline
//! environment: no rayon).
//!
//! The seed implementation spawned fresh OS threads inside
//! `thread::scope` on every call — a fixed ~0.1 ms tax per `par_map`
//! that dominates small tiles, and `par_chunks_mut` spawned one thread
//! *per chunk* (unbounded).  This version keeps `default_threads() - 1`
//! workers parked on a condvar and hands them lifetime-erased index
//! tasks; the submitting thread joins the computation and blocks until
//! every claimed index has finished, which is what keeps the borrows
//! alive for the workers' whole run (see DESIGN.md §7).
//!
//! Scheduling is work-stealing over an atomic index; results are keyed
//! by index, so output is deterministic regardless of interleaving.
//! Nested calls (a `par_map` inside a `par_map` worker) detect the busy
//! pool and fall back to serial execution instead of deadlocking.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Number of worker threads to use (cores, capped).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Raw-pointer wrapper that may cross thread boundaries.  Safety is the
/// caller's obligation: every user in this crate writes through it at
/// indices owned exclusively by one task item.
pub struct SendPtr<T>(pub *mut T);

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// A lifetime-erased index task. Workers call `call(data, i)` for every
/// claimed `i < n`.  The raw pointers stay valid because the submitter
/// (or its drop guard, on panic) blocks until no worker is still inside
/// the task before the referents leave scope.
#[derive(Clone, Copy)]
struct Task {
    data: *const (),
    call: unsafe fn(*const (), usize),
    next: *const AtomicUsize,
    done: *const AtomicUsize,
    poisoned: *const AtomicBool,
    n: usize,
}
unsafe impl Send for Task {}

struct PoolState {
    /// Bumped on every submission; workers use it to tell tasks apart.
    epoch: u64,
    /// The in-flight task, if any.  `Some` doubles as the busy flag that
    /// sends nested submissions down the serial path.
    task: Option<Task>,
    /// Workers currently executing the in-flight task.
    active: usize,
}

struct Shared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
    done_cv: Condvar,
}

struct Pool {
    shared: Arc<Shared>,
    workers: usize,
}

fn worker_main(shared: Arc<Shared>) {
    let mut seen = 0u64;
    let mut st = shared.state.lock().unwrap();
    loop {
        while st.epoch == seen || st.task.is_none() {
            st = shared.work_cv.wait(st).unwrap();
        }
        seen = st.epoch;
        let task = st.task.unwrap();
        st.active += 1;
        drop(st);
        loop {
            // SAFETY: `next`/`done`/`poisoned`/`data` live on the
            // submitter's stack; the submitter cannot return (or unwind
            // past them) until `active` drops back to zero, which only
            // happens after this loop exits.
            let i = unsafe { (*task.next).fetch_add(1, Ordering::Relaxed) };
            if i >= task.n {
                break;
            }
            let ok = catch_unwind(AssertUnwindSafe(|| unsafe { (task.call)(task.data, i) }))
                .is_ok();
            unsafe {
                if !ok {
                    (*task.poisoned).store(true, Ordering::Release);
                }
                (*task.done).fetch_add(1, Ordering::Release);
            }
        }
        st = shared.state.lock().unwrap();
        st.active -= 1;
        shared.done_cv.notify_all();
    }
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState { epoch: 0, task: None, active: 0 }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = default_threads().saturating_sub(1);
        for _ in 0..workers {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("flashkat-pool".into())
                .spawn(move || worker_main(shared))
                .expect("spawn pool worker");
        }
        Pool { shared, workers }
    })
}

/// Completion guard: even if the submitting thread unwinds, no stack
/// borrow leaves scope while a worker might still touch it.
struct SubmitGuard<'a> {
    shared: &'a Shared,
    next: &'a AtomicUsize,
    n: usize,
}

impl Drop for SubmitGuard<'_> {
    fn drop(&mut self) {
        // Stop further claims (workers that already claimed an index will
        // finish it), then wait until no worker is inside the task and
        // take the task back.
        self.next.fetch_add(self.n, Ordering::Relaxed);
        let mut st = self.shared.state.lock().unwrap();
        while st.active > 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.task = None;
    }
}

/// Type-erased trampoline: `data` is a `&F` lent by the submitter, valid
/// for the task's whole lifetime (see [`SubmitGuard`]).
unsafe fn call_thunk<F: Fn(usize) + Sync>(data: *const (), i: usize) {
    unsafe { (*(data as *const F))(i) }
}

/// Run `f(0..n)` across the pool, blocking until every index completed.
/// The submitting thread participates, so the pool being empty (or busy
/// with another task — e.g. a nested call) degrades to serial execution.
pub fn par_run<F: Fn(usize) + Sync>(n: usize, f: F) {
    if n == 0 {
        return;
    }
    let p = pool();
    if n == 1 || p.workers == 0 {
        for i in 0..n {
            f(i);
        }
        return;
    }

    let next = AtomicUsize::new(0);
    let done = AtomicUsize::new(0);
    let poisoned = AtomicBool::new(false);

    let task = Task {
        data: &f as *const F as *const (),
        call: call_thunk::<F>,
        next: &next,
        done: &done,
        poisoned: &poisoned,
        n,
    };

    {
        let mut st = p.shared.state.lock().unwrap();
        if st.task.is_some() {
            // Nested submission: the pool is committed to an outer task.
            drop(st);
            for i in 0..n {
                f(i);
            }
            return;
        }
        st.epoch = st.epoch.wrapping_add(1);
        st.task = Some(task);
        p.shared.work_cv.notify_all();
    }
    let guard = SubmitGuard { shared: &p.shared, next: &next, n };

    loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        f(i);
        done.fetch_add(1, Ordering::Release);
    }
    {
        let mut st = p.shared.state.lock().unwrap();
        while done.load(Ordering::Acquire) < n || st.active > 0 {
            st = p.shared.done_cv.wait(st).unwrap();
        }
    }
    drop(guard);
    if poisoned.load(Ordering::Acquire) {
        panic!("par_run: a pool worker panicked while executing a task item");
    }
}

/// Parallel map over a slice with work-stealing via an atomic index.
/// Results are returned in input order.
pub fn par_map<T: Sync, R: Send>(items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots = SendPtr(out.as_mut_ptr());
    par_run(n, |i| {
        let r = f(&items[i]);
        // SAFETY: each index is claimed by exactly one task item and the
        // Vec outlives par_run; `None` has nothing to drop.
        unsafe { slots.0.add(i).write(Some(r)) };
    });
    out.into_iter().map(|r| r.expect("pool filled slot")).collect()
}

/// `par_map` with at most `cap` items in flight at once (sequential
/// batches of `cap`).  Used where each item holds large buffers and full
/// pool width would multiply peak memory.
pub fn par_map_capped<T: Sync, R: Send>(
    items: &[T],
    cap: usize,
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R> {
    let mut out = Vec::with_capacity(items.len());
    for chunk in items.chunks(cap.max(1)) {
        out.extend(par_map(chunk, &f));
    }
    out
}

/// Parallel for over disjoint mutable chunks of a buffer.  Thread count
/// is bounded by the pool (the seed spawned one OS thread per chunk).
pub fn par_chunks_mut<T: Send>(
    buf: &mut [T],
    chunk: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    if buf.is_empty() || chunk == 0 {
        return;
    }
    let len = buf.len();
    let n_chunks = len.div_ceil(chunk);
    let base = SendPtr(buf.as_mut_ptr());
    par_run(n_chunks, |idx| {
        let start = idx * chunk;
        let end = (start + chunk).min(len);
        // SAFETY: chunk index ranges are disjoint and in-bounds, and the
        // buffer outlives par_run.
        let slice = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
        f(idx, slice);
    });
}

/// [`par_chunks_mut`] with chunk boundaries aligned to a lane width: the
/// requested `chunk` size is rounded up to the next multiple of `align`,
/// so every chunk except a single ragged final one is lane-multiple sized
/// and starts at a lane-multiple offset.  Parallel splits therefore never
/// bisect a SIMD lane tile (the `simd` feature's requirement — DESIGN.md
/// §14).  The callback receives the chunk's **element offset** into `buf`
/// (not its index): with the effective chunk size computed in here,
/// offsets are what callers need to recover row/segment positions.
pub fn par_chunks_mut_aligned<T: Send>(
    buf: &mut [T],
    chunk: usize,
    align: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    if buf.is_empty() {
        return;
    }
    let align = align.max(1);
    let eff = chunk.max(1).div_ceil(align) * align;
    let len = buf.len();
    let n_chunks = len.div_ceil(eff);
    let base = SendPtr(buf.as_mut_ptr());
    par_run(n_chunks, |idx| {
        let start = idx * eff;
        let end = (start + eff).min(len);
        // SAFETY: chunk index ranges are disjoint and in-bounds, and the
        // buffer outlives par_run.
        let slice = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
        f(start, slice);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<u64> = (0..1000).collect();
        let ys = par_map(&xs, |x| x * x);
        for (i, y) in ys.iter().enumerate() {
            assert_eq!(*y, (i * i) as u64);
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        assert!(par_map::<u32, u32>(&[], |x| *x).is_empty());
        assert_eq!(par_map(&[7], |x| x + 1), vec![8]);
    }

    #[test]
    fn par_chunks_mut_writes_disjoint() {
        let mut buf = vec![0u32; 100];
        par_chunks_mut(&mut buf, 7, |idx, c| {
            for v in c.iter_mut() {
                *v = idx as u32;
            }
        });
        assert_eq!(buf[0], 0);
        assert_eq!(buf[7], 1);
        assert_eq!(buf[99], (99 / 7) as u32);
    }

    #[test]
    fn par_chunks_mut_is_bounded_for_tiny_chunks() {
        // 10k single-element chunks: the seed spawned 10k threads here;
        // the pool must both bound that and stay correct.
        let mut buf = vec![0u64; 10_000];
        par_chunks_mut(&mut buf, 1, |idx, c| {
            c[0] = (idx * 3) as u64;
        });
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, (i * 3) as u64);
        }
    }

    #[test]
    fn aligned_chunks_respect_lane_boundaries_for_odd_counts() {
        // Odd element counts × odd requested chunks × every plausible lane
        // width: all chunk starts must sit on a lane boundary, every chunk
        // except (at most) the final one must be lane-multiple sized, and
        // together they must cover the buffer exactly once.
        use std::sync::Mutex;
        for &len in &[1usize, 7, 64, 97, 1000, 1023] {
            for &chunk in &[1usize, 3, 7, 16, 250] {
                for &align in &[1usize, 2, 4, 8, 16] {
                    let mut buf = vec![0u32; len];
                    let spans = Mutex::new(Vec::new());
                    par_chunks_mut_aligned(&mut buf, chunk, align, |offset, c| {
                        for v in c.iter_mut() {
                            *v += 1;
                        }
                        spans.lock().unwrap().push((offset, c.len()));
                    });
                    assert!(buf.iter().all(|&v| v == 1), "coverage len={len}");
                    let mut spans = spans.into_inner().unwrap();
                    spans.sort_unstable();
                    let eff = chunk.max(1).div_ceil(align) * align;
                    let mut expect_start = 0;
                    for (i, &(start, n)) in spans.iter().enumerate() {
                        assert_eq!(start, expect_start, "gap/overlap len={len}");
                        assert_eq!(start % align, 0, "unaligned start len={len} chunk={chunk} align={align}");
                        if i + 1 < spans.len() {
                            assert_eq!(n, eff, "non-final chunk not lane-multiple sized");
                            assert_eq!(n % align, 0);
                        }
                        expect_start += n;
                    }
                    assert_eq!(expect_start, len);
                }
            }
        }
    }

    #[test]
    fn aligned_chunks_degenerate_cases() {
        // Empty buffer: no calls; align larger than the buffer: one
        // ragged chunk holding everything.
        let mut empty: Vec<u8> = Vec::new();
        par_chunks_mut_aligned(&mut empty, 4, 8, |_, _| panic!("empty buf"));
        let mut buf = vec![0u8; 5];
        let mut seen = Vec::new();
        {
            let seen_cell = std::sync::Mutex::new(&mut seen);
            par_chunks_mut_aligned(&mut buf, 2, 16, |offset, c| {
                seen_cell.lock().unwrap().push((offset, c.len()));
            });
        }
        assert_eq!(seen, vec![(0, 5)]);
    }

    #[test]
    fn nested_par_map_falls_back_to_serial() {
        let outer: Vec<usize> = (0..8).collect();
        let sums = par_map(&outer, |&o| {
            let inner: Vec<usize> = (0..50).collect();
            par_map(&inner, |&i| o * 100 + i).into_iter().sum::<usize>()
        });
        for (o, s) in sums.iter().enumerate() {
            let want: usize = (0..50).map(|i| o * 100 + i).sum();
            assert_eq!(*s, want);
        }
    }

    #[test]
    fn pool_is_reusable_across_many_calls() {
        for round in 0..200 {
            let xs: Vec<u64> = (0..17 + round % 5).collect();
            let ys = par_map(&xs, |x| x + round);
            assert_eq!(ys.len(), xs.len());
            for (x, y) in xs.iter().zip(&ys) {
                assert_eq!(*y, x + round);
            }
        }
    }

    #[test]
    fn par_map_capped_matches_par_map() {
        let xs: Vec<u64> = (0..37).collect();
        for cap in [1, 2, 4, 100] {
            assert_eq!(par_map_capped(&xs, cap, |x| x * 7), par_map(&xs, |x| x * 7));
        }
    }
}
