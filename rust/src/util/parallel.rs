//! Scoped-thread parallel helpers (offline environment: no rayon).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (cores, capped).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Parallel map over a slice with work-stealing via an atomic index.
/// Results are returned in input order.
pub fn par_map<T: Sync, R: Send>(items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R> {
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = default_threads().min(n);
    if threads <= 1 {
        return items.iter().map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots: Vec<_> = out.iter_mut().map(|s| SendPtr(s as *mut Option<R>)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            let slots = &slots;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                // SAFETY: each index i is claimed by exactly one thread and
                // the Vec outlives the scope.
                unsafe { slots[i].0.write(Some(r)) };
            });
        }
    });
    out.into_iter().map(|r| r.expect("worker filled slot")).collect()
}

struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Parallel for over disjoint mutable chunks of a buffer.
pub fn par_chunks_mut<T: Send>(
    buf: &mut [T],
    chunk: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    if buf.is_empty() || chunk == 0 {
        return;
    }
    std::thread::scope(|scope| {
        for (idx, c) in buf.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || f(idx, c));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<u64> = (0..1000).collect();
        let ys = par_map(&xs, |x| x * x);
        for (i, y) in ys.iter().enumerate() {
            assert_eq!(*y, (i * i) as u64);
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        assert!(par_map::<u32, u32>(&[], |x| *x).is_empty());
        assert_eq!(par_map(&[7], |x| x + 1), vec![8]);
    }

    #[test]
    fn par_chunks_mut_writes_disjoint() {
        let mut buf = vec![0u32; 100];
        par_chunks_mut(&mut buf, 7, |idx, c| {
            for v in c.iter_mut() {
                *v = idx as u32;
            }
        });
        assert_eq!(buf[0], 0);
        assert_eq!(buf[7], 1);
        assert_eq!(buf[99], (99 / 7) as u32);
    }
}
