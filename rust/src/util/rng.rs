//! PCG64 pseudo-random generator + distributions.
//!
//! Deterministic per seed, portable, no dependencies.  Used by the data
//! pipeline, augmentations, and the rounding-error experiments.

/// PCG-XSL-RR 128/64 (O'Neill 2014).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Self {
        Self::with_stream(self.next_u64(), stream.wrapping_mul(2654435761).wrapping_add(1))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller (no cached spare: simpler & portable).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal_f32();
        }
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Beta(alpha, alpha) sample via the Jöhnk/gamma-free method for the
    /// symmetric case used by mixup/cutmix.
    pub fn beta_symmetric(&mut self, alpha: f64) -> f64 {
        // For alpha == 1 this is uniform; otherwise use two Gamma(alpha)
        // samples via Marsaglia-Tsang (alpha may be < 1).
        let g1 = self.gamma(alpha);
        let g2 = self.gamma(alpha);
        if g1 + g2 == 0.0 {
            0.5
        } else {
            g1 / (g1 + g2)
        }
    }

    /// Marsaglia-Tsang Gamma(shape, 1).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^{1/a}
            let u = self.uniform().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Pcg64::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(7);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg64::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn beta_symmetric_in_unit_interval() {
        let mut r = Pcg64::new(5);
        for _ in 0..1000 {
            let b = r.beta_symmetric(0.8);
            assert!((0.0..=1.0).contains(&b), "{b}");
        }
        // symmetric => mean ~ 0.5
        let mean: f64 = (0..5000).map(|_| r.beta_symmetric(0.8)).sum::<f64>() / 5000.0;
        assert!((mean - 0.5).abs() < 0.03, "{mean}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Pcg64::new(11);
        for shape in [0.5, 1.0, 2.0, 8.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((mean - shape).abs() / shape < 0.08, "shape={shape} mean={mean}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
