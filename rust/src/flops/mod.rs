//! Analytic parameter / FLOP counts (paper Table 1) for MLP, KAN, GR-KAN.
//!
//! These are the closed-form expressions the paper uses to argue that
//! GR-KAN's FLOPs are within a hair of MLP's — which is exactly why FLOPs
//! cannot explain the 123x slowdown (paper Insight 2).

/// Layer dimensioning shared by all three layer types.
#[derive(Clone, Copy, Debug)]
pub struct LayerDims {
    pub d_in: usize,
    pub d_out: usize,
}

/// MLP (ViT) layer: params = d_in*d_out; flops = FuncFLOPs*d_out + 2*d_in*d_out.
pub fn mlp_params(d: LayerDims) -> u64 {
    (d.d_in * d.d_out) as u64
}

pub fn mlp_flops(d: LayerDims, func_flops: u64) -> u64 {
    func_flops * d.d_out as u64 + 2 * (d.d_in * d.d_out) as u64
}

/// B-spline KAN layer (Liu et al. 2024): G intervals, K spline order.
/// params = d_in*d_out*(G+K+3);
/// flops  = FuncFLOPs*d_in + d_in*d_out*[9K*(G+1.5K) + 2G - 2.5K + 3].
pub fn kan_params(d: LayerDims, g: usize, k: usize) -> u64 {
    (d.d_in * d.d_out) as u64 * (g + k + 3) as u64
}

pub fn kan_flops(d: LayerDims, g: usize, k: usize, func_flops: u64) -> u64 {
    let gf = g as f64;
    let kf = k as f64;
    let per_edge = 9.0 * kf * (gf + 1.5 * kf) + 2.0 * gf - 2.5 * kf + 3.0;
    func_flops * d.d_in as u64 + ((d.d_in * d.d_out) as f64 * per_edge) as u64
}

/// GR-KAN (KAT) layer: m/n polynomial degrees, g groups.
/// params = d_in*d_out + (m + n*g + 1);
/// flops  = (2m + 2n + 3)*d_in + 2*d_in*d_out.
pub fn grkan_params(d: LayerDims, m: usize, n: usize, groups: usize) -> u64 {
    (d.d_in * d.d_out) as u64 + (m + n * groups + 1) as u64
}

pub fn grkan_flops(d: LayerDims, m: usize, n: usize) -> u64 {
    (2 * m + 2 * n + 3) as u64 * d.d_in as u64 + 2 * (d.d_in * d.d_out) as u64
}

/// One row of the reproduced Table 1.
#[derive(Clone, Debug)]
pub struct TableRow {
    pub name: &'static str,
    pub params: u64,
    pub flops: u64,
}

/// Reproduce paper Table 1 for a given layer size with the paper's
/// defaults: KAN G=8 intervals, K=3 order; GR-KAN m=5, n=4, 8 groups;
/// activation FuncFLOPs ~= 14 (GELU-class estimate used by KAT).
pub fn table1(d: LayerDims, func_flops: u64) -> Vec<TableRow> {
    vec![
        TableRow {
            name: "MLP (ViT)",
            params: mlp_params(d),
            flops: mlp_flops(d, func_flops),
        },
        TableRow {
            name: "KAN",
            params: kan_params(d, 8, 3),
            flops: kan_flops(d, 8, 3, func_flops),
        },
        TableRow {
            name: "GR-KAN (KAT)",
            params: grkan_params(d, 5, 4, 8),
            flops: grkan_flops(d, 5, 4),
        },
    ]
}

/// Paper Insight 2: GR-KAN's activation FLOPs, (2m+2n+3)*d_in, are
/// negligible next to the matmul term 2*d_in*d_out.
pub fn grkan_activation_fraction(d: LayerDims, m: usize, n: usize) -> f64 {
    let act = (2 * m + 2 * n + 3) as f64 * d.d_in as f64;
    act / grkan_flops(d, m, n) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    const D: LayerDims = LayerDims { d_in: 768, d_out: 3072 };

    #[test]
    fn mlp_formulas() {
        assert_eq!(mlp_params(D), 768 * 3072);
        assert_eq!(mlp_flops(D, 14), 14 * 3072 + 2 * 768 * 3072);
    }

    #[test]
    fn kan_is_orders_of_magnitude_more_flops() {
        // Paper: a KAN edge may require up to ~204 FLOPs vs MLP's 2.
        let kan = kan_flops(D, 8, 3, 14);
        let mlp = mlp_flops(D, 14);
        let ratio = kan as f64 / mlp as f64;
        assert!(ratio > 50.0, "ratio {ratio}");
        // per-edge cost: 9K(G+1.5K)+2G-2.5K+3 with G=8,K=3 = 9*3*12.5+16-7.5+3 = 349
        let per_edge = (kan - 14 * 768) / (768 * 3072);
        assert_eq!(per_edge, 349);
    }

    #[test]
    fn grkan_flops_close_to_mlp() {
        // Paper Insight 2: GR-KAN ~= MLP in FLOPs (within ~1%).
        let gr = grkan_flops(D, 5, 4) as f64;
        let ml = mlp_flops(D, 14) as f64;
        assert!((gr / ml - 1.0).abs() < 0.01, "{}", gr / ml);
    }

    #[test]
    fn grkan_activation_share_is_negligible() {
        let frac = grkan_activation_fraction(D, 5, 4);
        assert!(frac < 0.005, "{frac}");
    }

    #[test]
    fn grkan_params_close_to_mlp() {
        let gr = grkan_params(D, 5, 4, 8);
        let ml = mlp_params(D);
        assert_eq!(gr - ml, 5 + 4 * 8 + 1);
    }

    #[test]
    fn kan_param_blowup() {
        // (G+K+3) = 14x MLP params with the defaults.
        assert_eq!(kan_params(D, 8, 3), 14 * mlp_params(D));
    }

    #[test]
    fn table1_rows() {
        let rows = table1(D, 14);
        assert_eq!(rows.len(), 3);
        assert!(rows[1].flops > 10 * rows[0].flops); // KAN >> MLP
        assert!(rows[2].flops < rows[0].flops * 102 / 100); // GR-KAN ~ MLP
    }
}
