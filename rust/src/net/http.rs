//! Minimal HTTP/1.1 framing: request parser and response writer over
//! any `BufRead`/`Write`, built on `std` only (the crate's
//! vendored-stubs-only rule extends to the network frontend).
//!
//! Scope is exactly what the serving frontend needs — and no more:
//! request line + headers + `Content-Length` bodies, keep-alive
//! (HTTP/1.1 default, `Connection` header honored both ways), and hard
//! resource limits (`431` on an oversized header section, `413` on an
//! oversized body, `501` on `Transfer-Encoding`, which we do not
//! implement).  Everything is a pure function of the byte stream, so
//! the parser is unit-tested on in-memory cursors; only
//! [`super::listener`] ever hands it a real socket.
//!
//! Protocol errors are **data**, not `Err`: [`ReadOutcome::Bad`]
//! carries the status the connection handler should answer with before
//! closing, while `Err(io::Error)` is reserved for transport failures
//! (reset, timeout) where no answer can be delivered.

use std::io::{self, BufRead, Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Hard limits on a single request's wire size and patience.
#[derive(Clone, Copy, Debug)]
pub struct Limits {
    /// Request line + all header lines, bytes (431 above).  Also caps a
    /// single line's buffer, so a newline-free flood cannot grow memory
    /// past this (the check fires as 431 once the cap is hit).
    pub max_header_bytes: usize,
    /// `Content-Length` ceiling, bytes (413 above).
    pub max_body_bytes: usize,
    /// Read-timeout ticks (one per socket `read_timeout` expiry, 50ms
    /// in the listener) tolerated while waiting for bytes.  An idle
    /// keep-alive connection is closed after this many silent ticks
    /// (freeing its handler thread); a stall mid-request is answered
    /// with `408`.  Bounds how long a do-nothing peer can pin a
    /// handler.
    pub max_stall_ticks: usize,
    /// Wall-clock ceiling on reading one whole request.  The tick
    /// budget alone would not stop a drip-feeder (1 byte per tick makes
    /// "progress" forever); this bounds slow-as-possible peers too.
    /// Mid-request expiry is a `408`.
    pub max_request_secs: u64,
}

impl Default for Limits {
    fn default() -> Self {
        Self {
            max_header_bytes: 16 * 1024,
            max_body_bytes: 8 * 1024 * 1024,
            // 200 x 50ms = ~10s of patience per silent wait.
            max_stall_ticks: 200,
            max_request_secs: 60,
        }
    }
}

/// One parsed request.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    /// Request target as sent (path + optional query), percent-decoding
    /// deliberately not applied (model names are `[A-Za-z0-9_.-]`).
    pub target: String,
    /// `true` for HTTP/1.1, `false` for HTTP/1.0.
    pub http11: bool,
    /// Header name/value pairs in wire order; names lower-cased.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First header value by (case-insensitive) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// Whether the connection should stay open after this exchange:
    /// HTTP/1.1 defaults to keep-alive unless `Connection: close`;
    /// HTTP/1.0 defaults to close unless `Connection: keep-alive`.
    pub fn keep_alive(&self) -> bool {
        match self.header("connection").map(str::to_ascii_lowercase) {
            Some(v) if v.contains("close") => false,
            Some(v) if v.contains("keep-alive") => true,
            _ => self.http11,
        }
    }

    /// Path without the query string.
    pub fn path(&self) -> &str {
        self.target.split('?').next().unwrap_or(&self.target)
    }
}

/// Result of reading one request off a connection.
#[derive(Debug)]
pub enum ReadOutcome {
    /// A complete, well-formed request.
    Ok(Request),
    /// Clean EOF before the first byte of a request (the peer closed an
    /// idle keep-alive connection) — not an error.
    Closed,
    /// Protocol violation: answer with `status` and close.
    Bad { status: u16, msg: String },
}

fn bad(status: u16, msg: impl Into<String>) -> ReadOutcome {
    ReadOutcome::Bad { status, msg: msg.into() }
}

fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Grace window a read gets once the listener starts draining: long
/// enough to finish receiving a request already on the wire (which then
/// gets a real response), short enough to bound shutdown.
const DRAIN_GRACE: Duration = Duration::from_millis(500);

/// Shared budget for one request's reads: silent timeout ticks against
/// `max_stall_ticks` AND wall-clock elapsed against the request
/// deadline — the latter is what stops a drip-feeder whose 1-byte
/// "progress" would reset any activity-based scheme.  When the
/// listener's `stop` flag flips, reads are not aborted outright (that
/// would drop queued connections unanswered); they get [`DRAIN_GRACE`]
/// to complete, after which exhaustion surfaces like any other timeout:
/// `ErrorKind::TimedOut`, which the caller maps to a `408` answer
/// mid-request or a silent close at a request boundary.
///
/// `pub(crate)` because the flashwire frame codec (`crate::wire::frame`)
/// reads off the same kind of short-timeout socket and shares this exact
/// budget discipline.
pub(crate) struct Patience<'a> {
    stop: &'a AtomicBool,
    ticks: usize,
    max_ticks: usize,
    started: Instant,
    max_elapsed: Duration,
    /// Set when `stop` is first observed: the drain cutoff.
    grace_until: Option<Instant>,
}

impl Patience<'_> {
    fn new(stop: &AtomicBool, limits: &Limits) -> Patience<'_> {
        Patience::with_budget(stop, limits.max_stall_ticks, limits.max_request_secs)
    }

    /// Budget from explicit knobs (for non-HTTP framings that keep their
    /// own limits struct).
    pub(crate) fn with_budget(
        stop: &AtomicBool,
        max_ticks: usize,
        max_secs: u64,
    ) -> Patience<'_> {
        Patience {
            stop,
            ticks: 0,
            max_ticks,
            started: Instant::now(),
            max_elapsed: Duration::from_secs(max_secs),
            grace_until: None,
        }
    }

    /// Drain grace + wall-clock deadline; called on every read-loop
    /// iteration, progress or not.
    fn check(&mut self) -> io::Result<()> {
        if self.grace_until.is_none() && self.stop.load(Ordering::SeqCst) {
            self.grace_until = Some(Instant::now() + DRAIN_GRACE);
        }
        if self.grace_until.is_some_and(|g| Instant::now() >= g) {
            return Err(io::Error::new(io::ErrorKind::TimedOut, "drain grace expired"));
        }
        if self.started.elapsed() >= self.max_elapsed {
            return Err(io::Error::new(io::ErrorKind::TimedOut, "request deadline exceeded"));
        }
        Ok(())
    }

    /// Account one *silent* timeout tick; `Err` when the tick budget is
    /// spent (idle/stalled peer) or [`Self::check`] fails.
    fn tick(&mut self) -> io::Result<()> {
        self.check()?;
        self.ticks += 1;
        if self.ticks > self.max_ticks {
            return Err(io::Error::new(io::ErrorKind::TimedOut, "read stalled"));
        }
        Ok(())
    }
}

/// `read_until` that survives read-timeout ticks (partial bytes stay in
/// `buf` and the read resumes, so the listener's short socket
/// `read_timeout` never corrupts parsing) and never buffers more than
/// `cap` bytes for one line — a newline-free flood stops growing at the
/// cap and the caller's size check turns it into `431`.
fn read_line_resumable(
    r: &mut impl BufRead,
    buf: &mut Vec<u8>,
    cap: usize,
    patience: &mut Patience<'_>,
) -> io::Result<usize> {
    let start = buf.len();
    loop {
        patience.check()?;
        let consumed = buf.len() - start;
        if consumed >= cap {
            return Ok(consumed);
        }
        let mut limited = r.by_ref().take((cap - consumed) as u64);
        match limited.read_until(b'\n', buf) {
            // EOF (the cap > 0 here, so 0 bytes cannot mean cap-exhausted).
            Ok(0) => return Ok(buf.len() - start),
            Ok(_) => {
                if buf.last() == Some(&b'\n') {
                    return Ok(buf.len() - start);
                }
                // No newline: the Take hit the cap; the `consumed >= cap`
                // check at the top of the loop returns the capped line.
            }
            Err(e) if is_timeout(&e) => patience.tick()?,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
}

/// `read_exact` with the same resume-on-timeout behavior.  Shared with
/// the flashwire frame codec, which is all fixed-length reads.
pub(crate) fn read_exact_resumable(
    r: &mut impl BufRead,
    out: &mut [u8],
    patience: &mut Patience<'_>,
) -> io::Result<()> {
    let mut off = 0;
    while off < out.len() {
        patience.check()?;
        match r.read(&mut out[off..]) {
            Ok(0) => return Err(io::ErrorKind::UnexpectedEof.into()),
            Ok(n) => off += n,
            Err(e) if is_timeout(&e) => patience.tick()?,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Strip one trailing `\r\n` or `\n` and return the line as UTF-8.
fn trim_line(buf: &[u8]) -> Result<&str, ReadOutcome> {
    let mut end = buf.len();
    if end > 0 && buf[end - 1] == b'\n' {
        end -= 1;
        if end > 0 && buf[end - 1] == b'\r' {
            end -= 1;
        }
    }
    std::str::from_utf8(&buf[..end]).map_err(|_| bad(400, "non-UTF-8 header bytes"))
}

/// Read and parse one request.  `stop` is the listener's shutdown flag
/// (a read-timeout tick with `stop` set aborts the read as a transport
/// error).  The whole request shares one stall budget
/// ([`Limits::max_stall_ticks`]): a connection idle at a request
/// boundary is reported `Closed` (the handler just drops it); a stall
/// *inside* a request is a `408` the handler answers before closing.
pub fn read_request(
    r: &mut impl BufRead,
    limits: &Limits,
    stop: &AtomicBool,
) -> io::Result<ReadOutcome> {
    let mut patience = Patience::new(stop, limits);
    let line_cap = limits.max_header_bytes + 2;
    let mut line = Vec::new();
    let n = match read_line_resumable(r, &mut line, line_cap, &mut patience) {
        Ok(n) => n,
        Err(e) if e.kind() == io::ErrorKind::TimedOut => {
            // Stall budget spent.  Nothing read yet → idle keep-alive
            // connection: close silently.  Mid-line → a started request
            // stalled: tell the peer.
            return Ok(if line.is_empty() {
                ReadOutcome::Closed
            } else {
                bad(408, "request read timed out")
            });
        }
        Err(e) => return Err(e),
    };
    if n == 0 {
        return Ok(ReadOutcome::Closed);
    }
    if n > limits.max_header_bytes {
        return Ok(bad(431, format!("request line over {} bytes", limits.max_header_bytes)));
    }
    let mut header_bytes = n;
    let request_line = match trim_line(&line) {
        Ok(l) => l.to_string(),
        Err(b) => return Ok(b),
    };
    let mut parts = request_line.split_ascii_whitespace();
    let (method, target, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v), None) => (m.to_string(), t.to_string(), v),
            _ => return Ok(bad(400, format!("malformed request line {request_line:?}"))),
        };
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        other => return Ok(bad(505, format!("unsupported version {other:?}"))),
    };

    let mut headers = Vec::new();
    loop {
        line.clear();
        let n = match read_line_resumable(r, &mut line, line_cap, &mut patience) {
            Ok(n) => n,
            Err(e) if e.kind() == io::ErrorKind::TimedOut => {
                return Ok(bad(408, "request read timed out"));
            }
            Err(e) => return Err(e),
        };
        if n == 0 {
            return Ok(bad(400, "connection closed inside headers"));
        }
        header_bytes += n;
        if header_bytes > limits.max_header_bytes {
            return Ok(bad(431, format!("header section over {} bytes", limits.max_header_bytes)));
        }
        let text = match trim_line(&line) {
            Ok(l) => l,
            Err(b) => return Ok(b),
        };
        if text.is_empty() {
            break;
        }
        let Some((name, value)) = text.split_once(':') else {
            return Ok(bad(400, format!("malformed header line {text:?}")));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    let mut req = Request { method, target, http11, headers, body: Vec::new() };
    if req.header("transfer-encoding").is_some() {
        return Ok(bad(501, "transfer-encoding not supported; use content-length"));
    }
    let body_len = match req.header("content-length") {
        None => 0,
        Some(v) => match v.parse::<usize>() {
            Ok(n) => n,
            Err(_) => return Ok(bad(400, format!("bad content-length {v:?}"))),
        },
    };
    if body_len > limits.max_body_bytes {
        return Ok(bad(413, format!("body of {body_len} bytes over {} limit", limits.max_body_bytes)));
    }
    if body_len > 0 {
        let mut body = vec![0u8; body_len];
        match read_exact_resumable(r, &mut body, &mut patience) {
            Ok(()) => req.body = body,
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => {
                return Ok(bad(400, "connection closed inside body"));
            }
            Err(e) if e.kind() == io::ErrorKind::TimedOut => {
                return Ok(bad(408, "request body read timed out"));
            }
            Err(e) => return Err(e),
        }
    }
    Ok(ReadOutcome::Ok(req))
}

/// One response to serialize.
#[derive(Clone, Debug)]
pub struct HttpResponse {
    pub status: u16,
    /// Extra headers beyond the always-written `Content-Type`,
    /// `Content-Length`, and `Connection`.
    pub headers: Vec<(String, String)>,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Trace span of the request this response answers (set by the
    /// infer route on a traced server).  Never serialized — it exists
    /// so the connection handler can annotate its handler-track slice.
    pub span_id: Option<u64>,
}

impl HttpResponse {
    pub fn json(status: u16, body: &crate::util::json::Json) -> Self {
        Self {
            status,
            headers: Vec::new(),
            content_type: "application/json",
            body: body.to_string().into_bytes(),
            span_id: None,
        }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Self {
        Self {
            status,
            headers: Vec::new(),
            content_type: "text/plain; charset=utf-8",
            body: body.into().into_bytes(),
            span_id: None,
        }
    }

    pub fn with_header(mut self, name: &str, value: impl Into<String>) -> Self {
        self.headers.push((name.to_string(), value.into()));
        self
    }

    /// Tag this response with the trace span it answers.
    pub fn with_span(mut self, span_id: Option<u64>) -> Self {
        self.span_id = span_id;
        self
    }

    /// Serialize status line, headers, and body.  `keep_alive` decides
    /// the `Connection` header; the caller closes the stream when false.
    pub fn write(&self, w: &mut impl Write, keep_alive: bool) -> io::Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: {}\r\n",
            self.status,
            status_reason(self.status),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" },
        );
        for (name, value) in &self.headers {
            head.push_str(name);
            head.push_str(": ");
            head.push_str(value);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// Reason phrase for every status this frontend emits.
pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn no_stop() -> AtomicBool {
        AtomicBool::new(false)
    }

    fn parse(raw: &[u8]) -> ReadOutcome {
        read_request(&mut Cursor::new(raw.to_vec()), &Limits::default(), &no_stop()).unwrap()
    }

    fn parse_limited(raw: &[u8], limits: Limits) -> ReadOutcome {
        read_request(&mut Cursor::new(raw.to_vec()), &limits, &no_stop()).unwrap()
    }

    #[test]
    fn parses_post_with_body_and_headers() {
        let raw = b"POST /v1/models/grkan/infer HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\nContent-Length: 11\r\n\r\n{\"rows\": 1}";
        let ReadOutcome::Ok(req) = parse(raw) else { panic!("want Ok") };
        assert_eq!(req.method, "POST");
        assert_eq!(req.path(), "/v1/models/grkan/infer");
        assert!(req.http11);
        assert_eq!(req.header("content-type"), Some("application/json"));
        assert_eq!(req.header("HOST"), Some("x"), "header lookup is case-insensitive");
        assert_eq!(req.body, b"{\"rows\": 1}");
        assert!(req.keep_alive(), "HTTP/1.1 defaults to keep-alive");
    }

    #[test]
    fn parses_get_without_body_and_query() {
        let raw = b"GET /metrics?verbose=1 HTTP/1.1\r\n\r\n";
        let ReadOutcome::Ok(req) = parse(raw) else { panic!("want Ok") };
        assert_eq!(req.method, "GET");
        assert_eq!(req.path(), "/metrics");
        assert_eq!(req.target, "/metrics?verbose=1");
        assert!(req.body.is_empty());
    }

    #[test]
    fn bare_lf_lines_are_tolerated() {
        let raw = b"GET /healthz HTTP/1.1\nHost: x\n\n";
        let ReadOutcome::Ok(req) = parse(raw) else { panic!("want Ok") };
        assert_eq!(req.path(), "/healthz");
    }

    #[test]
    fn connection_header_overrides_version_default() {
        let raw = b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n";
        let ReadOutcome::Ok(req) = parse(raw) else { panic!("want Ok") };
        assert!(!req.keep_alive());
        let raw = b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n";
        let ReadOutcome::Ok(req) = parse(raw) else { panic!("want Ok") };
        assert!(!req.http11);
        assert!(req.keep_alive());
        let raw = b"GET / HTTP/1.0\r\n\r\n";
        let ReadOutcome::Ok(req) = parse(raw) else { panic!("want Ok") };
        assert!(!req.keep_alive(), "HTTP/1.0 defaults to close");
    }

    #[test]
    fn eof_before_first_byte_is_closed_not_error() {
        assert!(matches!(parse(b""), ReadOutcome::Closed));
    }

    #[test]
    fn malformed_inputs_get_400_class_statuses() {
        let cases: &[(&[u8], u16)] = &[
            (b"GARBAGE\r\n\r\n", 400),                                 // no method/target
            (b"GET / HTTP/2.0\r\n\r\n", 505),                          // unsupported version
            (b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n", 400),         // bad header line
            (b"GET / HTTP/1.1\r\nContent-Length: pony\r\n\r\n", 400),  // bad length
            (b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort", 400), // truncated body
            (b"GET / HTTP/1.1\r\nHost: x", 400),                       // EOF inside headers
            (b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n", 501),
        ];
        for (raw, want) in cases {
            match parse(raw) {
                ReadOutcome::Bad { status, .. } => {
                    assert_eq!(status, *want, "input {:?}", String::from_utf8_lossy(raw))
                }
                other => panic!("want Bad for {:?}, got {other:?}", String::from_utf8_lossy(raw)),
            }
        }
    }

    #[test]
    fn oversized_header_section_is_431() {
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        raw.extend_from_slice(format!("X-Big: {}\r\n\r\n", "v".repeat(200)).as_bytes());
        let limits = Limits { max_header_bytes: 64, ..Default::default() };
        match parse_limited(&raw, limits) {
            ReadOutcome::Bad { status, .. } => assert_eq!(status, 431),
            other => panic!("want 431, got {other:?}"),
        }
    }

    #[test]
    fn newline_free_flood_is_431_not_unbounded_buffering() {
        // 100KB of request-line bytes with no newline: the per-line cap
        // stops buffering at max_header_bytes + 2 and reports 431.
        let raw = vec![b'G'; 100_000];
        let limits = Limits { max_header_bytes: 1024, ..Default::default() };
        match parse_limited(&raw, limits) {
            ReadOutcome::Bad { status, .. } => assert_eq!(status, 431),
            other => panic!("want 431, got {other:?}"),
        }
    }

    /// A reader that yields its prefix, then stalls forever with
    /// `WouldBlock` — the unit-test stand-in for a silent socket.
    struct Stall(&'static [u8], usize);

    impl io::Read for Stall {
        fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
            if self.1 < self.0.len() {
                let n = (self.0.len() - self.1).min(out.len());
                out[..n].copy_from_slice(&self.0[self.1..self.1 + n]);
                self.1 += n;
                Ok(n)
            } else {
                Err(io::ErrorKind::WouldBlock.into())
            }
        }
    }

    #[test]
    fn expired_request_deadline_is_reported_not_looped() {
        // max_request_secs = 0: the wall-clock deadline (the drip-feed
        // defense) trips at the first check, before any read — proving
        // the deadline path is wired, without sleeping in the test.
        let limits = Limits { max_request_secs: 0, ..Default::default() };
        let mut r = Cursor::new(b"GET / HTTP/1.1\r\n\r\n".to_vec());
        assert!(matches!(
            read_request(&mut r, &limits, &no_stop()).unwrap(),
            ReadOutcome::Closed
        ));
    }

    #[test]
    fn stall_mid_request_is_408_and_idle_stall_is_closed() {
        let limits = Limits { max_stall_ticks: 3, ..Default::default() };
        // Bytes arrived, then silence: a started request timed out.
        let mut r = io::BufReader::new(Stall(b"GET /he", 0));
        match read_request(&mut r, &limits, &no_stop()).unwrap() {
            ReadOutcome::Bad { status, .. } => assert_eq!(status, 408),
            other => panic!("want 408, got {other:?}"),
        }
        // Silence from byte zero: just an idle keep-alive connection.
        let mut r = io::BufReader::new(Stall(b"", 0));
        assert!(matches!(
            read_request(&mut r, &limits, &no_stop()).unwrap(),
            ReadOutcome::Closed
        ));
    }

    #[test]
    fn oversized_body_is_413_without_reading_it() {
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 1000000\r\n\r\n";
        let limits = Limits { max_body_bytes: 1024, ..Default::default() };
        match parse_limited(raw, limits) {
            ReadOutcome::Bad { status, .. } => assert_eq!(status, 413),
            other => panic!("want 413, got {other:?}"),
        }
    }

    #[test]
    fn response_writes_framing_and_roundtrips_reason() {
        let resp = HttpResponse::json(200, &crate::util::json::Json::Obj(vec![]))
            .with_header("retry-after", "1");
        let mut out = Vec::new();
        resp.write(&mut out, true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("content-type: application/json\r\n"), "{text}");
        assert!(text.contains("content-length: 2\r\n"), "{text}");
        assert!(text.contains("connection: keep-alive\r\n"), "{text}");
        assert!(text.contains("retry-after: 1\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{}"), "{text}");
        let mut out = Vec::new();
        HttpResponse::text(429, "slow down").write(&mut out, false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("connection: close\r\n"), "{text}");
    }

    #[test]
    fn two_pipelined_requests_parse_in_sequence() {
        let raw = b"POST /a HTTP/1.1\r\nContent-Length: 2\r\n\r\nhiGET /b HTTP/1.1\r\n\r\n".to_vec();
        let mut cur = Cursor::new(raw);
        let stop = no_stop();
        let ReadOutcome::Ok(a) = read_request(&mut cur, &Limits::default(), &stop).unwrap()
        else {
            panic!("first")
        };
        assert_eq!((a.path(), a.body.as_slice()), ("/a", b"hi".as_slice()));
        let ReadOutcome::Ok(b) = read_request(&mut cur, &Limits::default(), &stop).unwrap()
        else {
            panic!("second")
        };
        assert_eq!(b.path(), "/b");
        assert!(matches!(
            read_request(&mut cur, &Limits::default(), &stop).unwrap(),
            ReadOutcome::Closed
        ));
    }
}
