//! Thin blocking HTTP/1.1 client over `std::net::TcpStream`.
//!
//! Exists for the HTTP loadgen mode, the e2e tests, and
//! `examples/http_client` — one keep-alive connection per client
//! thread, mirroring how the closed-loop in-process bench holds one
//! submitter per thread, so the in-process vs HTTP comparison in
//! `BENCH_http.json` measures transport overhead rather than
//! connection-setup overhead.  Not a general-purpose client: no
//! redirects, no TLS, no chunked bodies — the same scope as the server
//! side in [`super::http`].

use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use anyhow::{bail, Context, Result};

/// A response as the client sees it.
#[derive(Clone, Debug)]
pub struct ClientResponse {
    pub status: u16,
    /// Lower-cased header names, wire order.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl ClientResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// The `Retry-After` backoff hint (on `429`/`503`), in milliseconds.
    /// Parses the delay-seconds form — the only form this stack emits;
    /// an HTTP-date value (or garbage) is `None`, so callers fall back
    /// to their own backoff instead of sleeping until a misparsed date.
    pub fn retry_after_millis(&self) -> Option<u64> {
        let secs: u64 = self.header("retry-after")?.trim().parse().ok()?;
        secs.checked_mul(1000)
    }

    pub fn body_str(&self) -> std::borrow::Cow<'_, str> {
        String::from_utf8_lossy(&self.body)
    }
}

/// One keep-alive connection.
pub struct HttpClient {
    reader: BufReader<TcpStream>,
    addr: SocketAddr,
}

impl HttpClient {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        // A generous ceiling so a wedged server fails the call instead of
        // hanging the bench/test forever.
        stream.set_read_timeout(Some(Duration::from_secs(30))).ok();
        Ok(Self { reader: BufReader::new(stream), addr })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn get(&mut self, path: &str) -> Result<ClientResponse> {
        self.request("GET", path, None)
    }

    pub fn post_json(&mut self, path: &str, body: &str) -> Result<ClientResponse> {
        self.request("POST", path, Some(body.as_bytes()))
    }

    /// Send one request and read the response off the same connection.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&[u8]>,
    ) -> Result<ClientResponse> {
        let mut head = format!("{method} {path} HTTP/1.1\r\nhost: {}\r\n", self.addr);
        if body.is_some() {
            head.push_str("content-type: application/json\r\n");
        }
        head.push_str(&format!("content-length: {}\r\n\r\n", body.map_or(0, <[u8]>::len)));
        let stream = self.reader.get_mut();
        stream.write_all(head.as_bytes())?;
        if let Some(body) = body {
            stream.write_all(body)?;
        }
        stream.flush()?;
        self.read_response()
    }

    fn read_line(&mut self) -> Result<String> {
        let mut line = Vec::new();
        let n = self.reader.read_until(b'\n', &mut line)?;
        if n == 0 {
            bail!("server closed the connection");
        }
        while line.last().is_some_and(|c| *c == b'\n' || *c == b'\r') {
            line.pop();
        }
        String::from_utf8(line).map_err(|_| anyhow::anyhow!("non-UTF-8 response header"))
    }

    fn read_response(&mut self) -> Result<ClientResponse> {
        let status_line = self.read_line()?;
        let status: u16 = status_line
            .split_ascii_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .with_context(|| format!("bad status line {status_line:?}"))?;
        let mut headers = Vec::new();
        let mut content_length = 0usize;
        loop {
            let line = self.read_line()?;
            if line.is_empty() {
                break;
            }
            let (name, value) = line
                .split_once(':')
                .with_context(|| format!("bad response header {line:?}"))?;
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim().to_string();
            if name == "content-length" {
                content_length = value
                    .parse()
                    .with_context(|| format!("bad content-length {value:?}"))?;
            }
            headers.push((name, value));
        }
        let mut body = vec![0u8; content_length];
        io::Read::read_exact(&mut self.reader, &mut body).context("reading response body")?;
        Ok(ClientResponse { status, headers, body })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(headers: &[(&str, &str)]) -> ClientResponse {
        ClientResponse {
            status: 429,
            headers: headers
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
            body: Vec::new(),
        }
    }

    #[test]
    fn retry_after_parses_delay_seconds_to_millis() {
        assert_eq!(resp(&[("retry-after", "1")]).retry_after_millis(), Some(1000));
        assert_eq!(resp(&[("retry-after", " 30 ")]).retry_after_millis(), Some(30_000));
        assert_eq!(resp(&[("retry-after", "0")]).retry_after_millis(), Some(0));
    }

    #[test]
    fn retry_after_absent_or_unparseable_is_none() {
        assert_eq!(resp(&[]).retry_after_millis(), None);
        // HTTP-date form: unsupported, must not misparse into a sleep.
        assert_eq!(
            resp(&[("retry-after", "Wed, 21 Oct 2026 07:28:00 GMT")]).retry_after_millis(),
            None
        );
        assert_eq!(resp(&[("retry-after", "-2")]).retry_after_millis(), None);
        assert_eq!(resp(&[("retry-after", "1.5")]).retry_after_millis(), None);
        // Saturating garbage: u64::MAX seconds would overflow the
        // millisecond conversion — None, not a wrapped tiny sleep.
        assert_eq!(
            resp(&[("retry-after", &u64::MAX.to_string())]).retry_after_millis(),
            None
        );
    }
}
