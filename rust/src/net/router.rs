//! Route parsed HTTP requests onto a [`Server`] and render responses.
//!
//! Pure request → response mapping (no sockets, no threads), so every
//! route is unit-testable against an in-process server.  Endpoints:
//!
//! - `POST /v1/models/{name}/infer` — JSON `{"x": [f32...], "rows": N}`
//!   (`rows` optional: defaults to `x.len() / d_in`).  Admission uses
//!   [`Server::try_submit`], so a saturated shard queue is **shed** as
//!   `429 Too Many Requests` + `Retry-After` instead of stalling the
//!   connection handler — backpressure surfaces at the protocol layer.
//!   Success returns `{"y": [...], "batch_size": B, "cause": "...",
//!   "timing": {...}}` plus `"span_id"` when the server traces.
//! - `GET /v1/models` — registry metadata (name, widths, shard).
//! - `GET /healthz` — liveness probe.
//! - `GET /metrics` — Prometheus text: HTTP status counters plus the
//!   server's live per-model [`crate::serve::ExecStats`] snapshot.
//!
//! Float fidelity: request/response payloads round-trip f32 values
//! bit-exactly — f32 → f64 is exact, the JSON writer emits the shortest
//! round-trip decimal for the f64, and the parser rounds it back to the
//! identical f64, so `(sent f32) == (received f32)` for every finite
//! value (`tests/http_e2e.rs` asserts this end to end).

use std::sync::atomic::{AtomicU64, Ordering};

use super::http::{HttpResponse, Request};
use crate::serve::{Server, SubmitError};
use crate::util::json::Json;

/// Every status the frontend emits, in reporting order.
pub const TRACKED_STATUSES: [u16; 12] =
    [200, 400, 404, 405, 408, 413, 429, 431, 500, 501, 503, 505];

/// HTTP-layer counters (the serve-layer counters live in
/// [`crate::serve::ServeStats`] and are scraped live).
#[derive(Default)]
pub struct HttpMetrics {
    /// Indexed like [`TRACKED_STATUSES`]; the last slot catches unknowns.
    statuses: [AtomicU64; TRACKED_STATUSES.len() + 1],
    pub connections: AtomicU64,
}

impl HttpMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one response by status code.
    pub fn count(&self, status: u16) {
        let idx = TRACKED_STATUSES
            .iter()
            .position(|&s| s == status)
            .unwrap_or(TRACKED_STATUSES.len());
        self.statuses[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Responses recorded for `status` so far.
    pub fn status_count(&self, status: u16) -> u64 {
        let idx = TRACKED_STATUSES
            .iter()
            .position(|&s| s == status)
            .unwrap_or(TRACKED_STATUSES.len());
        self.statuses[idx].load(Ordering::Relaxed)
    }
}

fn error_json(status: u16, msg: &str) -> HttpResponse {
    HttpResponse::json(
        status,
        &Json::Obj(vec![("error".to_string(), Json::Str(msg.to_string()))]),
    )
}

/// Map one request to its response.  The caller (listener or test)
/// records `resp.status` into `metrics` afterwards, so parse-level
/// failures it generates itself are counted through the same funnel.
pub fn handle(req: &Request, server: &Server, metrics: &HttpMetrics) -> HttpResponse {
    let segments: Vec<&str> = req.path().split('/').filter(|s| !s.is_empty()).collect();
    match segments.as_slice() {
        ["healthz"] => match req.method.as_str() {
            "GET" => HttpResponse::text(200, "ok\n"),
            _ => error_json(405, "healthz supports GET"),
        },
        ["metrics"] => match req.method.as_str() {
            "GET" => HttpResponse::text(200, render_metrics(server, metrics)),
            _ => error_json(405, "metrics supports GET"),
        },
        ["v1", "models"] => match req.method.as_str() {
            "GET" => HttpResponse::json(200, &models_json(server)),
            _ => error_json(405, "models supports GET"),
        },
        ["v1", "models", name, "infer"] => match req.method.as_str() {
            "POST" => infer(req, server, name),
            _ => error_json(405, "infer supports POST"),
        },
        _ => error_json(404, &format!("no route for {}", req.path())),
    }
}

fn models_json(server: &Server) -> Json {
    let models: Vec<Json> = server
        .models()
        .iter()
        .map(|m| {
            Json::Obj(vec![
                ("name".to_string(), Json::Str(m.name.clone())),
                ("d_in".to_string(), Json::Int(m.d_in as i64)),
                ("d_out".to_string(), Json::Int(m.d_out as i64)),
                ("shard".to_string(), Json::Int(m.shard as i64)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("models".to_string(), Json::Arr(models)),
        ("shards".to_string(), Json::Int(server.shards() as i64)),
    ])
}

fn infer(req: &Request, server: &Server, name: &str) -> HttpResponse {
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t,
        Err(_) => return error_json(400, "body is not UTF-8"),
    };
    let body = match Json::parse(text) {
        Ok(v) => v,
        Err(e) => return error_json(400, &format!("bad JSON body: {e}")),
    };
    let Some(x_json) = body.get("x").and_then(Json::as_arr) else {
        return error_json(400, "body needs an \"x\" array of numbers");
    };
    let mut x = Vec::with_capacity(x_json.len());
    for v in x_json {
        // Finite in f32, not just f64: 1e999 parses to f64 infinity and
        // 1e300 overflows the f32 cast — both would silently corrupt the
        // model input, and JSON could not carry them back out anyway.
        let f = v.as_f64().map(|f| f as f32);
        match f {
            Some(f) if f.is_finite() => x.push(f),
            _ => return error_json(400, "\"x\" must contain only finite numbers"),
        }
    }
    let rows = match body.get("rows") {
        None => {
            // Default: one request = x.len()/d_in rows of the target
            // model (validated below by the server's shape check; an
            // unknown model still 404s first).
            let Some(idx) = server.model_index(name) else {
                return error_json(404, &format!("unknown model {name:?}"));
            };
            let d_in = server.models()[idx as usize].d_in;
            if x.is_empty() || x.len() % d_in != 0 {
                return error_json(
                    400,
                    &format!("x has {} values, not a positive multiple of d_in={d_in}", x.len()),
                );
            }
            (x.len() / d_in) as u32
        }
        // rows >= 1: a 0-row request would pass the server's shape check
        // (0 == 0 * d_in) and burn a queue slot + an executor wakeup on
        // a no-op, which the empty-`x` default path already rejects.
        Some(v) => match v.as_usize().and_then(|n| u32::try_from(n).ok()) {
            Some(n) if n > 0 => n,
            _ => return error_json(400, "\"rows\" must be a positive integer"),
        },
    };
    // Mint the span at the protocol edge so `t_admit_us` covers queue
    // wait from the moment the request was understood, not from shard
    // admission.  On an untraced server this is `None` and submission
    // falls back to its own (also-None) minting.
    let span = server.mint_span(name, rows);
    match server.try_submit_span(name, x, rows, span) {
        Ok(resp) => {
            // JSON numbers cannot carry NaN/inf (the writer would emit
            // null and the documented bit-identity would silently
            // break); a model emitting them is a server-side fault.
            if resp.y.iter().any(|v| !v.is_finite()) {
                return error_json(500, "model produced non-finite values");
            }
            let y: Vec<Json> = resp.y.iter().map(|&v| Json::Num(v as f64)).collect();
            let t = resp.timing;
            let mut fields = vec![
                ("y".to_string(), Json::Arr(y)),
                ("batch_size".to_string(), Json::Int(resp.batch_size as i64)),
                ("cause".to_string(), Json::Str(resp.cause.label().to_string())),
                (
                    "timing".to_string(),
                    Json::Obj(vec![
                        ("queue_wait_us".to_string(), Json::Int(t.queue_wait_us as i64)),
                        ("batch_form_us".to_string(), Json::Int(t.batch_form_us as i64)),
                        ("exec_us".to_string(), Json::Int(t.exec_us as i64)),
                        ("reply_us".to_string(), Json::Int(t.reply_us as i64)),
                    ]),
                ),
            ];
            if let Some(id) = resp.span_id {
                fields.push(("span_id".to_string(), Json::Int(id as i64)));
            }
            HttpResponse::json(200, &Json::Obj(fields)).with_span(resp.span_id)
        }
        Err(SubmitError::QueueFull { queue_depth }) => error_json(
            429,
            &format!("admission queue full (depth {queue_depth}); retry shortly"),
        )
        .with_header("retry-after", "1"),
        Err(SubmitError::ShuttingDown) => error_json(503, "server is draining"),
        Err(e @ SubmitError::ResponseTimeout) => {
            error_json(503, &e.to_string()).with_header("retry-after", "1")
        }
        Err(SubmitError::UnknownModel(what)) => {
            error_json(404, &format!("unknown model {what}"))
        }
        Err(SubmitError::BadRequest(msg)) => error_json(400, &msg),
        Err(SubmitError::Failed(msg)) => error_json(500, &msg),
    }
}

/// Prometheus label-value escaping: `\` → `\\`, `"` → `\"`, newline →
/// `\n`.  Model names are free-form registry strings, so emitting them
/// raw could make the whole exposition unparseable to a scraper.
fn prom_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Prometheus text exposition: HTTP counters + the live serve snapshot.
fn render_metrics(server: &Server, metrics: &HttpMetrics) -> String {
    let mut out = String::new();
    out.push_str("# TYPE flashkat_http_requests_total counter\n");
    for &status in &TRACKED_STATUSES {
        let n = metrics.status_count(status);
        if n > 0 {
            out.push_str(&format!(
                "flashkat_http_requests_total{{code=\"{status}\"}} {n}\n"
            ));
        }
    }
    out.push_str(&format!(
        "# TYPE flashkat_http_connections_total counter\nflashkat_http_connections_total {}\n",
        metrics.connections.load(Ordering::Relaxed)
    ));
    let stats = server.stats();
    for (metric, help) in [
        ("flashkat_serve_requests_total", "requests served per model"),
        ("flashkat_serve_rows_total", "rows served per model"),
        ("flashkat_serve_batches_total", "coalesced batches per model"),
        ("flashkat_serve_failed_total", "requests failed in the executor per model"),
    ] {
        out.push_str(&format!("# HELP {metric} {help}\n# TYPE {metric} counter\n"));
        for m in &stats.per_model {
            let v = match metric {
                "flashkat_serve_requests_total" => m.stats.requests,
                "flashkat_serve_rows_total" => m.stats.rows,
                "flashkat_serve_batches_total" => m.stats.batches,
                _ => m.stats.failed,
            };
            out.push_str(&format!("{metric}{{model=\"{}\"}} {v}\n", prom_escape(&m.name)));
        }
    }
    // Why each batch left the queue, per model: the cause mix is the
    // batcher's fingerprint (all-deadline = latency-bound, all-full =
    // saturated, all-idle = trickle traffic).
    out.push_str(
        "# HELP flashkat_flush_total batches flushed per model by cause\n\
         # TYPE flashkat_flush_total counter\n",
    );
    for m in &stats.per_model {
        for cause in crate::serve::FlushCause::ALL {
            out.push_str(&format!(
                "flashkat_flush_total{{model=\"{}\",cause=\"{}\"}} {}\n",
                prom_escape(&m.name),
                cause.label(),
                m.stats.causes[cause.index()]
            ));
        }
    }
    out.push_str("# TYPE flashkat_serve_busy_seconds_total counter\n");
    for m in &stats.per_model {
        out.push_str(&format!(
            "flashkat_serve_busy_seconds_total{{model=\"{}\"}} {}\n",
            prom_escape(&m.name),
            m.stats.busy_secs
        ));
    }
    // Payload bytes moved through the executors (successful batches
    // only): the serving-level counterpart of the kernel traffic probes
    // (DESIGN.md §17), split by direction.
    out.push_str(
        "# HELP flashkat_traffic_bytes_total executor payload bytes per model and direction\n\
         # TYPE flashkat_traffic_bytes_total counter\n",
    );
    for m in &stats.per_model {
        for (stream, v) in [("in", m.stats.bytes_in), ("out", m.stats.bytes_out)] {
            out.push_str(&format!(
                "flashkat_traffic_bytes_total{{model=\"{}\",stream=\"{stream}\"}} {v}\n",
                prom_escape(&m.name)
            ));
        }
    }
    // Per-request latency histograms from the log-scaled LogHist
    // accumulators: each occupied bucket's upper bound becomes a
    // cumulative `le` bucket (Prometheus histogram convention), closed
    // by the mandatory `+Inf` bucket, `_sum`, and `_count`.
    type HistPick = fn(&crate::serve::ExecStats) -> &crate::util::stats::LogHist;
    let hists: [(&str, &str, HistPick); 2] = [
        (
            "flashkat_queue_wait_us",
            "per-request queue wait in microseconds (admission to batch release)",
            |s| &s.queue_wait,
        ),
        (
            "flashkat_exec_us",
            "per-request executor time in microseconds (the batch's run duration)",
            |s| &s.exec,
        ),
    ];
    for (metric, help, pick) in hists {
        out.push_str(&format!("# HELP {metric} {help}\n# TYPE {metric} histogram\n"));
        for m in &stats.per_model {
            let h = pick(&m.stats);
            let name = prom_escape(&m.name);
            for (le, cum) in h.cumulative_buckets() {
                out.push_str(&format!(
                    "{metric}_bucket{{model=\"{name}\",le=\"{le}\"}} {cum}\n"
                ));
            }
            out.push_str(&format!(
                "{metric}_bucket{{model=\"{name}\",le=\"+Inf\"}} {}\n",
                h.count()
            ));
            out.push_str(&format!("{metric}_sum{{model=\"{name}\"}} {}\n", h.sum()));
            out.push_str(&format!("{metric}_count{{model=\"{name}\"}} {}\n", h.count()));
        }
    }
    out.push_str("# TYPE flashkat_serve_peak_queued gauge\n");
    for (s, peak) in stats.shard_peaks.iter().enumerate() {
        out.push_str(&format!("flashkat_serve_peak_queued{{shard=\"{s}\"}} {peak}\n"));
    }
    // The same live load signal StatsResponse v2 puts on the wire, so
    // an HTTP scrape and a router's least-loaded ranking read one truth.
    let loads = server.shard_loads();
    out.push_str("# TYPE flashkat_serve_queue_depth gauge\n");
    for (s, (queued, _)) in loads.iter().enumerate() {
        out.push_str(&format!("flashkat_serve_queue_depth{{shard=\"{s}\"}} {queued}\n"));
    }
    out.push_str("# TYPE flashkat_serve_inflight gauge\n");
    for (s, (_, in_flight)) in loads.iter().enumerate() {
        out.push_str(&format!("flashkat_serve_inflight{{shard=\"{s}\"}} {in_flight}\n"));
    }
    // Content-addressed result cache counters — present only when the
    // server was started with a cache (`--cache-bytes > 0`), so an
    // uncached scrape is byte-identical to before the cache existed.
    if let Some(cs) = server.cache_stats() {
        for (metric, help) in [
            ("flashkat_cache_hits_total", "verified cache hits per model"),
            ("flashkat_cache_misses_total", "cache misses per model"),
            ("flashkat_cache_evictions_total", "cache evictions per model"),
            (
                "flashkat_cache_coalesced_total",
                "requests coalesced onto an identical in-flight request per model",
            ),
        ] {
            out.push_str(&format!("# HELP {metric} {help}\n# TYPE {metric} counter\n"));
            for (name, c) in &cs.per_model {
                let v = match metric {
                    "flashkat_cache_hits_total" => c.hits,
                    "flashkat_cache_misses_total" => c.misses,
                    "flashkat_cache_evictions_total" => c.evictions,
                    _ => c.coalesced,
                };
                out.push_str(&format!("{metric}{{model=\"{}\"}} {v}\n", prom_escape(name)));
            }
        }
        out.push_str(&format!(
            "# TYPE flashkat_cache_bytes gauge\nflashkat_cache_bytes {}\n",
            cs.bytes
        ));
    }
    // Spans the trace collector discarded at ring capacity; nonzero
    // means any exported trace is incomplete.  0 on an untraced server.
    // With a tracer attached, a per-track split follows the total so
    // the saturated ring (slice or counter) is identifiable from the
    // scrape alone.
    out.push_str(&format!(
        "# TYPE flashkat_trace_dropped_total counter\nflashkat_trace_dropped_total {}\n",
        server.tracer().map_or(0, |t| t.dropped())
    ));
    if let Some(t) = server.tracer() {
        for (track, dropped) in t.dropped_by_track() {
            out.push_str(&format!(
                "flashkat_trace_dropped_total{{track=\"{}\"}} {dropped}\n",
                prom_escape(&track)
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rational::{forward, Coeffs};
    use crate::serve::{BatchPolicy, RationalExecutor};
    use crate::util::rng::Pcg64;

    const D: usize = 16;

    fn test_server() -> (Server, Coeffs<f32>) {
        let mut rng = Pcg64::new(71);
        let coeffs = Coeffs::<f32>::randn(4, 6, 4, &mut rng);
        let server = Server::start(
            vec![Box::new(RationalExecutor::new("grkan", D, coeffs.clone()).unwrap())],
            BatchPolicy::default(),
        )
        .unwrap();
        (server, coeffs)
    }

    fn post(server: &Server, path: &str, body: &str) -> HttpResponse {
        let req = Request {
            method: "POST".to_string(),
            target: path.to_string(),
            http11: true,
            headers: vec![],
            body: body.as_bytes().to_vec(),
        };
        handle(&req, server, &HttpMetrics::new())
    }

    fn get(server: &Server, path: &str, metrics: &HttpMetrics) -> HttpResponse {
        let req = Request {
            method: "GET".to_string(),
            target: path.to_string(),
            http11: true,
            headers: vec![],
            body: vec![],
        };
        handle(&req, server, metrics)
    }

    #[test]
    fn infer_round_trips_bit_identically() {
        let (server, coeffs) = test_server();
        let mut rng = Pcg64::new(72);
        let x: Vec<f32> = (0..2 * D).map(|_| rng.normal_f32()).collect();
        let want = forward(&x, 2, D, &coeffs);
        let body = Json::Obj(vec![
            ("x".to_string(), Json::Arr(x.iter().map(|&v| Json::Num(v as f64)).collect())),
            ("rows".to_string(), Json::Int(2)),
        ]);
        let resp = post(&server, "/v1/models/grkan/infer", &body.to_string());
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let parsed = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        let y: Vec<f32> = parsed
            .get("y")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        assert_eq!(y, want, "HTTP JSON round trip must be bit-exact");
        assert!(parsed.get("batch_size").unwrap().as_usize().unwrap() >= 1);
        assert!(parsed.get("cause").unwrap().as_str().is_some());
        // Timing breakdown rides along even without a tracer attached;
        // span_id does not (this server is untraced).
        let timing = parsed.get("timing").expect("timing object present");
        for phase in ["queue_wait_us", "batch_form_us", "exec_us", "reply_us"] {
            assert!(timing.get(phase).and_then(Json::as_i64).is_some(), "{phase}");
        }
        assert!(parsed.get("span_id").is_none(), "untraced server leaks no span id");
    }

    #[test]
    fn traced_server_reports_span_id_over_http() {
        let mut rng = Pcg64::new(74);
        let coeffs = Coeffs::<f32>::randn(4, 6, 4, &mut rng);
        let tracer = std::sync::Arc::new(crate::trace::TraceCollector::new());
        let server = Server::start_sharded_traced(
            vec![Box::new(RationalExecutor::new("grkan", D, coeffs).unwrap())],
            BatchPolicy::default(),
            1,
            Some(tracer.clone()),
        )
        .unwrap();
        let ok_body = format!("{{\"x\":[{}],\"rows\":1}}", vec!["0"; D].join(","));
        let resp = post(&server, "/v1/models/grkan/infer", &ok_body);
        assert_eq!(resp.status, 200);
        let parsed = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        let id = parsed.get("span_id").and_then(Json::as_i64).expect("span id in body");
        assert!(id >= 1);
        assert_eq!(resp.span_id, Some(id as u64), "response carries the handler-slice span");
    }

    #[test]
    fn infer_rows_defaults_to_payload_height() {
        let (server, coeffs) = test_server();
        let x: Vec<f32> = (0..3 * D).map(|i| i as f32 * 0.125).collect();
        let want = forward(&x, 3, D, &coeffs);
        let body = Json::Obj(vec![(
            "x".to_string(),
            Json::Arr(x.iter().map(|&v| Json::Num(v as f64)).collect()),
        )]);
        let resp = post(&server, "/v1/models/grkan/infer", &body.to_string());
        assert_eq!(resp.status, 200);
        let parsed = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        let y: Vec<f32> =
            parsed.get("y").unwrap().as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as f32).collect();
        assert_eq!(y, want);
    }

    #[test]
    fn infer_failures_map_to_http_statuses() {
        let (server, _) = test_server();
        // Malformed JSON → 400.
        assert_eq!(post(&server, "/v1/models/grkan/infer", "{\"x\":").status, 400);
        // Missing x → 400.
        assert_eq!(post(&server, "/v1/models/grkan/infer", "{\"rows\":1}").status, 400);
        // Non-numeric x → 400.
        assert_eq!(post(&server, "/v1/models/grkan/infer", "{\"x\":[\"a\"]}").status, 400);
        // Non-finite x → 400: f64 overflow (1e999 → inf) and f32
        // overflow (1e300 → inf after the cast) are both rejected.
        assert_eq!(post(&server, "/v1/models/grkan/infer", "{\"x\":[1e999]}").status, 400);
        assert_eq!(post(&server, "/v1/models/grkan/infer", "{\"x\":[1e300]}").status, 400);
        // Shape mismatch → 400 (server-side check).
        assert_eq!(post(&server, "/v1/models/grkan/infer", "{\"x\":[1,2],\"rows\":1}").status, 400);
        // Zero rows → 400 (would otherwise be a queue-slot-burning no-op).
        assert_eq!(post(&server, "/v1/models/grkan/infer", "{\"x\":[],\"rows\":0}").status, 400);
        // Unknown model → 404, with and without explicit rows.
        assert_eq!(post(&server, "/v1/models/nope/infer", "{\"x\":[1],\"rows\":1}").status, 404);
        assert_eq!(post(&server, "/v1/models/nope/infer", "{\"x\":[1]}").status, 404);
        // Unknown route → 404; wrong method → 405.
        assert_eq!(post(&server, "/v1/other", "{}").status, 404);
        assert_eq!(post(&server, "/healthz", "").status, 405);
        // Draining server → 503.
        server.shutdown();
        let ok_body = format!(
            "{{\"x\":[{}],\"rows\":1}}",
            vec!["0"; D].join(",")
        );
        assert_eq!(post(&server, "/v1/models/grkan/infer", &ok_body).status, 503);
    }

    #[test]
    fn models_healthz_and_metrics_render() {
        let (server, _) = test_server();
        let metrics = HttpMetrics::new();
        assert_eq!(get(&server, "/healthz", &metrics).status, 200);
        let models = get(&server, "/v1/models", &metrics);
        assert_eq!(models.status, 200);
        let parsed = Json::parse(std::str::from_utf8(&models.body).unwrap()).unwrap();
        let list = parsed.get("models").unwrap().as_arr().unwrap();
        assert_eq!(list.len(), 1);
        assert_eq!(list[0].get("name").unwrap().as_str(), Some("grkan"));
        assert_eq!(list[0].get("d_in").unwrap().as_usize(), Some(D));

        // Serve one request, then the scrape must show it.
        let ok_body = format!("{{\"x\":[{}],\"rows\":1}}", vec!["0"; D].join(","));
        assert_eq!(post(&server, "/v1/models/grkan/infer", &ok_body).status, 200);
        metrics.count(200);
        let scrape = get(&server, "/metrics", &metrics);
        assert_eq!(scrape.status, 200);
        let text = String::from_utf8(scrape.body).unwrap();
        assert!(text.contains("flashkat_http_requests_total{code=\"200\"} 1"), "{text}");
        assert!(text.contains("flashkat_serve_requests_total{model=\"grkan\"} 1"), "{text}");
        assert!(text.contains("flashkat_serve_peak_queued{shard=\"0\"}"), "{text}");
    }

    #[test]
    fn metrics_export_cache_and_trace_dropped_counters() {
        // Uncached, untraced server: no cache lines at all, and the
        // trace-dropped counter reads 0.
        let (server, _) = test_server();
        let text = String::from_utf8(get(&server, "/metrics", &HttpMetrics::new()).body).unwrap();
        assert!(!text.contains("flashkat_cache_"), "{text}");
        assert!(text.contains("flashkat_trace_dropped_total 0"), "{text}");

        // Cached server: the same body twice — the second serve is a
        // verified hit, and the scrape shows the split.
        let mut rng = Pcg64::new(75);
        let coeffs = Coeffs::<f32>::randn(4, 6, 4, &mut rng);
        let server = Server::start_configured(
            vec![Box::new(RationalExecutor::new("grkan", D, coeffs).unwrap())],
            BatchPolicy::default(),
            1,
            None,
            1 << 20,
        )
        .unwrap();
        let body = format!("{{\"x\":[{}],\"rows\":1}}", vec!["0"; D].join(","));
        assert_eq!(post(&server, "/v1/models/grkan/infer", &body).status, 200);
        let resp = post(&server, "/v1/models/grkan/infer", &body);
        assert_eq!(resp.status, 200);
        let parsed = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(parsed.get("cause").unwrap().as_str(), Some("cache"));
        let text = String::from_utf8(get(&server, "/metrics", &HttpMetrics::new()).body).unwrap();
        assert!(text.contains("flashkat_cache_hits_total{model=\"grkan\"} 1"), "{text}");
        assert!(text.contains("flashkat_cache_misses_total{model=\"grkan\"} 1"), "{text}");
        assert!(text.contains("flashkat_cache_coalesced_total{model=\"grkan\"} 0"), "{text}");
        assert!(text.contains("flashkat_cache_evictions_total{model=\"grkan\"} 0"), "{text}");
        assert!(text.contains("flashkat_cache_bytes "), "{text}");
    }

    /// After serving, the scrape exports the per-model traffic counters
    /// and latency histograms; on a traced server the dropped total also
    /// splits per track (slice and counter rings).
    #[test]
    fn metrics_export_traffic_and_latency_histograms() {
        let (server, _) = test_server();
        let ok_body = format!("{{\"x\":[{}],\"rows\":1}}", vec!["0"; D].join(","));
        assert_eq!(post(&server, "/v1/models/grkan/infer", &ok_body).status, 200);
        let text = String::from_utf8(get(&server, "/metrics", &HttpMetrics::new()).body).unwrap();
        let bytes = D * 4;
        assert!(
            text.contains(&format!(
                "flashkat_traffic_bytes_total{{model=\"grkan\",stream=\"in\"}} {bytes}"
            )),
            "{text}"
        );
        assert!(
            text.contains(&format!(
                "flashkat_traffic_bytes_total{{model=\"grkan\",stream=\"out\"}} {bytes}"
            )),
            "{text}"
        );
        for metric in ["flashkat_queue_wait_us", "flashkat_exec_us"] {
            assert!(text.contains(&format!("# TYPE {metric} histogram")), "{text}");
            assert!(
                text.contains(&format!("{metric}_bucket{{model=\"grkan\",le=\"+Inf\"}} 1")),
                "{text}"
            );
            assert!(text.contains(&format!("{metric}_count{{model=\"grkan\"}} 1")), "{text}");
            assert!(text.contains(&format!("{metric}_sum{{model=\"grkan\"}}")), "{text}");
        }
        // Untraced server: the dropped total has no per-track split.
        assert!(!text.contains("flashkat_trace_dropped_total{track="), "{text}");

        // Traced server: per-track dropped lines appear (all zero here),
        // covering both slice and counter tracks.
        let mut rng = Pcg64::new(76);
        let coeffs = Coeffs::<f32>::randn(4, 6, 4, &mut rng);
        let tracer = std::sync::Arc::new(crate::trace::TraceCollector::new());
        let server = Server::start_sharded_traced(
            vec![Box::new(RationalExecutor::new("grkan", D, coeffs).unwrap())],
            BatchPolicy::default(),
            1,
            Some(tracer),
        )
        .unwrap();
        let text = String::from_utf8(get(&server, "/metrics", &HttpMetrics::new()).body).unwrap();
        assert!(text.contains("flashkat_trace_dropped_total 0"), "{text}");
        assert!(
            text.contains("flashkat_trace_dropped_total{track=\"shard 0\"} 0"),
            "{text}"
        );
        assert!(
            text.contains("flashkat_trace_dropped_total{track=\"shard 0 queue\"} 0"),
            "{text}"
        );
    }

    #[test]
    fn metrics_counts_unknown_statuses_in_overflow_slot() {
        let m = HttpMetrics::new();
        m.count(200);
        m.count(418); // not tracked: falls into the overflow slot
        assert_eq!(m.status_count(200), 1);
        assert_eq!(m.status_count(777), 1, "all unknown statuses share the overflow slot");
    }

    #[test]
    fn prometheus_label_values_are_escaped() {
        assert_eq!(prom_escape("grkan"), "grkan");
        assert_eq!(prom_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        // End to end: a hostile model name still yields parseable lines.
        let mut rng = Pcg64::new(73);
        let coeffs = Coeffs::<f32>::randn(4, 6, 4, &mut rng);
        let server = Server::start(
            vec![Box::new(RationalExecutor::new("a\"b", D, coeffs).unwrap())],
            BatchPolicy::default(),
        )
        .unwrap();
        let scrape = get(&server, "/metrics", &HttpMetrics::new());
        let text = String::from_utf8(scrape.body).unwrap();
        assert!(text.contains("{model=\"a\\\"b\"}"), "{text}");
    }
}
