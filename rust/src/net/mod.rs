//! Network serving frontend: a zero-dependency HTTP/1.1 + JSON layer in
//! front of the sharded [`crate::serve::Server`] (DESIGN.md §12).
//!
//! FlashKAT's thesis — wall-clock cost is coordination overhead, not
//! FLOPs — shaped this subsystem the same way it shaped the kernel and
//! the batcher: the frontend's job is to move untrusted bytes onto the
//! serve engine's admission queue with bounded, measurable overhead, and
//! to surface every internal limit as protocol (queue full → `429
//! Retry-After`, oversized body → `413`, drain → `503`), never as an
//! unbounded wait.  Four layers, each testable on its own:
//!
//! - [`http`] — HTTP/1.1 framing over any `BufRead`/`Write`: parser +
//!   response writer, keep-alive, size limits.  Pure byte-stream logic.
//! - [`client`] — a thin blocking client (loadgen HTTP mode, e2e tests,
//!   `examples/http_client`).
//! - [`router`] — request → response mapping onto a [`crate::serve::Server`]:
//!   `POST /v1/models/{name}/infer`, `GET /v1/models`, `GET /healthz`,
//!   `GET /metrics` (Prometheus text from the live stats snapshot).
//! - [`listener`] — the threaded frontend: bounded accept loop, fixed
//!   handler pool, graceful drain, SIGTERM/SIGINT hook.

pub mod client;
pub mod http;
pub mod listener;
pub mod router;

pub use client::{ClientResponse, HttpClient};
pub use http::{HttpResponse, Limits, Request};
pub use listener::{install_signal_handler, HttpOptions, HttpServer};
pub use router::HttpMetrics;
