//! Threaded TCP frontend: bounded accept loop + connection-handler pool
//! around [`router::handle`].
//!
//! Thread layout mirrors the serve engine's own structure: one accept
//! thread pushes connections into a **bounded** hand-off queue, and a
//! fixed pool of handler threads drains it — so concurrency is capped
//! by construction, and overload degrades by protocol (the accept
//! thread answers `503` itself when the hand-off queue is full, and a
//! full *admission* queue inside the serve engine becomes `429` via
//! `try_submit`) instead of by unbounded thread growth.  The crate's
//! persistent worker pool (`util::parallel`, DESIGN.md §7) is a
//! join-on-submit compute pool and deliberately not reused here:
//! connections are long-lived I/O waits, which would wedge compute
//! capacity; executors keep using that pool *inside* batches.
//!
//! **Graceful drain** ([`HttpServer::shutdown`], also triggered by
//! SIGTERM/SIGINT via [`install_signal_handler`]): stop accepting,
//! finish every in-flight request, close keep-alive connections at the
//! next request boundary, join all threads, then drain the serve engine
//! itself (`Server::shutdown`) so every admitted request is answered —
//! never dropped.  Sockets carry a short read timeout so reads observe
//! the shutdown flag promptly; a read in progress then gets a short
//! grace window (`http::DRAIN_GRACE`) to finish receiving its request —
//! which is answered before the connection closes — while idle
//! keep-alive connections are simply dropped.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::http::{read_request, HttpResponse, Limits, ReadOutcome};
use super::router::{handle, HttpMetrics};
use crate::serve::{ServeStats, Server};
use crate::trace::{AnnValue, TraceCollector, TraceEvent, TrackId};

/// Per-handler-thread tracing context: the collector plus this thread's
/// own track (`http-{i}` / `wire-{i}`), so handler slices from
/// different threads never interleave on one track.  `pub(crate)`
/// because the flashwire frontend has the same shape and reuses it.
pub(crate) struct HandlerTrace {
    pub(crate) tracer: Arc<TraceCollector>,
    pub(crate) track: TrackId,
}

impl HandlerTrace {
    /// Record one handler slice covering `[t0_us, now]`, annotated with
    /// the response status and (when the route produced one) the span
    /// id of the inference it answered.
    pub(crate) fn record(&self, name: String, t0_us: u64, status: u64, span_id: Option<u64>) {
        let mut args = vec![("status", AnnValue::U64(status))];
        if let Some(id) = span_id {
            args.push(("span_id", AnnValue::U64(id)));
        }
        self.tracer.record(TraceEvent {
            track: self.track,
            name,
            t0_us,
            t1_us: self.tracer.now_us(),
            args,
        });
    }
}

/// Frontend tuning knobs.
#[derive(Clone, Debug)]
pub struct HttpOptions {
    /// Connection-handler threads (max concurrent connections).
    pub conn_threads: usize,
    /// Accepted-but-unclaimed connections the accept thread may hold
    /// before answering `503` itself.
    pub backlog: usize,
    pub limits: Limits,
}

impl Default for HttpOptions {
    fn default() -> Self {
        Self { conn_threads: 8, backlog: 64, limits: Limits::default() }
    }
}

/// Bounded blocking FIFO hand-off queue (accept thread → handler pool).
/// `pub(crate)`: the flashwire frontend (`crate::wire::server`) has the
/// same accept-thread/handler-pool shape and reuses it.
pub(crate) struct ConnQueue {
    q: Mutex<std::collections::VecDeque<TcpStream>>,
    ready: Condvar,
    cap: usize,
}

impl ConnQueue {
    pub(crate) fn new(cap: usize) -> ConnQueue {
        ConnQueue {
            q: Mutex::new(std::collections::VecDeque::new()),
            ready: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Enqueue, or hand the stream back when the queue is at capacity
    /// so the caller can answer `503` on it.
    pub(crate) fn push(&self, stream: TcpStream) -> std::result::Result<(), TcpStream> {
        let mut q = self.q.lock().unwrap();
        if q.len() >= self.cap {
            return Err(stream);
        }
        q.push_back(stream);
        self.ready.notify_one();
        Ok(())
    }

    /// Pop with a timeout so handlers can observe shutdown.
    pub(crate) fn pop(&self, timeout: Duration) -> Option<TcpStream> {
        let mut q = self.q.lock().unwrap();
        if q.is_empty() {
            q = self.ready.wait_timeout(q, timeout).unwrap().0;
        }
        q.pop_front()
    }
}

pub struct HttpServer {
    addr: SocketAddr,
    server: Arc<Server>,
    metrics: Arc<HttpMetrics>,
    stop: Arc<AtomicBool>,
    queue: Arc<ConnQueue>,
    limits: Limits,
    threads: Mutex<Option<Vec<std::thread::JoinHandle<()>>>>,
}

impl HttpServer {
    /// Bind `addr` (port 0 → ephemeral; see [`Self::local_addr`]) and
    /// start the accept thread plus the handler pool.
    pub fn bind(addr: &str, server: Arc<Server>, opts: HttpOptions) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr().context("reading bound address")?;
        // Nonblocking accept + sleep-poll lets the accept thread observe
        // the shutdown flag without a self-connect wakeup hack.
        listener.set_nonblocking(true).context("nonblocking listener")?;

        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(HttpMetrics::new());
        let queue = Arc::new(ConnQueue::new(opts.backlog));

        let mut threads = Vec::with_capacity(opts.conn_threads.max(1) + 1);
        {
            let (stop, queue, metrics) = (stop.clone(), queue.clone(), metrics.clone());
            threads.push(
                std::thread::Builder::new()
                    .name("flashkat-http-accept".into())
                    .spawn(move || accept_loop(&listener, &queue, &stop, &metrics))
                    .context("spawning accept thread")?,
            );
        }
        for i in 0..opts.conn_threads.max(1) {
            let (stop_t, queue, metrics) = (stop.clone(), queue.clone(), metrics.clone());
            let server = server.clone();
            let limits = opts.limits;
            // One handler track per thread: each thread is a serial
            // writer, so its slices are disjoint by construction (the
            // nesting precondition of the Perfetto renderer).
            let trace = server.tracer().map(|t| HandlerTrace {
                tracer: t.clone(),
                track: t.register_track(&format!("http-{i}")),
            });
            let spawned = std::thread::Builder::new()
                .name(format!("flashkat-http-{i}"))
                .spawn(move || {
                    handler_loop(&queue, &server, &metrics, &limits, &stop_t, trace.as_ref())
                });
            match spawned {
                Ok(handle) => threads.push(handle),
                Err(e) => {
                    // Don't leak the accept thread (and the bound port)
                    // on a partial start: stop and join what exists.
                    stop.store(true, Ordering::SeqCst);
                    for t in threads {
                        let _ = t.join();
                    }
                    anyhow::bail!("spawning handler thread {i}: {e}");
                }
            }
        }
        Ok(HttpServer {
            addr: local,
            server,
            metrics,
            stop,
            queue,
            limits: opts.limits,
            threads: Mutex::new(Some(threads)),
        })
    }

    /// The actually-bound address (resolves `--port 0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> &HttpMetrics {
        &self.metrics
    }

    /// The serve engine behind this frontend.
    pub fn server(&self) -> &Arc<Server> {
        &self.server
    }

    /// Graceful drain (idempotent): stop accepting, let in-flight
    /// requests finish, join every frontend thread, then drain the
    /// serve engine.  Returns the final [`ServeStats`] on the call that
    /// performed the engine shutdown.
    pub fn shutdown(&self) -> Option<ServeStats> {
        let threads = self.threads.lock().unwrap().take()?;
        self.stop.store(true, Ordering::SeqCst);
        for t in threads {
            let _ = t.join();
        }
        // Belt-and-braces: answer any connection that was accepted but
        // never claimed by a handler (all handlers may race out through
        // the idle path at the instant of shutdown).
        while let Some(stream) = self.queue.pop(Duration::from_millis(1)) {
            handle_connection(stream, &self.server, &self.metrics, &self.limits, &self.stop, None);
        }
        self.server.shutdown()
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: &TcpListener,
    queue: &ConnQueue,
    stop: &AtomicBool,
    metrics: &HttpMetrics,
) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                metrics.connections.fetch_add(1, Ordering::Relaxed);
                if let Err(mut stream) = queue.push(stream) {
                    // Hand-off queue full: shed at the door with a 503
                    // instead of queueing unboundedly or hanging the peer.
                    metrics.count(503);
                    let _ = HttpResponse::text(503, "connection backlog full\n")
                        .with_header("retry-after", "1")
                        .write(&mut stream, false);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

fn handler_loop(
    queue: &ConnQueue,
    server: &Server,
    metrics: &HttpMetrics,
    limits: &Limits,
    stop: &AtomicBool,
    trace: Option<&HandlerTrace>,
) {
    loop {
        let Some(stream) = queue.pop(Duration::from_millis(50)) else {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            continue;
        };
        handle_connection(stream, server, metrics, limits, stop, trace);
        if stop.load(Ordering::SeqCst) {
            // Drain what is already queued before exiting, so accepted
            // connections are answered, not abandoned.
            while let Some(stream) = queue.pop(Duration::from_millis(1)) {
                handle_connection(stream, server, metrics, limits, stop, trace);
            }
            return;
        }
    }
}

/// Serve one connection until close, protocol error, or drain.
fn handle_connection(
    stream: TcpStream,
    server: &Server,
    metrics: &HttpMetrics,
    limits: &Limits,
    stop: &AtomicBool,
    trace: Option<&HandlerTrace>,
) {
    stream.set_nodelay(true).ok();
    // Short read timeout: idle keep-alive connections poll the shutdown
    // flag at this cadence (the parser resumes across timeout ticks).
    stream.set_read_timeout(Some(Duration::from_millis(50))).ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let outcome = match read_request(&mut reader, limits, stop) {
            Ok(o) => o,
            Err(_) => return, // transport failure / drain tick: nothing to answer
        };
        match outcome {
            ReadOutcome::Closed => return,
            ReadOutcome::Bad { status, msg } => {
                // Framing is broken; answer and close rather than guess
                // where the next request starts.
                metrics.count(status);
                let resp = HttpResponse::json(
                    status,
                    &crate::util::json::Json::Obj(vec![(
                        "error".to_string(),
                        crate::util::json::Json::Str(msg),
                    )]),
                );
                let _ = resp.write(&mut writer, false);
                return;
            }
            ReadOutcome::Ok(req) => {
                let t0 = trace.map(|tr| tr.tracer.now_us());
                let resp = handle(&req, server, metrics);
                if let (Some(tr), Some(t0)) = (trace, t0) {
                    tr.record(
                        format!("http {}", req.path()),
                        t0,
                        resp.status as u64,
                        resp.span_id,
                    );
                }
                metrics.count(resp.status);
                // During drain, finish this response but close the
                // connection so the handler can exit.
                let keep = req.keep_alive() && !stop.load(Ordering::SeqCst);
                if resp.write(&mut writer, keep).is_err() || !keep {
                    return;
                }
            }
        }
    }
}

/// Install a process-wide SIGTERM/SIGINT handler that flips the
/// returned flag (for `flashkat serve-http`'s run-until-signaled loop).
/// Zero-dependency: `std` already links libc on unix, so declaring
/// `signal(2)` ourselves adds nothing to the dependency graph.  The
/// handler only stores to an atomic, which is async-signal-safe.
/// On non-unix targets this is a no-op and the flag never flips.
pub fn install_signal_handler() -> &'static AtomicBool {
    static FLAG: AtomicBool = AtomicBool::new(false);
    #[cfg(unix)]
    {
        extern "C" fn on_signal(_sig: i32) {
            FLAG.store(true, Ordering::SeqCst);
        }
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGTERM, on_signal as extern "C" fn(i32) as usize);
            signal(SIGINT, on_signal as extern "C" fn(i32) as usize);
        }
    }
    &FLAG
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::client::HttpClient;
    use crate::rational::{forward, Coeffs};
    use crate::serve::{BatchPolicy, RationalExecutor};
    use crate::util::json::Json;
    use crate::util::rng::Pcg64;

    const D: usize = 16;

    fn start() -> (HttpServer, Coeffs<f32>) {
        let mut rng = Pcg64::new(81);
        let coeffs = Coeffs::<f32>::randn(4, 6, 4, &mut rng);
        let server = Arc::new(
            Server::start(
                vec![Box::new(RationalExecutor::new("grkan", D, coeffs.clone()).unwrap())],
                BatchPolicy::default(),
            )
            .unwrap(),
        );
        let http = HttpServer::bind("127.0.0.1:0", server, HttpOptions::default()).unwrap();
        (http, coeffs)
    }

    #[test]
    fn serves_infer_over_loopback_with_keep_alive() {
        let (http, coeffs) = start();
        let mut client = HttpClient::connect(http.local_addr()).unwrap();
        for i in 0..3u64 {
            let mut rng = Pcg64::with_stream(81, i);
            let x: Vec<f32> = (0..D).map(|_| rng.normal_f32()).collect();
            let want = forward(&x, 1, D, &coeffs);
            let body = Json::Obj(vec![
                ("x".to_string(), Json::Arr(x.iter().map(|&v| Json::Num(v as f64)).collect())),
                ("rows".to_string(), Json::Int(1)),
            ]);
            // Same connection across iterations: keep-alive works.
            let resp = client.post_json("/v1/models/grkan/infer", &body.to_string()).unwrap();
            assert_eq!(resp.status, 200, "{}", resp.body_str());
            let parsed = Json::parse(&resp.body_str()).unwrap();
            let y: Vec<f32> = parsed
                .get("y")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap() as f32)
                .collect();
            assert_eq!(y, want, "request {i}");
        }
        let health = client.get("/healthz").unwrap();
        assert_eq!(health.status, 200);
        let stats = http.shutdown().expect("first shutdown yields stats");
        assert_eq!(stats.total().requests, 3);
        assert!(http.shutdown().is_none(), "idempotent");
    }

    #[test]
    fn malformed_request_line_gets_400_and_close() {
        let (http, _) = start();
        use std::io::{Read, Write};
        let mut raw = std::net::TcpStream::connect(http.local_addr()).unwrap();
        raw.write_all(b"NONSENSE\r\n\r\n").unwrap();
        let mut buf = String::new();
        raw.read_to_string(&mut buf).unwrap(); // server closes after answering
        assert!(buf.starts_with("HTTP/1.1 400 "), "{buf}");
        assert_eq!(http.metrics().status_count(400), 1);
        http.shutdown();
    }

    #[test]
    fn drain_finishes_inflight_then_refuses_new_connections() {
        let (http, coeffs) = start();
        let addr = http.local_addr();
        let mut client = HttpClient::connect(addr).unwrap();
        let mut rng = Pcg64::with_stream(81, 99);
        let x: Vec<f32> = (0..D).map(|_| rng.normal_f32()).collect();
        let want = forward(&x, 1, D, &coeffs);
        let body = Json::Obj(vec![
            ("x".to_string(), Json::Arr(x.iter().map(|&v| Json::Num(v as f64)).collect())),
            ("rows".to_string(), Json::Int(1)),
        ])
        .to_string();
        let resp = client.post_json("/v1/models/grkan/infer", &body).unwrap();
        assert_eq!(resp.status, 200);
        let parsed = Json::parse(&resp.body_str()).unwrap();
        let y: Vec<f32> =
            parsed.get("y").unwrap().as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as f32).collect();
        assert_eq!(y, want);

        let stats = http.shutdown().expect("stats");
        assert_eq!(stats.total().requests, 1);
        // After drain: either the connect is refused or the engine
        // answers 503 — never a served request.
        if let Ok(mut c) = HttpClient::connect(addr) {
            match c.post_json("/v1/models/grkan/infer", &body) {
                Ok(resp) => assert_ne!(resp.status, 200),
                Err(_) => {} // connection refused/reset: equally fine
            }
        }
    }
}
