//! Minimal dense tensor (row-major) used by the coordinator's host-side
//! compute: data pipeline, augmentations, EMA, rounding-error experiments.
//!
//! Deliberately small: shape + flat Vec, elementwise ops, no broadcasting
//! beyond what the coordinator needs.  Device compute is XLA's job.

use crate::util::rng::Pcg64;

pub trait Scalar: Copy + Default + PartialOrd + std::fmt::Debug + Send + Sync + 'static {
    fn from_f64(x: f64) -> Self;
    fn to_f64(self) -> f64;
    const ZERO: Self;
    const ONE: Self;

    /// `self + b` with a single rounding into `Self`'s precision.
    ///
    /// The default round-trips through f64, which *is* the definition of
    /// one correctly-rounded add in `Self` (both operands convert to f64
    /// exactly for every `Scalar` in this crate, the f64 sum of two f32
    /// values is exact, and `from_f64` performs the one rounding).  f32
    /// and f64 override this with the native add — bit-identical, minus
    /// the conversion traffic (DESIGN.md §4).
    #[inline]
    fn add_r(self, b: Self) -> Self {
        Self::from_f64(self.to_f64() + b.to_f64())
    }

    /// `self * b` with a single rounding into `Self`'s precision; same
    /// bit-identity argument as [`Scalar::add_r`] (an f32×f32 product
    /// needs ≤48 significand bits, exact in f64).
    #[inline]
    fn mul_r(self, b: Self) -> Self {
        Self::from_f64(self.to_f64() * b.to_f64())
    }
}

impl Scalar for f32 {
    fn from_f64(x: f64) -> Self {
        x as f32
    }
    fn to_f64(self) -> f64 {
        self as f64
    }
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;

    #[inline]
    fn add_r(self, b: Self) -> Self {
        self + b
    }
    #[inline]
    fn mul_r(self, b: Self) -> Self {
        self * b
    }
}

impl Scalar for f64 {
    fn from_f64(x: f64) -> Self {
        x
    }
    fn to_f64(self) -> f64 {
        self
    }
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;

    #[inline]
    fn add_r(self, b: Self) -> Self {
        self + b
    }
    #[inline]
    fn mul_r(self, b: Self) -> Self {
        self * b
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor<T: Scalar = f32> {
    shape: Vec<usize>,
    data: Vec<T>,
}

impl<T: Scalar> Tensor<T> {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![T::ZERO; n] }
    }

    pub fn full(shape: &[usize], v: T) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> T) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: (0..n).map(&mut f).collect() }
    }

    pub fn randn(shape: &[usize], rng: &mut Pcg64) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| T::from_f64(rng.normal())).collect();
        Self { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Flat index from a multi-dimensional index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&ix, &dim)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(ix < dim, "index {ix} out of bounds for dim {i} ({dim})");
            off = off * dim + ix;
        }
        off
    }

    pub fn at(&self, idx: &[usize]) -> T {
        self.data[self.offset(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: T) {
        let off = self.offset(idx);
        self.data[off] = v;
    }

    pub fn map(&self, f: impl Fn(T) -> T) -> Self {
        Self { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    pub fn zip_mut(&mut self, other: &Self, f: impl Fn(T, T) -> T) {
        assert_eq!(self.shape, other.shape);
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a = f(*a, b);
        }
    }

    pub fn scale(&mut self, s: f64) {
        for a in self.data.iter_mut() {
            *a = T::from_f64(a.to_f64() * s);
        }
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|x| x.to_f64()).sum()
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            f64::NAN
        } else {
            self.sum() / self.data.len() as f64
        }
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().map(|x| x.to_f64().abs()).fold(0.0, f64::max)
    }

    pub fn cast<U: Scalar>(&self) -> Tensor<U> {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|x| U::from_f64(x.to_f64())).collect(),
        }
    }
}

impl Tensor<f32> {
    /// EMA update: self = decay*self + (1-decay)*other  (paper: decay 0.9999).
    pub fn ema_update(&mut self, other: &Self, decay: f32) {
        assert_eq!(self.shape, other.shape);
        let om = 1.0 - decay;
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a = decay * *a + om * b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::<f32>::from_fn(&[2, 3, 4], |i| i as f32);
        assert_eq!(t.at(&[0, 0, 0]), 0.0);
        assert_eq!(t.at(&[1, 2, 3]), 23.0);
        assert_eq!(t.at(&[1, 0, 2]), 14.0);
        assert_eq!(t.shape(), &[2, 3, 4]);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::<f32>::from_vec(&[2, 2], vec![1.0; 5]);
    }

    #[test]
    fn reshape_and_ops() {
        let mut t = Tensor::<f32>::full(&[4], 2.0).reshape(&[2, 2]);
        t.scale(0.5);
        assert_eq!(t.data(), &[1.0; 4]);
        let u = t.map(|x| x + 1.0);
        assert_eq!(u.sum(), 8.0);
        t.zip_mut(&u, |a, b| a * b);
        assert_eq!(t.sum(), 8.0);
    }

    #[test]
    fn randn_moments() {
        let mut rng = Pcg64::new(0);
        let t = Tensor::<f32>::randn(&[10_000], &mut rng);
        assert!(t.mean().abs() < 0.05);
        let var = t.data().iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / 10_000.0;
        assert!((var - 1.0).abs() < 0.08, "{var}");
    }

    #[test]
    fn ema_converges_toward_target() {
        let mut ema = Tensor::<f32>::zeros(&[8]);
        let target = Tensor::<f32>::full(&[8], 1.0);
        for _ in 0..1000 {
            ema.ema_update(&target, 0.99);
        }
        assert!((ema.mean() - 1.0).abs() < 1e-4);
    }

    #[test]
    fn native_single_rounding_ops_match_roundtrip() {
        // The f32 overrides of add_r/mul_r must be bit-identical to the
        // generic f64 round-trip they replace (the kernel fast paths
        // depend on this; see DESIGN.md §4).
        let mut rng = Pcg64::new(9);
        for _ in 0..10_000 {
            let a = (rng.normal() * 10f64.powi(rng.below(9) as i32 - 4)) as f32;
            let b = (rng.normal() * 10f64.powi(rng.below(9) as i32 - 4)) as f32;
            let add_rt = f32::from_f64(a.to_f64() + b.to_f64());
            let mul_rt = f32::from_f64(a.to_f64() * b.to_f64());
            assert_eq!(a.add_r(b).to_bits(), add_rt.to_bits(), "{a} + {b}");
            assert_eq!(a.mul_r(b).to_bits(), mul_rt.to_bits(), "{a} * {b}");
        }
    }

    #[test]
    fn cast_f32_f64_roundtrip() {
        let t = Tensor::<f32>::from_vec(&[3], vec![0.1, -2.5, 7.0]);
        let d: Tensor<f64> = t.cast();
        let back: Tensor<f32> = d.cast();
        assert_eq!(t, back);
    }
}
