//! Threaded flashwire frontend: bounded accept loop + fixed
//! connection-handler pool speaking length-prefixed binary frames onto
//! the same sharded [`Server`] the HTTP frontend serves.
//!
//! Thread layout is deliberately identical to `net::listener` (one
//! accept thread → bounded [`ConnQueue`] → fixed handler pool; the
//! queue type is literally shared), so the two frontends differ only in
//! what bytes they speak — which is exactly what `serve-bench --wire`
//! measures.  Overload degrades by protocol at every layer: hand-off
//! queue full → [`ErrCode::Backlog`] error frame at the door, serve
//! admission queue full → [`ErrCode::QueueFull`] with a retry-after
//! hint (via `Server::try_submit`), drain → in-flight frames are
//! answered, then connections close at the next frame boundary and the
//! engine drains so every admitted request is served.
//!
//! Per-connection semantics mirror HTTP keep-alive: many frames per
//! connection, one response frame per request frame, the shared
//! stall/deadline budget per frame read.  **Message**-level errors (a
//! well-framed payload that fails to decode or validate) are answered
//! and the connection stays open — the framing is intact; **frame**-
//! level errors are answered and the connection closes, because the
//! byte stream can no longer be trusted.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::frame::{read_frame, write_frame, BadKind, Frame, FrameOutcome, MsgType, WireLimits};
use super::proto::{
    decode_ping, ErrCode, InferRequest, InferResponse, StatsResponse, WireError,
};
use crate::net::listener::{ConnQueue, HandlerTrace};
use crate::serve::{ServeStats, Server, SubmitError};

/// Frontend tuning knobs (mirrors `net::HttpOptions`).
#[derive(Clone, Debug)]
pub struct WireOptions {
    /// Connection-handler threads (max concurrent connections).
    pub conn_threads: usize,
    /// Accepted-but-unclaimed connections the accept thread may hold
    /// before answering a `Backlog` error frame itself.
    pub backlog: usize,
    pub limits: WireLimits,
}

impl Default for WireOptions {
    fn default() -> Self {
        Self { conn_threads: 8, backlog: 64, limits: WireLimits::default() }
    }
}

/// Wire-layer counters (serve-layer counters live in [`ServeStats`] and
/// are served over the protocol itself via `StatsRequest`).
#[derive(Default)]
pub struct WireMetrics {
    pub connections: AtomicU64,
    /// Successful `InferResponse` frames written.
    pub infer_ok: AtomicU64,
    /// Error frames written, indexed by [`ErrCode::ALL`] position.
    errors: [AtomicU64; ErrCode::ALL.len()],
}

impl WireMetrics {
    fn count_err(&self, code: ErrCode) {
        let idx = ErrCode::ALL.iter().position(|c| *c == code).expect("known code");
        self.errors[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Error frames written so far for `code`.
    pub fn error_count(&self, code: ErrCode) -> u64 {
        let idx = ErrCode::ALL.iter().position(|c| *c == code).expect("known code");
        self.errors[idx].load(Ordering::Relaxed)
    }
}

/// Backoff hint carried on shed-load error frames: mirrors the HTTP
/// frontend's `Retry-After: 1` (whole seconds is all HTTP can say;
/// flashwire says it in milliseconds).
pub const SHED_RETRY_AFTER_MILLIS: u32 = 1000;

pub struct WireServer {
    addr: SocketAddr,
    server: Arc<Server>,
    metrics: Arc<WireMetrics>,
    stop: Arc<AtomicBool>,
    queue: Arc<ConnQueue>,
    limits: WireLimits,
    threads: Mutex<Option<Vec<std::thread::JoinHandle<()>>>>,
}

impl WireServer {
    /// Bind `addr` (port 0 → ephemeral; see [`Self::local_addr`]) and
    /// start the accept thread plus the handler pool.
    pub fn bind(addr: &str, server: Arc<Server>, opts: WireOptions) -> Result<WireServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr().context("reading bound address")?;
        listener.set_nonblocking(true).context("nonblocking listener")?;

        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(WireMetrics::default());
        let queue = Arc::new(ConnQueue::new(opts.backlog));

        let mut threads = Vec::with_capacity(opts.conn_threads.max(1) + 1);
        {
            let (stop, queue, metrics) = (stop.clone(), queue.clone(), metrics.clone());
            threads.push(
                std::thread::Builder::new()
                    .name("flashkat-wire-accept".into())
                    .spawn(move || accept_loop(&listener, &queue, &stop, &metrics))
                    .context("spawning accept thread")?,
            );
        }
        for i in 0..opts.conn_threads.max(1) {
            let (stop_t, queue, metrics) = (stop.clone(), queue.clone(), metrics.clone());
            let server = server.clone();
            let limits = opts.limits;
            // One handler track per thread, same discipline as the HTTP
            // frontend: a serial writer keeps its slices disjoint.
            let trace = server.tracer().map(|t| HandlerTrace {
                tracer: t.clone(),
                track: t.register_track(&format!("wire-{i}")),
            });
            let spawned = std::thread::Builder::new()
                .name(format!("flashkat-wire-{i}"))
                .spawn(move || {
                    handler_loop(&queue, &server, &metrics, &limits, &stop_t, trace.as_ref())
                });
            match spawned {
                Ok(handle) => threads.push(handle),
                Err(e) => {
                    // Same partial-start discipline as HttpServer::bind:
                    // never leak the accept thread and the bound port.
                    stop.store(true, Ordering::SeqCst);
                    for t in threads {
                        let _ = t.join();
                    }
                    anyhow::bail!("spawning handler thread {i}: {e}");
                }
            }
        }
        Ok(WireServer {
            addr: local,
            server,
            metrics,
            stop,
            queue,
            limits: opts.limits,
            threads: Mutex::new(Some(threads)),
        })
    }

    /// The actually-bound address (resolves `--port 0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> &WireMetrics {
        &self.metrics
    }

    /// The serve engine behind this frontend.
    pub fn server(&self) -> &Arc<Server> {
        &self.server
    }

    /// Graceful drain (idempotent): stop accepting, let in-flight
    /// frames finish, join every frontend thread, then drain the serve
    /// engine.  Returns the final [`ServeStats`] on the call that
    /// performed the engine shutdown.
    pub fn shutdown(&self) -> Option<ServeStats> {
        let threads = self.threads.lock().unwrap().take()?;
        self.stop.store(true, Ordering::SeqCst);
        for t in threads {
            let _ = t.join();
        }
        // Answer any connection that was accepted but never claimed.
        while let Some(stream) = self.queue.pop(Duration::from_millis(1)) {
            handle_connection(stream, &self.server, &self.metrics, &self.limits, &self.stop, None);
        }
        self.server.shutdown()
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: &TcpListener,
    queue: &ConnQueue,
    stop: &AtomicBool,
    metrics: &WireMetrics,
) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                metrics.connections.fetch_add(1, Ordering::Relaxed);
                if let Err(mut stream) = queue.push(stream) {
                    // Shed at the door: the binary analogue of the HTTP
                    // 503-with-Retry-After on a full hand-off queue.
                    metrics.count_err(ErrCode::Backlog);
                    let err = WireError::new(ErrCode::Backlog, "connection backlog full")
                        .with_retry_after(SHED_RETRY_AFTER_MILLIS);
                    let _ = write_frame(&mut stream, MsgType::Error, &err.encode());
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

fn handler_loop(
    queue: &ConnQueue,
    server: &Server,
    metrics: &WireMetrics,
    limits: &WireLimits,
    stop: &AtomicBool,
    trace: Option<&HandlerTrace>,
) {
    loop {
        let Some(stream) = queue.pop(Duration::from_millis(50)) else {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            continue;
        };
        handle_connection(stream, server, metrics, limits, stop, trace);
        if stop.load(Ordering::SeqCst) {
            while let Some(stream) = queue.pop(Duration::from_millis(1)) {
                handle_connection(stream, server, metrics, limits, stop, trace);
            }
            return;
        }
    }
}

/// One response to one request frame, plus whether the connection can
/// carry further frames afterwards.  `code` keeps the typed error (for
/// the metrics counters) alongside its already-encoded frame, so the
/// accounting never depends on re-decoding bytes we just built.
struct Reply {
    msg_type: MsgType,
    payload: Vec<u8>,
    keep: bool,
    code: Option<ErrCode>,
    /// Span of the inference this reply answers, for the handler's
    /// trace slice.  Never serialized: the wire frame format is frozen,
    /// so timing travels via the trace + stats, not the protocol.
    span_id: Option<u64>,
}

impl Reply {
    fn ok(msg_type: MsgType, payload: Vec<u8>) -> Reply {
        Reply { msg_type, payload, keep: true, code: None, span_id: None }
    }

    /// Message-level error: answered, connection stays open.
    fn err(e: WireError) -> Reply {
        Reply {
            msg_type: MsgType::Error,
            code: Some(e.code),
            payload: e.encode(),
            keep: true,
            span_id: None,
        }
    }

    /// Protocol-confusion error: answered, then close.
    fn fatal(e: WireError) -> Reply {
        Reply {
            msg_type: MsgType::Error,
            code: Some(e.code),
            payload: e.encode(),
            keep: false,
            span_id: None,
        }
    }
}

/// Serve one connection until close, framing error, or drain.
fn handle_connection(
    stream: TcpStream,
    server: &Server,
    metrics: &WireMetrics,
    limits: &WireLimits,
    stop: &AtomicBool,
    trace: Option<&HandlerTrace>,
) {
    stream.set_nodelay(true).ok();
    // Short read timeout: idle connections poll the shutdown flag at
    // this cadence (the frame reader resumes across timeout ticks).
    stream.set_read_timeout(Some(Duration::from_millis(50))).ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let outcome = match read_frame(&mut reader, limits, stop) {
            Ok(o) => o,
            Err(_) => return, // transport failure: nothing to answer
        };
        match outcome {
            FrameOutcome::Closed => return,
            FrameOutcome::Bad { kind, msg } => {
                // Framing is broken; answer and close rather than guess
                // where the next frame starts.
                let code = match kind {
                    BadKind::Malformed => ErrCode::BadFrame,
                    // The peer's own stall/drip-feed, not a wedged
                    // server: the 408 analogue, no retry hint.
                    BadKind::Timeout => ErrCode::RequestTimeout,
                };
                metrics.count_err(code);
                let _ = write_frame(
                    &mut writer,
                    MsgType::Error,
                    &WireError::new(code, msg).encode(),
                );
                return;
            }
            FrameOutcome::Ok(frame) => {
                let msg_type = frame.msg_type;
                let t0 = trace.map(|tr| tr.tracer.now_us());
                let reply = dispatch(frame, server, metrics);
                if let (Some(tr), Some(t0)) = (trace, t0) {
                    let status = reply.code.map(|c| c as u64).unwrap_or(0);
                    tr.record(format!("wire {msg_type:?}"), t0, status, reply.span_id);
                }
                // During drain, finish this response but close the
                // connection so the handler can exit.
                let keep = reply.keep && !stop.load(Ordering::SeqCst);
                if write_frame(&mut writer, reply.msg_type, &reply.payload).is_err() || !keep {
                    return;
                }
            }
        }
    }
}

/// Map one well-framed request to its reply and record it in the
/// counters — pure apart from the serve engine, so unit tests drive it
/// without sockets.
fn dispatch(frame: Frame, server: &Server, metrics: &WireMetrics) -> Reply {
    let reply = dispatch_inner(frame, server);
    match reply.code {
        Some(code) => metrics.count_err(code),
        None if reply.msg_type == MsgType::InferResponse => {
            metrics.infer_ok.fetch_add(1, Ordering::Relaxed);
        }
        None => {}
    }
    reply
}

fn dispatch_inner(frame: Frame, server: &Server) -> Reply {
    match frame.msg_type {
        MsgType::Ping => match decode_ping(&frame.payload) {
            Ok(token) => Reply::ok(MsgType::Pong, token.to_vec()),
            Err(msg) => Reply::err(WireError::new(ErrCode::BadMsg, msg)),
        },
        MsgType::StatsRequest => {
            if !frame.payload.is_empty() {
                return Reply::err(WireError::new(
                    ErrCode::BadMsg,
                    "StatsRequest carries no payload",
                ));
            }
            // v2 form: the live (queued, in_flight) tail rides along so
            // a router's least-loaded policy can rank this backend.
            let stats =
                StatsResponse::from_stats_with_loads(&server.stats(), &server.shard_loads());
            Reply::ok(MsgType::StatsResponse, stats.encode())
        }
        MsgType::InferRequest => match InferRequest::decode(&frame.payload) {
            Ok(req) => infer(req, server),
            Err(msg) => Reply::err(WireError::new(ErrCode::BadMsg, msg)),
        },
        // Server-to-client types arriving from a client mean the peer is
        // not speaking the protocol; answer and close.
        MsgType::InferResponse | MsgType::StatsResponse | MsgType::Pong | MsgType::Error => {
            Reply::fatal(WireError::new(
                ErrCode::BadMsg,
                format!("{:?} is a server-to-client msg-type", frame.msg_type),
            ))
        }
    }
}

/// The infer path: validate, admit via `try_submit` (load shedding, not
/// blocking), and map every [`SubmitError`] onto the shared error
/// taxonomy — the same outcomes the HTTP router maps to statuses.
fn infer(req: InferRequest, server: &Server) -> Reply {
    if req.rows == 0 {
        // Parity with the HTTP router: a 0-row request would burn a
        // queue slot and an executor wakeup on a no-op.
        return Reply::err(WireError::new(ErrCode::BadShape, "rows must be positive"));
    }
    // Parity with the JSON frontend's 400 on non-finite inputs: the
    // binary encoding *could* carry them, but the serving contract is
    // finite inputs (see DESIGN.md §13).
    if req.x.iter().any(|v| !v.is_finite()) {
        return Reply::err(WireError::new(
            ErrCode::NonFiniteInput,
            "x must contain only finite values",
        ));
    }
    // Mint the span at the protocol edge (parity with the HTTP router)
    // so queue wait is measured from frame decode, not shard admission.
    let span = server.mint_span(&req.model, req.rows);
    match server.try_submit_span(&req.model, req.x, req.rows, span) {
        Ok(resp) => {
            let out = InferResponse {
                y: resp.y,
                batch_size: resp.batch_size as u32,
                cause: resp.cause,
            };
            Reply { span_id: resp.span_id, ..Reply::ok(MsgType::InferResponse, out.encode()) }
        }
        Err(SubmitError::QueueFull { queue_depth }) => Reply::err(
            WireError::new(
                ErrCode::QueueFull,
                format!("admission queue full (depth {queue_depth})"),
            )
            .with_retry_after(SHED_RETRY_AFTER_MILLIS),
        ),
        Err(SubmitError::ShuttingDown) => {
            Reply::err(WireError::new(ErrCode::Draining, "server is draining"))
        }
        Err(e @ SubmitError::ResponseTimeout) => Reply::err(
            WireError::new(ErrCode::Timeout, e.to_string())
                .with_retry_after(SHED_RETRY_AFTER_MILLIS),
        ),
        Err(SubmitError::UnknownModel(what)) => {
            Reply::err(WireError::new(ErrCode::BadModel, format!("unknown model {what}")))
        }
        Err(SubmitError::BadRequest(msg)) => Reply::err(WireError::new(ErrCode::BadShape, msg)),
        Err(SubmitError::Failed(msg)) => Reply::err(WireError::new(ErrCode::Internal, msg)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rational::{forward, Coeffs};
    use crate::serve::{BatchPolicy, RationalExecutor};
    use crate::util::rng::Pcg64;
    use crate::wire::client::WireClient;

    const D: usize = 16;

    fn start() -> (WireServer, Coeffs<f32>) {
        let mut rng = Pcg64::new(91);
        let coeffs = Coeffs::<f32>::randn(4, 6, 4, &mut rng);
        let server = Arc::new(
            Server::start(
                vec![Box::new(RationalExecutor::new("grkan", D, coeffs.clone()).unwrap())],
                BatchPolicy::default(),
            )
            .unwrap(),
        );
        let wire = WireServer::bind("127.0.0.1:0", server, WireOptions::default()).unwrap();
        (wire, coeffs)
    }

    #[test]
    fn serves_infer_over_loopback_with_keep_alive() {
        let (wire, coeffs) = start();
        let mut client = WireClient::connect(wire.local_addr()).unwrap();
        for i in 0..3u64 {
            let mut rng = Pcg64::with_stream(91, i);
            let x: Vec<f32> = (0..D).map(|_| rng.normal_f32()).collect();
            let want = forward(&x, 1, D, &coeffs);
            // Same connection across iterations: keep-alive works.
            let resp = client.infer("grkan", &x, 1).unwrap().unwrap();
            assert_eq!(resp.y, want, "request {i}");
            assert!(resp.batch_size >= 1);
        }
        client.ping(7).unwrap();
        let stats = client.stats().unwrap();
        assert_eq!(stats.models.len(), 1);
        assert_eq!(stats.models[0].requests, 3);
        assert_eq!(wire.metrics().infer_ok.load(Ordering::Relaxed), 3);
        let stats = wire.shutdown().expect("first shutdown yields stats");
        assert_eq!(stats.total().requests, 3);
        assert!(wire.shutdown().is_none(), "idempotent");
    }

    #[test]
    fn message_errors_keep_the_connection_framing_errors_close_it() {
        let (wire, coeffs) = start();
        let mut client = WireClient::connect(wire.local_addr()).unwrap();
        // Unknown model: typed error, connection still usable.
        let err = client.infer("nope", &[0.0; D], 1).unwrap().unwrap_err();
        assert_eq!(err.code, ErrCode::BadModel);
        // Bad shape: typed error, connection still usable.
        let err = client.infer("grkan", &[0.0; D - 1], 1).unwrap().unwrap_err();
        assert_eq!(err.code, ErrCode::BadShape);
        // Non-finite input: typed error.
        let mut x = vec![0.0f32; D];
        x[3] = f32::NAN;
        let err = client.infer("grkan", &x, 1).unwrap().unwrap_err();
        assert_eq!(err.code, ErrCode::NonFiniteInput);
        // Zero rows never reaches the queue.
        let err = client.infer("grkan", &[], 0).unwrap().unwrap_err();
        assert_eq!(err.code, ErrCode::BadShape);
        // ...and the same connection still serves.
        let mut rng = Pcg64::with_stream(91, 99);
        let x: Vec<f32> = (0..D).map(|_| rng.normal_f32()).collect();
        let want = forward(&x, 1, D, &coeffs);
        assert_eq!(client.infer("grkan", &x, 1).unwrap().unwrap().y, want);
        assert_eq!(wire.metrics().error_count(ErrCode::BadModel), 1);
        assert_eq!(wire.metrics().error_count(ErrCode::BadShape), 2);
        assert_eq!(wire.metrics().error_count(ErrCode::NonFiniteInput), 1);

        // Garbage magic: the server answers a BadFrame error frame and
        // closes the connection.
        use std::io::{Read, Write};
        let mut raw = std::net::TcpStream::connect(wire.local_addr()).unwrap();
        raw.write_all(b"GARBAGE!").unwrap();
        let mut buf = Vec::new();
        raw.read_to_end(&mut buf).unwrap(); // server closes after answering
        assert!(buf.len() >= super::super::frame::HEADER_LEN);
        assert_eq!(&buf[0..2], b"FW");
        assert_eq!(buf[3], MsgType::Error as u8);
        let err = WireError::decode(&buf[super::super::frame::HEADER_LEN..]).unwrap();
        assert_eq!(err.code, ErrCode::BadFrame);
        assert_eq!(wire.metrics().error_count(ErrCode::BadFrame), 1);
        wire.shutdown();
    }

    #[test]
    fn drain_answers_inflight_then_refuses_new_work() {
        let (wire, coeffs) = start();
        let addr = wire.local_addr();
        let mut client = WireClient::connect(addr).unwrap();
        let mut rng = Pcg64::with_stream(91, 5);
        let x: Vec<f32> = (0..D).map(|_| rng.normal_f32()).collect();
        let want = forward(&x, 1, D, &coeffs);
        assert_eq!(client.infer("grkan", &x, 1).unwrap().unwrap().y, want);

        let stats = wire.shutdown().expect("stats");
        assert_eq!(stats.total().requests, 1);
        // After drain: either the connect is refused or the engine
        // answers a typed error — never a served request.
        if let Ok(mut c) = WireClient::connect(addr) {
            match c.infer("grkan", &x, 1) {
                Ok(Ok(_)) => panic!("served after drain"),
                Ok(Err(_)) | Err(_) => {}
            }
        }
    }
}
