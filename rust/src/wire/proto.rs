//! Typed flashwire messages: the payload encodings for each
//! [`super::frame::MsgType`] (DESIGN.md §13).
//!
//! All integers are little-endian; strings are UTF-8 behind a `u16`
//! length; f32 arrays are a flat little-endian byte copy
//! (`f32::to_le_bytes` per element), so a float crosses the wire
//! **bit-exactly** — no decimal formatting, no parse, no rounding.
//! That byte copy is the whole point of the protocol: the HTTP/JSON
//! frontend preserves f32 bits too, but only by paying a
//! shortest-round-trip decimal encode *and* a parse per value, which is
//! exactly the FLOP-free data-movement cost the FlashKAT analysis says
//! dominates — here the payload moves as the bytes it already is.
//!
//! Decoding is strict: every message must consume its payload exactly
//! (trailing bytes are an error, as is truncation), and counts are
//! cross-checked in u64 so hostile `rows * dim` values cannot overflow
//! into a small allocation.  Decode errors are `String`s; the server
//! answers them as [`ErrCode::BadMsg`] error frames but keeps the
//! connection (the framing layer is still intact).

use crate::serve::{FlushCause, ServeStats};

use super::frame::HEADER_LEN;

/// Typed error codes carried by [`WireError`] frames — one per distinct
/// failure the HTTP router maps to a status, plus the frame/message
/// codec's own rejects, so binary clients can branch on outcomes
/// without string matching (the wire analogue of `serve::SubmitError`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u16)]
pub enum ErrCode {
    /// Framing violation (bad magic/version/type, oversized, truncated);
    /// the server closes the connection after answering.
    BadFrame = 1,
    /// A well-framed payload that does not decode as its msg-type.
    BadMsg = 2,
    /// Request shape mismatch (`rows * dim != payload`, zero rows, or a
    /// width the routed model rejects).
    BadShape = 3,
    /// Input values must be finite (parity with the JSON frontend's
    /// `400`; see DESIGN.md §13 on why *outputs* have no such rule).
    NonFiniteInput = 4,
    /// No such model in the registry.
    BadModel = 5,
    /// Admission queue at depth — retry after
    /// [`WireError::retry_after_millis`].
    QueueFull = 6,
    /// Connection-handler backlog full at the door.
    Backlog = 7,
    /// Server is draining; no further request will be served.
    Draining = 8,
    /// Admitted, but the response timed out (wedged executor) — retry
    /// after the hint.
    Timeout = 9,
    /// The model's executor failed the batch.
    Internal = 10,
    /// The *client's* frame stalled or drip-fed past the read budget —
    /// the HTTP `408` analogue.  The peer's own fault: no retry hint.
    RequestTimeout = 11,
}

impl ErrCode {
    pub const ALL: [ErrCode; 11] = [
        ErrCode::BadFrame,
        ErrCode::BadMsg,
        ErrCode::BadShape,
        ErrCode::NonFiniteInput,
        ErrCode::BadModel,
        ErrCode::QueueFull,
        ErrCode::Backlog,
        ErrCode::Draining,
        ErrCode::Timeout,
        ErrCode::Internal,
        ErrCode::RequestTimeout,
    ];

    pub fn from_u16(v: u16) -> Option<ErrCode> {
        ErrCode::ALL.iter().copied().find(|c| *c as u16 == v)
    }

    pub fn label(self) -> &'static str {
        match self {
            ErrCode::BadFrame => "bad-frame",
            ErrCode::BadMsg => "bad-msg",
            ErrCode::BadShape => "bad-shape",
            ErrCode::NonFiniteInput => "non-finite-input",
            ErrCode::BadModel => "bad-model",
            ErrCode::QueueFull => "queue-full",
            ErrCode::Backlog => "backlog",
            ErrCode::Draining => "draining",
            ErrCode::Timeout => "timeout",
            ErrCode::Internal => "internal",
            ErrCode::RequestTimeout => "request-timeout",
        }
    }

    /// The HTTP status the router maps the same failure to — the two
    /// frontends expose one error taxonomy over two encodings.
    pub fn http_equiv(self) -> u16 {
        match self {
            ErrCode::BadFrame | ErrCode::BadMsg | ErrCode::BadShape
            | ErrCode::NonFiniteInput => 400,
            ErrCode::BadModel => 404,
            ErrCode::RequestTimeout => 408,
            ErrCode::QueueFull => 429,
            ErrCode::Backlog | ErrCode::Draining | ErrCode::Timeout => 503,
            ErrCode::Internal => 500,
        }
    }
}

/// A typed server-side failure, carried in a [`MsgType::Error`] frame.
///
/// [`MsgType::Error`]: super::frame::MsgType::Error
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireError {
    pub code: ErrCode,
    /// Backoff hint in milliseconds (`0` = none); nonzero on
    /// [`ErrCode::QueueFull`]/[`ErrCode::Backlog`]/[`ErrCode::Timeout`]
    /// — the binary analogue of the HTTP `Retry-After` header.
    pub retry_after_millis: u32,
    pub message: String,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} ({}): {}", self.code.label(), self.code as u16, self.message)?;
        if self.retry_after_millis > 0 {
            write!(f, " [retry after {}ms]", self.retry_after_millis)?;
        }
        Ok(())
    }
}

impl std::error::Error for WireError {}

/// `POST /v1/models/{model}/infer`, binary form: `rows` rows of `dim`
/// f32s, flat row-major, little-endian.
#[derive(Clone, Debug, PartialEq)]
pub struct InferRequest {
    pub model: String,
    pub rows: u32,
    pub dim: u32,
    pub x: Vec<f32>,
}

/// The served rows plus the same batching telemetry the JSON response
/// carries (`batch_size`, flush `cause`).
#[derive(Clone, Debug, PartialEq)]
pub struct InferResponse {
    pub y: Vec<f32>,
    pub batch_size: u32,
    pub cause: FlushCause,
}

/// Per-model counter snapshot (the binary `/metrics` analogue).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StatsModel {
    pub name: String,
    pub d_in: u32,
    pub d_out: u32,
    pub requests: u64,
    pub rows: u64,
    pub batches: u64,
    pub failed: u64,
}

/// Live per-shard load sample — the v2 `StatsResponse` tail that gives
/// the router's `--policy least-loaded` a real signal: requests admitted
/// but not yet popped (`queued`) and requests inside the executor but
/// not yet replied to (`in_flight`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct ShardLoad {
    pub queued: u64,
    pub in_flight: u64,
}

#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct StatsResponse {
    pub models: Vec<StatsModel>,
    pub shard_peaks: Vec<u64>,
    /// v2 extension (empty on v1 payloads): one live load sample per
    /// shard, in `shard_peaks` order.  Encoded as an optional tail so a
    /// v2 decoder still reads v1 payloads; see [`StatsResponse::decode`].
    pub shard_loads: Vec<ShardLoad>,
}

impl StatsResponse {
    pub fn from_stats(stats: &ServeStats) -> StatsResponse {
        Self::from_stats_with_loads(stats, &[])
    }

    /// [`Self::from_stats`] plus the live `(queued, in_flight)` samples
    /// from [`Server::shard_loads`] — what the wire server attaches so
    /// routers can rank backends by load.
    ///
    /// [`Server::shard_loads`]: crate::serve::Server::shard_loads
    pub fn from_stats_with_loads(stats: &ServeStats, loads: &[(usize, usize)]) -> StatsResponse {
        StatsResponse {
            models: stats
                .per_model
                .iter()
                .map(|m| StatsModel {
                    name: m.name.clone(),
                    d_in: m.d_in as u32,
                    d_out: m.d_out as u32,
                    requests: m.stats.requests as u64,
                    rows: m.stats.rows as u64,
                    batches: m.stats.batches as u64,
                    failed: m.stats.failed as u64,
                })
                .collect(),
            shard_peaks: stats.shard_peaks.iter().map(|&p| p as u64).collect(),
            shard_loads: loads
                .iter()
                .map(|&(q, f)| ShardLoad { queued: q as u64, in_flight: f as u64 })
                .collect(),
        }
    }

    /// Total outstanding work across shards — the scalar a least-loaded
    /// chooser ranks backends by.
    pub fn total_load(&self) -> u64 {
        self.shard_loads.iter().map(|l| l.queued + l.in_flight).sum()
    }
}

/// Ping/Pong payload: an opaque token the server echoes verbatim.
pub const PING_TOKEN_LEN: usize = 8;

// ---- encoding helpers -------------------------------------------------

/// `u16` length + UTF-8 bytes.  A string over `u16::MAX` bytes is
/// truncated at a char boundary rather than letting `as u16` silently
/// wrap the length prefix into a self-inconsistent encoding (callers
/// that must not lose bytes — the client's model-name path — validate
/// the length before encoding).
fn put_str16(out: &mut Vec<u8>, s: &str) {
    let mut end = s.len().min(u16::MAX as usize);
    while !s.is_char_boundary(end) {
        end -= 1;
    }
    out.extend_from_slice(&(end as u16).to_le_bytes());
    out.extend_from_slice(&s.as_bytes()[..end]);
}

/// Flat little-endian f32 copy — the zero-text-round-trip hot path.
fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for &v in xs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Strict little-endian reader over one payload: every getter errors on
/// truncation, and [`Cur::done`] errors on trailing bytes, so a message
/// either decodes exactly or not at all.
struct Cur<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, off: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.off
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated {what}: {} bytes left, {n} needed",
                self.remaining()
            ));
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u16(&mut self, what: &str) -> Result<u16, String> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self, what: &str) -> Result<u32, String> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64, String> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8-byte slice")))
    }

    fn u8(&mut self, what: &str) -> Result<u8, String> {
        Ok(self.take(1, what)?[0])
    }

    fn str16(&mut self, what: &str) -> Result<String, String> {
        let n = self.u16(what)? as usize;
        let b = self.take(n, what)?;
        String::from_utf8(b.to_vec()).map_err(|_| format!("non-UTF-8 {what}"))
    }

    /// Exactly `count` f32s; `count` is cross-checked in u64 so a
    /// hostile header cannot overflow the byte math.
    fn f32s(&mut self, count: u64, what: &str) -> Result<Vec<f32>, String> {
        let want_bytes = count.checked_mul(4).ok_or_else(|| format!("{what} count overflows"))?;
        if want_bytes != self.remaining() as u64 {
            return Err(format!(
                "{what}: {} payload bytes for {count} f32s (want {want_bytes})",
                self.remaining()
            ));
        }
        let b = self.take(self.remaining(), what)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn done(self, what: &str) -> Result<(), String> {
        if self.remaining() != 0 {
            return Err(format!("{} trailing bytes after {what}", self.remaining()));
        }
        Ok(())
    }
}

// ---- message codecs ---------------------------------------------------

impl InferRequest {
    /// Wire size of this request including the frame header — the
    /// bytes-per-request accounting the bench records.
    pub fn wire_bytes(&self) -> usize {
        HEADER_LEN + 2 + self.model.len() + 4 + 4 + self.x.len() * 4
    }

    /// Encode straight from borrowed parts — the client hot path, so a
    /// caller (or a retry loop) never copies the floats into an owned
    /// [`InferRequest`] just to serialize them.
    pub fn encode_parts(model: &str, rows: u32, dim: u32, x: &[f32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(2 + model.len() + 8 + x.len() * 4);
        put_str16(&mut out, model);
        out.extend_from_slice(&rows.to_le_bytes());
        out.extend_from_slice(&dim.to_le_bytes());
        put_f32s(&mut out, x);
        out
    }

    pub fn encode(&self) -> Vec<u8> {
        Self::encode_parts(&self.model, self.rows, self.dim, &self.x)
    }

    pub fn decode(p: &[u8]) -> Result<InferRequest, String> {
        let mut c = Cur::new(p);
        let model = c.str16("model name")?;
        let rows = c.u32("rows")?;
        let dim = c.u32("dim")?;
        let x = c.f32s(rows as u64 * dim as u64, "x")?;
        c.done("InferRequest")?;
        Ok(InferRequest { model, rows, dim, x })
    }

    /// Read just the leading model name — the routing key.  A relay that
    /// forwards the payload verbatim never parses the float bulk (that
    /// is the backend's job, and re-encoding an MB of rows per hop is
    /// exactly the data-movement tax this codebase exists to avoid).
    pub fn peek_model(p: &[u8]) -> Result<String, String> {
        Cur::new(p).str16("model name")
    }
}

impl InferResponse {
    pub fn wire_bytes(&self) -> usize {
        HEADER_LEN + 4 + 1 + 4 + self.y.len() * 4
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes() - HEADER_LEN);
        out.extend_from_slice(&self.batch_size.to_le_bytes());
        out.push(self.cause.index() as u8);
        out.extend_from_slice(&(self.y.len() as u32).to_le_bytes());
        put_f32s(&mut out, &self.y);
        out
    }

    pub fn decode(p: &[u8]) -> Result<InferResponse, String> {
        let mut c = Cur::new(p);
        let batch_size = c.u32("batch_size")?;
        let cause_idx = c.u8("cause")? as usize;
        let cause = *FlushCause::ALL
            .get(cause_idx)
            .ok_or_else(|| format!("unknown flush cause {cause_idx}"))?;
        let n = c.u32("y length")?;
        let y = c.f32s(n as u64, "y")?;
        c.done("InferResponse")?;
        Ok(InferResponse { y, batch_size, cause })
    }
}

impl WireError {
    pub fn new(code: ErrCode, message: impl Into<String>) -> WireError {
        WireError { code, retry_after_millis: 0, message: message.into() }
    }

    pub fn with_retry_after(mut self, millis: u32) -> WireError {
        self.retry_after_millis = millis;
        self
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(2 + 4 + 2 + self.message.len().min(64));
        out.extend_from_slice(&(self.code as u16).to_le_bytes());
        out.extend_from_slice(&self.retry_after_millis.to_le_bytes());
        // Messages can embed client-supplied text (model names);
        // put_str16's char-boundary truncation bounds the error path
        // without ever panicking mid-UTF-8.
        put_str16(&mut out, &self.message);
        out
    }

    pub fn decode(p: &[u8]) -> Result<WireError, String> {
        let mut c = Cur::new(p);
        let raw = c.u16("error code")?;
        let code =
            ErrCode::from_u16(raw).ok_or_else(|| format!("unknown error code {raw}"))?;
        let retry_after_millis = c.u32("retry-after")?;
        let message = c.str16("error message")?;
        c.done("Error")?;
        Ok(WireError { code, retry_after_millis, message })
    }
}

impl StatsResponse {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&(self.models.len() as u32).to_le_bytes());
        for m in &self.models {
            put_str16(&mut out, &m.name);
            out.extend_from_slice(&m.d_in.to_le_bytes());
            out.extend_from_slice(&m.d_out.to_le_bytes());
            for v in [m.requests, m.rows, m.batches, m.failed] {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out.extend_from_slice(&(self.shard_peaks.len() as u32).to_le_bytes());
        for &p in &self.shard_peaks {
            out.extend_from_slice(&p.to_le_bytes());
        }
        // v2 tail, appended only when there are load samples: a v1
        // payload and a v2 payload with no loads are byte-identical, so
        // old round-trip expectations hold.
        if !self.shard_loads.is_empty() {
            out.extend_from_slice(&(self.shard_loads.len() as u32).to_le_bytes());
            for l in &self.shard_loads {
                out.extend_from_slice(&l.queued.to_le_bytes());
                out.extend_from_slice(&l.in_flight.to_le_bytes());
            }
        }
        out
    }

    pub fn decode(p: &[u8]) -> Result<StatsResponse, String> {
        let mut c = Cur::new(p);
        let n_models = c.u32("model count")?;
        // Truncation-safe pre-check: each entry is at least 2+4+4+32 bytes.
        if n_models as u64 * 42 > c.remaining() as u64 {
            return Err(format!("model count {n_models} larger than the payload"));
        }
        let mut models = Vec::with_capacity(n_models as usize);
        for _ in 0..n_models {
            let name = c.str16("model name")?;
            let d_in = c.u32("d_in")?;
            let d_out = c.u32("d_out")?;
            let requests = c.u64("requests")?;
            let rows = c.u64("rows")?;
            let batches = c.u64("batches")?;
            let failed = c.u64("failed")?;
            models.push(StatsModel { name, d_in, d_out, requests, rows, batches, failed });
        }
        let n_shards = c.u32("shard count")?;
        // `>` not `!=`: a v2 payload legitimately carries a load tail
        // after the peaks, so only truncation is rejected here.
        if n_shards as u64 * 8 > c.remaining() as u64 {
            return Err(format!("shard count {n_shards} does not match the payload"));
        }
        let mut shard_peaks = Vec::with_capacity(n_shards as usize);
        for _ in 0..n_shards {
            shard_peaks.push(c.u64("shard peak")?);
        }
        // v1 payloads end here; a v2 tail is a counted list of
        // (queued, in_flight) u64 pairs, strict like everything else.
        let mut shard_loads = Vec::new();
        if c.remaining() > 0 {
            let n_loads = c.u32("shard load count")?;
            if n_loads as u64 * 16 != c.remaining() as u64 {
                return Err(format!("shard load count {n_loads} does not match the payload"));
            }
            shard_loads.reserve(n_loads as usize);
            for _ in 0..n_loads {
                let queued = c.u64("shard queued")?;
                let in_flight = c.u64("shard in-flight")?;
                shard_loads.push(ShardLoad { queued, in_flight });
            }
        }
        c.done("StatsResponse")?;
        Ok(StatsResponse { models, shard_peaks, shard_loads })
    }
}

/// Decode a Ping/Pong token: exactly [`PING_TOKEN_LEN`] opaque bytes.
pub fn decode_ping(p: &[u8]) -> Result<[u8; PING_TOKEN_LEN], String> {
    <[u8; PING_TOKEN_LEN]>::try_from(p)
        .map_err(|_| format!("ping token is {} bytes, want {PING_TOKEN_LEN}", p.len()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infer_request_round_trips_bit_exactly() {
        let x = vec![
            0.0f32,
            -0.0,
            1.5,
            f32::MIN_POSITIVE,
            f32::from_bits(0x0000_0001), // subnormal
            -3.25e-7,
            f32::MAX,
        ];
        let req = InferRequest { model: "grkan".into(), rows: 1, dim: 7, x: x.clone() };
        let enc = req.encode();
        assert_eq!(enc.len() + super::HEADER_LEN, req.wire_bytes());
        let back = InferRequest::decode(&enc).unwrap();
        assert_eq!(back.model, "grkan");
        assert_eq!((back.rows, back.dim), (1, 7));
        let bits: Vec<u32> = back.x.iter().map(|v| v.to_bits()).collect();
        let want: Vec<u32> = x.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, want, "every f32 must survive bit-for-bit");
    }

    #[test]
    fn infer_response_round_trips_including_non_finite() {
        // Binary transport carries NaN/inf bit-exactly — the capability
        // JSON lacks (DESIGN.md §13).
        let y = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 2.0];
        let resp = InferResponse { y: y.clone(), batch_size: 3, cause: FlushCause::Deadline };
        let back = InferResponse::decode(&resp.encode()).unwrap();
        assert_eq!(back.batch_size, 3);
        assert_eq!(back.cause, FlushCause::Deadline);
        let bits: Vec<u32> = back.y.iter().map(|v| v.to_bits()).collect();
        let want: Vec<u32> = y.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits, want);
    }

    #[test]
    fn infer_request_rejects_mismatched_counts_and_trailing_bytes() {
        let req = InferRequest { model: "m".into(), rows: 2, dim: 3, x: vec![0.0; 6] };
        let mut enc = req.encode();
        assert!(InferRequest::decode(&enc).is_ok());
        enc.push(0);
        assert!(InferRequest::decode(&enc).is_err(), "trailing byte");
        let mut short = req.encode();
        short.pop();
        assert!(InferRequest::decode(&short).is_err(), "truncated");
        // rows*dim disagreeing with the actual payload is an error, not
        // a resize.
        let lying = InferRequest { model: "m".into(), rows: 9, dim: 9, x: vec![0.0; 6] };
        assert!(InferRequest::decode(&lying.encode()).is_err());
    }

    #[test]
    fn hostile_rows_times_dim_cannot_overflow() {
        // rows = dim = u32::MAX: rows*dim*4 overflows u64 math only if
        // done in u32/usize — the checked u64 path must reject cleanly.
        let mut enc = Vec::new();
        super::put_str16(&mut enc, "m");
        enc.extend_from_slice(&u32::MAX.to_le_bytes());
        enc.extend_from_slice(&u32::MAX.to_le_bytes());
        enc.extend_from_slice(&[0u8; 12]);
        assert!(InferRequest::decode(&enc).is_err());
    }

    #[test]
    fn error_codes_round_trip_with_http_equivalents() {
        for code in ErrCode::ALL {
            assert_eq!(ErrCode::from_u16(code as u16), Some(code));
            assert!([400, 404, 408, 429, 500, 503].contains(&code.http_equiv()), "{code:?}");
            let e = WireError::new(code, format!("synthetic {}", code.label()))
                .with_retry_after(if code == ErrCode::QueueFull { 1000 } else { 0 });
            let back = WireError::decode(&e.encode()).unwrap();
            assert_eq!(back, e);
            assert!(e.to_string().contains(code.label()));
        }
        assert!(ErrCode::from_u16(999).is_none());
    }

    #[test]
    fn stats_response_round_trips() {
        let s = StatsResponse {
            models: vec![
                StatsModel {
                    name: "wide".into(),
                    d_in: 96,
                    d_out: 96,
                    requests: 41,
                    rows: 99,
                    batches: 7,
                    failed: 1,
                },
                StatsModel {
                    name: "narrow".into(),
                    d_in: 32,
                    d_out: 32,
                    requests: 0,
                    rows: 0,
                    batches: 0,
                    failed: 0,
                },
            ],
            shard_peaks: vec![3, 0],
            shard_loads: Vec::new(),
        };
        assert_eq!(StatsResponse::decode(&s.encode()).unwrap(), s);
        // A count larger than the payload is rejected up front.
        let mut lying = 100u32.to_le_bytes().to_vec();
        lying.extend_from_slice(&[0u8; 8]);
        assert!(StatsResponse::decode(&lying).is_err());
    }

    #[test]
    fn stats_response_v2_load_tail_round_trips_and_v1_still_decodes() {
        let mut s = StatsResponse {
            models: vec![StatsModel {
                name: "m".into(),
                d_in: 8,
                d_out: 8,
                requests: 5,
                rows: 9,
                batches: 2,
                failed: 0,
            }],
            shard_peaks: vec![7, 1],
            shard_loads: vec![
                ShardLoad { queued: 4, in_flight: 2 },
                ShardLoad { queued: 0, in_flight: 1 },
            ],
        };
        let enc = s.encode();
        assert_eq!(StatsResponse::decode(&enc).unwrap(), s);
        assert_eq!(s.total_load(), 7);
        // A v1 payload (no tail) decodes with empty loads: backward
        // compatible with pre-v2 servers.
        s.shard_loads.clear();
        let v1 = s.encode();
        assert!(v1.len() < enc.len());
        assert_eq!(StatsResponse::decode(&v1).unwrap(), s);
        // A truncated or lying load tail is rejected, not resized.
        let mut bad = v1.clone();
        bad.extend_from_slice(&9u32.to_le_bytes());
        bad.extend_from_slice(&[0u8; 16]);
        assert!(StatsResponse::decode(&bad).is_err());
    }

    #[test]
    fn peek_model_reads_only_the_routing_key() {
        let req = InferRequest { model: "wide".into(), rows: 2, dim: 3, x: vec![0.5; 6] };
        assert_eq!(InferRequest::peek_model(&req.encode()).unwrap(), "wide");
        // Works on the name alone even if the bulk is truncated — the
        // relay never validates what only the backend must.
        assert_eq!(InferRequest::peek_model(&req.encode()[..6]).unwrap(), "wide");
        assert!(InferRequest::peek_model(&[0x09, 0x00, b'x']).is_err());
    }

    #[test]
    fn oversized_strings_truncate_at_char_boundaries_not_wrap() {
        // 80_000 bytes of 2-byte chars: `as u16` would wrap the length
        // prefix to garbage; put_str16 instead cuts at the last char
        // boundary at or below u16::MAX and stays self-consistent.
        let long = "\u{e9}".repeat(40_000);
        let mut out = Vec::new();
        super::put_str16(&mut out, &long);
        let n = u16::from_le_bytes([out[0], out[1]]) as usize;
        assert_eq!(n, 65_534, "65_535 splits a 2-byte char");
        assert_eq!(out.len(), 2 + n, "length prefix matches the bytes written");
        assert!(std::str::from_utf8(&out[2..]).is_ok(), "cut on a char boundary");
    }

    #[test]
    fn ping_token_is_exactly_eight_bytes() {
        assert_eq!(decode_ping(b"abcdefgh").unwrap(), *b"abcdefgh");
        assert!(decode_ping(b"short").is_err());
        assert!(decode_ping(b"way-too-long!").is_err());
    }
}
