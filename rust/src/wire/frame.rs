//! Length-prefixed binary frame codec — the flashwire transport's
//! lowest layer (DESIGN.md §13).
//!
//! Every message on a flashwire connection is one frame:
//!
//! ```text
//! offset  size  field
//! 0       2     magic  0x46 0x57 ("FW")
//! 2       1     version (currently 1)
//! 3       1     msg-type ([`MsgType`])
//! 4       4     payload length, u32 little-endian
//! 8       n     payload ([`super::proto`] defines each type's encoding)
//! ```
//!
//! The codec is deliberately strict: a bad magic, an unknown version, an
//! unknown msg-type, or a length over [`WireLimits::max_payload_bytes`]
//! is rejected **at the header**, before a single payload byte is read —
//! so a hostile or confused peer can never make the server buffer more
//! than 8 bytes of garbage, and the property tests can assert the
//! no-over-read guarantee byte for byte.  Truncation mid-frame is an
//! error, never a silent partial message.
//!
//! Reads share the HTTP parser's patience discipline
//! (`net::http::Patience`): they resume across the listener's short
//! socket read-timeout ticks, an idle connection at a frame boundary is
//! reported [`FrameOutcome::Closed`], and a stall or drip-feed *inside*
//! a frame exhausts the tick/wall-clock budget and surfaces as
//! [`FrameOutcome::Bad`] with [`BadKind::Timeout`] — the binary analogue
//! of the HTTP `408`.

use std::io::{self, BufRead, Write};
use std::sync::atomic::AtomicBool;

use crate::net::http::{read_exact_resumable, Patience};

/// First two bytes of every frame.
pub const MAGIC: [u8; 2] = *b"FW";
/// Protocol version this codec speaks (byte 2 of the header).
pub const VERSION: u8 = 1;
/// Fixed frame-header size: magic + version + msg-type + u32 length.
pub const HEADER_LEN: usize = 8;

/// Hard limits on a single frame's wire size and patience — mirrors
/// `net::Limits` so the binary frontend is exactly as abuse-bounded as
/// the HTTP one.
#[derive(Clone, Copy, Debug)]
pub struct WireLimits {
    /// Payload-length ceiling, bytes; a header declaring more is
    /// rejected before any payload byte is read.
    pub max_payload_bytes: usize,
    /// Silent read-timeout ticks (one per socket `read_timeout` expiry,
    /// 50ms in the server) tolerated while waiting for bytes; same
    /// semantics as `net::Limits::max_stall_ticks`.
    pub max_stall_ticks: usize,
    /// Wall-clock ceiling on reading one whole frame (drip-feed
    /// defense); same semantics as `net::Limits::max_request_secs`.
    pub max_request_secs: u64,
}

impl Default for WireLimits {
    fn default() -> Self {
        Self {
            // Same body ceiling as the HTTP frontend's default.
            max_payload_bytes: 8 * 1024 * 1024,
            max_stall_ticks: 200,
            max_request_secs: 60,
        }
    }
}

/// Frame discriminator (byte 3 of the header).  Odd = client → server,
/// even = server → client, except [`MsgType::Error`], which only the
/// server sends.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum MsgType {
    InferRequest = 1,
    InferResponse = 2,
    StatsRequest = 3,
    StatsResponse = 4,
    Ping = 5,
    Pong = 6,
    Error = 7,
}

impl MsgType {
    pub const ALL: [MsgType; 7] = [
        MsgType::InferRequest,
        MsgType::InferResponse,
        MsgType::StatsRequest,
        MsgType::StatsResponse,
        MsgType::Ping,
        MsgType::Pong,
        MsgType::Error,
    ];

    pub fn from_u8(v: u8) -> Option<MsgType> {
        MsgType::ALL.iter().copied().find(|t| *t as u8 == v)
    }
}

/// One decoded frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    pub msg_type: MsgType,
    pub payload: Vec<u8>,
}

/// Why a frame read failed in a way the connection handler should
/// answer (with an error frame) before closing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BadKind {
    /// Framing violation: bad magic/version/type, oversized length, or
    /// truncation mid-frame.  The byte stream can no longer be trusted.
    Malformed,
    /// Stall/deadline budget exhausted mid-frame (the HTTP `408`
    /// analogue).
    Timeout,
}

/// Result of reading one frame off a connection.
#[derive(Debug)]
pub enum FrameOutcome {
    /// A complete, well-formed frame.
    Ok(Frame),
    /// Clean EOF or idle-timeout before the first byte of a frame (the
    /// peer closed or parked an idle keep-alive connection) — not an
    /// error.
    Closed,
    /// Protocol violation: answer an error frame and close.
    Bad { kind: BadKind, msg: String },
}

fn bad(kind: BadKind, msg: impl Into<String>) -> FrameOutcome {
    FrameOutcome::Bad { kind, msg: msg.into() }
}

/// Validate a frame header against `limits`.  Pure — the property tests
/// drive it directly.  `Err` carries the reason; the caller has read
/// exactly [`HEADER_LEN`] bytes and must not read more on error.
pub fn decode_header(
    h: &[u8; HEADER_LEN],
    limits: &WireLimits,
) -> Result<(MsgType, usize), String> {
    if h[0..2] != MAGIC {
        return Err(format!("bad magic {:#04x}{:02x} (want \"FW\")", h[0], h[1]));
    }
    if h[2] != VERSION {
        return Err(format!("unsupported flashwire version {} (want {VERSION})", h[2]));
    }
    let Some(msg_type) = MsgType::from_u8(h[3]) else {
        return Err(format!("unknown msg-type {}", h[3]));
    };
    let len = u32::from_le_bytes([h[4], h[5], h[6], h[7]]) as usize;
    if len > limits.max_payload_bytes {
        return Err(format!(
            "payload of {len} bytes over the {} cap",
            limits.max_payload_bytes
        ));
    }
    Ok((msg_type, len))
}

/// Read one frame.  `stop` is the server's shutdown flag: reads get the
/// shared drain-grace window, after which exhaustion surfaces as a
/// timeout.  An idle connection (no bytes of a next frame) is `Closed`;
/// truncation or a stall inside a frame is `Bad`.
pub fn read_frame(
    r: &mut impl BufRead,
    limits: &WireLimits,
    stop: &AtomicBool,
) -> io::Result<FrameOutcome> {
    let mut patience =
        Patience::with_budget(stop, limits.max_stall_ticks, limits.max_request_secs);
    let mut header = [0u8; HEADER_LEN];
    // The first byte separates "idle peer went away / never spoke" from
    // "a started frame was cut short".
    match read_exact_resumable(r, &mut header[..1], &mut patience) {
        Ok(()) => {}
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(FrameOutcome::Closed),
        Err(e) if e.kind() == io::ErrorKind::TimedOut => return Ok(FrameOutcome::Closed),
        Err(e) => return Err(e),
    }
    match read_exact_resumable(r, &mut header[1..], &mut patience) {
        Ok(()) => {}
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => {
            return Ok(bad(BadKind::Malformed, "connection closed inside a frame header"));
        }
        Err(e) if e.kind() == io::ErrorKind::TimedOut => {
            return Ok(bad(BadKind::Timeout, "frame header read timed out"));
        }
        Err(e) => return Err(e),
    }
    let (msg_type, len) = match decode_header(&header, limits) {
        Ok(v) => v,
        Err(msg) => return Ok(bad(BadKind::Malformed, msg)),
    };
    let mut payload = vec![0u8; len];
    if len > 0 {
        match read_exact_resumable(r, &mut payload, &mut patience) {
            Ok(()) => {}
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => {
                return Ok(bad(BadKind::Malformed, "connection closed inside a frame payload"));
            }
            Err(e) if e.kind() == io::ErrorKind::TimedOut => {
                return Ok(bad(BadKind::Timeout, "frame payload read timed out"));
            }
            Err(e) => return Err(e),
        }
    }
    Ok(FrameOutcome::Ok(Frame { msg_type, payload }))
}

/// Serialize one frame: 8-byte header, payload, flush.
pub fn write_frame(w: &mut impl Write, msg_type: MsgType, payload: &[u8]) -> io::Result<()> {
    let len = u32::try_from(payload.len()).map_err(|_| {
        io::Error::new(io::ErrorKind::InvalidInput, "frame payload over u32::MAX bytes")
    })?;
    let mut header = [0u8; HEADER_LEN];
    header[..2].copy_from_slice(&MAGIC);
    header[2] = VERSION;
    header[3] = msg_type as u8;
    header[4..].copy_from_slice(&len.to_le_bytes());
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn no_stop() -> AtomicBool {
        AtomicBool::new(false)
    }

    fn read(raw: &[u8], limits: &WireLimits) -> FrameOutcome {
        read_frame(&mut Cursor::new(raw.to_vec()), limits, &no_stop()).unwrap()
    }

    fn encoded(msg_type: MsgType, payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        write_frame(&mut out, msg_type, payload).unwrap();
        out
    }

    #[test]
    fn round_trips_a_frame() {
        let raw = encoded(MsgType::Ping, b"12345678");
        assert_eq!(raw.len(), HEADER_LEN + 8);
        let FrameOutcome::Ok(f) = read(&raw, &WireLimits::default()) else {
            panic!("want Ok")
        };
        assert_eq!(f.msg_type, MsgType::Ping);
        assert_eq!(f.payload, b"12345678");
    }

    #[test]
    fn empty_payload_and_pipelined_frames_parse_in_sequence() {
        let mut raw = encoded(MsgType::StatsRequest, b"");
        raw.extend_from_slice(&encoded(MsgType::Ping, b"abcdefgh"));
        let mut cur = Cursor::new(raw);
        let stop = no_stop();
        let FrameOutcome::Ok(a) = read_frame(&mut cur, &WireLimits::default(), &stop).unwrap()
        else {
            panic!("first")
        };
        assert_eq!((a.msg_type, a.payload.len()), (MsgType::StatsRequest, 0));
        let FrameOutcome::Ok(b) = read_frame(&mut cur, &WireLimits::default(), &stop).unwrap()
        else {
            panic!("second")
        };
        assert_eq!(b.msg_type, MsgType::Ping);
        assert!(matches!(
            read_frame(&mut cur, &WireLimits::default(), &stop).unwrap(),
            FrameOutcome::Closed
        ));
    }

    #[test]
    fn eof_before_first_byte_is_closed_not_error() {
        assert!(matches!(read(b"", &WireLimits::default()), FrameOutcome::Closed));
    }

    #[test]
    fn bad_magic_version_and_type_are_rejected_at_the_header() {
        let good = encoded(MsgType::Ping, b"12345678");
        for (mutate, want_sub) in [
            (0usize, "bad magic"),
            (2, "unsupported flashwire version"),
            (3, "unknown msg-type"),
        ] {
            let mut raw = good.clone();
            raw[mutate] = 0xEE;
            match read(&raw, &WireLimits::default()) {
                FrameOutcome::Bad { kind: BadKind::Malformed, msg } => {
                    assert!(msg.contains(want_sub), "byte {mutate}: {msg}")
                }
                other => panic!("byte {mutate}: want Bad, got {other:?}"),
            }
        }
    }

    #[test]
    fn oversized_length_is_rejected_without_reading_payload() {
        let limits = WireLimits { max_payload_bytes: 16, ..Default::default() };
        let raw = encoded(MsgType::Ping, &[0u8; 64]);
        let mut cur = Cursor::new(raw);
        match read_frame(&mut cur, &limits, &no_stop()).unwrap() {
            FrameOutcome::Bad { kind: BadKind::Malformed, msg } => {
                assert!(msg.contains("over the 16 cap"), "{msg}")
            }
            other => panic!("want Bad, got {other:?}"),
        }
        assert_eq!(cur.position(), HEADER_LEN as u64, "no payload byte was read");
    }

    #[test]
    fn truncated_frames_are_malformed_not_hangs() {
        let raw = encoded(MsgType::Ping, b"12345678");
        // Every strict prefix (past the first byte) is a truncation.
        for cut in 1..raw.len() {
            match read(&raw[..cut], &WireLimits::default()) {
                FrameOutcome::Bad { kind: BadKind::Malformed, .. } => {}
                other => panic!("cut at {cut}: want Bad, got {other:?}"),
            }
        }
    }

    /// A reader that yields its prefix, then stalls forever with
    /// `WouldBlock` — the frame-codec analogue of http.rs's stall stub.
    struct Stall(Vec<u8>, usize);

    impl io::Read for Stall {
        fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
            if self.1 < self.0.len() {
                let n = (self.0.len() - self.1).min(out.len());
                out[..n].copy_from_slice(&self.0[self.1..self.1 + n]);
                self.1 += n;
                Ok(n)
            } else {
                Err(io::ErrorKind::WouldBlock.into())
            }
        }
    }

    #[test]
    fn stall_mid_frame_is_timeout_and_idle_stall_is_closed() {
        let limits = WireLimits { max_stall_ticks: 3, ..Default::default() };
        let raw = encoded(MsgType::Ping, b"12345678");
        let mut r = io::BufReader::new(Stall(raw[..5].to_vec(), 0));
        match read_frame(&mut r, &limits, &no_stop()).unwrap() {
            FrameOutcome::Bad { kind: BadKind::Timeout, .. } => {}
            other => panic!("want Timeout, got {other:?}"),
        }
        let mut r = io::BufReader::new(Stall(Vec::new(), 0));
        assert!(matches!(
            read_frame(&mut r, &limits, &no_stop()).unwrap(),
            FrameOutcome::Closed
        ));
    }

    #[test]
    fn header_layout_matches_the_design_doc_table() {
        let raw = encoded(MsgType::InferRequest, &[9, 9, 9]);
        assert_eq!(&raw[0..2], b"FW");
        assert_eq!(raw[2], VERSION);
        assert_eq!(raw[3], MsgType::InferRequest as u8);
        assert_eq!(u32::from_le_bytes([raw[4], raw[5], raw[6], raw[7]]), 3);
        assert_eq!(&raw[8..], &[9, 9, 9]);
    }
}
