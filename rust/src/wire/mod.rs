//! flashwire: a zero-dependency, length-prefixed binary wire protocol
//! for float-heavy inference traffic (DESIGN.md §13).
//!
//! The HTTP/JSON frontend (DESIGN.md §12) preserves f32 payloads
//! bit-exactly, but pays a text round trip per value — shortest
//! round-trip decimal encode on the way out, parse on the way in.  For
//! realistic batch payloads that encode/parse cost dominates the GR-KAN
//! forward itself: the transport-layer image of FlashKAT's thesis that
//! FLOP-equivalent systems lose orders of magnitude to data movement.
//! flashwire removes the text round trip: f32 rows cross the wire as
//! the little-endian bytes they already are, inside gRPC-style
//! length-prefixed frames.  Four layers, each testable on its own:
//!
//! - [`frame`] — the versioned frame codec: magic + version + msg-type
//!   + u32 length, hard caps mirroring `net::Limits`,
//!   timeout-resumable reads, strict rejection of truncated /
//!   oversized / unknown frames *before* their payload is read.
//! - [`proto`] — typed messages: `InferRequest`/`InferResponse` (flat
//!   f32 LE payloads), `StatsRequest`/`StatsResponse`, `Ping`/`Pong`,
//!   and `Error` frames carrying the same typed failure taxonomy the
//!   HTTP router maps to statuses (queue full → retry-after-millis,
//!   bad-model, bad-shape, non-finite-input, ...).
//! - [`server`] — the threaded frontend: bounded accept loop + fixed
//!   handler pool (sharing `net::listener`'s hand-off queue), graceful
//!   SIGTERM drain, per-connection keep-alive under the shared
//!   stall/deadline budget.
//! - [`client`] — a thin blocking client (wire loadgen mode, e2e
//!   tests, `examples/wire_client`).
//!
//! Served by `flashkat serve-wire`; measured against HTTP/JSON and
//! in-process submission by `serve-bench --wire` → `BENCH_wire.json`.

pub mod client;
pub mod frame;
pub mod proto;
pub mod server;

pub use client::WireClient;
pub use frame::{Frame, FrameOutcome, MsgType, WireLimits, HEADER_LEN, MAGIC, VERSION};
pub use proto::{
    ErrCode, InferRequest, InferResponse, ShardLoad, StatsModel, StatsResponse, WireError,
};
pub use server::{WireMetrics, WireOptions, WireServer};
