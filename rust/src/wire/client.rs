//! Thin blocking flashwire client over `std::net::TcpStream`.
//!
//! Exists for the wire loadgen mode, the e2e tests, and
//! `examples/wire_client` — one keep-alive connection per client
//! thread, mirroring `net::HttpClient` so the three-way transport
//! comparison in `BENCH_wire.json` measures encodings, not
//! connection-setup strategy.  Request/response are strictly one frame
//! each, in order, on one connection.
//!
//! Outcome shape: the outer `Result` is transport failure (connection
//! reset, protocol confusion — the conversation is over); the inner
//! `Result<_, WireError>` is a *typed server answer* (queue full,
//! unknown model, ...) on a connection that is still healthy — callers
//! branch on [`ErrCode`](super::proto::ErrCode) without string
//! matching, e.g. the bench's retry-after-aware backoff on
//! `QueueFull`.

use std::io::{BufReader, Read};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::frame::{
    decode_header, write_frame, Frame, MsgType, WireLimits, HEADER_LEN,
};
use super::proto::{InferRequest, InferResponse, StatsResponse, WireError, PING_TOKEN_LEN};

/// One keep-alive flashwire connection.
pub struct WireClient {
    reader: BufReader<TcpStream>,
    addr: SocketAddr,
    limits: WireLimits,
    /// Set when a frame from the server failed to parse: the unread
    /// remainder of that frame is still on the wire, so any further
    /// read would misparse mid-payload bytes as a header.  Fail fast
    /// instead; the caller reconnects.
    broken: bool,
}

impl WireClient {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        Self::connect_with_limits(addr, WireLimits::default())
    }

    /// [`Self::connect`] with explicit limits.  The client enforces
    /// `limits.max_payload_bytes` on frames it *reads*, so talking to a
    /// server started with a raised `--max-payload-bytes` (responses
    /// can be as large as requests) needs a matching cap here.
    pub fn connect_with_limits(addr: SocketAddr, limits: WireLimits) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        // A generous ceiling so a wedged server fails the call instead
        // of hanging the bench/test forever (same as HttpClient).
        stream.set_read_timeout(Some(Duration::from_secs(30))).ok();
        Ok(Self { reader: BufReader::new(stream), addr, limits, broken: false })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Whether an earlier frame failure poisoned this connection (see
    /// [`Self::call_reconnecting`] for the recovery path).
    pub fn is_broken(&self) -> bool {
        self.broken
    }

    /// Run `op` against this client, reconnecting with capped
    /// exponential backoff on transport failure — up to `attempts`
    /// tries total.  This is the one reconnect loop every caller used
    /// to hand-roll: any `Err` from `op` poisons the connection
    /// ([`Self::round_trip`]), so the helper replaces the whole client
    /// (`connect_with_limits` to the same address and limits) and
    /// retries.  Typed server answers (`Ok(Err(WireError))` from
    /// [`Self::infer_encoded`], say) are successes here: the connection
    /// is healthy and retrying is the *caller's* policy decision.
    ///
    /// Backoff between attempts is `1ms << tries`, capped at 100ms —
    /// enough for a backend restart to win the race, small enough that
    /// a router's failover path is never stalled behind it.
    pub fn call_reconnecting<T>(
        &mut self,
        attempts: usize,
        mut op: impl FnMut(&mut WireClient) -> Result<T>,
    ) -> Result<T> {
        const BACKOFF_CAP: Duration = Duration::from_millis(100);
        let attempts = attempts.max(1);
        let mut last: Option<anyhow::Error> = None;
        for tries in 0..attempts {
            if tries > 0 || self.broken {
                if tries > 0 {
                    let backoff = Duration::from_millis(1u64 << tries.min(16));
                    std::thread::sleep(backoff.min(BACKOFF_CAP));
                }
                match Self::connect_with_limits(self.addr, self.limits) {
                    Ok(fresh) => *self = fresh,
                    Err(e) => {
                        last = Some(e);
                        continue;
                    }
                }
            }
            match op(self) {
                Ok(v) => return Ok(v),
                Err(e) => last = Some(e),
            }
        }
        Err(last.expect("at least one attempt ran").context(format!(
            "giving up on {} after {attempts} attempt(s)",
            self.addr
        )))
    }

    /// Validate and pre-encode one infer request's frame payload.  `x`
    /// must hold `rows` full rows; the row width is derived as
    /// `x.len() / rows`.  Callers that may resend — the bench's
    /// shed-backoff retry loop — encode once here and replay the bytes
    /// via [`Self::infer_encoded`] instead of re-copying the floats on
    /// every attempt.
    pub fn encode_infer(model: &str, x: &[f32], rows: u32) -> Result<Vec<u8>> {
        let dim = if rows == 0 {
            // An empty 0-row request still round-trips so the server
            // can answer its typed BadShape; 0 rows WITH payload could
            // never decode server-side (0 rows of any dim is 0 values),
            // so fail it here as the caller bug it is.
            if !x.is_empty() {
                bail!("0 rows cannot carry {} payload values", x.len());
            }
            0
        } else {
            if x.len() % rows as usize != 0 {
                bail!("{} values is not {rows} whole rows", x.len());
            }
            (x.len() / rows as usize) as u32
        };
        if model.len() > u16::MAX as usize {
            bail!("model name over u16::MAX bytes");
        }
        Ok(InferRequest::encode_parts(model, rows, dim, x))
    }

    /// Submit one infer request.  Outer `Err` = transport failure;
    /// inner `Err` = typed server error on a still-healthy connection.
    pub fn infer(
        &mut self,
        model: &str,
        x: &[f32],
        rows: u32,
    ) -> Result<std::result::Result<InferResponse, WireError>> {
        let payload = Self::encode_infer(model, x, rows)?;
        self.infer_encoded(&payload)
    }

    /// [`Self::infer`] over a payload pre-built by
    /// [`Self::encode_infer`].
    pub fn infer_encoded(
        &mut self,
        payload: &[u8],
    ) -> Result<std::result::Result<InferResponse, WireError>> {
        let frame = self.round_trip(MsgType::InferRequest, payload)?;
        match frame.msg_type {
            MsgType::InferResponse => Ok(Ok(InferResponse::decode(&frame.payload)
                .map_err(|e| anyhow::anyhow!("bad InferResponse: {e}"))?)),
            MsgType::Error => Ok(Err(WireError::decode(&frame.payload)
                .map_err(|e| anyhow::anyhow!("bad Error frame: {e}"))?)),
            other => bail!("unexpected reply {other:?} to an InferRequest"),
        }
    }

    /// Round-trip a ping token; errors if the echo does not match.
    pub fn ping(&mut self, token: u64) -> Result<()> {
        let sent = token.to_le_bytes();
        debug_assert_eq!(sent.len(), PING_TOKEN_LEN);
        let frame = self.round_trip(MsgType::Ping, &sent)?;
        match frame.msg_type {
            MsgType::Pong if frame.payload == sent => Ok(()),
            MsgType::Pong => bail!("pong echoed a different token"),
            MsgType::Error => {
                let e = WireError::decode(&frame.payload)
                    .map_err(|e| anyhow::anyhow!("bad Error frame: {e}"))?;
                bail!("ping refused: {e}")
            }
            other => bail!("unexpected reply {other:?} to a Ping"),
        }
    }

    /// Fetch the live per-model counter snapshot.
    pub fn stats(&mut self) -> Result<StatsResponse> {
        let frame = self.round_trip(MsgType::StatsRequest, &[])?;
        match frame.msg_type {
            MsgType::StatsResponse => StatsResponse::decode(&frame.payload)
                .map_err(|e| anyhow::anyhow!("bad StatsResponse: {e}")),
            MsgType::Error => {
                let e = WireError::decode(&frame.payload)
                    .map_err(|e| anyhow::anyhow!("bad Error frame: {e}"))?;
                bail!("stats refused: {e}")
            }
            other => bail!("unexpected reply {other:?} to a StatsRequest"),
        }
    }

    /// Write one frame and read the one reply frame.  ANY failure —
    /// partial write, timeout or EOF mid-read, header reject — leaves
    /// the stream position unknowable, so it poisons the connection:
    /// further calls fail fast instead of parsing stale mid-frame bytes
    /// as a header.  Callers reconnect (as the bench's retry loop does).
    /// `pub(crate)` so the router tier can relay a request's payload
    /// verbatim and hand the reply frame back byte-for-byte — decoding
    /// and re-encoding megabytes of f32 rows per hop is exactly the
    /// data-movement tax the protocol exists to avoid.
    pub(crate) fn round_trip(&mut self, msg_type: MsgType, payload: &[u8]) -> Result<Frame> {
        if self.broken {
            bail!("connection desynced by an earlier frame failure; reconnect");
        }
        let res = write_frame(self.reader.get_mut(), msg_type, payload)
            .context("writing request frame")
            .and_then(|()| self.read_frame());
        if res.is_err() {
            self.broken = true;
        }
        res
    }

    /// Blocking frame read (the 30s socket timeout is the only budget a
    /// client needs; servers are the side that meters patience).
    fn read_frame(&mut self) -> Result<Frame> {
        let mut header = [0u8; HEADER_LEN];
        self.reader
            .read_exact(&mut header)
            .context("reading frame header (connection closed?)")?;
        let (msg_type, len) = decode_header(&header, &self.limits)
            .map_err(|e| anyhow::anyhow!("bad frame from server: {e}"))?;
        let mut payload = vec![0u8; len];
        self.reader.read_exact(&mut payload).context("reading frame payload")?;
        Ok(Frame { msg_type, payload })
    }
}
