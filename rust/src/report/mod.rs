//! Paper-table regeneration: every table and figure in the evaluation
//! (see DESIGN.md §5 for the experiment index).  Each function returns the
//! formatted report as a String so benches, examples and the CLI share one
//! implementation.

use crate::flops::{self, LayerDims};
use crate::gpusim::kernels::{
    RationalBwdFlashKernel, RationalBwdKatKernel, RationalDims, RationalFwdKernel,
};
use crate::gpusim::model_cost::{paper_models, train_step_cost, Ffn};
use crate::gpusim::{simulate, GpuConfig, SimReport};
use crate::rational::experiment::{run as rounding_run, RoundingConfig};
use crate::util::stats::{human_count, human_time};

fn hdr(title: &str) -> String {
    format!("\n=== {title} ===\n")
}

/// Paper reference values for side-by-side comparison.
pub mod paper {
    /// Fig 1 slowdowns: KAT vs ViT (T, S, B).
    pub const FIG1_SLOWDOWN: [(f64, &str); 3] = [(102.0, "T"), (123.0, "S"), (116.0, "B")];
    /// Table 3: KAT bwd 1.03 s, FlashKAT bwd 7.33 ms -> 140.5x.
    pub const TABLE3_KAT_SECS: f64 = 1.03;
    pub const TABLE3_FLASH_SECS: f64 = 7.33e-3;
    pub const TABLE3_SPEEDUP: f64 = 140.5;
    /// Table 4 training throughput (images/s) on H200.
    pub const TABLE4: [(&str, f64, f64); 12] = [
        ("vit-t", 72.7, 8954.97),
        ("deit-t", 72.2, 8954.97),
        ("kat-t", 74.6, 87.73),
        ("flashkat-t", 74.6, 6317.90),
        ("vit-s", 78.8, 5311.71),
        ("deit-s", 79.8, 5311.71),
        ("kat-s", 81.2, 43.28),
        ("flashkat-s", 81.4, 3741.91),
        ("vit-b", 79.1, 2457.15),
        ("deit-b", 81.8, 2457.15),
        ("kat-b", 82.3, 21.24),
        ("flashkat-b", 82.2, 1801.75),
    ];
    /// Table 5/8 MAE values.
    pub const TABLE5_KAT_DA: f64 = 8.84e-2;
    pub const TABLE5_FLASH_DA: f64 = 8.42e-4;
    /// Fig 2/3 Long-Scoreboard cycles per instruction.
    pub const FIG2_LSB: f64 = 981.51;
    pub const FIG3_LSB: f64 = 2.31;
}

const TABLE_HEADER: &str =
    "model                    cycles       time   SM%      L1%      L2%     HBM%";

/// Figure 1: ViT vs KAT (vs FlashKAT) fwd+bwd step time per model size.
pub fn fig1(cfg: &GpuConfig, b_sim: u64) -> String {
    let mut out = hdr(&format!("Figure 1: training step time (Fwd+Bwd), {}", cfg.name));
    let models = paper_models();
    let costs: Vec<_> = models.iter().map(|m| (m, train_step_cost(cfg, m, b_sim))).collect();
    out.push_str("model         fwd+bwd      vs vit     (paper)\n");
    for size in ["t", "s", "b"] {
        let find = |pfx: &str| {
            costs
                .iter()
                .find(|(m, _)| m.name == format!("{pfx}-{size}"))
                .map(|(_, c)| c.total_secs())
                .unwrap()
        };
        let vit = find("vit");
        let kat = find("kat");
        let flash = find("flashkat");
        let paper_ratio = paper::FIG1_SLOWDOWN
            .iter()
            .find(|(_, s)| s.to_lowercase() == size)
            .map(|(r, _)| *r)
            .unwrap();
        out.push_str(&format!(
            "vit-{size}       {:>10}      1.0x\nkat-{size}       {:>10}  {:>7.1}x    ({paper_ratio:.0}x)\nflashkat-{size}  {:>10}  {:>7.1}x\n",
            human_time(vit),
            human_time(kat),
            kat / vit,
            human_time(flash),
            flash / vit,
        ));
    }
    out
}

/// Table 1: params/FLOPs for MLP vs KAN vs GR-KAN.
pub fn table1() -> String {
    let mut out = hdr("Table 1: parameter counts and FLOPs per layer");
    for (d_in, d_out) in [(768usize, 3072usize), (192, 768), (384, 1536)] {
        out.push_str(&format!("layer {d_in} -> {d_out} (FuncFLOPs=14):\n"));
        for row in flops::table1(LayerDims { d_in, d_out }, 14) {
            out.push_str(&format!(
                "  {:<14} params {:>12}  flops {:>14}\n",
                row.name,
                human_count(row.params as f64),
                human_count(row.flops as f64)
            ));
        }
    }
    out.push_str(&format!(
        "GR-KAN activation share of FLOPs: {:.3}% (paper Insight 2: negligible)\n",
        100.0 * flops::grkan_activation_fraction(LayerDims { d_in: 768, d_out: 3072 }, 5, 4)
    ));
    out
}

/// Table 2: FLOP-loop sweep for the group-wise rational fwd/bwd.
pub fn table2(cfg: &GpuConfig, dims: RationalDims) -> String {
    let mut out = hdr(&format!(
        "Table 2: FLOPs scaling, X in R^({}x{}x{}), {}",
        dims.batch, dims.seq, dims.d, cfg.name
    ));
    out.push_str("-- forward --\nloops    flops    ");
    out.push_str(TABLE_HEADER);
    out.push('\n');
    for loops in [1u32, 2, 4, 8] {
        let mut d = dims;
        d.flop_loops = loops;
        let r = simulate(cfg, &RationalFwdKernel::new(d));
        out.push_str(&format!("{loops:<6} {:>8}  {}\n", human_count(r.flops as f64), r.table_row()));
    }
    out.push_str("-- backward (Algorithm 1) --\nloops    flops    ");
    out.push_str(TABLE_HEADER);
    out.push('\n');
    for loops in [1u32, 2, 4, 8] {
        let mut d = dims;
        d.flop_loops = loops;
        let r = simulate(cfg, &RationalBwdKatKernel::new(d));
        out.push_str(&format!("{loops:<6} {:>8}  {}\n", human_count(r.flops as f64), r.table_row()));
    }
    out.push_str("(paper: cycles/time flat across 1-8x FLOPs in both passes)\n");
    out
}

/// Figure 2: warp states of the Algorithm 1 backward.
pub fn fig2(cfg: &GpuConfig, dims: RationalDims) -> SimReport {
    simulate(cfg, &RationalBwdKatKernel::new(dims))
}

/// Figure 3: warp states of the FlashKAT backward.
pub fn fig3(cfg: &GpuConfig, dims: RationalDims) -> SimReport {
    simulate(cfg, &RationalBwdFlashKernel::new(dims))
}

pub fn fig2_fig3(cfg: &GpuConfig, dims: RationalDims) -> String {
    let mut out = hdr("Figures 2-3: warp-state statistics (backward pass)");
    let kat = fig2(cfg, dims);
    let flash = fig3(cfg, dims);
    out.push_str(&kat.warp_state_figure());
    out.push_str(&format!(
        "  -> Long Scoreboard / Selected = {:.0}x (paper: 412x; LSB {:.2} cyc/instr, paper {})\n\n",
        kat.lsb_over_selected(),
        kat.cycles_per_instr(crate::gpusim::WarpState::LongScoreboard),
        paper::FIG2_LSB
    ));
    out.push_str(&flash.warp_state_figure());
    out.push_str(&format!(
        "  -> LSB {:.2} cyc/instr (paper {}); all other stalls below Selected: {}\n",
        flash.cycles_per_instr(crate::gpusim::WarpState::LongScoreboard),
        paper::FIG3_LSB,
        flash_other_stalls_below_selected(&flash)
    ));
    out
}

pub fn flash_other_stalls_below_selected(r: &SimReport) -> bool {
    use crate::gpusim::stats::ALL_STATES;
    use crate::gpusim::WarpState;
    let sel = r.cycles_per_instr(WarpState::Selected);
    ALL_STATES
        .iter()
        .filter(|s| !matches!(s, WarpState::Selected | WarpState::LongScoreboard))
        .all(|s| r.cycles_per_instr(*s) <= sel * 50.0)
}

/// Table 3: Algorithm 1 vs Algorithm 2 backward kernel.
pub fn table3(cfg: &GpuConfig, dims: RationalDims) -> String {
    let mut out = hdr(&format!("Table 3: backward kernel comparison, {}", cfg.name));
    let kat = simulate(cfg, &RationalBwdKatKernel::new(dims));
    let flash = simulate(cfg, &RationalBwdFlashKernel::new(dims));
    out.push_str("model     ");
    out.push_str(TABLE_HEADER);
    out.push('\n');
    out.push_str(&format!("KAT       {}\n", kat.table_row()));
    out.push_str(&format!("FlashKAT  {}\n", flash.table_row()));
    out.push_str(&format!(
        "speedup: {:.1}x  (paper: {:.1}x; KAT {} vs ours {}, Flash {} vs ours {})\n",
        kat.elapsed_secs / flash.elapsed_secs,
        paper::TABLE3_SPEEDUP,
        human_time(paper::TABLE3_KAT_SECS),
        human_time(kat.elapsed_secs),
        human_time(paper::TABLE3_FLASH_SECS),
        human_time(flash.elapsed_secs),
    ));
    out
}

/// Table 4: projected training throughput for the paper's nine variants.
pub fn table4(cfg: &GpuConfig, b_sim: u64) -> String {
    let mut out = hdr(&format!("Table 4: training throughput projection, {}", cfg.name));
    out.push_str("model        #param   thp (img/s)   vs-vit     paper-thp  paper-top1\n");
    for shape in paper_models() {
        let cost = train_step_cost(cfg, &shape, b_sim);
        let thp = cost.throughput(shape.batch);
        let preset_name = match shape.ffn {
            Ffn::Mlp => shape.name.to_string(),
            _ => shape.name.replace("flashkat", "kat"),
        };
        let d = crate::config::ModelConfig::preset(&preset_name)
            .map(|c| c.param_count() as f64 / 1e6)
            .unwrap_or(f64::NAN);
        let paper_row = paper::TABLE4.iter().find(|(n, _, _)| *n == shape.name);
        let vit_name = format!("vit-{}", &shape.name[shape.name.len() - 1..]);
        let vit_cost = paper_models()
            .into_iter()
            .find(|m| m.name == vit_name)
            .map(|m| train_step_cost(cfg, &m, b_sim).throughput(m.batch))
            .unwrap_or(f64::NAN);
        out.push_str(&format!(
            "{:<12} {:>5.1}M   {:>11.1}   {:>6.3}   {:>11}  {:>8}\n",
            shape.name,
            d,
            thp,
            thp / vit_cost,
            paper_row.map(|(_, _, t)| format!("{t:.0}")).unwrap_or_default(),
            paper_row.map(|(_, a, _)| format!("{a:.1}")).unwrap_or_default(),
        ));
    }
    out.push_str(
        "(accuracy column is the paper's ImageNet Top-1; our synthetic-task accuracy\n is reported by examples/train_kat — content-dependent metrics don't transfer)\n",
    );
    out
}

/// Table 5/8: gradient rounding error.
pub fn table5(cfg: &RoundingConfig) -> String {
    let rep = rounding_run(cfg);
    let mut out = hdr("Table 5/8: coefficient-gradient rounding error (f32 vs f64 oracle)");
    out.push_str(&format!("config: {}\n", rep.cfg_desc));
    out.push_str(&format!(
        "KAT      dA MAE {:.3e} (± {:.1e})  var {:.3e}\nKAT      dB MAE {:.3e} (± {:.1e})  var {:.3e}\n",
        rep.kat_da.mae_mean, rep.kat_da.mae_ci95, rep.kat_da.variance,
        rep.kat_db.mae_mean, rep.kat_db.mae_ci95, rep.kat_db.variance,
    ));
    out.push_str(&format!(
        "FlashKAT dA MAE {:.3e} (± {:.1e})  var {:.3e}\nFlashKAT dB MAE {:.3e} (± {:.1e})  var {:.3e}\n",
        rep.flash_da.mae_mean, rep.flash_da.mae_ci95, rep.flash_da.variance,
        rep.flash_db.mae_mean, rep.flash_db.mae_ci95, rep.flash_db.variance,
    ));
    out.push_str(&format!(
        "improvement: dA {:.1}x, dB {:.1}x  (paper at B*N=201728: dA {:.0}x)\n",
        rep.improvement_da(),
        rep.improvement_db(),
        paper::TABLE5_KAT_DA / paper::TABLE5_FLASH_DA,
    ));
    out
}

/// Latency cell that survives empty samples: a model that completed
/// zero requests in a short run has no latency distribution, and its
/// percentile is NaN — render a dash instead of leaking `NaNms` into
/// the table (and into anything parsing it).
fn ms_cell(v: f64) -> String {
    if v.is_finite() {
        format!("{v:>7.3}ms")
    } else {
        format!("{:>9}", "-")
    }
}

/// Signed-delta analogue of [`ms_cell`] for the overhead summary lines
/// (a transport leg that served nothing has NaN percentiles, so its
/// deltas are NaN too).
fn delta_ms(v: f64) -> String {
    if v.is_finite() {
        format!("{v:+.3}ms")
    } else {
        "-".to_string()
    }
}

fn ratio_cell(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.2}x")
    } else {
        "-".to_string()
    }
}

/// Percentage cell with the same dash guard as [`ms_cell`]: a cache-off
/// leg has no counter snapshot and a zero-request run divides by zero —
/// both must render `-`, never `NaN`.
fn pct_cell(v: f64) -> String {
    if v.is_finite() {
        format!("{:.1}%", v * 100.0)
    } else {
        "-".to_string()
    }
}

/// Column header matching [`serve_row`], shared by the serve-family
/// reports (first column label varies by table).
fn serve_header(first: &str) -> String {
    format!(
        "{first:<24}    img/s   rows/s   mean-b     p50      p95      p99    qw-p50    qw-p99    ex-p50    ex-p99\n"
    )
}

/// One transport/run table row shared by the serve-family reports.
/// The last four columns split server-side time per request out of the
/// client latency: queue wait (admission → batch release) and executor
/// run, p50/p99 each (log-histogram resolution, µs rendered as ms).
fn serve_row(r: &crate::serve::BenchResult) -> String {
    format!(
        "{:<24} {:>8.0} {:>8.0} {:>8.1} {} {} {} {} {} {} {}\n",
        r.label,
        r.throughput_rps,
        r.rows_per_sec,
        r.exec.mean_batch(),
        ms_cell(r.p50_ms),
        ms_cell(r.p95_ms),
        ms_cell(r.p99_ms),
        ms_cell(r.exec.queue_wait.percentile(50.0) / 1e3),
        ms_cell(r.exec.queue_wait.percentile(99.0) / 1e3),
        ms_cell(r.exec.exec.percentile(50.0) / 1e3),
        ms_cell(r.exec.exec.percentile(99.0) / 1e3),
    )
}

/// Serve-bench report: latency percentiles, throughput, the batch-size
/// histogram, and the per-model split for the main run plus the
/// unbatched baseline.  One request = one image's activations, so req/s
/// is the img/s metric.
pub fn serve(
    main: &crate::serve::BenchResult,
    baseline: Option<&crate::serve::BenchResult>,
) -> String {
    let mut out = hdr("Serve: dynamic micro-batching KAT inference");
    out.push_str(&serve_header("run"));
    out.push_str(&serve_row(main));
    if let Some(base) = baseline {
        out.push_str(&serve_row(base));
        out.push_str(&format!(
            "throughput vs max-batch 1: {:.2}x\n",
            main.throughput_rps / base.throughput_rps.max(1e-9)
        ));
    }
    let hist: Vec<String> = main
        .exec
        .batch_hist
        .iter()
        .enumerate()
        .filter(|(_, n)| **n > 0)
        .map(|(size, n)| format!("{size}x{n}"))
        .collect();
    out.push_str(&format!(
        "batches: {} (sizes {}), errors {}, failed {}, peak queue {}\n",
        main.exec.batches,
        hist.join(" "),
        main.errors,
        main.exec.failed,
        main.peak_queued
    ));
    if main.per_model.len() > 1 {
        out.push_str("per-model:\n");
    }
    for m in &main.per_model {
        out.push_str(&format!(
            "  {:<16} {:>4} -> {:<4}  served {:>6}  rows {:>7}  mean-b {:>5.1}  p50 {}  p99 {}\n",
            m.name,
            m.d_in,
            m.d_out,
            m.served,
            m.exec.rows,
            m.exec.mean_batch(),
            ms_cell(m.p50_ms),
            ms_cell(m.p99_ms)
        ));
    }
    out
}

/// HTTP-vs-in-process report: the same workload through both
/// transports, with the frontend's added latency and throughput cost
/// called out explicitly (the `BENCH_http.json` acceptance view).
pub fn serve_http(
    inproc: &crate::serve::BenchResult,
    http: &crate::serve::BenchResult,
    shards: usize,
) -> String {
    let mut out = hdr("Serve: loopback HTTP frontend vs in-process submit");
    out.push_str(&format!("executor shards: {shards}\n"));
    out.push_str(&serve_header("transport"));
    for r in [inproc, http] {
        out.push_str(&serve_row(r));
    }
    out.push_str(&format!(
        "http overhead: p50 {}, p99 {}, throughput {} of in-process\n",
        delta_ms(http.p50_ms - inproc.p50_ms),
        delta_ms(http.p99_ms - inproc.p99_ms),
        ratio_cell(http.throughput_rps / inproc.throughput_rps.max(1e-9)),
    ));
    if http.errors > 0 || inproc.errors > 0 {
        out.push_str(&format!(
            "errors: in-process {}, http {}\n",
            inproc.errors, http.errors
        ));
    }
    out
}

/// Three-way transport report: the identical seeded workload
/// in-process, over HTTP/JSON, and over flashwire, plus the
/// deterministic bytes-per-request accounting — the `BENCH_wire.json`
/// acceptance view (DESIGN.md §13).
pub fn serve_wire(
    inproc: &crate::serve::BenchResult,
    http: &crate::serve::BenchResult,
    wire: &crate::serve::BenchResult,
    shards: usize,
    bytes: &crate::serve::TransportBytes,
) -> String {
    let mut out = hdr("Serve: flashwire binary frontend vs HTTP/JSON vs in-process");
    out.push_str(&format!("executor shards: {shards}\n"));
    out.push_str(&serve_header("transport"));
    for r in [inproc, http, wire] {
        out.push_str(&serve_row(r));
    }
    out.push_str(&format!(
        "wire vs json: p50 {}, p99 {}, throughput {}\n",
        delta_ms(wire.p50_ms - http.p50_ms),
        delta_ms(wire.p99_ms - http.p99_ms),
        ratio_cell(wire.throughput_rps / http.throughput_rps.max(1e-9)),
    ));
    out.push_str(&format!(
        "wire vs in-process: p50 {}, p99 {}, throughput {}\n",
        delta_ms(wire.p50_ms - inproc.p50_ms),
        delta_ms(wire.p99_ms - inproc.p99_ms),
        ratio_cell(wire.throughput_rps / inproc.throughput_rps.max(1e-9)),
    ));
    out.push_str(&format!(
        "bytes/request (req+resp): json {:.0}+{:.0} B, flashwire {:.0}+{:.0} B ({:.2}x of json)\n",
        bytes.json_request,
        bytes.json_response,
        bytes.wire_request,
        bytes.wire_response,
        bytes.wire_vs_json_ratio(),
    ));
    if inproc.errors + http.errors + wire.errors > 0 {
        out.push_str(&format!(
            "errors: in-process {}, http {}, wire {}\n",
            inproc.errors, http.errors, wire.errors
        ));
    }
    if http.retries + wire.retries > 0 {
        out.push_str(&format!(
            "shed retries (backoff-absorbed 429/queue-full): http {}, wire {}\n",
            http.retries, wire.retries
        ));
    }
    out
}

/// Multi-node scaling report: the identical seeded workload through a
/// 1-node route tier and an N-node tier (same router hop both times),
/// with the scaling-efficiency verdict and the bit-identity gate — the
/// `BENCH_route.json` acceptance view (DESIGN.md §18).
pub fn serve_route(
    single: &crate::serve::BenchResult,
    multi: &crate::serve::BenchResult,
    nodes: usize,
    shards: usize,
    policy_label: &str,
    identical: bool,
) -> String {
    let mut out = hdr("Serve: flashroute multi-node tier vs single node");
    out.push_str(&format!(
        "nodes: {nodes}, shards/node: {shards}, policy: {policy_label}\n"
    ));
    out.push_str(&serve_header("tier"));
    for r in [single, multi] {
        out.push_str(&serve_row(r));
    }
    let efficiency =
        multi.throughput_rps / (nodes as f64 * single.throughput_rps).max(1e-9);
    out.push_str(&format!(
        "scaling: {} -> {} img/s across {nodes} nodes, efficiency {} (1.00x = perfect)\n",
        single.throughput_rps.round(),
        multi.throughput_rps.round(),
        ratio_cell(efficiency),
    ));
    out.push_str(&format!(
        "bit-identity through the router: {}\n",
        if identical { "OK" } else { "FAILED" }
    ));
    if single.errors + multi.errors > 0 {
        out.push_str(&format!(
            "errors: 1-node {}, {nodes}-node {}\n",
            single.errors, multi.errors
        ));
    }
    if single.retries + multi.retries + single.failovers + multi.failovers > 0 {
        out.push_str(&format!(
            "shed retries: 1-node {}, {nodes}-node {}; router failovers: 1-node {}, {nodes}-node {}\n",
            single.retries, multi.retries, single.failovers, multi.failovers
        ));
    }
    out
}

/// Cached-vs-uncached report per transport over the same
/// duplicate-heavy seeded workload — the `BENCH_cache.json` acceptance
/// view (`serve-bench --cache-bytes`).  Hit-rate and speedup cells are
/// dash-guarded like every latency cell: a cache-off leg (no counter
/// snapshot) or a zero-request run renders `-`, never `NaN`.
pub fn serve_cache(
    legs: &[crate::serve::loadgen::CacheLeg],
    identity: &crate::serve::loadgen::CacheIdentity,
    shards: usize,
    cache_bytes: usize,
) -> String {
    let mut out = hdr("Serve: content-addressed forward cache, cached vs uncached");
    out.push_str(&format!("executor shards: {shards}, cache capacity: {cache_bytes} B\n"));
    out.push_str(
        "transport    hit-rate  speedup   p50-delta   p99-delta     hits   misses  coalesced  evictions\n",
    );
    for l in legs {
        let count = |v: Option<u64>| v.map_or("-".to_string(), |n| n.to_string());
        let c = l.stats.as_ref().map(|s| &s.total);
        out.push_str(&format!(
            "{:<12} {:>8} {:>8} {:>11} {:>11} {:>8} {:>8} {:>10} {:>10}\n",
            l.transport,
            pct_cell(l.hit_rate()),
            ratio_cell(l.speedup()),
            delta_ms(l.cached.p50_ms - l.uncached.p50_ms),
            delta_ms(l.cached.p99_ms - l.uncached.p99_ms),
            count(c.map(|c| c.hits)),
            count(c.map(|c| c.misses)),
            count(c.map(|c| c.coalesced)),
            count(c.map(|c| c.evictions)),
        ));
    }
    let verdict = |ok: bool| if ok { "ok" } else { "FAIL" };
    out.push_str(&format!(
        "bit identity vs unbatched oracle: inproc {}, http {}, wire {}\n",
        verdict(identity.inproc),
        verdict(identity.http),
        verdict(identity.wire),
    ));
    out
}

/// Autotune report: every swept `(max_batch, deadline_us)` grid point
/// with its throughput and p99, and the selected policy vs the SLO.
pub fn serve_autotune(res: &crate::serve::AutotuneResult) -> String {
    let mut out = hdr("Serve autotune: (max_batch, deadline_us) policy sweep");
    out.push_str("policy                 max-b  deadline    img/s      p99\n");
    for (i, r) in res.runs.iter().enumerate() {
        let mark = if i == res.best { " <- best" } else { "" };
        out.push_str(&format!(
            "{:<22} {:>5} {:>7}us {:>8.0} {:>7.3}ms{}\n",
            r.label, r.max_batch, r.deadline_us, r.throughput_rps, r.p99_ms, mark
        ));
    }
    let best = res.best();
    out.push_str(&format!(
        "SLO p99 <= {:.3}ms: {} — selected max-batch {} / deadline {}us ({:.0} img/s, p99 {:.3}ms)\n",
        res.slo_p99_us as f64 / 1e3,
        if res.met_slo { "met" } else { "NOT met (lowest-p99 fallback)" },
        best.max_batch,
        best.deadline_us,
        best.throughput_rps,
        best.p99_ms,
    ));
    out
}

/// Tables 6/7: model configs and hyperparameters as encoded in `config`.
pub fn configs() -> String {
    let mut out = hdr("Tables 6-7: model variants and training hyperparameters");
    out.push_str("model    layers  hidden  mlp   heads   params\n");
    for name in ["kat-t", "kat-s", "kat-b", "kat-micro"] {
        let c = crate::config::ModelConfig::preset(name).unwrap();
        out.push_str(&format!(
            "{:<8} {:>5}  {:>6}  {:>5}  {:>5}  {:>6.1}M\n",
            c.name,
            c.depth,
            c.d,
            c.d * c.mlp_ratio,
            c.heads,
            c.param_count() as f64 / 1e6
        ));
    }
    let t = crate::config::TrainConfig::default();
    out.push_str(&format!(
        "\ntrain: AdamW lr={} cosine, warmup {} steps, wd {}, label-smooth {},\n  mixup {} / cutmix {} (switch {}), erase {}, EMA {}\n",
        t.base_lr, t.warmup_steps, t.weight_decay, t.label_smoothing,
        t.mixup_alpha, t.cutmix_alpha, t.mix_switch_prob, t.erase_prob, t.ema_decay
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_dims() -> RationalDims {
        RationalDims { batch: 4, seq: 197, d: 768, n_groups: 8, m1: 6, n: 4, flop_loops: 1 }
    }

    #[test]
    fn table1_contains_all_layers() {
        let t = table1();
        for name in ["MLP (ViT)", "KAN", "GR-KAN (KAT)"] {
            assert!(t.contains(name), "{t}");
        }
    }

    #[test]
    fn table3_reports_speedup() {
        let t = table3(&GpuConfig::rtx4060ti(), small_dims());
        assert!(t.contains("speedup:"));
        assert!(t.contains("KAT"));
        assert!(t.contains("FlashKAT"));
    }

    #[test]
    fn fig2_fig3_signature_flip() {
        let cfg = GpuConfig::rtx4060ti();
        let kat = fig2(&cfg, small_dims());
        let flash = fig3(&cfg, small_dims());
        assert!(kat.lsb_over_selected() > 10.0 * flash.lsb_over_selected());
    }

    #[test]
    fn table2_renders_all_loops() {
        let t = table2(&GpuConfig::rtx4060ti(), small_dims());
        assert!(t.contains("-- forward --"));
        assert!(t.contains("-- backward (Algorithm 1) --"));
    }

    #[test]
    fn configs_table_has_paper_sizes() {
        let c = configs();
        assert!(c.contains("kat-b"));
        assert!(c.contains("86.6M") || c.contains("86.5M") || c.contains("86.7M"), "{c}");
    }

    #[test]
    fn serve_report_formats_speedup_histogram_and_models() {
        use crate::serve::{BenchResult, ExecStats, ModelBench};
        let exec = ExecStats {
            batches: 5,
            requests: 10,
            rows: 20,
            failed: 0,
            batch_hist: vec![0, 0, 5],
            causes: [5, 0, 0, 0, 0],
            busy_secs: 0.05,
            ..Default::default()
        };
        let mk = |label: &str, rps: f64| BenchResult {
            label: label.into(),
            requests: 10,
            concurrency: 2,
            max_batch: 8,
            deadline_us: 200,
            wall_secs: 0.1,
            throughput_rps: rps,
            rows_per_sec: rps * 2.0,
            mean_ms: 1.0,
            p50_ms: 1.0,
            p95_ms: 2.0,
            p99_ms: 3.0,
            max_ms: 4.0,
            errors: 0,
            retries: 0,
            exec: exec.clone(),
            peak_queued: 3,
            per_model: vec![
                ModelBench {
                    name: "grkan".into(),
                    d_in: 64,
                    d_out: 64,
                    exec: exec.clone(),
                    served: 10,
                    p50_ms: 1.0,
                    p99_ms: 3.0,
                },
                ModelBench {
                    name: "kat_micro".into(),
                    d_in: 3072,
                    d_out: 10,
                    exec: ExecStats::default(),
                    served: 0,
                    p50_ms: f64::NAN,
                    p99_ms: f64::NAN,
                },
            ],
        };
        let t = serve(&mk("batched", 4000.0), Some(&mk("baseline", 1000.0)));
        assert!(t.contains("4.00x"), "{t}");
        assert!(t.contains("2x5"), "{t}");
        assert!(t.contains("batched") && t.contains("baseline"), "{t}");
        assert!(t.contains("per-model:"), "{t}");
        assert!(t.contains("grkan") && t.contains("kat_micro"), "{t}");
        // The zero-served model (kat_micro: 0 requests in this short
        // run) must render dashes, never NaN/divide-by-zero artifacts.
        assert!(!t.contains("NaN"), "zero-served model leaked NaN: {t}");
        let micro_row = t.lines().find(|l| l.contains("kat_micro")).unwrap();
        for stat in ["p50", "p99"] {
            let cell = micro_row.split(stat).nth(1).unwrap();
            assert!(
                cell.trim_start().starts_with('-'),
                "want a dash {stat} cell in {micro_row:?}"
            );
        }
    }

    #[test]
    fn serve_http_report_shows_overhead() {
        use crate::serve::{BenchResult, ExecStats};
        let mk = |label: &str, rps: f64, p50: f64| BenchResult {
            label: label.into(),
            requests: 10,
            concurrency: 2,
            max_batch: 8,
            deadline_us: 200,
            wall_secs: 0.1,
            throughput_rps: rps,
            rows_per_sec: rps * 2.0,
            mean_ms: p50,
            p50_ms: p50,
            p95_ms: p50 * 2.0,
            p99_ms: p50 * 3.0,
            max_ms: p50 * 4.0,
            errors: 0,
            retries: 0,
            exec: ExecStats::default(),
            peak_queued: 1,
            per_model: vec![],
        };
        let t = serve_http(&mk("in-process", 4000.0, 0.5), &mk("loopback-http", 3000.0, 0.8), 2);
        assert!(t.contains("executor shards: 2"), "{t}");
        assert!(t.contains("in-process") && t.contains("loopback-http"), "{t}");
        assert!(t.contains("0.75x"), "{t}");
        assert!(t.contains("+0.300ms"), "{t}");

        // A run where nothing completed (all NaN percentiles) renders
        // dashes everywhere — the rows AND the overhead summary line.
        let mut empty = mk("empty", 0.0, f64::NAN);
        empty.mean_ms = f64::NAN;
        let t = serve_http(&mk("in-process", 4000.0, 0.5), &empty, 2);
        assert!(!t.contains("NaN"), "{t}");
        assert!(t.contains("http overhead: p50 -, p99 -,"), "{t}");
    }

    #[test]
    fn serve_wire_report_compares_three_transports_and_bytes() {
        use crate::serve::{BenchResult, ExecStats, TransportBytes};
        let mk = |label: &str, rps: f64, p50: f64| BenchResult {
            label: label.into(),
            requests: 10,
            concurrency: 2,
            max_batch: 8,
            deadline_us: 200,
            wall_secs: 0.1,
            throughput_rps: rps,
            rows_per_sec: rps * 2.0,
            mean_ms: p50,
            p50_ms: p50,
            p95_ms: p50 * 2.0,
            p99_ms: p50 * 3.0,
            max_ms: p50 * 4.0,
            errors: 0,
            retries: 0,
            exec: ExecStats::default(),
            peak_queued: 1,
            per_model: vec![],
        };
        let mut http = mk("loopback-http", 3000.0, 0.8);
        http.retries = 4;
        let bytes = TransportBytes {
            json_request: 5000.0,
            json_response: 5200.0,
            wire_request: 1200.0,
            wire_response: 1100.0,
        };
        let t = serve_wire(
            &mk("in-process", 4000.0, 0.5),
            &http,
            &mk("loopback-wire", 3600.0, 0.6),
            2,
            &bytes,
        );
        assert!(t.contains("executor shards: 2"), "{t}");
        assert!(
            t.contains("in-process") && t.contains("loopback-http") && t.contains("loopback-wire"),
            "{t}"
        );
        assert!(t.contains("wire vs json:"), "{t}");
        assert!(t.contains("1.20x"), "{t}"); // 3600/3000
        assert!(t.contains("json 5000+5200 B, flashwire 1200+1100 B (0.23x of json)"), "{t}");
        assert!(t.contains("shed retries"), "{t}");
    }

    #[test]
    fn serve_cache_report_dash_guards_cache_off_legs() {
        use crate::serve::loadgen::{CacheIdentity, CacheLeg};
        use crate::serve::{BenchResult, CacheCounters, CacheStats, ExecStats};
        let mk = |label: &str, rps: f64, p50: f64| BenchResult {
            label: label.into(),
            requests: 12,
            concurrency: 2,
            max_batch: 8,
            deadline_us: 200,
            wall_secs: 0.1,
            throughput_rps: rps,
            rows_per_sec: rps * 2.0,
            mean_ms: p50,
            p50_ms: p50,
            p95_ms: p50 * 2.0,
            p99_ms: p50 * 3.0,
            max_ms: p50 * 4.0,
            errors: 0,
            retries: 0,
            exec: ExecStats::default(),
            peak_queued: 1,
            per_model: vec![],
        };
        let stats = CacheStats {
            capacity_bytes: 1 << 20,
            bytes: 2048,
            entries: 3,
            in_flight: 0,
            total: CacheCounters {
                hits: 6,
                misses: 4,
                inserts: 4,
                evictions: 1,
                coalesced: 2,
                collisions: 0,
            },
            per_model: vec![],
        };
        let on = CacheLeg {
            transport: "inproc".to_string(),
            uncached: mk("uncached", 1000.0, 1.0),
            cached: mk("cached", 2000.0, 0.5),
            stats: Some(stats),
        };
        // Cache-off leg that also served nothing: hit rate, speedup and
        // both deltas are all undefined — every cell must dash-guard.
        let mut dead = mk("cached", 0.0, f64::NAN);
        dead.mean_ms = f64::NAN;
        let off = CacheLeg {
            transport: "http".to_string(),
            uncached: mk("uncached", 1000.0, 1.0),
            cached: dead,
            stats: None,
        };
        let identity = CacheIdentity { inproc: true, http: true, wire: false };
        let t = serve_cache(&[on, off], &identity, 2, 1 << 20);
        assert!(t.contains("66.7%"), "{t}"); // (6 hits + 2 coalesced) / 12
        assert!(t.contains("2.00x"), "{t}");
        assert!(t.contains("-0.500ms"), "{t}");
        assert!(!t.contains("NaN"), "{t}");
        let row = t.lines().find(|l| l.starts_with("http")).unwrap();
        for cell in row.split_whitespace().skip(1) {
            assert_eq!(cell, "-", "cache-off leg must be all dashes: {row:?}");
        }
        assert!(t.contains("inproc ok, http ok, wire FAIL"), "{t}");
    }

    #[test]
    fn serve_autotune_report_marks_the_selected_policy() {
        let cfg = crate::serve::LoadConfig {
            requests: 16,
            concurrency: 2,
            models: vec![crate::serve::ModelSpec::new("grkan", 64, 8)],
            ..Default::default()
        };
        let res = crate::serve::loadgen::autotune(
            &cfg,
            crate::serve::BatchPolicy::default(),
            5_000_000,
            &[1, 8],
            &[200],
        )
        .unwrap();
        let t = serve_autotune(&res);
        assert!(t.contains("<- best"), "{t}");
        assert!(t.contains("SLO p99 <= 5000.000ms"), "{t}");
        assert!(t.contains("mb1-dl200") && t.contains("mb8-dl200"), "{t}");
    }

    #[test]
    fn table5_small_runs() {
        let cfg = RoundingConfig {
            rows: 512,
            d: 64,
            n_groups: 8,
            m1: 6,
            n: 4,
            s_block: 32,
            passes: 2,
            seed: 1,
        };
        let t = table5(&cfg);
        assert!(t.contains("improvement:"));
    }
}
