//! Model executors: the serving stack's execution abstraction.
//!
//! The server used to own a `Vec<Model>` of PAU coefficient tables and
//! call `rational::forward_into` directly, which hard-wired it to one
//! kind of workload.  [`ModelExecutor`] inverts that dependency: the
//! server drives a registry of named executors and knows nothing about
//! what a model *is* — only that it maps `rows x d_in` request rows to
//! `rows x d_out` response rows.  Two implementations ship:
//!
//! - [`RationalExecutor`] — the original single GR-KAN layer forward,
//!   still **bit-identical** to unbatched [`crate::rational::forward`]
//!   (the forward is strictly elementwise per row, so coalescing cannot
//!   change any output element's accumulation order).
//! - [`PipelineExecutor`] — a whole AOT-compiled model (`<tag>_eval`)
//!   behind a [`crate::runtime::RowsAdapter`], which chunks coalesced
//!   rows into the module's fixed batch dimension.  Bit-identity here
//!   rests on the adapter's row-independence contract (DESIGN.md §11).
//!
//! The executor contract (`run`): read `rows * d_in()` values from `x`,
//! leave exactly `rows * d_out()` values in `out` (cleared first), and
//! return `Err` — never panic — on internal failure; the server turns an
//! `Err` into per-request submit errors and keeps serving other models.

use anyhow::{Context, Result};

use super::batcher::FlushCause;
use crate::rational::{forward_into, Coeffs};
use crate::runtime::{HostTensor, RowsAdapter, Runtime};
use crate::util::stats::LogHist;

/// One named, servable model.  `Send` because the registry moves onto
/// the executor thread; `&mut self` so implementations can keep scratch.
pub trait ModelExecutor: Send {
    /// Registry name — the routing key clients submit against.
    fn name(&self) -> &str;
    /// Flattened per-row input width.
    fn d_in(&self) -> usize;
    /// Flattened per-row output width.
    fn d_out(&self) -> usize;
    /// Run a coalesced batch: `x` holds `rows * d_in()` values; `out` is
    /// cleared and filled with `rows * d_out()` values in row order.
    fn run(&mut self, x: &[f32], rows: usize, out: &mut Vec<f32>) -> Result<()>;
}

/// The GR-KAN layer forward over one grouped-PAU coefficient table.
pub struct RationalExecutor {
    name: String,
    d: usize,
    coeffs: Coeffs<f32>,
}

impl RationalExecutor {
    /// Fails if `d` is not a positive multiple of the table's group
    /// count (the same invariant `forward_into` asserts).
    pub fn new(name: impl Into<String>, d: usize, coeffs: Coeffs<f32>) -> Result<Self> {
        coeffs.validate_width(d)?;
        Ok(Self { name: name.into(), d, coeffs })
    }

    pub fn coeffs(&self) -> &Coeffs<f32> {
        &self.coeffs
    }
}

impl ModelExecutor for RationalExecutor {
    fn name(&self) -> &str {
        &self.name
    }

    fn d_in(&self) -> usize {
        self.d
    }

    fn d_out(&self) -> usize {
        self.d
    }

    fn run(&mut self, x: &[f32], rows: usize, out: &mut Vec<f32>) -> Result<()> {
        // Elementwise per row: batched == unbatched bit for bit.
        forward_into(x, rows, self.d, &self.coeffs, out);
        Ok(())
    }
}

/// A full model pipeline behind the runtime's batched-rows adapter.
pub struct PipelineExecutor {
    name: String,
    adapter: RowsAdapter,
}

impl PipelineExecutor {
    pub fn new(name: impl Into<String>, adapter: RowsAdapter) -> Self {
        Self { name: name.into(), adapter }
    }

    /// Load `<tag>_init` + `<tag>_eval` from the runtime and wrap them:
    /// parameters come from running the init module, request rows flow
    /// through the eval module.  The one artifact-to-executor recipe
    /// shared by the CLI and the examples.
    pub fn from_runtime(rt: &Runtime, tag: &str) -> Result<Self> {
        let init = rt.load(&format!("{tag}_init"))?;
        let params = init.execute(&[]).with_context(|| format!("running {tag}_init"))?;
        Self::from_runtime_with_params(rt, tag, params)
    }

    /// [`Self::from_runtime`] with pre-computed parameter leaves —
    /// callers building several executors for the same tag (the autotune
    /// sweep, the max-batch-1 baseline) run the init module once and
    /// clone the parameters instead of re-executing it per instance.
    pub fn from_runtime_with_params(
        rt: &Runtime,
        tag: &str,
        params: Vec<HostTensor>,
    ) -> Result<Self> {
        let eval = std::sync::Arc::new(rt.load(&format!("{tag}_eval"))?);
        Self::from_module(tag, eval, params)
    }

    /// Wrap an already-compiled eval module.  `Arc` so every executor
    /// instance in a sweep shares one compilation instead of recompiling
    /// the identical HLO per grid point.
    pub fn from_module(
        tag: &str,
        eval: std::sync::Arc<crate::runtime::LoadedModule>,
        params: Vec<HostTensor>,
    ) -> Result<Self> {
        Ok(Self::new(tag, RowsAdapter::for_eval_shared(eval, params)?))
    }
}

impl ModelExecutor for PipelineExecutor {
    fn name(&self) -> &str {
        &self.name
    }

    fn d_in(&self) -> usize {
        self.adapter.d_in()
    }

    fn d_out(&self) -> usize {
        self.adapter.d_out()
    }

    fn run(&mut self, x: &[f32], rows: usize, out: &mut Vec<f32>) -> Result<()> {
        self.adapter.execute_rows(x, rows, out)
    }
}

/// Executor-side counters for one model (or, merged, for the server).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ExecStats {
    pub batches: usize,
    pub requests: usize,
    pub rows: usize,
    /// Requests whose batch failed inside the executor (the submitters
    /// received errors, not rows).
    pub failed: usize,
    /// `batch_hist[k]` = number of batches that coalesced `k` requests.
    pub batch_hist: Vec<usize>,
    /// Batches by [`FlushCause::index`].  The `Cache` slot stays zero
    /// here — cached replies never form a batch, so the executor never
    /// records that cause; the cache's own counters live in
    /// [`super::cache::CacheStats`].
    pub causes: [usize; 5],
    /// Wall time inside the executor's `run` (busy time).
    pub busy_secs: f64,
    /// Per-request queue wait (admission to batch release, µs).
    pub queue_wait: LogHist,
    /// Per-request executor time (µs; every request of a batch records
    /// the batch's `run` duration — that is the latency it observed).
    pub exec: LogHist,
    /// Request payload bytes executed for this model (`rows * d_in * 4`
    /// over successful batches) — the serving-level traffic analogue of
    /// the kernel probes, exported as
    /// `flashkat_traffic_bytes_total{model,stream="in"}`.
    pub bytes_in: u64,
    /// Response payload bytes produced (`rows * d_out * 4`).
    pub bytes_out: u64,
}

impl ExecStats {
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// Record one executed batch.
    pub fn record(&mut self, requests: usize, rows: usize, cause: FlushCause, busy_secs: f64) {
        self.batches += 1;
        self.requests += requests;
        self.rows += rows;
        self.causes[cause.index()] += 1;
        self.busy_secs += busy_secs;
        if self.batch_hist.len() <= requests {
            self.batch_hist.resize(requests + 1, 0);
        }
        self.batch_hist[requests] += 1;
    }

    /// Record one served request's timing breakdown (µs).  Separate
    /// from [`Self::record`]: batches record once, requests each.
    pub fn record_request_timing(&mut self, queue_wait_us: u64, exec_us: u64) {
        self.queue_wait.record(queue_wait_us);
        self.exec.record(exec_us);
    }

    /// Record one successful batch's payload traffic.  Separate from
    /// [`Self::record`] (which also counts failed batches): traffic is
    /// only rows actually executed and returned.
    pub fn record_traffic(&mut self, bytes_in: u64, bytes_out: u64) {
        self.bytes_in += bytes_in;
        self.bytes_out += bytes_out;
    }

    /// Fold `other` into `self` (used to form server-wide totals).
    pub fn merge(&mut self, other: &ExecStats) {
        self.batches += other.batches;
        self.requests += other.requests;
        self.rows += other.rows;
        self.failed += other.failed;
        self.busy_secs += other.busy_secs;
        for (c, o) in self.causes.iter_mut().zip(&other.causes) {
            *c += o;
        }
        if self.batch_hist.len() < other.batch_hist.len() {
            self.batch_hist.resize(other.batch_hist.len(), 0);
        }
        for (h, o) in self.batch_hist.iter_mut().zip(&other.batch_hist) {
            *h += o;
        }
        self.queue_wait.merge(&other.queue_wait);
        self.exec.merge(&other.exec);
        self.bytes_in += other.bytes_in;
        self.bytes_out += other.bytes_out;
    }
}

/// One registry entry's identity plus its counters.
#[derive(Clone, Debug)]
pub struct ModelStats {
    pub name: String,
    pub d_in: usize,
    pub d_out: usize,
    pub stats: ExecStats,
}

/// Server-wide counter snapshot: counters split per model (global
/// registry order) plus queue-depth peaks, which are properties of the
/// per-shard admission queues and therefore not attributable to any
/// single model.  Produced live by `Server::stats` (the `/metrics`
/// feed) and finally by `Server::shutdown`.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub per_model: Vec<ModelStats>,
    /// Peak admitted-but-unserved count of the most loaded shard — must
    /// never exceed the policy's `queue_depth` (the backpressure
    /// invariant, which holds per shard).
    pub peak_queued: usize,
    /// Per-shard peak queue depths, shard order; `peak_queued` is their
    /// max.  A single-shard server has one entry.
    pub shard_peaks: Vec<usize>,
}

impl ServeStats {
    /// Server-wide totals: the fold of every model's counters.
    pub fn total(&self) -> ExecStats {
        let mut t = ExecStats::default();
        for m in &self.per_model {
            t.merge(&m.stats);
        }
        t
    }

    pub fn model(&self, name: &str) -> Option<&ModelStats> {
        self.per_model.iter().find(|m| m.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rational::forward;
    use crate::runtime::ModuleExec;
    use crate::util::rng::Pcg64;

    #[test]
    fn rational_executor_is_bit_identical_to_forward() {
        let mut rng = Pcg64::new(21);
        let coeffs = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
        let mut ex = RationalExecutor::new("grkan", 64, coeffs.clone()).unwrap();
        assert_eq!((ex.d_in(), ex.d_out()), (64, 64));
        let x: Vec<f32> = (0..5 * 64).map(|_| rng.normal_f32()).collect();
        let mut out = Vec::new();
        ex.run(&x, 5, &mut out).unwrap();
        assert_eq!(out, forward(&x, 5, 64, &coeffs));
    }

    #[test]
    fn rational_executor_rejects_bad_width() {
        let mut rng = Pcg64::new(22);
        let coeffs = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
        assert!(RationalExecutor::new("bad", 12, coeffs.clone()).is_err());
        assert!(RationalExecutor::new("bad", 0, coeffs).is_err());
    }

    /// Doubler module: `y = 2x` with d_out == d_in, row-independent.
    struct Doubler {
        batch: usize,
        d: usize,
    }

    impl ModuleExec for Doubler {
        fn execute_batch(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
            let x = inputs[0].as_f32()?;
            let y: Vec<f32> = x.iter().map(|v| v * 2.0).collect();
            Ok(vec![HostTensor::F32 { shape: vec![self.batch, self.d], data: y }])
        }
    }

    #[test]
    fn pipeline_executor_runs_rows_through_the_adapter() {
        let adapter = RowsAdapter::from_parts(
            Box::new(Doubler { batch: 3, d: 4 }),
            vec![],
            vec![3, 4],
            vec![3, 4],
        )
        .unwrap();
        let mut ex = PipelineExecutor::new("pipe", adapter);
        assert_eq!((ex.name(), ex.d_in(), ex.d_out()), ("pipe", 4, 4));
        // 5 rows: one full chunk of 3 + a padded chunk of 2.
        let x: Vec<f32> = (0..20).map(|i| i as f32).collect();
        let mut out = Vec::new();
        ex.run(&x, 5, &mut out).unwrap();
        let want: Vec<f32> = x.iter().map(|v| v * 2.0).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn stats_record_and_merge_sum_exactly() {
        let mut a = ExecStats::default();
        a.record(3, 7, FlushCause::Full, 0.25);
        a.record(1, 2, FlushCause::Deadline, 0.5);
        let mut b = ExecStats::default();
        b.record(3, 5, FlushCause::Idle, 0.125);
        b.record_request_timing(120, 30);
        b.record_request_timing(15, 30);
        b.failed += 3;
        let mut total = ExecStats::default();
        total.merge(&a);
        total.merge(&b);
        assert_eq!(total.batches, 3);
        assert_eq!(total.requests, 7);
        assert_eq!(total.rows, 14);
        assert_eq!(total.failed, 3);
        assert_eq!(total.busy_secs, 0.875);
        assert_eq!(total.causes, [1, 1, 1, 0, 0]);
        assert_eq!(total.batch_hist, vec![0, 1, 0, 2]);
        assert!((total.mean_batch() - 7.0 / 3.0).abs() < 1e-12);
        // Timing histograms merge by count; exec had two identical
        // samples so the full percentile range maps into one bucket.
        assert_eq!(total.queue_wait.count(), 2);
        assert_eq!(total.exec.count(), 2);
        assert_eq!(total.exec.percentile(0.0), total.exec.percentile(100.0));

        let serve = ServeStats {
            per_model: vec![
                ModelStats { name: "a".into(), d_in: 8, d_out: 8, stats: a.clone() },
                ModelStats { name: "b".into(), d_in: 4, d_out: 2, stats: b },
            ],
            peak_queued: 5,
            shard_peaks: vec![5, 2],
        };
        assert_eq!(serve.total(), total);
        assert_eq!(serve.model("a").unwrap().stats, a);
        assert!(serve.model("nope").is_none());
    }
}
