//! Content-addressed forward result cache with in-flight request
//! coalescing (singleflight).  DESIGN.md §16.
//!
//! Every serving path in this repo is proven bit-identical to the
//! unbatched GR-KAN reference (`tests/serve_e2e.rs`), so a forward's
//! output is a pure function of `(model, row bytes)`.  That determinism
//! has so far been a correctness story; here it becomes a throughput
//! one — the fastest forward is the one never executed.  Three layers,
//! all zero-dependency:
//!
//! - **Key derivation** — FNV-1a 64-bit over the model's registry index
//!   and every input value's `f32::to_bits()` little-endian bytes.  The
//!   full key (model + exact bit pattern) is stored alongside each
//!   entry and re-verified on every probe, so a 64-bit hash collision
//!   can never serve the wrong rows — it only costs the colliding key
//!   its cacheability ([`Lookup::Solo`]).
//! - **Segmented LRU** — per-shard probation/protected lists over a
//!   slab with intrusive links.  New entries enter probation; a hit
//!   promotes to protected (capped at ~80% of the shard's byte budget,
//!   demoting the protected tail back to probation); eviction drains
//!   the probation tail before touching protected.  Scan-resistant,
//!   bounded by bytes, no background threads.
//! - **Singleflight** — identical requests already being computed are
//!   coalesced: the first becomes the *leader* ([`Lookup::Lead`],
//!   executes and publishes), the rest *join* ([`Lookup::Join`]) and
//!   park on a channel for the leader's bit-exact rows.  Leader failure
//!   fans the typed [`SubmitError`] to every follower, and an abandoned
//!   leader's [`FlightToken`] drop-guard does the same — followers can
//!   never wedge on a leader that went away.
//!
//! The cache is attached to [`crate::serve::Server`] behind
//! `cache_bytes` (0 = off, the default): with it off, the submit path
//! is byte-for-byte the pre-cache code.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::serve::batcher::FlushCause;
use crate::serve::server::SubmitError;
use crate::trace::Timing;

/// Sentinel slot index for intrusive list links.
const NIL: usize = usize::MAX;

/// Fixed per-entry bookkeeping charge (slab slot, map entry, vec
/// headers) added to the key + payload bytes when billing the budget.
const ENTRY_OVERHEAD: usize = 96;

/// Protected segment budget as a fraction of the shard capacity.
const PROTECTED_NUM: usize = 4;
const PROTECTED_DEN: usize = 5;

/// Budgets at or above this get the full shard fan-out; tiny budgets
/// (eviction tests, pathological configs) stay single-sharded so the
/// per-shard capacity is never silently rounded toward zero.
const SHARD_THRESHOLD_BYTES: usize = 1 << 20;
const N_SHARDS: usize = 8;

/// FNV-1a 64-bit over `(model index, row bytes)`.  Zero-dependency,
/// deterministic across runs, and fast enough that hashing is noise
/// next to even a single-row forward.
pub fn content_hash(model: u32, x: &[f32]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for b in model.to_le_bytes() {
        h = (h ^ u64::from(b)).wrapping_mul(PRIME);
    }
    for v in x {
        for b in v.to_bits().to_le_bytes() {
            h = (h ^ u64::from(b)).wrapping_mul(PRIME);
        }
    }
    h
}

fn bits_eq(bits: &[u32], x: &[f32]) -> bool {
    bits.len() == x.len() && bits.iter().zip(x).all(|(&b, v)| b == v.to_bits())
}

/// What a singleflight leader publishes: everything a follower needs to
/// assemble its own [`crate::serve::Response`] (the follower keeps its
/// own span id; rows, batch accounting and timing come from the leader).
#[derive(Clone, Debug)]
pub struct FlightValue {
    pub y: Vec<f32>,
    pub batch_size: usize,
    pub cause: FlushCause,
    pub timing: Timing,
}

/// Result a parked follower receives from its leader.
pub type FlightResult = Result<FlightValue, SubmitError>;

/// Outcome of a cache probe.
pub enum Lookup {
    /// Verified cache hit: the stored rows, bit-exact.
    Hit(Vec<f32>),
    /// An identical request is in flight; park on the receiver for the
    /// leader's result (value or typed error).
    Join(mpsc::Receiver<FlightResult>),
    /// This request leads a new flight: execute, then
    /// [`FlightToken::publish`] the outcome (dropping the token
    /// unpublished fans a typed failure instead — never a wedge).
    Lead(FlightToken),
    /// A 64-bit hash collision with a different key (cached or in
    /// flight): execute uncached.  Verification makes collisions a
    /// throughput event, never a correctness one.
    Solo,
}

/// Per-model (and, summed, global) cache counters.
///
/// Every request that enters the cache path is exactly one of
/// `hits` / `misses` / `coalesced`; `misses` (leaders + solos) is also
/// exactly the number of executor submissions the cache let through.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CacheCounters {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    pub coalesced: u64,
    pub collisions: u64,
}

impl CacheCounters {
    pub fn merge(&mut self, o: &CacheCounters) {
        self.hits += o.hits;
        self.misses += o.misses;
        self.inserts += o.inserts;
        self.evictions += o.evictions;
        self.coalesced += o.coalesced;
        self.collisions += o.collisions;
    }

    /// Requests that went through the cache path.
    pub fn requests(&self) -> u64 {
        self.hits + self.misses + self.coalesced
    }

    /// Fraction of cache-path requests answered without their own
    /// executor submission (hits + coalesced followers).  `NaN` when no
    /// requests were seen — render with a dash guard, never raw.
    pub fn hit_rate(&self) -> f64 {
        let n = self.requests();
        if n == 0 {
            f64::NAN
        } else {
            (self.hits + self.coalesced) as f64 / n as f64
        }
    }
}

/// Snapshot of the whole cache: occupancy plus per-model counters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub capacity_bytes: usize,
    pub bytes: usize,
    pub entries: usize,
    /// Flights currently open (leaders executing).
    pub in_flight: usize,
    pub total: CacheCounters,
    pub per_model: Vec<(String, CacheCounters)>,
}

impl CacheStats {
    pub fn model(&self, name: &str) -> Option<&CacheCounters> {
        self.per_model.iter().find(|(n, _)| n == name).map(|(_, c)| c)
    }
}

struct Entry {
    hash: u64,
    model: u32,
    bits: Vec<u32>,
    y: Vec<f32>,
    bytes: usize,
    protected: bool,
    prev: usize,
    next: usize,
}

/// Intrusive doubly-linked list endpoints (slot indices, MRU at head).
struct List {
    head: usize,
    tail: usize,
}

impl List {
    fn new() -> Self {
        List { head: NIL, tail: NIL }
    }
}

struct Flight {
    model: u32,
    bits: Vec<u32>,
    waiters: Vec<mpsc::Sender<FlightResult>>,
}

struct ShardState {
    /// `content_hash -> slab slot`; full key verified on every probe.
    map: HashMap<u64, usize>,
    slab: Vec<Option<Entry>>,
    free: Vec<usize>,
    probation: List,
    protected: List,
    bytes: usize,
    protected_bytes: usize,
    flights: HashMap<u64, Flight>,
    counters: Vec<CacheCounters>,
}

impl ShardState {
    fn new(n_models: usize) -> Self {
        ShardState {
            map: HashMap::new(),
            slab: Vec::new(),
            free: Vec::new(),
            probation: List::new(),
            protected: List::new(),
            bytes: 0,
            protected_bytes: 0,
            flights: HashMap::new(),
            counters: vec![CacheCounters::default(); n_models],
        }
    }

    fn unlink(&mut self, slot: usize) {
        let (prev, next, prot) = {
            let e = self.slab[slot].as_ref().expect("linked slot");
            (e.prev, e.next, e.protected)
        };
        if prev == NIL {
            if prot {
                self.protected.head = next;
            } else {
                self.probation.head = next;
            }
        } else {
            self.slab[prev].as_mut().expect("prev slot").next = next;
        }
        if next == NIL {
            if prot {
                self.protected.tail = prev;
            } else {
                self.probation.tail = prev;
            }
        } else {
            self.slab[next].as_mut().expect("next slot").prev = prev;
        }
        let e = self.slab[slot].as_mut().expect("linked slot");
        e.prev = NIL;
        e.next = NIL;
    }

    fn push_front(&mut self, slot: usize, prot: bool) {
        let head = if prot { self.protected.head } else { self.probation.head };
        {
            let e = self.slab[slot].as_mut().expect("pushed slot");
            e.prev = NIL;
            e.next = head;
            e.protected = prot;
        }
        if head != NIL {
            self.slab[head].as_mut().expect("head slot").prev = slot;
        }
        let list = if prot { &mut self.protected } else { &mut self.probation };
        list.head = slot;
        if list.tail == NIL {
            list.tail = slot;
        }
    }

    fn pop_tail(&mut self, prot: bool) -> Option<usize> {
        let tail = if prot { self.protected.tail } else { self.probation.tail };
        if tail == NIL {
            return None;
        }
        self.unlink(tail);
        Some(tail)
    }

    /// Move a hit entry to the protected MRU position, demoting the
    /// protected tail while the segment exceeds its budget.
    fn touch(&mut self, slot: usize, shard_cap: usize) {
        let (was_prot, ebytes) = {
            let e = self.slab[slot].as_ref().expect("touched slot");
            (e.protected, e.bytes)
        };
        self.unlink(slot);
        self.push_front(slot, true);
        if !was_prot {
            self.protected_bytes += ebytes;
        }
        let budget = shard_cap / PROTECTED_DEN * PROTECTED_NUM;
        while self.protected_bytes > budget {
            let Some(t) = self.pop_tail(true) else { break };
            let tb = self.slab[t].as_ref().expect("demoted slot").bytes;
            self.protected_bytes -= tb;
            self.push_front(t, false);
        }
    }

    /// Evict one entry: probation tail first, protected tail only when
    /// probation is empty.  Returns false when the shard is empty.
    fn evict_one(&mut self) -> bool {
        let slot = match self.pop_tail(false) {
            Some(s) => s,
            None => match self.pop_tail(true) {
                Some(s) => s,
                None => return false,
            },
        };
        let e = self.slab[slot].take().expect("evicted slot");
        self.map.remove(&e.hash);
        self.free.push(slot);
        self.bytes -= e.bytes;
        if e.protected {
            self.protected_bytes -= e.bytes;
        }
        self.counters[e.model as usize].evictions += 1;
        true
    }

    fn insert(&mut self, hash: u64, model: u32, bits: Vec<u32>, y: &[f32], shard_cap: usize) {
        let entry_bytes = bits.len() * 4 + y.len() * 4 + ENTRY_OVERHEAD;
        if entry_bytes > shard_cap {
            return; // would evict the whole shard for one entry
        }
        if let Some(&slot) = self.map.get(&hash) {
            let e = self.slab[slot].as_ref().expect("indexed slot");
            if e.model != model || !bits_match(&e.bits, &bits) {
                // Same 64-bit hash, different key: the incumbent wins
                // and the newcomer stays uncached (verification on
                // probe keeps this safe; counting keeps it observable).
                self.counters[model as usize].collisions += 1;
            }
            return;
        }
        while self.bytes + entry_bytes > shard_cap {
            if !self.evict_one() {
                break;
            }
        }
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.slab.push(None);
                self.slab.len() - 1
            }
        };
        self.slab[slot] = Some(Entry {
            hash,
            model,
            bits,
            y: y.to_vec(),
            bytes: entry_bytes,
            protected: false,
            prev: NIL,
            next: NIL,
        });
        self.map.insert(hash, slot);
        self.push_front(slot, false);
        self.bytes += entry_bytes;
        self.counters[model as usize].inserts += 1;
    }
}

fn bits_match(a: &[u32], b: &[u32]) -> bool {
    a == b
}

/// Leader handle for an open flight.  Exactly one of three things
/// happens to it: `publish(Ok(..))` (fans the value and inserts it into
/// the cache), `publish(Err(..))` (fans the typed error), or drop
/// (fans [`SubmitError::Failed`] so followers never wedge).
pub struct FlightToken {
    cache: Arc<ForwardCache>,
    hash: u64,
    shard: usize,
    published: bool,
}

impl FlightToken {
    pub fn publish(mut self, result: FlightResult) {
        self.resolve(result);
    }

    fn resolve(&mut self, result: FlightResult) {
        if self.published {
            return;
        }
        self.published = true;
        let waiters = {
            let mut st = self.cache.shards[self.shard].lock().expect("cache shard lock");
            let Some(flight) = st.flights.remove(&self.hash) else { return };
            let Flight { model, bits, waiters } = flight;
            if let Ok(v) = &result {
                st.insert(self.hash, model, bits, &v.y, self.cache.shard_capacity);
            }
            waiters
        };
        // Fan out after releasing the shard lock: unbounded senders
        // never block, but waiter wakeup should not serialize behind
        // unrelated cache traffic either.  A follower that already gave
        // up (timed out) just drops its receiver; ignore those.
        for w in &waiters {
            let _ = w.send(result.clone());
        }
    }
}

impl Drop for FlightToken {
    fn drop(&mut self) {
        if !self.published {
            self.resolve(Err(SubmitError::Failed(
                "cache leader abandoned the request".to_string(),
            )));
        }
    }
}

/// The sharded content-addressed result cache.  Construct with
/// [`ForwardCache::new`]; probe with [`ForwardCache::lookup`]; the
/// insert path is driven entirely by leaders publishing.
pub struct ForwardCache {
    capacity_bytes: usize,
    shard_capacity: usize,
    models: Vec<String>,
    shards: Vec<Mutex<ShardState>>,
}

impl ForwardCache {
    /// `models[i]` names registry index `i` (counter labels only — keys
    /// use the index, so renames never alias entries).
    pub fn new(capacity_bytes: usize, models: Vec<String>) -> Arc<Self> {
        let n_shards = if capacity_bytes >= SHARD_THRESHOLD_BYTES { N_SHARDS } else { 1 };
        let shard_capacity = (capacity_bytes / n_shards).max(1);
        let shards = (0..n_shards).map(|_| Mutex::new(ShardState::new(models.len()))).collect();
        Arc::new(ForwardCache { capacity_bytes, shard_capacity, models, shards })
    }

    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }

    /// One lock round-trip: verified cache probe, then the singleflight
    /// table.  Exactly one counter (`hits`/`misses`/`coalesced`) is
    /// bumped per call.
    pub fn lookup(self: &Arc<Self>, model: u32, x: &[f32]) -> Lookup {
        let hash = content_hash(model, x);
        let shard = (hash % self.shards.len() as u64) as usize;
        let mut st = self.shards[shard].lock().expect("cache shard lock");
        if let Some(&slot) = st.map.get(&hash) {
            let verified = {
                let e = st.slab[slot].as_ref().expect("indexed slot");
                e.model == model && bits_eq(&e.bits, x)
            };
            if verified {
                st.touch(slot, self.shard_capacity);
                st.counters[model as usize].hits += 1;
                return Lookup::Hit(st.slab[slot].as_ref().expect("indexed slot").y.clone());
            }
            st.counters[model as usize].collisions += 1;
            st.counters[model as usize].misses += 1;
            return Lookup::Solo;
        }
        if let Some(f) = st.flights.get_mut(&hash) {
            if f.model == model && bits_eq(&f.bits, x) {
                let (tx, rx) = mpsc::channel();
                f.waiters.push(tx);
                st.counters[model as usize].coalesced += 1;
                return Lookup::Join(rx);
            }
            st.counters[model as usize].collisions += 1;
            st.counters[model as usize].misses += 1;
            return Lookup::Solo;
        }
        st.flights.insert(
            hash,
            Flight { model, bits: x.iter().map(|v| v.to_bits()).collect(), waiters: Vec::new() },
        );
        st.counters[model as usize].misses += 1;
        Lookup::Lead(FlightToken { cache: Arc::clone(self), hash, shard, published: false })
    }

    pub fn stats(&self) -> CacheStats {
        let mut per = vec![CacheCounters::default(); self.models.len()];
        let (mut bytes, mut entries, mut in_flight) = (0usize, 0usize, 0usize);
        for shard in &self.shards {
            let st = shard.lock().expect("cache shard lock");
            bytes += st.bytes;
            entries += st.map.len();
            in_flight += st.flights.len();
            for (acc, c) in per.iter_mut().zip(&st.counters) {
                acc.merge(c);
            }
        }
        let mut total = CacheCounters::default();
        for c in &per {
            total.merge(c);
        }
        CacheStats {
            capacity_bytes: self.capacity_bytes,
            bytes,
            entries,
            in_flight,
            total,
            per_model: self.models.iter().cloned().zip(per).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(capacity: usize) -> Arc<ForwardCache> {
        ForwardCache::new(capacity, vec!["a".to_string(), "b".to_string()])
    }

    fn value(y: Vec<f32>) -> FlightValue {
        FlightValue { y, batch_size: 1, cause: FlushCause::Full, timing: Timing::default() }
    }

    /// Lead, publish, then hit — the stored rows come back bit-exact,
    /// including payloads ordinary float equality would mangle.
    #[test]
    fn publish_then_hit_is_bit_exact() {
        let c = cache(1 << 16);
        let x = vec![-0.0f32, f32::MIN_POSITIVE, 1.5, f32::NAN];
        let y = vec![f32::NAN, -0.0, 3.25];
        let Lookup::Lead(tok) = c.lookup(0, &x) else { panic!("first probe must lead") };
        tok.publish(Ok(value(y.clone())));
        let Lookup::Hit(got) = c.lookup(0, &x) else { panic!("second probe must hit") };
        assert_eq!(got.len(), y.len());
        assert!(got.iter().zip(&y).all(|(a, b)| a.to_bits() == b.to_bits()));
        // -0.0 and +0.0 are different keys: content addressing is over
        // bits, not float equality.
        let x2 = vec![0.0f32, f32::MIN_POSITIVE, 1.5, f32::NAN];
        assert!(matches!(c.lookup(0, &x2), Lookup::Lead(_)), "sign of zero is part of the key");
        let st = c.stats();
        assert_eq!(st.total.hits, 1);
        assert_eq!(st.total.misses, 2);
        assert_eq!(st.total.inserts, 1);
        assert_eq!(st.total.requests(), 3);
    }

    #[test]
    fn same_bytes_different_model_are_distinct_keys() {
        let c = cache(1 << 16);
        let x = vec![1.0f32, 2.0];
        let Lookup::Lead(t0) = c.lookup(0, &x) else { panic!("lead 0") };
        t0.publish(Ok(value(vec![10.0])));
        assert!(matches!(c.lookup(1, &x), Lookup::Lead(_)), "model index is part of the key");
        let st = c.stats();
        assert_eq!(st.model("a").unwrap().hits, 0);
        assert_eq!(st.model("b").unwrap().misses, 1);
    }

    /// A hash collision (forced via the shard-internal insert) keeps
    /// the incumbent and counts, rather than corrupting either key.
    #[test]
    fn forced_hash_collision_keeps_incumbent() {
        let c = cache(1 << 16);
        {
            let mut st = c.shards[0].lock().unwrap();
            st.insert(42, 0, vec![1u32], &[1.0], c.shard_capacity);
            st.insert(42, 0, vec![2u32], &[2.0], c.shard_capacity);
            assert_eq!(st.counters[0].inserts, 1);
            assert_eq!(st.counters[0].collisions, 1);
            let slot = st.map[&42];
            assert_eq!(st.slab[slot].as_ref().unwrap().y, vec![1.0]);
        }
        assert_eq!(c.stats().entries, 1);
    }

    /// Inserting past the byte budget evicts from the probation tail
    /// (oldest un-hit entry first), and occupancy never exceeds the
    /// budget.
    #[test]
    fn eviction_is_lru_and_respects_budget() {
        // Each entry: 4 key bytes + 4 payload bytes + overhead = 104;
        // capacity fits exactly 3 (single shard below the threshold).
        let c = cache(312);
        for i in 0..5 {
            let x = [i as f32];
            let Lookup::Lead(tok) = c.lookup(0, &x) else { panic!("lead {i}") };
            tok.publish(Ok(value(vec![i as f32 * 10.0])));
        }
        let st = c.stats();
        assert_eq!(st.total.inserts, 5);
        assert_eq!(st.total.evictions, 2);
        assert_eq!(st.entries, 3);
        assert!(st.bytes <= 312, "occupancy {} over budget", st.bytes);
        // Oldest two are gone, newest three still hit.  (The probe's
        // Lead token is a temporary; its drop-guard closes the flight.)
        assert!(matches!(c.lookup(0, &[0.0f32]), Lookup::Lead(_)));
        for i in 2..5 {
            assert!(matches!(c.lookup(0, &[i as f32]), Lookup::Hit(_)), "entry {i} evicted early");
        }
    }

    /// A hit entry is promoted to the protected segment and survives a
    /// scan of cold insertions that evicts everything around it.
    #[test]
    fn promoted_entry_survives_a_cold_scan() {
        let c = cache(312); // 3 entries
        let hot = [123.0f32];
        let Lookup::Lead(tok) = c.lookup(0, &hot) else { panic!("lead hot") };
        tok.publish(Ok(value(vec![1.0])));
        assert!(matches!(c.lookup(0, &hot), Lookup::Hit(_)), "promote to protected");
        for i in 0..6 {
            let x = [1000.0 + i as f32];
            let Lookup::Lead(t) = c.lookup(0, &x) else { panic!("lead scan {i}") };
            t.publish(Ok(value(vec![0.0])));
        }
        assert!(matches!(c.lookup(0, &hot), Lookup::Hit(_)), "hot entry scanned out");
    }

    #[test]
    fn oversized_entry_is_never_inserted() {
        let c = cache(256);
        let x: Vec<f32> = (0..128).map(|i| i as f32).collect(); // 512 key bytes alone
        let Lookup::Lead(tok) = c.lookup(0, &x) else { panic!("lead") };
        tok.publish(Ok(value(vec![0.0; 128])));
        let st = c.stats();
        assert_eq!(st.total.inserts, 0);
        assert_eq!(st.bytes, 0);
        assert!(matches!(c.lookup(0, &x), Lookup::Lead(_)), "oversized entry must not cache");
    }

    #[test]
    fn followers_receive_the_leader_value() {
        let c = cache(1 << 16);
        let x = vec![7.0f32, 8.0];
        let Lookup::Lead(tok) = c.lookup(0, &x) else { panic!("lead") };
        let Lookup::Join(rx1) = c.lookup(0, &x) else { panic!("join 1") };
        let Lookup::Join(rx2) = c.lookup(0, &x) else { panic!("join 2") };
        tok.publish(Ok(FlightValue {
            y: vec![9.0, 10.0],
            batch_size: 3,
            cause: FlushCause::Deadline,
            timing: Timing::default(),
        }));
        for rx in [rx1, rx2] {
            let v = rx.recv().unwrap().unwrap();
            assert_eq!(v.y, vec![9.0, 10.0]);
            assert_eq!(v.batch_size, 3);
            assert_eq!(v.cause, FlushCause::Deadline);
        }
        let st = c.stats();
        assert_eq!(st.total.coalesced, 2);
        assert_eq!(st.total.misses, 1);
        assert_eq!(st.total.hits, 0);
        assert_eq!(st.in_flight, 0, "flight closed on publish");
        // The published value is now cached for later arrivals.
        assert!(matches!(c.lookup(0, &x), Lookup::Hit(_)));
    }

    #[test]
    fn leader_error_fans_to_all_followers_and_caches_nothing() {
        let c = cache(1 << 16);
        let x = vec![3.0f32];
        let Lookup::Lead(tok) = c.lookup(0, &x) else { panic!("lead") };
        let Lookup::Join(rx) = c.lookup(0, &x) else { panic!("join") };
        tok.publish(Err(SubmitError::Failed("boom".to_string())));
        assert_eq!(rx.recv().unwrap(), Err(SubmitError::Failed("boom".to_string())));
        assert_eq!(c.stats().entries, 0, "errors are not cached");
        assert!(matches!(c.lookup(0, &x), Lookup::Lead(_)), "flight closed, next arrival leads");
    }

    /// Dropping the token without publishing (leader panicked or bailed
    /// early) must still unpark every follower with a typed error.
    #[test]
    fn abandoned_leader_unwedges_followers() {
        let c = cache(1 << 16);
        let x = vec![4.0f32];
        let tok = match c.lookup(0, &x) {
            Lookup::Lead(t) => t,
            _ => panic!("lead"),
        };
        let Lookup::Join(rx) = c.lookup(0, &x) else { panic!("join") };
        drop(tok);
        match rx.recv().unwrap() {
            Err(SubmitError::Failed(msg)) => assert!(msg.contains("abandoned"), "{msg}"),
            other => panic!("expected abandoned-leader failure, got {other:?}"),
        }
        assert_eq!(c.stats().in_flight, 0);
    }

    /// The counter invariant the e2e suite leans on: every cache-path
    /// probe bumps exactly one of hits/misses/coalesced.
    #[test]
    fn probes_partition_into_hits_misses_coalesced() {
        let c = cache(1 << 16);
        let mut probes = 0u64;
        for round in 0..4u32 {
            for key in 0..8u32 {
                let x = [key as f32];
                probes += 1;
                match c.lookup(key % 2, &x) {
                    Lookup::Hit(_) => {}
                    Lookup::Lead(tok) => tok.publish(Ok(value(vec![round as f32]))),
                    Lookup::Join(_) | Lookup::Solo => panic!("serial probes never coalesce"),
                }
            }
        }
        let st = c.stats();
        assert_eq!(st.total.requests(), probes);
        assert_eq!(st.total.hits + st.total.misses + st.total.coalesced, probes);
        let per: u64 = st.per_model.iter().map(|(_, c)| c.requests()).sum();
        assert_eq!(per, probes, "per-model counters sum to the global view");
    }
}
