//! Deterministic batch-coalescing core.
//!
//! This is the admission queue's brain, kept free of threads and wall
//! clocks so its behavior is a pure function of the call sequence: time
//! enters only as explicit microsecond arguments, buckets live in a
//! `BTreeMap` (stable iteration order), and ties break by enqueue order.
//! The threaded [`super::server`] drives it with real timestamps; tests
//! drive it with a virtual clock and get bit-reproducible coalescing.
//!
//! Policy (FlashKAT's tile lesson applied at the request level): requests
//! wait in per-shape buckets so one kernel dispatch can amortize
//! coefficient loads and worker-pool wakeups across many requests, but a
//! bucket is released as soon as it is *full* or its oldest request hits
//! the *deadline*, so p99 latency stays bounded.  With an `eager` policy
//! a partial bucket is also released the moment the executor goes idle
//! (adaptive batching: batch size then tracks the instantaneous load
//! instead of stalling a lone request for the whole deadline).

use std::collections::BTreeMap;

/// Coalescing key: requests are concatenated along the row axis, so
/// everything *except* the row count must match.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct ShapeKey {
    /// Index into the server's executor registry.  Executors differ in
    /// `d_in`/`d_out`, so the index alone already separates incompatible
    /// payloads.
    pub model: u32,
    /// Per-row input width (duplicates the executor's `d_in`; keeps the
    /// key self-describing in logs and lets one executor serve several
    /// widths later without changing this type).
    pub d: u32,
}

/// Flush policy for the admission queue.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Release a bucket once it holds this many requests.
    pub max_batch: usize,
    /// Release a bucket once its oldest request has waited this long (µs).
    pub deadline_us: u64,
    /// Total admitted-but-unserved requests across all buckets; `admit`
    /// refuses above this (backpressure).
    pub queue_depth: usize,
    /// Release a partial bucket as soon as the executor reports idle
    /// instead of holding it until the deadline.
    pub eager: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 64, deadline_us: 200, queue_depth: 1024, eager: true }
    }
}

/// Queue-side record of one admitted request.  Deliberately carries no
/// payload metadata (row counts etc.): the server keys payloads by `id`,
/// keeping a single source of truth.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Ticket {
    /// Admission sequence number, unique per [`Batcher`].
    pub id: u64,
    /// Enqueue time (µs on the caller's clock).
    pub enq_us: u64,
}

/// Why a batch was released.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushCause {
    /// The bucket reached `max_batch`.
    Full,
    /// The oldest request hit `deadline_us`.
    Deadline,
    /// Eager release to an idle executor.
    Idle,
    /// Shutdown drain.
    Drain,
    /// Served from the content-addressed result cache ([`super::cache`])
    /// — no batch was formed at all.  Never produced by the batcher
    /// itself; carried by [`super::Response`] so clients and the wire
    /// protocol can distinguish cached replies.
    Cache,
}

impl FlushCause {
    pub const ALL: [FlushCause; 5] = [
        FlushCause::Full,
        FlushCause::Deadline,
        FlushCause::Idle,
        FlushCause::Drain,
        FlushCause::Cache,
    ];

    pub fn index(self) -> usize {
        match self {
            FlushCause::Full => 0,
            FlushCause::Deadline => 1,
            FlushCause::Idle => 2,
            FlushCause::Drain => 3,
            FlushCause::Cache => 4,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            FlushCause::Full => "full",
            FlushCause::Deadline => "deadline",
            FlushCause::Idle => "idle",
            FlushCause::Drain => "drain",
            FlushCause::Cache => "cache",
        }
    }
}

/// A released batch: tickets in admission order, all sharing `key`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Batch {
    pub key: ShapeKey,
    pub tickets: Vec<Ticket>,
    pub cause: FlushCause,
    /// Release time (µs, the `now_us` handed to `pop`/`drain`).  The
    /// span timeline splits each request's wait here: `enq_us →
    /// released_us` is queue wait, `released_us →` executor call is
    /// batch formation.
    pub released_us: u64,
}

/// Shape-keyed admission queue (see module docs).
pub struct Batcher {
    policy: BatchPolicy,
    buckets: BTreeMap<ShapeKey, Vec<Ticket>>,
    queued: usize,
    next_id: u64,
}

impl Batcher {
    pub fn new(mut policy: BatchPolicy) -> Self {
        // Degenerate limits would make `release` spin or `admit` refuse
        // everything; clamp rather than propagate a config foot-gun.
        policy.max_batch = policy.max_batch.max(1);
        policy.queue_depth = policy.queue_depth.max(1);
        Self { policy, buckets: BTreeMap::new(), queued: 0, next_id: 0 }
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// Admitted-but-unserved request count.
    pub fn queued(&self) -> usize {
        self.queued
    }

    /// Admit a request, or refuse it (`None`) when the queue is at depth —
    /// the caller decides whether to block, retry, or shed load.
    pub fn admit(&mut self, key: ShapeKey, now_us: u64) -> Option<Ticket> {
        if self.queued >= self.policy.queue_depth {
            return None;
        }
        let t = Ticket { id: self.next_id, enq_us: now_us };
        self.next_id += 1;
        self.buckets.entry(key).or_default().push(t);
        self.queued += 1;
        Some(t)
    }

    /// Earliest instant at which some bucket becomes deadline-due, for
    /// the executor's sleep.  `None` when the queue is empty.
    pub fn next_deadline_us(&self) -> Option<u64> {
        self.buckets
            .values()
            .filter_map(|b| b.first())
            .map(|t| t.enq_us.saturating_add(self.policy.deadline_us))
            .min()
    }

    /// Release the next due batch, if any.  Precedence (all deterministic):
    /// the bucket with the oldest *expired* deadline, then full buckets in
    /// key order, then — if `idle` and the policy is eager — the bucket
    /// with the oldest request overall.
    ///
    /// Deadline outranks Full on purpose: under closed-loop load a hot
    /// bucket refills to `max_batch` between every executor poll, so a
    /// Full-first rule would let it monopolize the (single) executor and
    /// starve a cold bucket's lone request arbitrarily far past its
    /// deadline — the exact tail-latency bound the deadline exists to
    /// enforce.  An expired bucket that is also full still releases (as
    /// `Deadline`, capped at `max_batch` tickets).
    pub fn pop(&mut self, now_us: u64, idle: bool) -> Option<Batch> {
        let oldest = self
            .buckets
            .iter()
            .filter_map(|(k, b)| b.first().map(|t| (t.enq_us, *k)))
            .min();
        if let Some((enq_us, key)) = oldest {
            if now_us >= enq_us.saturating_add(self.policy.deadline_us) {
                return Some(self.release(key, FlushCause::Deadline, now_us));
            }
        }
        let full = self
            .buckets
            .iter()
            .find(|(_, b)| b.len() >= self.policy.max_batch)
            .map(|(k, _)| *k);
        if let Some(key) = full {
            return Some(self.release(key, FlushCause::Full, now_us));
        }
        if let Some((_, key)) = oldest {
            if idle && self.policy.eager {
                return Some(self.release(key, FlushCause::Idle, now_us));
            }
        }
        None
    }

    /// Unconditionally release every pending request (shutdown path);
    /// batches still respect `max_batch`.  `now_us` stamps each batch's
    /// `released_us` so drained requests keep an honest queue-wait.
    pub fn drain(&mut self, now_us: u64) -> Vec<Batch> {
        let mut out = Vec::new();
        let keys: Vec<ShapeKey> = self.buckets.keys().copied().collect();
        for key in keys {
            while self.buckets.get(&key).is_some_and(|b| !b.is_empty()) {
                out.push(self.release(key, FlushCause::Drain, now_us));
            }
        }
        out
    }

    fn release(&mut self, key: ShapeKey, cause: FlushCause, now_us: u64) -> Batch {
        let bucket = self.buckets.get_mut(&key).expect("releasing a known bucket");
        let take = bucket.len().min(self.policy.max_batch);
        let tickets: Vec<Ticket> = bucket.drain(..take).collect();
        self.queued -= tickets.len();
        Batch { key, tickets, cause, released_us: now_us }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn key(model: u32, d: u32) -> ShapeKey {
        ShapeKey { model, d }
    }

    fn policy(max_batch: usize, deadline_us: u64, queue_depth: usize, eager: bool) -> BatchPolicy {
        BatchPolicy { max_batch, deadline_us, queue_depth, eager }
    }

    #[test]
    fn full_bucket_flushes_in_admission_order() {
        let mut b = Batcher::new(policy(4, 1_000, 64, false));
        for i in 0..4 {
            assert!(b.admit(key(0, 8), i).is_some());
        }
        let batch = b.pop(0, false).expect("full bucket");
        assert_eq!(batch.cause, FlushCause::Full);
        assert_eq!(batch.tickets.len(), 4);
        let ids: Vec<u64> = batch.tickets.iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert_eq!(b.queued(), 0);
        assert!(b.pop(0, false).is_none());
    }

    #[test]
    fn deadline_bounds_wait_exactly() {
        let mut b = Batcher::new(policy(64, 200, 64, false));
        b.admit(key(0, 8), 50).unwrap();
        assert_eq!(b.next_deadline_us(), Some(250));
        // One microsecond early: nothing is due, even to an idle executor
        // (non-eager policy holds partial buckets for the full deadline).
        assert!(b.pop(249, true).is_none());
        let batch = b.pop(250, false).expect("deadline flush");
        assert_eq!(batch.cause, FlushCause::Deadline);
        assert_eq!(batch.tickets.len(), 1);
    }

    #[test]
    fn eager_policy_releases_partial_bucket_to_idle_executor() {
        let mut b = Batcher::new(policy(64, 1_000_000, 64, true));
        b.admit(key(0, 8), 0).unwrap();
        // Busy executor: not due yet.
        assert!(b.pop(0, false).is_none());
        let batch = b.pop(0, true).expect("idle flush");
        assert_eq!(batch.cause, FlushCause::Idle);
        assert_eq!(batch.tickets.len(), 1);
    }

    #[test]
    fn backpressure_refuses_above_depth_then_recovers() {
        let mut b = Batcher::new(policy(64, 1_000, 4, true));
        for _ in 0..4 {
            assert!(b.admit(key(0, 8), 0).is_some());
        }
        assert!(b.admit(key(0, 8), 0).is_none(), "5th admit must be refused");
        assert!(b.admit(key(1, 16), 0).is_none(), "depth is global across buckets");
        let batch = b.pop(0, true).unwrap();
        assert_eq!(batch.tickets.len(), 4);
        assert!(b.admit(key(0, 8), 1).is_some(), "space frees after release");
    }

    #[test]
    fn shape_keys_do_not_mix() {
        let mut b = Batcher::new(policy(2, 1_000, 64, false));
        b.admit(key(0, 8), 0).unwrap();
        b.admit(key(1, 16), 0).unwrap();
        b.admit(key(0, 8), 0).unwrap();
        b.admit(key(1, 16), 0).unwrap();
        let first = b.pop(0, false).unwrap();
        assert_eq!(first.key, key(0, 8));
        assert!(first.tickets.iter().all(|t| t.id % 2 == 0));
        let second = b.pop(0, false).unwrap();
        assert_eq!(second.key, key(1, 16));
        assert!(second.tickets.iter().all(|t| t.id % 2 == 1));
    }

    #[test]
    fn oldest_expired_deadline_wins() {
        let mut b = Batcher::new(policy(64, 100, 64, false));
        b.admit(key(1, 16), 10).unwrap();
        b.admit(key(0, 8), 40).unwrap();
        // Both expired at t=200; the older enqueue (key 1) must go first
        // even though key 0 sorts earlier.
        let batch = b.pop(200, false).unwrap();
        assert_eq!(batch.key, key(1, 16));
        assert_eq!(b.pop(200, false).unwrap().key, key(0, 8));
    }

    #[test]
    fn drain_releases_everything_in_max_batch_chunks() {
        let mut b = Batcher::new(policy(4, 1_000_000, 64, false));
        for i in 0..10 {
            b.admit(key(i % 2, 8), 0).unwrap();
        }
        let batches = b.drain(77);
        assert!(batches.iter().all(|x| x.cause == FlushCause::Drain));
        assert!(batches.iter().all(|x| x.released_us == 77));
        assert!(batches.iter().all(|x| x.tickets.len() <= 4));
        let total: usize = batches.iter().map(|x| x.tickets.len()).sum();
        assert_eq!(total, 10);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn degenerate_policy_is_clamped() {
        let mut b = Batcher::new(policy(0, 0, 0, true));
        assert!(b.admit(key(0, 8), 0).is_some(), "depth 0 clamps to 1");
        // deadline_us = 0 means the ticket is expired on arrival, so the
        // deadline-first precedence releases it immediately (max_batch 0
        // clamps to 1, so a Full release would also be legal here).
        let batch = b.pop(0, false).expect("deadline 0 => due immediately");
        assert_eq!(batch.cause, FlushCause::Deadline);
        assert_eq!(batch.tickets.len(), 1);
    }

    /// A deadline-expired bucket preempts a full one: with a single pop
    /// per executor poll (the live server's pattern), Full-first would
    /// let a continuously-refilling hot bucket starve a cold request
    /// indefinitely.
    #[test]
    fn expired_deadline_preempts_full_bucket() {
        let mut b = Batcher::new(policy(2, 100, 64, false));
        b.admit(key(1, 16), 0).unwrap(); // cold, due at t=100
        b.admit(key(0, 8), 150).unwrap(); // hot bucket at max_batch
        b.admit(key(0, 8), 150).unwrap();
        let first = b.pop(150, false).expect("something due");
        assert_eq!(first.key, key(1, 16), "expired cold bucket goes first");
        assert_eq!(first.cause, FlushCause::Deadline);
        let second = b.pop(150, false).expect("hot full bucket next");
        assert_eq!(second.key, key(0, 8));
        assert_eq!(second.cause, FlushCause::Full);
    }

    /// A hot key flushing continuously via Full must not starve a cold
    /// key past its deadline: the cold request is released the first time
    /// the executor polls at/after `enq + deadline_us`.
    #[test]
    fn cold_key_is_not_starved_by_a_hot_key() {
        let mut b = Batcher::new(policy(4, 100, 256, false));
        b.admit(key(1, 8), 0).unwrap(); // the cold request
        let mut released_cold = None;
        for now in 0..=120u64 {
            // Hot key 0 stays permanently full: admit 4 every tick.
            for _ in 0..4 {
                b.admit(key(0, 8), now).unwrap();
            }
            // Busy executor (idle=false): only Full and Deadline release.
            while let Some(batch) = b.pop(now, false) {
                if batch.key == key(1, 8) {
                    assert_eq!(batch.cause, FlushCause::Deadline);
                    released_cold = Some(now);
                }
            }
            if released_cold.is_some() {
                break;
            }
        }
        assert_eq!(released_cold, Some(100), "cold key must flush exactly at its deadline");
    }

    /// Interleaved admissions across several keys preserve per-bucket
    /// FIFO: within each key, released ids appear in admission order.
    #[test]
    fn interleaved_multikey_admissions_keep_per_bucket_fifo() {
        let mut b = Batcher::new(policy(3, 1_000, 256, true));
        let mut admitted: Vec<Vec<u64>> = vec![Vec::new(); 3];
        let mut released: Vec<Vec<u64>> = vec![Vec::new(); 3];
        let mut rng = Pcg64::new(17);
        for step in 0..200u64 {
            let k = rng.below(3) as u32;
            let t = b.admit(key(k, 8 * (k + 1)), step).unwrap();
            admitted[k as usize].push(t.id);
            if let Some(batch) = b.pop(step, step % 4 == 0) {
                released[batch.key.model as usize]
                    .extend(batch.tickets.iter().map(|t| t.id));
            }
        }
        for batch in b.drain(200) {
            released[batch.key.model as usize].extend(batch.tickets.iter().map(|t| t.id));
        }
        for k in 0..3 {
            assert_eq!(released[k], admitted[k], "key {k} must release in admission order");
        }
    }

    /// Every released batch stamps the virtual `now` it was popped at,
    /// and no ticket is ever released before it was enqueued — the
    /// batcher half of the span-nesting invariant (admit ≤ release).
    #[test]
    fn released_us_is_pop_time_and_bounds_enqueue() {
        let mut b = Batcher::new(policy(3, 50, 64, true));
        let mut rng = Pcg64::new(5);
        let mut now = 0u64;
        for step in 0..300u64 {
            now += rng.below(30) as u64;
            let _ = b.admit(key(rng.below(2) as u32, 8), now);
            if let Some(batch) = b.pop(now, step % 2 == 0) {
                assert_eq!(batch.released_us, now);
                for t in &batch.tickets {
                    assert!(t.enq_us <= batch.released_us, "ticket released before enqueue");
                }
            }
        }
    }

    /// Fixed seed → identical coalescing, independent of anything but the
    /// call sequence.  Guards the no-wall-clock / no-HashMap invariant.
    #[test]
    fn coalescing_is_deterministic_for_a_seeded_schedule() {
        let run = || {
            let mut rng = Pcg64::new(99);
            let mut b = Batcher::new(policy(8, 50, 32, true));
            let mut now = 0u64;
            let mut trace: Vec<(ShapeKey, Vec<u64>, FlushCause)> = Vec::new();
            for step in 0..500 {
                now += rng.below(40) as u64;
                let k = key(rng.below(2) as u32, 8);
                let _ = b.admit(k, now);
                // Executor polls with a data-dependent idle pattern.
                if let Some(batch) = b.pop(now, step % 3 == 0) {
                    trace.push((
                        batch.key,
                        batch.tickets.iter().map(|t| t.id).collect(),
                        batch.cause,
                    ));
                }
            }
            for batch in b.drain(now) {
                trace.push((batch.key, batch.tickets.iter().map(|t| t.id).collect(), batch.cause));
            }
            trace
        };
        let a = run();
        let c = run();
        assert_eq!(a, c);
        assert!(!a.is_empty());
        // Every admitted ticket appears exactly once, in per-bucket order.
        let mut ids: Vec<u64> = a.iter().flat_map(|(_, ids, _)| ids.iter().copied()).collect();
        ids.sort_unstable();
        ids.dedup();
        let admitted: usize = a.iter().map(|(_, ids, _)| ids.len()).sum();
        assert_eq!(ids.len(), admitted, "no ticket served twice");
    }
}
