//! Deterministic load generator + latency/throughput report.
//!
//! Every random choice — request row counts, input values, model
//! routing, open-loop arrival offsets — derives from `util::rng::Pcg64`
//! streams keyed by the request id, so the workload is byte-identical
//! across runs and across submitter-thread interleavings; only the
//! *timing* varies with the machine.  Workloads target a registry of
//! named models (round-robin across `LoadConfig::models`), so one run
//! exercises the server's multi-model routing path.  The report side
//! reuses `util::stats`: interpolated p50/p95/p99 latency, requests
//! ("images") per second, and per-model executor counters.
//!
//! [`autotune`] layers a policy search on top: sweep a small
//! `(max_batch, deadline_us)` grid, keep every run's record, and pick
//! the throughput-optimal policy whose p99 meets the SLO.
//!
//! [`run_http`] replays the identical workload over loopback HTTP
//! (sharded server behind `net::HttpServer`, one keep-alive client per
//! submitter thread); [`http_bench_json`] pairs it with the in-process
//! record in `BENCH_http.json` so the frontend's overhead is a measured
//! number, not a hope.  [`run_wire`] does the same over the flashwire
//! binary protocol, and [`wire_bench_json`] assembles the three-way
//! in-process / HTTP-JSON / flashwire record (`BENCH_wire.json`),
//! including the deterministic bytes-per-request accounting from
//! [`transport_bytes`].

use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::batcher::{BatchPolicy, FlushCause};
use super::cache::CacheStats;
use super::executor::{ExecStats, ModelExecutor, RationalExecutor, ServeStats};
use super::server::Server;
use crate::rational::Coeffs;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::stats::percentile;

/// Arrival process for the generated request stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrival {
    /// Each of `concurrency` clients submits its next request as soon as
    /// the previous one completes (throughput-oriented).
    Closed,
    /// Poisson arrivals at `rate_rps`, pre-scheduled and split across
    /// the submitter threads; a slow response delays only that thread's
    /// own later arrivals (bounded open loop).
    Open { rate_rps: f64 },
}

/// One rational model to register and drive traffic at.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub d: usize,
    pub n_groups: usize,
}

impl ModelSpec {
    pub fn new(name: impl Into<String>, d: usize, n_groups: usize) -> Self {
        Self { name: name.into(), d, n_groups }
    }
}

#[derive(Clone, Debug)]
pub struct LoadConfig {
    pub requests: usize,
    pub concurrency: usize,
    /// Rows per request are drawn uniformly from `rows_min..=rows_max`.
    pub rows_min: u32,
    pub rows_max: u32,
    pub seed: u64,
    pub arrival: Arrival,
    /// Fraction of requests that *duplicate* an earlier request —
    /// replaying its exact payload bytes, model, and row count (see
    /// [`source_id`]).  `0.0` (the default) keeps every request
    /// distinct, so historical workloads are byte-identical to before
    /// the knob existed.  Duplicate-heavy streams feed the
    /// content-addressed cache (`serve-bench --cache-bytes`) and stress
    /// the batcher with repeated shape keys.
    pub dup_frac: f64,
    /// Registry to serve; request `id` targets model `id % models.len()`.
    pub models: Vec<ModelSpec>,
}

impl Default for LoadConfig {
    fn default() -> Self {
        Self {
            requests: 2000,
            concurrency: 16,
            rows_min: 1,
            rows_max: 4,
            seed: 7,
            arrival: Arrival::Closed,
            dup_frac: 0.0,
            models: vec![ModelSpec::new("grkan", 256, 8)],
        }
    }
}

/// Registry index targeted by request `id` (round-robin over the specs).
/// Panics with a clear message on an empty registry — `run`/`run_with`
/// reject that configuration up front.
pub fn model_for(cfg: &LoadConfig, id: u64) -> usize {
    assert!(!cfg.models.is_empty(), "load config has no model specs");
    (id % cfg.models.len() as u64) as usize
}

/// Stream salt for the duplication coin flips: the coins must come from
/// a stream *disjoint* from the payload streams, or turning `dup_frac`
/// on would perturb the bytes of the non-duplicate requests too.
const DUP_STREAM_SALT: u64 = 0xd00d_f00d;

/// The request id whose payload request `id` actually carries.
///
/// With `dup_frac = 0` this is `id` itself — every request distinct.
/// With `dup_frac = F`, each id flips a seeded coin: with probability
/// `F` it becomes a duplicate of a uniformly chosen earlier id, which
/// may itself chain to an even earlier one (the chain strictly
/// decreases, so it terminates, and repeated redirection skews the
/// duplicate mass toward early "popular" ids — the shape a
/// content-addressed cache feeds on).  Pure in `(seed, id)` and
/// idempotent (`source_id(source_id(id)) == source_id(id)`): a resolved
/// source never redirects again, so the originals' payloads are
/// byte-identical to the `dup_frac = 0` stream.
pub fn source_id(cfg: &LoadConfig, mut id: u64) -> u64 {
    if cfg.dup_frac <= 0.0 {
        return id;
    }
    loop {
        if id == 0 {
            return 0;
        }
        let mut rng = Pcg64::with_stream(cfg.seed ^ DUP_STREAM_SALT, id);
        if !rng.bernoulli(cfg.dup_frac) {
            return id;
        }
        id = rng.below(id as usize) as u64;
    }
}

/// Target model, row count, and input payload for request `id` — a pure
/// function of `(seed, id)`, independent of which thread materializes
/// it.  Under `dup_frac > 0` the id first resolves through
/// [`source_id`], so duplicates reproduce their source's model routing
/// and exact payload bytes (a different model would mean a different
/// row width — duplicates must be byte-for-byte replays to hit the
/// content-addressed cache).
pub fn request(cfg: &LoadConfig, id: u64) -> (usize, u32, Vec<f32>) {
    let sid = source_id(cfg, id);
    let m = model_for(cfg, sid);
    let d = cfg.models[m].d;
    let mut rng = Pcg64::with_stream(cfg.seed, sid);
    let span = cfg.rows_max.max(cfg.rows_min) - cfg.rows_min;
    let rows = cfg.rows_min + rng.below(span as usize + 1) as u32;
    let x = (0..rows as usize * d).map(|_| rng.normal_f32()).collect();
    (m, rows, x)
}

/// Build the registry described by `cfg.models`: one seeded
/// [`RationalExecutor`] per spec (coefficients from per-spec streams of
/// `cfg.seed`, so each model's table is distinct but reproducible).
pub fn executors(cfg: &LoadConfig) -> Result<Vec<Box<dyn ModelExecutor>>> {
    cfg.models
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            if spec.n_groups == 0 {
                bail!("model {:?}: n_groups must be positive", spec.name);
            }
            let mut rng = Pcg64::with_stream(cfg.seed, 0xc0ef_f000 + i as u64);
            let coeffs = Coeffs::<f32>::randn(spec.n_groups, 6, 4, &mut rng);
            let ex = RationalExecutor::new(spec.name.as_str(), spec.d, coeffs)
                .with_context(|| format!("model {:?}", spec.name))?;
            Ok(Box::new(ex) as Box<dyn ModelExecutor>)
        })
        .collect()
}

/// Cumulative Poisson arrival offsets (µs) for the open-loop schedule.
pub fn open_schedule(requests: usize, rate_rps: f64, seed: u64) -> Vec<u64> {
    let mut rng = Pcg64::with_stream(seed, 0x5eed_a11);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(requests);
    for _ in 0..requests {
        // Exponential interarrival; clamp the log argument away from 0.
        let u = rng.uniform().max(1e-12);
        t += -u.ln() / rate_rps.max(1e-9);
        out.push((t * 1e6) as u64);
    }
    out
}

/// Per-model slice of a bench run: the executor's counters plus the
/// client-side latency view for the requests routed to this model.
#[derive(Clone, Debug)]
pub struct ModelBench {
    pub name: String,
    pub d_in: usize,
    pub d_out: usize,
    pub exec: ExecStats,
    /// Successfully served requests (client side).
    pub served: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// Outcome of one load run against one server policy.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub label: String,
    pub requests: usize,
    pub concurrency: usize,
    pub max_batch: usize,
    pub deadline_us: u64,
    pub wall_secs: f64,
    /// Requests ("images") per second over the whole run.
    pub throughput_rps: f64,
    pub rows_per_sec: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    pub errors: usize,
    /// Shed-load (429 / QueueFull) retries the transport clients
    /// performed — backpressure that was absorbed by backoff, distinct
    /// from `errors` (requests that ultimately failed).  Always 0 for
    /// the in-process transport, which blocks at admission instead.
    pub retries: usize,
    /// Router-side failover events during this leg (shed-class typed
    /// errors plus transport failures that moved a request to another
    /// backend) — read from the route tier's counters, 0 for every
    /// direct transport.
    pub failovers: usize,
    /// Server-wide executor totals.
    pub exec: ExecStats,
    pub peak_queued: usize,
    /// Registry-order split of the totals.
    pub per_model: Vec<ModelBench>,
}

fn exec_json(exec: &ExecStats) -> Vec<(String, Json)> {
    let hist: Vec<Json> = exec.batch_hist.iter().map(|&n| Json::Int(n as i64)).collect();
    let causes: Vec<(String, Json)> = FlushCause::ALL
        .iter()
        .map(|c| (c.label().to_string(), Json::Int(exec.causes[c.index()] as i64)))
        .collect();
    vec![
        ("batches".to_string(), Json::Int(exec.batches as i64)),
        ("exec_requests".to_string(), Json::Int(exec.requests as i64)),
        ("rows".to_string(), Json::Int(exec.rows as i64)),
        ("failed".to_string(), Json::Int(exec.failed as i64)),
        ("mean_batch".to_string(), Json::Num(exec.mean_batch())),
        ("exec_busy_secs".to_string(), Json::Num(exec.busy_secs)),
        // Server-side phase percentiles (µs, log-histogram resolution;
        // `null` when no requests were recorded).  Client latency above
        // covers the whole round trip — these split out where inside
        // the server the time went.
        ("queue_wait_p50_us".to_string(), Json::Num(exec.queue_wait.percentile(50.0))),
        ("queue_wait_p99_us".to_string(), Json::Num(exec.queue_wait.percentile(99.0))),
        ("exec_p50_us".to_string(), Json::Num(exec.exec.percentile(50.0))),
        ("exec_p99_us".to_string(), Json::Num(exec.exec.percentile(99.0))),
        ("batch_hist".to_string(), Json::Arr(hist)),
        ("flush_causes".to_string(), Json::Obj(causes)),
    ]
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        let models: Vec<Json> = self
            .per_model
            .iter()
            .map(|m| {
                let mut fields = vec![
                    ("name".to_string(), Json::Str(m.name.clone())),
                    ("d_in".to_string(), Json::Int(m.d_in as i64)),
                    ("d_out".to_string(), Json::Int(m.d_out as i64)),
                    ("served".to_string(), Json::Int(m.served as i64)),
                    ("p50_ms".to_string(), Json::Num(m.p50_ms)),
                    ("p99_ms".to_string(), Json::Num(m.p99_ms)),
                ];
                fields.extend(exec_json(&m.exec));
                Json::Obj(fields)
            })
            .collect();
        let mut fields = vec![
            ("label".to_string(), Json::Str(self.label.clone())),
            ("requests".to_string(), Json::Int(self.requests as i64)),
            ("concurrency".to_string(), Json::Int(self.concurrency as i64)),
            ("max_batch".to_string(), Json::Int(self.max_batch as i64)),
            ("deadline_us".to_string(), Json::Int(self.deadline_us as i64)),
            ("wall_secs".to_string(), Json::Num(self.wall_secs)),
            ("throughput_rps".to_string(), Json::Num(self.throughput_rps)),
            ("rows_per_sec".to_string(), Json::Num(self.rows_per_sec)),
            ("mean_ms".to_string(), Json::Num(self.mean_ms)),
            ("p50_ms".to_string(), Json::Num(self.p50_ms)),
            ("p95_ms".to_string(), Json::Num(self.p95_ms)),
            ("p99_ms".to_string(), Json::Num(self.p99_ms)),
            ("max_ms".to_string(), Json::Num(self.max_ms)),
            ("errors".to_string(), Json::Int(self.errors as i64)),
            ("shed_retries".to_string(), Json::Int(self.retries as i64)),
            ("failovers".to_string(), Json::Int(self.failovers as i64)),
            ("peak_queued".to_string(), Json::Int(self.peak_queued as i64)),
        ];
        fields.extend(exec_json(&self.exec));
        fields.push(("models".to_string(), Json::Arr(models)));
        Json::Obj(fields)
    }
}

/// Run the workload against a fresh server built from `cfg.models`.
pub fn run(cfg: &LoadConfig, policy: BatchPolicy, label: &str) -> Result<BenchResult> {
    run_with(cfg, executors(cfg)?, policy, label)
}

/// [`run`] on a server sharded across `shards` executor threads — the
/// apples-to-apples in-process baseline for [`run_http`] (comparing a
/// 1-shard in-process run against an N-shard HTTP run would conflate
/// sharding speedup with transport overhead).
pub fn run_sharded(
    cfg: &LoadConfig,
    policy: BatchPolicy,
    label: &str,
    shards: usize,
) -> Result<BenchResult> {
    run_with_sharded(cfg, executors(cfg)?, policy, label, shards)
}

/// [`run_sharded`] with a trace collector attached to the server, for
/// `serve-bench --trace-out`: identical workload and accounting, plus a
/// span per request in `tracer`.
pub fn run_sharded_traced(
    cfg: &LoadConfig,
    policy: BatchPolicy,
    label: &str,
    shards: usize,
    tracer: std::sync::Arc<crate::trace::TraceCollector>,
) -> Result<BenchResult> {
    run_with_sharded_inner(cfg, executors(cfg)?, policy, label, shards, Some(tracer), 0)
        .map(|(r, _)| r)
}

/// [`run_sharded`] with a content-addressed forward cache of
/// `cache_bytes` capacity in front of the batcher (`serve-bench
/// --cache-bytes`).  Returns the bench record plus the cache's final
/// counter snapshot; `cache_bytes == 0` means cache off — the run is
/// then byte-identical to [`run_sharded`] and the snapshot is `None`.
pub fn run_sharded_cached(
    cfg: &LoadConfig,
    policy: BatchPolicy,
    label: &str,
    shards: usize,
    cache_bytes: usize,
) -> Result<(BenchResult, Option<CacheStats>)> {
    run_with_sharded_inner(cfg, executors(cfg)?, policy, label, shards, None, cache_bytes)
}

/// Run the workload against caller-provided executors (e.g. a
/// [`super::PipelineExecutor`] over an AOT artifact).  `cfg.models` must
/// describe the registry in order: names and widths are cross-checked so
/// generated payloads always fit the executor they are routed to.
pub fn run_with(
    cfg: &LoadConfig,
    executors: Vec<Box<dyn ModelExecutor>>,
    policy: BatchPolicy,
    label: &str,
) -> Result<BenchResult> {
    run_with_sharded(cfg, executors, policy, label, 1)
}

/// [`run_with`] on a sharded server.
pub fn run_with_sharded(
    cfg: &LoadConfig,
    executors: Vec<Box<dyn ModelExecutor>>,
    policy: BatchPolicy,
    label: &str,
    shards: usize,
) -> Result<BenchResult> {
    run_with_sharded_inner(cfg, executors, policy, label, shards, None, 0).map(|(r, _)| r)
}

/// [`run_with`] with a trace collector attached — the traced analogue
/// for caller-provided executors (e.g. `serve-bench --pipeline
/// --trace-out`).
pub fn run_with_traced(
    cfg: &LoadConfig,
    executors: Vec<Box<dyn ModelExecutor>>,
    policy: BatchPolicy,
    label: &str,
    tracer: std::sync::Arc<crate::trace::TraceCollector>,
) -> Result<BenchResult> {
    run_with_sharded_inner(cfg, executors, policy, label, 1, Some(tracer), 0).map(|(r, _)| r)
}

fn run_with_sharded_inner(
    cfg: &LoadConfig,
    executors: Vec<Box<dyn ModelExecutor>>,
    policy: BatchPolicy,
    label: &str,
    shards: usize,
    tracer: Option<std::sync::Arc<crate::trace::TraceCollector>>,
    cache_bytes: usize,
) -> Result<(BenchResult, Option<CacheStats>)> {
    if cfg.requests == 0 || cfg.concurrency == 0 {
        bail!("load config needs at least one request and one client");
    }
    if cfg.models.is_empty() {
        bail!("load config needs at least one model spec");
    }
    if executors.len() != cfg.models.len() {
        bail!("{} executors for {} model specs", executors.len(), cfg.models.len());
    }
    for (spec, ex) in cfg.models.iter().zip(&executors) {
        if spec.name != ex.name() {
            bail!("spec {:?} does not match executor {:?}", spec.name, ex.name());
        }
        if spec.d != ex.d_in() {
            bail!("model {:?}: spec d={} but executor d_in={}", spec.name, spec.d, ex.d_in());
        }
    }
    let server = Server::start_configured(executors, policy, shards, tracer, cache_bytes)?;
    let (wall_secs, per_client) = drive(cfg, || {
        let server = &server;
        move |id| {
            let (model, rows, x) = request(cfg, id);
            let ts = Instant::now();
            let outcome = server
                .submit_at(model as u32, x, rows)
                .map(|_| ts.elapsed().as_secs_f64())
                .map_err(|_| ());
            (model, outcome)
        }
    });
    let stats = server.shutdown().expect("first shutdown");
    let cache = server.cache_stats();
    Ok((aggregate(cfg, policy, label, wall_secs, per_client, &stats), cache))
}

/// The workload driver shared by every transport: fan `cfg.concurrency`
/// client threads out over the request ids (round-robin partition),
/// pace open-loop arrivals against one shared epoch, and collect
/// per-model latency samples.  `make_client` runs once inside each
/// client thread and returns that thread's submit closure — the
/// closure generates request `id`'s payload, times its own submission,
/// and reports `(routed model, Ok(latency_secs) | Err(()))`.  Keeping
/// pacing/partitioning here is what makes the in-process and HTTP
/// records comparable by construction: the transports differ only in
/// the closure.
fn drive<M, S>(cfg: &LoadConfig, make_client: M) -> (f64, Vec<(Vec<Vec<f64>>, usize)>)
where
    M: Fn() -> S + Sync,
    S: FnMut(u64) -> (usize, std::result::Result<f64, ()>),
{
    let offsets = match cfg.arrival {
        Arrival::Open { rate_rps } => Some(open_schedule(cfg.requests, rate_rps, cfg.seed)),
        Arrival::Closed => None,
    };
    let n_models = cfg.models.len();
    let t0 = Instant::now();
    let per_client: Vec<(Vec<Vec<f64>>, usize)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.concurrency)
            .map(|client| {
                let offsets = offsets.as_deref();
                let make_client = &make_client;
                s.spawn(move || {
                    let mut submit = make_client();
                    let mut lats: Vec<Vec<f64>> = vec![Vec::new(); n_models];
                    let mut errors = 0usize;
                    let mut id = client;
                    while id < cfg.requests {
                        if let Some(offs) = offsets {
                            let due = Duration::from_micros(offs[id]);
                            let since = t0.elapsed();
                            if due > since {
                                std::thread::sleep(due - since);
                            }
                        }
                        let (model, outcome) = submit(id as u64);
                        match outcome {
                            Ok(latency) => lats[model].push(latency),
                            Err(()) => errors += 1,
                        }
                        id += cfg.concurrency;
                    }
                    (lats, errors)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    (t0.elapsed().as_secs_f64().max(1e-9), per_client)
}

/// Fold client-side latency samples and the server's counter snapshot
/// into one [`BenchResult`] record — shared by the in-process and the
/// HTTP transports so `BENCH_http.json` compares like with like.
fn aggregate(
    cfg: &LoadConfig,
    policy: BatchPolicy,
    label: &str,
    wall_secs: f64,
    per_client: Vec<(Vec<Vec<f64>>, usize)>,
    stats: &ServeStats,
) -> BenchResult {
    let n_models = cfg.models.len();
    let exec = stats.total();
    let mut per_model_lats: Vec<Vec<f64>> = vec![Vec::new(); n_models];
    let mut errors = 0usize;
    for (lats, errs) in &per_client {
        errors += errs;
        for (m, l) in lats.iter().enumerate() {
            per_model_lats[m].extend_from_slice(l);
        }
    }
    let mut all: Vec<f64> = per_model_lats.iter().flatten().copied().collect();
    all.sort_by(|a, b| a.total_cmp(b));
    let served = all.len();
    let mean_ms =
        if served == 0 { f64::NAN } else { all.iter().sum::<f64>() / served as f64 * 1e3 };

    let per_model: Vec<ModelBench> = stats
        .per_model
        .iter()
        .zip(per_model_lats.iter_mut())
        .map(|(m, lats)| {
            lats.sort_by(|a, b| a.total_cmp(b));
            ModelBench {
                name: m.name.clone(),
                d_in: m.d_in,
                d_out: m.d_out,
                exec: m.stats.clone(),
                served: lats.len(),
                p50_ms: percentile(lats, 50.0) * 1e3,
                p99_ms: percentile(lats, 99.0) * 1e3,
            }
        })
        .collect();

    BenchResult {
        label: label.to_string(),
        requests: cfg.requests,
        concurrency: cfg.concurrency,
        max_batch: policy.max_batch,
        deadline_us: policy.deadline_us,
        wall_secs,
        throughput_rps: served as f64 / wall_secs,
        rows_per_sec: exec.rows as f64 / wall_secs,
        mean_ms,
        p50_ms: percentile(&all, 50.0) * 1e3,
        p95_ms: percentile(&all, 95.0) * 1e3,
        p99_ms: percentile(&all, 99.0) * 1e3,
        max_ms: all.last().copied().unwrap_or(f64::NAN) * 1e3,
        errors,
        retries: 0,
        failovers: 0,
        exec,
        peak_queued: stats.peak_queued,
        per_model,
    }
}

/// Backoff before retrying a shed (429 / QueueFull) request: honor the
/// server's Retry-After hint, but cap it — on loopback the queue drains
/// in microseconds, and sleeping out a full advisory second per retry
/// would make the bench measure `sleep()`, not the transport.  No hint
/// (or an unparseable one) falls back to a short fixed poll.
const SHED_BACKOFF_CAP: Duration = Duration::from_millis(5);

fn shed_backoff(hint_millis: Option<u64>) -> Duration {
    match hint_millis {
        Some(ms) => Duration::from_millis(ms.max(1)).min(SHED_BACKOFF_CAP),
        None => Duration::from_micros(200),
    }
}

/// Serialize one infer request body — the HTTP wire encoding of a
/// `(payload, rows)` pair.
pub fn infer_body(x: &[f32], rows: u32) -> String {
    Json::Obj(vec![
        ("x".to_string(), Json::Arr(x.iter().map(|&v| Json::Num(v as f64)).collect())),
        ("rows".to_string(), Json::Int(rows as i64)),
    ])
    .to_string()
}

/// JSON infer body for request `id` — the exact payload the in-process
/// run submits, serialized once per request.
pub fn http_body(cfg: &LoadConfig, id: u64) -> (usize, String) {
    let (model, rows, x) = request(cfg, id);
    (model, infer_body(&x, rows))
}

/// Run the same seeded workload **over loopback HTTP**: a sharded
/// server behind `net::HttpServer`, one keep-alive `net::HttpClient`
/// per submitter thread.  Latencies are measured around the full
/// serialize → TCP → server parse → admit → respond round trip
/// (payload *generation* stays outside the window, as in-process;
/// client-side decoding of `y` is the one cost not included).
/// Comparing this record against [`run_sharded`]'s at the same shard
/// count isolates the frontend's overhead.  A `429` (shed load) is
/// retried after a `Retry-After`-aware backoff ([`shed_backoff`]) and
/// recorded in [`BenchResult::retries`] — the bench counts only
/// irrecoverable failures as errors.
pub fn run_http(
    cfg: &LoadConfig,
    policy: BatchPolicy,
    label: &str,
    shards: usize,
) -> Result<BenchResult> {
    run_http_traced(cfg, policy, label, shards, None)
}

/// [`run_http`] with an optional trace collector attached to the serve
/// engine — the HTTP frontend then also records one handler slice per
/// request on its per-thread `http-{i}` tracks.
pub fn run_http_traced(
    cfg: &LoadConfig,
    policy: BatchPolicy,
    label: &str,
    shards: usize,
    tracer: Option<std::sync::Arc<crate::trace::TraceCollector>>,
) -> Result<BenchResult> {
    run_http_inner(cfg, policy, label, shards, tracer, 0).map(|(r, _)| r)
}

/// [`run_http`] with a content-addressed forward cache of `cache_bytes`
/// capacity in front of the batcher; returns the cache's final counter
/// snapshot alongside the record (`None` when `cache_bytes == 0`).
pub fn run_http_cached(
    cfg: &LoadConfig,
    policy: BatchPolicy,
    label: &str,
    shards: usize,
    cache_bytes: usize,
) -> Result<(BenchResult, Option<CacheStats>)> {
    run_http_inner(cfg, policy, label, shards, None, cache_bytes)
}

fn run_http_inner(
    cfg: &LoadConfig,
    policy: BatchPolicy,
    label: &str,
    shards: usize,
    tracer: Option<std::sync::Arc<crate::trace::TraceCollector>>,
    cache_bytes: usize,
) -> Result<(BenchResult, Option<CacheStats>)> {
    use crate::net::{HttpClient, HttpOptions, HttpServer};

    if cfg.requests == 0 || cfg.concurrency == 0 {
        bail!("load config needs at least one request and one client");
    }
    if cfg.models.is_empty() {
        bail!("load config needs at least one model spec");
    }
    let server = std::sync::Arc::new(Server::start_configured(
        executors(cfg)?,
        policy,
        shards,
        tracer,
        cache_bytes,
    )?);
    let http = HttpServer::bind(
        "127.0.0.1:0",
        server,
        HttpOptions { conn_threads: cfg.concurrency.max(1), ..Default::default() },
    )?;
    let addr = http.local_addr();
    let paths: Vec<String> = cfg
        .models
        .iter()
        .map(|m| format!("/v1/models/{}/infer", m.name))
        .collect();

    let retries = std::sync::atomic::AtomicUsize::new(0);
    let (wall_secs, per_client) = drive(cfg, || {
        let paths = &paths;
        let retries = &retries;
        let mut conn = HttpClient::connect(addr).ok();
        move |id| {
            // Workload generation stays outside the timed window (as in
            // the in-process run); JSON serialization goes inside — it
            // is transport cost, and the http_overhead numbers exist to
            // charge the transport for everything it adds.
            let (model, rows, x) = request(cfg, id);
            let ts = Instant::now();
            let body = infer_body(&x, rows);
            let mut ok = false;
            // Bounded 429 retry: shed load is backpressure, not
            // failure, but a wedged server must not spin the bench
            // forever.
            for _attempt in 0..1000 {
                if conn.is_none() {
                    match HttpClient::connect(addr) {
                        Ok(c) => conn = Some(c),
                        Err(_) => break,
                    }
                }
                let c = conn.as_mut().expect("connection established above");
                match c.post_json(&paths[model], &body) {
                    Ok(resp) if resp.status == 200 => {
                        ok = true;
                        break;
                    }
                    Ok(resp) if resp.status == 429 => {
                        // Backoff-aware retry: honor the server's
                        // Retry-After hint (capped for loopback) and
                        // record the shed instead of failing the
                        // request.
                        retries.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        std::thread::sleep(shed_backoff(resp.retry_after_millis()));
                    }
                    Ok(_) => break,
                    Err(_) => {
                        // Reconnect once on a broken stream.
                        conn = None;
                    }
                }
            }
            (model, if ok { Ok(ts.elapsed().as_secs_f64()) } else { Err(()) })
        }
    });
    let cache = http.server().cache_stats();
    let stats = http.shutdown().expect("first shutdown");
    let mut res = aggregate(cfg, policy, label, wall_secs, per_client, &stats);
    res.retries = retries.into_inner();
    Ok((res, cache))
}

/// Run the same seeded workload **over loopback flashwire**: a sharded
/// server behind [`crate::wire::WireServer`], one keep-alive
/// [`crate::wire::WireClient`] per submitter thread.  The timed window
/// matches [`run_http`]'s exactly — payload generation outside, encode
/// → TCP → decode → admit → respond inside — so the three records
/// (in-process, HTTP/JSON, flashwire) differ only in transport.
/// `QueueFull`/`Backlog` error frames are retried with the same
/// [`shed_backoff`] policy, honoring the frame's typed
/// retry-after-millis hint.
pub fn run_wire(
    cfg: &LoadConfig,
    policy: BatchPolicy,
    label: &str,
    shards: usize,
) -> Result<BenchResult> {
    run_wire_traced(cfg, policy, label, shards, None)
}

/// [`run_wire`] with an optional trace collector attached to the serve
/// engine — the flashwire frontend then also records one handler slice
/// per frame on its per-thread `wire-{i}` tracks.
pub fn run_wire_traced(
    cfg: &LoadConfig,
    policy: BatchPolicy,
    label: &str,
    shards: usize,
    tracer: Option<std::sync::Arc<crate::trace::TraceCollector>>,
) -> Result<BenchResult> {
    run_wire_inner(cfg, policy, label, shards, tracer, 0).map(|(r, _)| r)
}

/// [`run_wire`] with a content-addressed forward cache of `cache_bytes`
/// capacity in front of the batcher; returns the cache's final counter
/// snapshot alongside the record (`None` when `cache_bytes == 0`).
pub fn run_wire_cached(
    cfg: &LoadConfig,
    policy: BatchPolicy,
    label: &str,
    shards: usize,
    cache_bytes: usize,
) -> Result<(BenchResult, Option<CacheStats>)> {
    run_wire_inner(cfg, policy, label, shards, None, cache_bytes)
}

fn run_wire_inner(
    cfg: &LoadConfig,
    policy: BatchPolicy,
    label: &str,
    shards: usize,
    tracer: Option<std::sync::Arc<crate::trace::TraceCollector>>,
    cache_bytes: usize,
) -> Result<(BenchResult, Option<CacheStats>)> {
    use crate::wire::{ErrCode, WireClient, WireOptions, WireServer};

    if cfg.requests == 0 || cfg.concurrency == 0 {
        bail!("load config needs at least one request and one client");
    }
    if cfg.models.is_empty() {
        bail!("load config needs at least one model spec");
    }
    let server = std::sync::Arc::new(Server::start_configured(
        executors(cfg)?,
        policy,
        shards,
        tracer,
        cache_bytes,
    )?);
    let wire = WireServer::bind(
        "127.0.0.1:0",
        server,
        WireOptions { conn_threads: cfg.concurrency.max(1), ..Default::default() },
    )?;
    let addr = wire.local_addr();

    let retries = std::sync::atomic::AtomicUsize::new(0);
    let (wall_secs, per_client) = drive(cfg, || {
        let retries = &retries;
        let mut conn = WireClient::connect(addr).ok();
        move |id| {
            // Payload generation outside the timed window, encoding
            // inside — mirroring run_http's window exactly.  Encode
            // once: retries resend the same bytes instead of re-copying
            // the floats per attempt (as run_http reuses its body
            // string).
            let (model, rows, x) = request(cfg, id);
            let name = cfg.models[model].name.as_str();
            let ts = Instant::now();
            let payload = match WireClient::encode_infer(name, &x, rows) {
                Ok(p) => p,
                Err(_) => return (model, Err(())),
            };
            let mut ok = false;
            for _attempt in 0..1000 {
                if conn.is_none() {
                    match WireClient::connect(addr) {
                        Ok(c) => conn = Some(c),
                        Err(_) => break,
                    }
                }
                let c = conn.as_mut().expect("connection established above");
                match c.infer_encoded(&payload) {
                    Ok(Ok(_resp)) => {
                        ok = true;
                        break;
                    }
                    // `Backlog` joins `QueueFull` in the retry arm: a
                    // router's accept-door shed and its exhausted-
                    // failover verdict both arrive as `Backlog`/
                    // `Draining`-class frames carrying the same
                    // retry-after hint a direct server sends — the
                    // client's backoff must not depend on whether a
                    // router sits in between.
                    Ok(Err(e)) if matches!(e.code, ErrCode::QueueFull | ErrCode::Backlog) => {
                        retries.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        let hint = (e.retry_after_millis > 0)
                            .then_some(e.retry_after_millis as u64);
                        std::thread::sleep(shed_backoff(hint));
                    }
                    Ok(Err(_)) => break,
                    Err(_) => {
                        // Reconnect once on a broken stream.
                        conn = None;
                    }
                }
            }
            (model, if ok { Ok(ts.elapsed().as_secs_f64()) } else { Err(()) })
        }
    });
    let cache = wire.server().cache_stats();
    let stats = wire.shutdown().expect("first shutdown");
    let mut res = aggregate(cfg, policy, label, wall_secs, per_client, &stats);
    res.retries = retries.into_inner();
    Ok((res, cache))
}

/// The `BENCH_http.json` artifact: the same workload in-process and over
/// loopback HTTP, with the frontend's overhead made explicit.
pub fn http_bench_json(
    cfg: &LoadConfig,
    inproc: &BenchResult,
    http: &BenchResult,
    shards: usize,
) -> Json {
    Json::Obj(vec![
        ("bench".to_string(), Json::Str("serve_http".to_string())),
        ("config".to_string(), config_json(cfg)),
        ("shards".to_string(), Json::Int(shards as i64)),
        (
            "http_overhead".to_string(),
            Json::Obj(vec![
                ("p50_ms".to_string(), Json::Num(http.p50_ms - inproc.p50_ms)),
                ("p99_ms".to_string(), Json::Num(http.p99_ms - inproc.p99_ms)),
                (
                    "throughput_ratio".to_string(),
                    Json::Num(http.throughput_rps / inproc.throughput_rps.max(1e-9)),
                ),
            ]),
        ),
        ("results".to_string(), Json::Arr(vec![inproc.to_json(), http.to_json()])),
    ])
}

/// Mean on-the-wire payload bytes per request, per transport — computed
/// deterministically over the **whole** seeded workload (every request
/// id, its exact payload, and the exact response rows the executor
/// produces), not sampled from a live run.  Counted bytes are the
/// message encodings themselves: the JSON body for HTTP (headers are a
/// near-constant ~150B/request and depend on the bound address), and
/// the full frame (8-byte header + payload) for flashwire.  Response
/// sizes assume a batch of 1 (`batch_size`/`cause` cost O(1) bytes
/// either way, so coalescing does not change the comparison).
#[derive(Clone, Copy, Debug, Default)]
pub struct TransportBytes {
    pub json_request: f64,
    pub json_response: f64,
    pub wire_request: f64,
    pub wire_response: f64,
}

impl TransportBytes {
    pub fn json_total(&self) -> f64 {
        self.json_request + self.json_response
    }

    pub fn wire_total(&self) -> f64 {
        self.wire_request + self.wire_response
    }

    /// flashwire bytes as a fraction of JSON bytes (request + response).
    pub fn wire_vs_json_ratio(&self) -> f64 {
        self.wire_total() / self.json_total().max(1e-9)
    }

    fn to_json_pair(v_req: f64, v_resp: f64) -> Json {
        Json::Obj(vec![
            ("request".to_string(), Json::Num(v_req)),
            ("response".to_string(), Json::Num(v_resp)),
            ("total".to_string(), Json::Num(v_req + v_resp)),
        ])
    }
}

/// Compute [`TransportBytes`] for `cfg`'s workload: every request is
/// encoded in both formats, and its response rows come from running the
/// registry's executors directly (single-request batches, so the
/// response payload is exact, not estimated).
pub fn transport_bytes(cfg: &LoadConfig) -> Result<TransportBytes> {
    use crate::wire::{InferRequest, InferResponse};

    if cfg.requests == 0 {
        bail!("load config needs at least one request");
    }
    let mut execs = executors(cfg)?;
    let mut sums = TransportBytes::default();
    let mut y = Vec::new();
    for id in 0..cfg.requests as u64 {
        let (model, rows, x) = request(cfg, id);
        sums.json_request += infer_body(&x, rows).len() as f64;
        let req = InferRequest {
            model: cfg.models[model].name.clone(),
            rows,
            dim: cfg.models[model].d as u32,
            x,
        };
        sums.wire_request += req.wire_bytes() as f64;
        execs[model]
            .run(&req.x, rows as usize, &mut y)
            .with_context(|| format!("reference forward for request {id}"))?;
        let resp_json = Json::Obj(vec![
            ("y".to_string(), Json::Arr(y.iter().map(|&v| Json::Num(v as f64)).collect())),
            ("batch_size".to_string(), Json::Int(1)),
            ("cause".to_string(), Json::Str(FlushCause::Idle.label().to_string())),
            // The live response always carries the timing breakdown;
            // representative small values keep the accounting honest
            // (real digits vary by a few bytes per request at most).
            (
                "timing".to_string(),
                Json::Obj(vec![
                    ("queue_wait_us".to_string(), Json::Int(0)),
                    ("batch_form_us".to_string(), Json::Int(0)),
                    ("exec_us".to_string(), Json::Int(0)),
                    ("reply_us".to_string(), Json::Int(0)),
                ]),
            ),
        ]);
        sums.json_response += resp_json.to_string().len() as f64;
        let resp = InferResponse { y: std::mem::take(&mut y), batch_size: 1, cause: FlushCause::Idle };
        sums.wire_response += resp.wire_bytes() as f64;
        y = resp.y; // reuse the buffer across requests
    }
    let n = cfg.requests as f64;
    Ok(TransportBytes {
        json_request: sums.json_request / n,
        json_response: sums.json_response / n,
        wire_request: sums.wire_request / n,
        wire_response: sums.wire_response / n,
    })
}

/// The `BENCH_wire.json` artifact: the identical seeded workload
/// in-process, over HTTP/JSON, and over flashwire (all at the same
/// shard count), with per-transport latency and the deterministic
/// bytes-per-request accounting side by side.
pub fn wire_bench_json(
    cfg: &LoadConfig,
    inproc: &BenchResult,
    http: &BenchResult,
    wire: &BenchResult,
    shards: usize,
    bytes: &TransportBytes,
) -> Json {
    let leg = |r: &BenchResult, b_req: f64, b_resp: f64| {
        Json::Obj(vec![
            ("p50_ms".to_string(), Json::Num(r.p50_ms)),
            ("p99_ms".to_string(), Json::Num(r.p99_ms)),
            ("throughput_rps".to_string(), Json::Num(r.throughput_rps)),
            ("shed_retries".to_string(), Json::Int(r.retries as i64)),
            (
                "bytes_per_request".to_string(),
                TransportBytes::to_json_pair(b_req, b_resp),
            ),
        ])
    };
    Json::Obj(vec![
        ("bench".to_string(), Json::Str("serve_wire".to_string())),
        ("config".to_string(), config_json(cfg)),
        ("shards".to_string(), Json::Int(shards as i64)),
        (
            "transport_comparison".to_string(),
            Json::Obj(vec![
                ("json".to_string(), leg(http, bytes.json_request, bytes.json_response)),
                (
                    "flashwire".to_string(),
                    leg(wire, bytes.wire_request, bytes.wire_response),
                ),
                (
                    "wire_vs_json".to_string(),
                    Json::Obj(vec![
                        ("p50_ms".to_string(), Json::Num(wire.p50_ms - http.p50_ms)),
                        ("p99_ms".to_string(), Json::Num(wire.p99_ms - http.p99_ms)),
                        (
                            "throughput_ratio".to_string(),
                            Json::Num(wire.throughput_rps / http.throughput_rps.max(1e-9)),
                        ),
                        ("bytes_ratio".to_string(), Json::Num(bytes.wire_vs_json_ratio())),
                    ]),
                ),
            ]),
        ),
        (
            "wire_overhead".to_string(),
            Json::Obj(vec![
                ("p50_ms".to_string(), Json::Num(wire.p50_ms - inproc.p50_ms)),
                ("p99_ms".to_string(), Json::Num(wire.p99_ms - inproc.p99_ms)),
                (
                    "throughput_ratio".to_string(),
                    Json::Num(wire.throughput_rps / inproc.throughput_rps.max(1e-9)),
                ),
            ]),
        ),
        (
            "results".to_string(),
            Json::Arr(vec![inproc.to_json(), http.to_json(), wire.to_json()]),
        ),
    ])
}

/// Per-transport bit-identity outcome of [`verify_cached_bit_identity`]:
/// `true` means every request's rows came back `to_bits()`-identical to
/// the unbatched, uncached executor oracle.
#[derive(Clone, Copy, Debug)]
pub struct CacheIdentity {
    pub inproc: bool,
    pub http: bool,
    pub wire: bool,
}

impl CacheIdentity {
    pub fn all_ok(&self) -> bool {
        self.inproc && self.http && self.wire
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("inproc".to_string(), Json::Bool(self.inproc)),
            ("http".to_string(), Json::Bool(self.http)),
            ("wire".to_string(), Json::Bool(self.wire)),
            ("all_ok".to_string(), Json::Bool(self.all_ok())),
        ])
    }
}

/// The cache-correctness gate behind `serve-bench --cache-bytes`: replay
/// the whole seeded workload serially against a *cached* server on each
/// transport and compare every response bit-for-bit against the
/// unbatched executor oracle (the same ground truth `serve_e2e` uses).
/// A duplicate-heavy `cfg` makes the replay traverse the verified-hit
/// path on most requests; the cold and insert paths are covered by the
/// misses.  Concurrent coalescing is exercised separately in
/// `tests/cache_e2e.rs` — a serial replay can never have two identical
/// requests in flight.
///
/// HTTP responses are compared through the JSON round trip, which is
/// bit-exact by construction (`util::json` serializes `f64` with Rust's
/// shortest-round-trip formatting, and `f32 -> f64 -> f32` is lossless).
pub fn verify_cached_bit_identity(
    cfg: &LoadConfig,
    policy: BatchPolicy,
    shards: usize,
    cache_bytes: usize,
) -> Result<CacheIdentity> {
    use crate::net::{HttpClient, HttpOptions, HttpServer};
    use crate::wire::{WireClient, WireOptions, WireServer};

    if cfg.requests == 0 {
        bail!("load config needs at least one request");
    }
    if cfg.models.is_empty() {
        bail!("load config needs at least one model spec");
    }

    // Oracle: each request's rows through the bare executors, one
    // request per batch — no batcher, no cache, no transport.
    let mut oracle = executors(cfg)?;
    let mut y = Vec::new();
    let mut want: Vec<Vec<u32>> = Vec::with_capacity(cfg.requests);
    for id in 0..cfg.requests as u64 {
        let (model, rows, x) = request(cfg, id);
        oracle[model]
            .run(&x, rows as usize, &mut y)
            .with_context(|| format!("oracle forward for request {id}"))?;
        want.push(y.iter().map(|v| v.to_bits()).collect());
    }
    let bits_ok = |got: &[f32], id: u64| -> bool {
        let w = &want[id as usize];
        got.len() == w.len() && got.iter().zip(w).all(|(v, b)| v.to_bits() == *b)
    };

    // In-process replay.
    let server = Server::start_configured(executors(cfg)?, policy, shards, None, cache_bytes)?;
    let mut inproc = true;
    for id in 0..cfg.requests as u64 {
        let (model, rows, x) = request(cfg, id);
        match server.submit_at(model as u32, x, rows) {
            Ok(resp) => inproc &= bits_ok(&resp.y, id),
            Err(_) => inproc = false,
        }
    }
    let _ = server.shutdown();

    // HTTP replay: parse `y` out of the JSON response body.
    let server = std::sync::Arc::new(Server::start_configured(
        executors(cfg)?,
        policy,
        shards,
        None,
        cache_bytes,
    )?);
    let http_srv = HttpServer::bind("127.0.0.1:0", server, HttpOptions::default())?;
    let mut conn = HttpClient::connect(http_srv.local_addr())?;
    let mut http = true;
    for id in 0..cfg.requests as u64 {
        let (model, rows, x) = request(cfg, id);
        let path = format!("/v1/models/{}/infer", cfg.models[model].name);
        let body = infer_body(&x, rows);
        let mut ok = false;
        for _attempt in 0..100 {
            match conn.post_json(&path, &body) {
                Ok(resp) if resp.status == 200 => {
                    ok = Json::parse(&resp.body_str())
                        .ok()
                        .and_then(|j| {
                            let arr = j.get("y")?.as_arr()?.to_vec();
                            let got: Option<Vec<f32>> =
                                arr.iter().map(|v| v.as_f64().map(|f| f as f32)).collect();
                            got
                        })
                        .is_some_and(|got| bits_ok(&got, id));
                    break;
                }
                Ok(resp) if resp.status == 429 => {
                    std::thread::sleep(shed_backoff(resp.retry_after_millis()));
                }
                _ => break,
            }
        }
        http &= ok;
    }
    let _ = http_srv.shutdown();

    // flashwire replay: the binary response carries `y` verbatim.
    let server = std::sync::Arc::new(Server::start_configured(
        executors(cfg)?,
        policy,
        shards,
        None,
        cache_bytes,
    )?);
    let wire_srv = WireServer::bind("127.0.0.1:0", server, WireOptions::default())?;
    let mut conn = WireClient::connect(wire_srv.local_addr())?;
    let mut wire = true;
    for id in 0..cfg.requests as u64 {
        let (model, rows, x) = request(cfg, id);
        let ok = matches!(
            conn.infer(cfg.models[model].name.as_str(), &x, rows),
            Ok(Ok(resp)) if bits_ok(&resp.y, id)
        );
        wire &= ok;
    }
    let _ = wire_srv.shutdown();

    Ok(CacheIdentity { inproc, http, wire })
}

/// One transport's cached-vs-uncached pair for `BENCH_cache.json`.
#[derive(Clone, Debug)]
pub struct CacheLeg {
    /// `"inproc"`, `"http"`, or `"wire"`.
    pub transport: String,
    pub uncached: BenchResult,
    pub cached: BenchResult,
    /// Final counter snapshot of the cached leg's cache.
    pub stats: Option<CacheStats>,
}

impl CacheLeg {
    /// Verified-hit + coalesced fraction of the cached leg's requests;
    /// `NaN` when the leg recorded no cache snapshot (cache off) — the
    /// report layer renders that as a dash, the JSON as `null`.
    pub fn hit_rate(&self) -> f64 {
        self.stats.as_ref().map_or(f64::NAN, |s| s.total.hit_rate())
    }

    /// Cached over uncached throughput; `NaN` when either leg served
    /// nothing — a ratio against zero is meaningless, and the report
    /// layer dash-guards it like the hit rate.
    pub fn speedup(&self) -> f64 {
        if self.cached.throughput_rps <= 0.0 || self.uncached.throughput_rps <= 0.0 {
            return f64::NAN;
        }
        self.cached.throughput_rps / self.uncached.throughput_rps
    }
}

/// The `BENCH_cache.json` artifact: cached-vs-uncached legs per
/// transport over the same duplicate-heavy seeded workload, the cache
/// counters that explain the deltas, and the bit-identity gate verdict.
pub fn cache_bench_json(
    cfg: &LoadConfig,
    shards: usize,
    cache_bytes: usize,
    legs: &[CacheLeg],
    identity: &CacheIdentity,
) -> Json {
    let leg_json = |l: &CacheLeg| {
        let counters = l.stats.as_ref().map_or(Json::Null, |s| {
            Json::Obj(vec![
                ("hits".to_string(), Json::Int(s.total.hits as i64)),
                ("misses".to_string(), Json::Int(s.total.misses as i64)),
                ("coalesced".to_string(), Json::Int(s.total.coalesced as i64)),
                ("inserts".to_string(), Json::Int(s.total.inserts as i64)),
                ("evictions".to_string(), Json::Int(s.total.evictions as i64)),
                ("collisions".to_string(), Json::Int(s.total.collisions as i64)),
                ("bytes".to_string(), Json::Int(s.bytes as i64)),
                ("entries".to_string(), Json::Int(s.entries as i64)),
            ])
        });
        Json::Obj(vec![
            ("transport".to_string(), Json::Str(l.transport.clone())),
            ("hit_rate".to_string(), Json::Num(l.hit_rate())),
            ("speedup".to_string(), Json::Num(l.speedup())),
            ("p50_ms_delta".to_string(), Json::Num(l.cached.p50_ms - l.uncached.p50_ms)),
            ("p99_ms_delta".to_string(), Json::Num(l.cached.p99_ms - l.uncached.p99_ms)),
            ("cache".to_string(), counters),
            ("uncached".to_string(), l.uncached.to_json()),
            ("cached".to_string(), l.cached.to_json()),
        ])
    };
    Json::Obj(vec![
        ("bench".to_string(), Json::Str("serve_cache".to_string())),
        ("config".to_string(), config_json(cfg)),
        ("shards".to_string(), Json::Int(shards as i64)),
        ("cache_bytes".to_string(), Json::Int(cache_bytes as i64)),
        ("bit_identity".to_string(), identity.to_json()),
        ("legs".to_string(), Json::Arr(legs.iter().map(leg_json).collect())),
    ])
}

/// Fold per-node [`ServeStats`] into one tier-wide snapshot: all nodes
/// share the registry (same specs, same seeds), so per-model counters
/// merge by registry position; shard peaks concatenate node-major.
fn merge_serve_stats(parts: Vec<ServeStats>) -> ServeStats {
    let mut out = ServeStats::default();
    for part in parts {
        if out.per_model.is_empty() {
            out.per_model = part.per_model;
        } else {
            for (o, p) in out.per_model.iter_mut().zip(&part.per_model) {
                o.stats.merge(&p.stats);
            }
        }
        out.shard_peaks.extend(part.shard_peaks);
        out.peak_queued = out.peak_queued.max(part.peak_queued);
    }
    out
}

/// Spawn `nodes` loopback backend wire servers, each carrying the FULL
/// seeded registry.  Replication (not partitioning) is deliberate: the
/// ring decides which node *normally* serves a model, but failover only
/// works if any node *can* serve any model — and identical per-spec
/// coefficient seeds make every replica bit-identical, which is what
/// lets the router treat them as interchangeable.
fn spawn_backend_nodes(
    cfg: &LoadConfig,
    policy: BatchPolicy,
    shards: usize,
    nodes: usize,
) -> Result<Vec<crate::wire::WireServer>> {
    use crate::wire::{WireOptions, WireServer};
    (0..nodes)
        .map(|i| {
            let server = std::sync::Arc::new(Server::start_configured(
                executors(cfg)?,
                policy,
                shards,
                None,
                0,
            )?);
            WireServer::bind(
                "127.0.0.1:0",
                server,
                // Headroom over the router's handler pool plus the
                // prober, so a node never door-sheds the tier's own
                // traffic during the bench.
                WireOptions { conn_threads: (cfg.concurrency + 2).max(8), ..Default::default() },
            )
            .with_context(|| format!("binding backend node {i}"))
        })
        .collect()
}

/// Run the seeded workload **through the route tier**: `nodes` backend
/// wire servers behind one [`crate::route::RouteServer`], clients
/// talking only to the front port.  Workload, timed window, and retry
/// policy are identical to [`run_wire`]'s, so comparing the two records
/// isolates the router hop; comparing `nodes = 1` against `nodes = N`
/// (same front door both times) isolates horizontal scaling.  Returns
/// the bench record with `failovers` filled from the router's counters.
pub fn run_route(
    cfg: &LoadConfig,
    policy: BatchPolicy,
    label: &str,
    shards: usize,
    nodes: usize,
    route_policy: crate::route::RoutePolicy,
) -> Result<BenchResult> {
    use crate::route::{RouteOptions, RouteServer};
    use crate::wire::{ErrCode, WireClient};

    if cfg.requests == 0 || cfg.concurrency == 0 {
        bail!("load config needs at least one request and one client");
    }
    if cfg.models.is_empty() {
        bail!("load config needs at least one model spec");
    }
    if nodes == 0 {
        bail!("route bench needs at least one node");
    }
    let backends = spawn_backend_nodes(cfg, policy, shards, nodes)?;
    let addrs: Vec<_> = backends.iter().map(|b| b.local_addr()).collect();
    let router = RouteServer::bind(
        "127.0.0.1:0",
        addrs,
        RouteOptions {
            conn_threads: cfg.concurrency.max(1),
            policy: route_policy,
            ..Default::default()
        },
    )?;
    let addr = router.local_addr();

    let retries = std::sync::atomic::AtomicUsize::new(0);
    let (wall_secs, per_client) = drive(cfg, || {
        let retries = &retries;
        let mut conn = WireClient::connect(addr).ok();
        move |id| {
            let (model, rows, x) = request(cfg, id);
            let name = cfg.models[model].name.as_str();
            let ts = Instant::now();
            let payload = match WireClient::encode_infer(name, &x, rows) {
                Ok(p) => p,
                Err(_) => return (model, Err(())),
            };
            let mut ok = false;
            for _attempt in 0..1000 {
                if conn.is_none() {
                    match WireClient::connect(addr) {
                        Ok(c) => conn = Some(c),
                        Err(_) => break,
                    }
                }
                let c = conn.as_mut().expect("connection established above");
                match c.infer_encoded(&payload) {
                    Ok(Ok(_resp)) => {
                        ok = true;
                        break;
                    }
                    Ok(Err(e)) if matches!(e.code, ErrCode::QueueFull | ErrCode::Backlog) => {
                        retries.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        let hint = (e.retry_after_millis > 0)
                            .then_some(e.retry_after_millis as u64);
                        std::thread::sleep(shed_backoff(hint));
                    }
                    Ok(Err(_)) => break,
                    Err(_) => {
                        conn = None;
                    }
                }
            }
            (model, if ok { Ok(ts.elapsed().as_secs_f64()) } else { Err(()) })
        }
    });
    let failovers = router.metrics().total_retried();
    router.shutdown();
    let stats = merge_serve_stats(
        backends.iter().map(|b| b.shutdown().expect("first shutdown")).collect(),
    );
    let mut res = aggregate(cfg, policy, label, wall_secs, per_client, &stats);
    res.retries = retries.into_inner();
    res.failovers = failovers as usize;
    Ok(res)
}

/// The route tier's bit-identity gate: replay the whole seeded workload
/// serially through a router over `nodes` replicas and compare every
/// response `to_bits()`-exact against the unbatched executor oracle —
/// the same ground truth as [`verify_cached_bit_identity`], now also
/// covering the relay path (sniff, failover, verbatim frame copy).
pub fn verify_route_bit_identity(
    cfg: &LoadConfig,
    policy: BatchPolicy,
    shards: usize,
    nodes: usize,
) -> Result<bool> {
    use crate::route::{RouteOptions, RouteServer};
    use crate::wire::{ErrCode, WireClient};

    if cfg.requests == 0 {
        bail!("load config needs at least one request");
    }
    if cfg.models.is_empty() {
        bail!("load config needs at least one model spec");
    }
    if nodes == 0 {
        bail!("route identity gate needs at least one node");
    }

    // Oracle: each request's rows through bare executors, unbatched.
    let mut oracle = executors(cfg)?;
    let mut y = Vec::new();
    let mut want: Vec<Vec<u32>> = Vec::with_capacity(cfg.requests);
    for id in 0..cfg.requests as u64 {
        let (model, rows, x) = request(cfg, id);
        oracle[model]
            .run(&x, rows as usize, &mut y)
            .with_context(|| format!("oracle forward for request {id}"))?;
        want.push(y.iter().map(|v| v.to_bits()).collect());
    }

    let backends = spawn_backend_nodes(cfg, policy, shards, nodes)?;
    let addrs: Vec<_> = backends.iter().map(|b| b.local_addr()).collect();
    let router = RouteServer::bind("127.0.0.1:0", addrs, RouteOptions::default())?;
    let mut conn = WireClient::connect(router.local_addr())?;
    let mut identical = true;
    for id in 0..cfg.requests as u64 {
        let (model, rows, x) = request(cfg, id);
        let mut ok = false;
        for _attempt in 0..100 {
            match conn.infer(cfg.models[model].name.as_str(), &x, rows) {
                Ok(Ok(resp)) => {
                    let w = &want[id as usize];
                    ok = resp.y.len() == w.len()
                        && resp.y.iter().zip(w).all(|(v, b)| v.to_bits() == *b);
                    break;
                }
                Ok(Err(e)) if matches!(e.code, ErrCode::QueueFull | ErrCode::Backlog) => {
                    let hint =
                        (e.retry_after_millis > 0).then_some(e.retry_after_millis as u64);
                    std::thread::sleep(shed_backoff(hint));
                }
                _ => break,
            }
        }
        identical &= ok;
    }
    router.shutdown();
    for b in &backends {
        let _ = b.shutdown();
    }
    Ok(identical)
}

/// The `BENCH_route.json` artifact: the identical seeded workload
/// through a 1-node tier and an `nodes`-node tier (same router hop both
/// times), the scaling-efficiency verdict, and the bit-identity gate.
/// `efficiency` is `throughput_N / (N × throughput_1)` — 1.0 is perfect
/// horizontal scaling, and the denominator guard keeps a degenerate
/// zero-throughput leg from minting an infinite ratio.
pub fn route_bench_json(
    cfg: &LoadConfig,
    shards: usize,
    nodes: usize,
    policy_label: &str,
    single: &BenchResult,
    multi: &BenchResult,
    identical: bool,
) -> Json {
    let per_node = |n: usize, r: &BenchResult| {
        Json::Obj(vec![
            ("nodes".to_string(), Json::Int(n as i64)),
            ("p50_ms".to_string(), Json::Num(r.p50_ms)),
            ("p99_ms".to_string(), Json::Num(r.p99_ms)),
            ("throughput_rps".to_string(), Json::Num(r.throughput_rps)),
            ("shed_retries".to_string(), Json::Int(r.retries as i64)),
            ("failovers".to_string(), Json::Int(r.failovers as i64)),
        ])
    };
    Json::Obj(vec![
        ("bench".to_string(), Json::Str("serve_route".to_string())),
        ("config".to_string(), config_json(cfg)),
        ("shards".to_string(), Json::Int(shards as i64)),
        ("policy".to_string(), Json::Str(policy_label.to_string())),
        ("bit_identity".to_string(), Json::Bool(identical)),
        (
            "scaling".to_string(),
            Json::Obj(vec![
                ("nodes".to_string(), Json::Int(nodes as i64)),
                ("throughput_1_rps".to_string(), Json::Num(single.throughput_rps)),
                ("throughput_n_rps".to_string(), Json::Num(multi.throughput_rps)),
                (
                    "efficiency".to_string(),
                    Json::Num(
                        multi.throughput_rps
                            / (nodes as f64 * single.throughput_rps).max(1e-9),
                    ),
                ),
                (
                    "per_node".to_string(),
                    Json::Arr(vec![per_node(1, single), per_node(nodes, multi)]),
                ),
            ]),
        ),
        ("results".to_string(), Json::Arr(vec![single.to_json(), multi.to_json()])),
    ])
}

fn config_json(cfg: &LoadConfig) -> Json {
    let models: Vec<Json> = cfg
        .models
        .iter()
        .map(|m| {
            Json::Obj(vec![
                ("name".to_string(), Json::Str(m.name.clone())),
                ("d".to_string(), Json::Int(m.d as i64)),
                ("n_groups".to_string(), Json::Int(m.n_groups as i64)),
            ])
        })
        .collect();
    Json::Obj(vec![
        // Which kernel variant (scalar/simd) served the run: transport
        // comparisons across CI runs must not be silently confounded by
        // the `simd` feature flag.
        (
            "kernel_variant".to_string(),
            Json::Str(crate::rational::kernel::variant().to_string()),
        ),
        ("requests".to_string(), Json::Int(cfg.requests as i64)),
        ("concurrency".to_string(), Json::Int(cfg.concurrency as i64)),
        ("rows_min".to_string(), Json::Int(cfg.rows_min as i64)),
        ("rows_max".to_string(), Json::Int(cfg.rows_max as i64)),
        ("seed".to_string(), Json::Int(cfg.seed as i64)),
        ("dup_frac".to_string(), Json::Num(cfg.dup_frac)),
        (
            "arrival".to_string(),
            match cfg.arrival {
                Arrival::Closed => Json::Str("closed".to_string()),
                Arrival::Open { rate_rps } => {
                    Json::Obj(vec![("open_rate_rps".to_string(), Json::Num(rate_rps))])
                }
            },
        ),
        ("models".to_string(), Json::Arr(models)),
        ("threads".to_string(), Json::Int(crate::util::parallel::default_threads() as i64)),
    ])
}

/// One trace file written by a `--trace-out` bench run.
#[derive(Clone, Debug)]
pub struct TraceRun {
    pub path: String,
    /// `TracePacket` count ([`crate::trace::stat`]).
    pub packets: usize,
    pub bytes: usize,
}

/// The `"tracing"` section of a `--trace-out` bench artifact: which
/// files were written, and the measured collector overhead (untraced vs
/// traced throughput on the same in-process workload).
pub fn tracing_json(
    trace_out: &str,
    untraced_rps: f64,
    traced_rps: f64,
    traces: &[TraceRun],
) -> Json {
    let files: Vec<Json> = traces
        .iter()
        .map(|t| {
            Json::Obj(vec![
                ("path".to_string(), Json::Str(t.path.clone())),
                ("packets".to_string(), Json::Int(t.packets as i64)),
                ("bytes".to_string(), Json::Int(t.bytes as i64)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("trace_out".to_string(), Json::Str(trace_out.to_string())),
        ("throughput_rps_untraced".to_string(), Json::Num(untraced_rps)),
        ("throughput_rps_traced".to_string(), Json::Num(traced_rps)),
        (
            "overhead_ratio".to_string(),
            Json::Num(traced_rps / untraced_rps.max(1e-9)),
        ),
        ("traces".to_string(), Json::Arr(files)),
    ])
}

/// Assemble the `BENCH_serve.json` artifact from the main run and the
/// optional `max_batch = 1` baseline.
pub fn bench_json(cfg: &LoadConfig, main: &BenchResult, baseline: Option<&BenchResult>) -> Json {
    let mut top = vec![
        ("bench".to_string(), Json::Str("serve".to_string())),
        ("config".to_string(), config_json(cfg)),
    ];
    let mut results = vec![main.to_json()];
    if let Some(base) = baseline {
        results.push(base.to_json());
        top.push((
            "speedup_vs_max_batch_1".to_string(),
            Json::Num(main.throughput_rps / base.throughput_rps.max(1e-9)),
        ));
    }
    top.push(("results".to_string(), Json::Arr(results)));
    Json::Obj(top)
}

/// Default autotune sweep grid (12 runs).
pub const AUTOTUNE_MAX_BATCH: [usize; 4] = [1, 8, 16, 64];
pub const AUTOTUNE_DEADLINE_US: [u64; 3] = [50, 200, 1000];

/// Outcome of an autotune sweep: every run's record plus the selected
/// policy (`runs[best]`).
#[derive(Clone, Debug)]
pub struct AutotuneResult {
    pub slo_p99_us: u64,
    pub runs: Vec<BenchResult>,
    /// Index into `runs` of the selected policy.
    pub best: usize,
    /// Whether the selected policy actually meets the SLO; `false` means
    /// no grid point did and `best` is the lowest-p99 fallback.
    pub met_slo: bool,
}

impl AutotuneResult {
    pub fn best(&self) -> &BenchResult {
        &self.runs[self.best]
    }
}

/// Sweep `(max_batch, deadline_us)` with a fresh registry per run (from
/// `build`) and pick the throughput-optimal policy whose p99 latency
/// meets `slo_p99_us`; fall back to the lowest-p99 point when none does.
pub fn autotune_with(
    cfg: &LoadConfig,
    base: BatchPolicy,
    slo_p99_us: u64,
    max_batches: &[usize],
    deadlines_us: &[u64],
    mut build: impl FnMut() -> Result<Vec<Box<dyn ModelExecutor>>>,
) -> Result<AutotuneResult> {
    if max_batches.is_empty() || deadlines_us.is_empty() {
        bail!("autotune needs a non-empty (max_batch, deadline_us) grid");
    }
    let mut runs = Vec::with_capacity(max_batches.len() * deadlines_us.len());
    for &mb in max_batches {
        for &dl in deadlines_us {
            let policy = BatchPolicy { max_batch: mb, deadline_us: dl, ..base };
            runs.push(run_with(cfg, build()?, policy, &format!("mb{mb}-dl{dl}"))?);
        }
    }
    let slo_ms = slo_p99_us as f64 / 1e3;
    let meets = |r: &BenchResult| r.errors == 0 && r.p99_ms.is_finite() && r.p99_ms <= slo_ms;
    let best_meeting = runs
        .iter()
        .enumerate()
        .filter(|(_, r)| meets(r))
        .max_by(|(_, a), (_, b)| a.throughput_rps.total_cmp(&b.throughput_rps))
        .map(|(i, _)| i);
    let (best, met_slo) = match best_meeting {
        Some(i) => (i, true),
        None => {
            let i = runs
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| a.p99_ms.total_cmp(&b.p99_ms))
                .map(|(i, _)| i)
                .expect("non-empty grid");
            (i, false)
        }
    };
    Ok(AutotuneResult { slo_p99_us, runs, best, met_slo })
}

/// [`autotune_with`] over the registry described by `cfg.models`.
pub fn autotune(
    cfg: &LoadConfig,
    base: BatchPolicy,
    slo_p99_us: u64,
    max_batches: &[usize],
    deadlines_us: &[u64],
) -> Result<AutotuneResult> {
    autotune_with(cfg, base, slo_p99_us, max_batches, deadlines_us, || executors(cfg))
}

/// `BENCH_serve.json`-shaped artifact for an autotune sweep: the same
/// top-level record layout, with every grid point in `results` and the
/// selected policy summarized under `autotune`.
pub fn autotune_json(cfg: &LoadConfig, res: &AutotuneResult) -> Json {
    let best = res.best();
    Json::Obj(vec![
        ("bench".to_string(), Json::Str("serve".to_string())),
        ("config".to_string(), config_json(cfg)),
        (
            "autotune".to_string(),
            Json::Obj(vec![
                ("slo_p99_us".to_string(), Json::Int(res.slo_p99_us as i64)),
                ("met_slo".to_string(), Json::Bool(res.met_slo)),
                ("best_label".to_string(), Json::Str(best.label.clone())),
                ("best_max_batch".to_string(), Json::Int(best.max_batch as i64)),
                ("best_deadline_us".to_string(), Json::Int(best.deadline_us as i64)),
                ("best_throughput_rps".to_string(), Json::Num(best.throughput_rps)),
                ("best_p99_ms".to_string(), Json::Num(best.p99_ms)),
            ]),
        ),
        ("results".to_string(), Json::Arr(res.runs.iter().map(|r| r.to_json()).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(requests: usize, concurrency: usize, d: usize) -> LoadConfig {
        LoadConfig {
            requests,
            concurrency,
            models: vec![ModelSpec::new("grkan", d, 8)],
            ..Default::default()
        }
    }

    #[test]
    fn request_payloads_are_deterministic_per_id() {
        let cfg = LoadConfig::default();
        let (m1, r1, x1) = request(&cfg, 42);
        let (m2, r2, x2) = request(&cfg, 42);
        assert_eq!((m1, r1), (m2, r2));
        assert_eq!(x1, x2);
        assert!((cfg.rows_min..=cfg.rows_max).contains(&r1));
        let (_, _, other) = request(&cfg, 43);
        assert_ne!(x1, other);
    }

    #[test]
    fn config_json_records_kernel_variant() {
        // Every serve-bench artifact embeds the config object, so this
        // one key flows into BENCH_serve.json, BENCH_http.json and
        // BENCH_wire.json alike.  The value is fixed at compile time by
        // the `simd` feature.
        let text = config_json(&LoadConfig::default()).to_string();
        let want = format!("\"kernel_variant\":\"{}\"", crate::rational::kernel::variant());
        assert!(text.contains(&want), "{text}");
        #[cfg(not(feature = "simd"))]
        assert!(text.contains("\"kernel_variant\":\"scalar\""));
        #[cfg(feature = "simd")]
        assert!(text.contains("\"kernel_variant\":\"simd\""));
    }

    #[test]
    fn requests_round_robin_across_models() {
        let cfg = LoadConfig {
            models: vec![ModelSpec::new("a", 64, 8), ModelSpec::new("b", 32, 8)],
            ..Default::default()
        };
        for id in 0..6u64 {
            let (m, _, x) = request(&cfg, id);
            assert_eq!(m, (id % 2) as usize);
            assert_eq!(x.len() % cfg.models[m].d, 0, "payload width follows the routed model");
        }
    }

    #[test]
    fn open_schedule_is_deterministic_and_monotone() {
        let a = open_schedule(200, 5000.0, 3);
        let b = open_schedule(200, 5000.0, 3);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        // ~200 arrivals at 5k rps span ~40 ms; allow generous slack.
        let last = *a.last().unwrap();
        assert!((5_000..400_000).contains(&last), "{last}");
        assert_ne!(a, open_schedule(200, 5000.0, 4));
    }

    /// The open-loop arrivals really are Poisson-distributed: the mean
    /// interarrival gap converges to `1/rate` (seeded, so the check is
    /// exact-reproducible, not flaky), and the exponential shape shows
    /// up as ~63% of gaps below the mean.
    #[test]
    fn open_schedule_poisson_interarrival_mean_matches_rate() {
        let (n, rate) = (20_000usize, 10_000.0f64);
        let sched = open_schedule(n, rate, 42);
        let want_us = 1e6 / rate; // 100 µs
        let mean_us = *sched.last().unwrap() as f64 / n as f64;
        assert!(
            (mean_us - want_us).abs() / want_us < 0.05,
            "mean interarrival {mean_us:.2}µs vs expected {want_us:.2}µs"
        );
        // Exponential(λ): P(gap < mean) = 1 - 1/e ≈ 0.632.
        let below: usize = sched
            .windows(2)
            .filter(|w| ((w[1] - w[0]) as f64) < want_us)
            .count();
        let frac = below as f64 / (n - 1) as f64;
        assert!((frac - 0.632).abs() < 0.03, "sub-mean gap fraction {frac:.3}");
    }

    /// Identical seeds reproduce identical schedules AND identical
    /// request payloads — the invariant the HTTP-mode client refactor
    /// leans on when it compares transports on "the same workload".
    #[test]
    fn identical_seeds_reproduce_identical_request_streams() {
        let cfg = LoadConfig { seed: 9, ..Default::default() };
        let cfg2 = LoadConfig { seed: 9, ..Default::default() };
        assert_eq!(open_schedule(64, 2_000.0, cfg.seed), open_schedule(64, 2_000.0, cfg2.seed));
        for id in 0..32u64 {
            assert_eq!(request(&cfg, id), request(&cfg2, id), "request {id}");
            assert_eq!(http_body(&cfg, id), http_body(&cfg2, id), "http body {id}");
        }
        let other = LoadConfig { seed: 10, ..Default::default() };
        assert_ne!(request(&cfg, 0).2, request(&other, 0).2, "different seed, different stream");
    }

    #[test]
    fn shed_backoff_honors_and_caps_the_hint() {
        assert_eq!(shed_backoff(None), Duration::from_micros(200));
        assert_eq!(shed_backoff(Some(2)), Duration::from_millis(2));
        assert_eq!(shed_backoff(Some(0)), Duration::from_millis(1), "floor at 1ms");
        assert_eq!(
            shed_backoff(Some(60_000)),
            SHED_BACKOFF_CAP,
            "an advisory minute must not stall the bench"
        );
    }

    /// End-to-end wire-mode smoke: the loopback flashwire run serves
    /// everything it serves in-process, with the same counters
    /// accounting, and the three-way record assembles.
    #[test]
    fn wire_mode_run_serves_the_workload() {
        let cfg = LoadConfig {
            requests: 40,
            concurrency: 4,
            models: vec![ModelSpec::new("wide", 64, 8), ModelSpec::new("narrow", 16, 4)],
            ..Default::default()
        };
        let res = run_wire(
            &cfg,
            BatchPolicy { max_batch: 8, ..Default::default() },
            "wire smoke",
            2,
        )
        .unwrap();
        assert_eq!(res.errors, 0, "all requests served over flashwire");
        assert_eq!(res.exec.requests, 40);
        let served: usize = res.per_model.iter().map(|m| m.served).sum();
        assert_eq!(served, 40);
        assert!(res.throughput_rps > 0.0);
        let bytes = transport_bytes(&cfg).unwrap();
        let j = wire_bench_json(&cfg, &res, &res, &res, 2, &bytes);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str(), Some("serve_wire"));
        assert_eq!(back.get("shards").unwrap().as_usize(), Some(2));
        let cmp = back.get("transport_comparison").unwrap();
        assert!(cmp.get("json").unwrap().get("bytes_per_request").unwrap().get("total").is_some());
        assert!(cmp.get("flashwire").unwrap().get("bytes_per_request").is_some());
        assert!(cmp.get("wire_vs_json").unwrap().get("bytes_ratio").is_some());
        assert_eq!(back.get("results").unwrap().as_arr().unwrap().len(), 3);
    }

    /// Route-mode smoke: the workload through a 2-node tier serves
    /// everything, the merged counters account for every request, the
    /// serial replay is bit-identical through the router, and the
    /// artifact carries the scaling block.
    #[test]
    fn route_mode_run_serves_and_stays_bit_identical() {
        let cfg = LoadConfig {
            requests: 40,
            concurrency: 4,
            models: vec![ModelSpec::new("wide", 64, 8), ModelSpec::new("narrow", 16, 4)],
            ..Default::default()
        };
        let policy = BatchPolicy { max_batch: 8, ..Default::default() };
        let res = run_route(&cfg, policy, "route smoke", 2, 2, crate::route::RoutePolicy::Ring)
            .unwrap();
        assert_eq!(res.errors, 0, "all requests served through the router");
        assert_eq!(res.exec.requests, 40, "tier-wide merged counters");
        assert_eq!(res.exec.failed, 0);
        assert!(res.throughput_rps > 0.0);
        assert!(verify_route_bit_identity(&cfg, policy, 2, 2).unwrap());
        let j = route_bench_json(&cfg, 2, 2, "ring", &res, &res, true);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str(), Some("serve_route"));
        assert_eq!(back.get("bit_identity").unwrap().as_bool(), Some(true));
        let scaling = back.get("scaling").unwrap();
        assert_eq!(scaling.get("nodes").unwrap().as_usize(), Some(2));
        let eff = scaling.get("efficiency").unwrap().as_f64().unwrap();
        assert_eq!(eff, 0.5, "same record on both legs => throughput_n == throughput_1");
        assert_eq!(scaling.get("per_node").unwrap().as_arr().unwrap().len(), 2);
    }

    /// The binary encoding must be strictly smaller than JSON for
    /// float-heavy payloads — that is the protocol's reason to exist —
    /// and the accounting must be deterministic.
    #[test]
    fn transport_bytes_show_binary_smaller_than_json() {
        let cfg = LoadConfig {
            requests: 32,
            models: vec![ModelSpec::new("grkan", 64, 8)],
            ..Default::default()
        };
        let a = transport_bytes(&cfg).unwrap();
        let b = transport_bytes(&cfg).unwrap();
        assert_eq!(a.json_total(), b.json_total(), "deterministic");
        assert_eq!(a.wire_total(), b.wire_total(), "deterministic");
        // A 64-wide f32 row is 256 payload bytes on the wire vs ~a
        // dozen decimal characters per value in JSON.
        assert!(
            a.wire_request < a.json_request && a.wire_response < a.json_response,
            "binary must beat text: {a:?}"
        );
        assert!(a.wire_vs_json_ratio() < 0.5, "expected >2x byte saving, got {a:?}");
        // Exact request size: header(8) + name(2+5) + rows(4) + dim(4)
        // + rows*64*4 payload bytes, averaged over the row distribution.
        let mut want = 0.0;
        for id in 0..cfg.requests as u64 {
            let (_, rows, x) = request(&cfg, id);
            assert_eq!(x.len(), rows as usize * 64);
            want += (8 + 2 + 5 + 4 + 4 + x.len() * 4) as f64;
        }
        assert_eq!(a.wire_request, want / cfg.requests as f64);
    }

    /// End-to-end HTTP-mode smoke: the loopback run serves everything it
    /// serves in-process, with the same counters accounting.
    #[test]
    fn http_mode_run_serves_the_workload() {
        let cfg = LoadConfig {
            requests: 40,
            concurrency: 4,
            models: vec![ModelSpec::new("wide", 64, 8), ModelSpec::new("narrow", 16, 4)],
            ..Default::default()
        };
        let res = run_http(
            &cfg,
            BatchPolicy { max_batch: 8, ..Default::default() },
            "http smoke",
            2,
        )
        .unwrap();
        assert_eq!(res.errors, 0, "all requests served over HTTP");
        assert_eq!(res.exec.requests, 40);
        assert_eq!(res.per_model.len(), 2);
        let served: usize = res.per_model.iter().map(|m| m.served).sum();
        assert_eq!(served, 40);
        assert!(res.throughput_rps > 0.0);
        let j = http_bench_json(&cfg, &res, &res, 2);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str(), Some("serve_http"));
        assert_eq!(back.get("shards").unwrap().as_usize(), Some(2));
        assert!(back.get("http_overhead").unwrap().get("throughput_ratio").is_some());
        assert_eq!(back.get("results").unwrap().as_arr().unwrap().len(), 2);
    }

    /// Tracing must not change what the bench measures: same request
    /// accounting, one request span per served request, and a render
    /// that the trace scanner accepts.
    #[test]
    fn traced_run_keeps_results_and_records_every_span() {
        let cfg = small_cfg(30, 4, 64);
        let policy = BatchPolicy { max_batch: 8, ..Default::default() };
        let tracer = std::sync::Arc::new(crate::trace::TraceCollector::new());
        let traced = run_sharded_traced(&cfg, policy, "traced", 1, tracer.clone()).unwrap();
        assert_eq!(traced.errors, 0);
        assert_eq!(traced.exec.requests, 30);
        let req_events: usize = tracer
            .snapshot()
            .iter()
            .filter(|(name, _)| name.ends_with(" req"))
            .map(|(_, events)| events.len())
            .sum();
        assert_eq!(req_events, 30, "one request slice per served request");
        let bytes = tracer.render();
        let stat = crate::trace::stat(&bytes).unwrap();
        assert!(stat.packets > 0);
        assert_eq!(stat.slice_begins, stat.slice_ends);

        // The bench JSON carries the server-side phase percentiles...
        let j = Json::Obj(exec_json(&traced.exec));
        for key in ["queue_wait_p50_us", "queue_wait_p99_us", "exec_p50_us", "exec_p99_us"] {
            assert!(j.get(key).and_then(Json::as_f64).is_some(), "{key}");
        }
        // ...and the tracing section assembles and round-trips.
        let run = TraceRun {
            path: "trace.pftrace".to_string(),
            packets: stat.packets,
            bytes: bytes.len(),
        };
        let rps = traced.throughput_rps;
        let tj = tracing_json("trace.pftrace", rps, rps, &[run]);
        let back = Json::parse(&tj.to_string()).unwrap();
        assert!(back.get("overhead_ratio").and_then(Json::as_f64).is_some());
        assert_eq!(back.get("traces").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn closed_loop_smoke_run_serves_everything() {
        let cfg = small_cfg(40, 4, 64);
        let res = run(&cfg, BatchPolicy { max_batch: 8, ..Default::default() }, "smoke").unwrap();
        assert_eq!(res.errors, 0);
        assert_eq!(res.exec.requests, 40);
        assert!(res.throughput_rps > 0.0);
        assert!(res.p50_ms <= res.p95_ms && res.p95_ms <= res.p99_ms);
        let hist_total: usize =
            res.exec.batch_hist.iter().enumerate().map(|(size, n)| size * n).sum();
        assert_eq!(hist_total, 40);
        assert_eq!(res.per_model.len(), 1);
        assert_eq!(res.per_model[0].served, 40);
    }

    #[test]
    fn multi_model_run_splits_stats_by_model() {
        let cfg = LoadConfig {
            requests: 60,
            concurrency: 4,
            models: vec![ModelSpec::new("wide", 64, 8), ModelSpec::new("narrow", 16, 4)],
            ..Default::default()
        };
        let res = run(&cfg, BatchPolicy { max_batch: 8, ..Default::default() }, "multi").unwrap();
        assert_eq!(res.errors, 0);
        assert_eq!(res.per_model.len(), 2);
        let served: usize = res.per_model.iter().map(|m| m.served).sum();
        assert_eq!(served, 60);
        assert_eq!(res.per_model[0].served, 30, "round-robin split");
        let req_sum: usize = res.per_model.iter().map(|m| m.exec.requests).sum();
        let row_sum: usize = res.per_model.iter().map(|m| m.exec.rows).sum();
        assert_eq!(req_sum, res.exec.requests);
        assert_eq!(row_sum, res.exec.rows);
    }

    #[test]
    fn run_rejects_bad_dims() {
        let cfg = small_cfg(10, 2, 100); // 100 % 8 != 0
        assert!(run(&cfg, BatchPolicy::default(), "bad").is_err());
        let empty = LoadConfig { models: vec![], ..Default::default() };
        assert!(run(&empty, BatchPolicy::default(), "empty").is_err());
    }

    #[test]
    fn run_with_cross_checks_specs_against_executors() {
        let cfg = small_cfg(10, 2, 64);
        let mismatched = LoadConfig {
            models: vec![ModelSpec::new("other", 64, 8)],
            ..cfg.clone()
        };
        let ex = executors(&cfg).unwrap();
        assert!(run_with(&mismatched, ex, BatchPolicy::default(), "x").is_err(), "name mismatch");
        let wrong_d = LoadConfig {
            models: vec![ModelSpec::new("grkan", 32, 8)],
            ..cfg.clone()
        };
        let ex = executors(&cfg).unwrap();
        assert!(run_with(&wrong_d, ex, BatchPolicy::default(), "x").is_err(), "width mismatch");
    }

    #[test]
    fn bench_json_carries_speedup_and_models() {
        let cfg = small_cfg(20, 2, 64);
        let a = run(&cfg, BatchPolicy { max_batch: 8, ..Default::default() }, "a").unwrap();
        let b = run(&cfg, BatchPolicy { max_batch: 1, ..Default::default() }, "b").unwrap();
        let j = bench_json(&cfg, &a, Some(&b));
        assert!(j.get("speedup_vs_max_batch_1").is_some());
        assert_eq!(j.get("results").unwrap().as_arr().unwrap().len(), 2);
        // Round-trips through the parser (artifact is valid JSON).
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str(), Some("serve"));
        let models = back.get("results").unwrap().as_arr().unwrap()[0]
            .get("models")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(models.len(), 1);
        assert_eq!(models[0].get("name").unwrap().as_str(), Some("grkan"));
    }

    #[test]
    fn autotune_picks_a_policy_and_serializes() {
        let cfg = small_cfg(24, 4, 64);
        // Tiny grid to keep the test quick; generous SLO so the sweep
        // normally meets it (scheduling noise can't fail the test either
        // way — the fallback path is also a valid outcome).
        let res = autotune(&cfg, BatchPolicy::default(), 5_000_000, &[1, 8], &[200]).unwrap();
        assert_eq!(res.runs.len(), 2);
        assert!(res.best < res.runs.len());
        if res.met_slo {
            let best_thp = res.best().throughput_rps;
            assert!(res
                .runs
                .iter()
                .filter(|r| r.errors == 0 && r.p99_ms <= 5_000.0)
                .all(|r| r.throughput_rps <= best_thp));
        }
        let j = autotune_json(&cfg, &res);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("autotune").unwrap().get("slo_p99_us").unwrap().as_usize(), Some(5_000_000));
        assert_eq!(back.get("results").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn autotune_rejects_empty_grid() {
        let cfg = small_cfg(10, 2, 64);
        assert!(autotune(&cfg, BatchPolicy::default(), 1000, &[], &[200]).is_err());
    }

    /// `dup_frac` duplicates are exact replays: the resolved source id
    /// is idempotent, duplicates reproduce their source's full request
    /// tuple, and originals keep the exact `dup_frac = 0` payloads.
    #[test]
    fn duplicates_replay_exact_prior_request_bytes() {
        let plain = LoadConfig {
            models: vec![ModelSpec::new("a", 64, 8), ModelSpec::new("b", 32, 8)],
            ..Default::default()
        };
        let dup = LoadConfig { dup_frac: 0.5, ..plain.clone() };
        let mut dup_count = 0usize;
        for id in 0..1000u64 {
            let sid = source_id(&dup, id);
            assert!(sid <= id);
            assert_eq!(source_id(&dup, sid), sid, "idempotent at {id}");
            assert_eq!(request(&dup, id), request(&dup, sid), "replay at {id}");
            if sid != id {
                dup_count += 1;
            } else {
                // Originals are byte-identical to the dup_frac = 0
                // stream — the knob only redirects, never perturbs.
                assert_eq!(request(&dup, id), request(&plain, id), "original at {id}");
            }
            assert_eq!(source_id(&plain, id), id, "dup_frac = 0 never redirects");
        }
        // Coin flips are Bernoulli(0.5) over 1000 ids; a seeded stream
        // lands well inside this band.
        assert!((350..=650).contains(&dup_count), "{dup_count} duplicates");
    }

    /// Cached in-process run: everything serves, the counters partition
    /// the requests, and only cache misses reach the executors.
    #[test]
    fn cached_run_reports_stats_and_serves_everything() {
        let cfg = LoadConfig {
            requests: 80,
            concurrency: 4,
            dup_frac: 0.6,
            models: vec![ModelSpec::new("wide", 64, 8), ModelSpec::new("narrow", 16, 4)],
            ..Default::default()
        };
        let policy = BatchPolicy { max_batch: 8, ..Default::default() };
        let (res, cs) = run_sharded_cached(&cfg, policy, "cached", 2, 1 << 20).unwrap();
        let cs = cs.expect("cache on");
        assert_eq!(res.errors, 0);
        assert_eq!(cs.total.requests(), 80, "hits+misses+coalesced cover every request");
        assert!(cs.total.hits + cs.total.coalesced > 0, "duplicate-heavy load must hit");
        assert_eq!(
            cs.total.misses as usize, res.exec.requests,
            "only cache misses reach the executors"
        );
        assert!(cs.total.hit_rate() > 0.0);
        // Cache off: same workload, no snapshot, all requests executed.
        let (off, none) = run_sharded_cached(&cfg, policy, "uncached", 2, 0).unwrap();
        assert!(none.is_none());
        assert_eq!(off.exec.requests, 80);
    }

    /// The `--cache-bytes` correctness gate passes on all three
    /// transports, and the `BENCH_cache.json` record assembles.
    #[test]
    fn cache_identity_gate_and_bench_record() {
        let cfg = LoadConfig {
            requests: 24,
            concurrency: 4,
            dup_frac: 0.5,
            models: vec![ModelSpec::new("wide", 64, 8), ModelSpec::new("narrow", 16, 4)],
            ..Default::default()
        };
        let policy = BatchPolicy { max_batch: 8, ..Default::default() };
        let identity = verify_cached_bit_identity(&cfg, policy, 2, 1 << 20).unwrap();
        assert!(identity.all_ok(), "{identity:?}");

        let (uncached, _) = run_sharded_cached(&cfg, policy, "inproc uncached", 2, 0).unwrap();
        let (cached, stats) =
            run_sharded_cached(&cfg, policy, "inproc cached", 2, 1 << 20).unwrap();
        let leg = CacheLeg { transport: "inproc".to_string(), uncached, cached, stats };
        assert!(leg.hit_rate() > 0.0 && leg.speedup() > 0.0);
        let j = cache_bench_json(&cfg, 2, 1 << 20, std::slice::from_ref(&leg), &identity);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str(), Some("serve_cache"));
        assert_eq!(back.get("cache_bytes").unwrap().as_usize(), Some(1 << 20));
        assert_eq!(back.get("bit_identity").unwrap().get("all_ok").unwrap().as_bool(), Some(true));
        assert_eq!(back.get("config").unwrap().get("dup_frac").unwrap().as_f64(), Some(0.5));
        let legs = back.get("legs").unwrap().as_arr().unwrap();
        assert_eq!(legs.len(), 1);
        assert!(legs[0].get("hit_rate").unwrap().as_f64().unwrap() > 0.0);
        assert!(legs[0].get("cache").unwrap().get("hits").is_some());
    }
}
