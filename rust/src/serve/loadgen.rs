//! Deterministic load generator + latency/throughput report.
//!
//! Every random choice — request row counts, input values, open-loop
//! arrival offsets — derives from `util::rng::Pcg64` streams keyed by
//! the request id, so the workload is byte-identical across runs and
//! across submitter-thread interleavings; only the *timing* varies with
//! the machine.  The report side reuses `util::stats`: interpolated
//! p50/p95/p99 latency, requests ("images") per second, and the
//! executor's batch-size histogram.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::batcher::{BatchPolicy, FlushCause};
use super::server::{ExecStats, Model, Server};
use crate::rational::Coeffs;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::stats::percentile;

/// Arrival process for the generated request stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrival {
    /// Each of `concurrency` clients submits its next request as soon as
    /// the previous one completes (throughput-oriented).
    Closed,
    /// Poisson arrivals at `rate_rps`, pre-scheduled and split across
    /// the submitter threads; a slow response delays only that thread's
    /// own later arrivals (bounded open loop).
    Open { rate_rps: f64 },
}

#[derive(Clone, Debug)]
pub struct LoadConfig {
    pub requests: usize,
    pub concurrency: usize,
    /// Rows per request are drawn uniformly from `rows_min..=rows_max`.
    pub rows_min: u32,
    pub rows_max: u32,
    pub d: usize,
    pub n_groups: usize,
    pub seed: u64,
    pub arrival: Arrival,
}

impl Default for LoadConfig {
    fn default() -> Self {
        Self {
            requests: 2000,
            concurrency: 16,
            rows_min: 1,
            rows_max: 4,
            d: 256,
            n_groups: 8,
            seed: 7,
            arrival: Arrival::Closed,
        }
    }
}

/// Row count and input payload for request `id` — a pure function of
/// `(seed, id)`, independent of which thread materializes it.
pub fn request(cfg: &LoadConfig, id: u64) -> (u32, Vec<f32>) {
    let mut rng = Pcg64::with_stream(cfg.seed, id);
    let span = cfg.rows_max.max(cfg.rows_min) - cfg.rows_min;
    let rows = cfg.rows_min + rng.below(span as usize + 1) as u32;
    let x = (0..rows as usize * cfg.d).map(|_| rng.normal_f32()).collect();
    (rows, x)
}

/// Cumulative Poisson arrival offsets (µs) for the open-loop schedule.
pub fn open_schedule(requests: usize, rate_rps: f64, seed: u64) -> Vec<u64> {
    let mut rng = Pcg64::with_stream(seed, 0x5eed_a11);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(requests);
    for _ in 0..requests {
        // Exponential interarrival; clamp the log argument away from 0.
        let u = rng.uniform().max(1e-12);
        t += -u.ln() / rate_rps.max(1e-9);
        out.push((t * 1e6) as u64);
    }
    out
}

/// Outcome of one load run against one server policy.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub label: String,
    pub requests: usize,
    pub concurrency: usize,
    pub max_batch: usize,
    pub deadline_us: u64,
    pub wall_secs: f64,
    /// Requests ("images") per second over the whole run.
    pub throughput_rps: f64,
    pub rows_per_sec: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    pub errors: usize,
    pub exec: ExecStats,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        let hist: Vec<Json> =
            self.exec.batch_hist.iter().map(|&n| Json::Int(n as i64)).collect();
        let causes: Vec<(String, Json)> = FlushCause::ALL
            .iter()
            .map(|c| (c.label().to_string(), Json::Int(self.exec.causes[c.index()] as i64)))
            .collect();
        Json::Obj(vec![
            ("label".to_string(), Json::Str(self.label.clone())),
            ("requests".to_string(), Json::Int(self.requests as i64)),
            ("concurrency".to_string(), Json::Int(self.concurrency as i64)),
            ("max_batch".to_string(), Json::Int(self.max_batch as i64)),
            ("deadline_us".to_string(), Json::Int(self.deadline_us as i64)),
            ("wall_secs".to_string(), Json::Num(self.wall_secs)),
            ("throughput_rps".to_string(), Json::Num(self.throughput_rps)),
            ("rows_per_sec".to_string(), Json::Num(self.rows_per_sec)),
            ("mean_ms".to_string(), Json::Num(self.mean_ms)),
            ("p50_ms".to_string(), Json::Num(self.p50_ms)),
            ("p95_ms".to_string(), Json::Num(self.p95_ms)),
            ("p99_ms".to_string(), Json::Num(self.p99_ms)),
            ("max_ms".to_string(), Json::Num(self.max_ms)),
            ("errors".to_string(), Json::Int(self.errors as i64)),
            ("batches".to_string(), Json::Int(self.exec.batches as i64)),
            ("mean_batch".to_string(), Json::Num(self.exec.mean_batch())),
            ("exec_busy_secs".to_string(), Json::Num(self.exec.busy_secs)),
            ("peak_queued".to_string(), Json::Int(self.exec.peak_queued as i64)),
            ("batch_hist".to_string(), Json::Arr(hist)),
            ("flush_causes".to_string(), Json::Obj(causes)),
        ])
    }
}

/// Run the workload against a fresh server configured with `policy`.
pub fn run(cfg: &LoadConfig, policy: BatchPolicy, label: &str) -> Result<BenchResult> {
    if cfg.requests == 0 || cfg.concurrency == 0 {
        bail!("load config needs at least one request and one client");
    }
    if cfg.d == 0 || cfg.d % cfg.n_groups != 0 {
        bail!("d={} must be a positive multiple of n_groups={}", cfg.d, cfg.n_groups);
    }
    let mut rng = Pcg64::new(cfg.seed);
    let coeffs = Coeffs::<f32>::randn(cfg.n_groups, 6, 4, &mut rng);
    let server = Server::start(
        vec![Model { name: "grkan".into(), d: cfg.d, coeffs }],
        policy,
    );

    let offsets = match cfg.arrival {
        Arrival::Open { rate_rps } => Some(open_schedule(cfg.requests, rate_rps, cfg.seed)),
        Arrival::Closed => None,
    };

    let t0 = Instant::now();
    let per_client: Vec<(Vec<f64>, usize)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.concurrency)
            .map(|client| {
                let server = &server;
                let offsets = offsets.as_deref();
                s.spawn(move || {
                    let mut lats = Vec::new();
                    let mut errors = 0usize;
                    let mut id = client;
                    while id < cfg.requests {
                        if let Some(offs) = offsets {
                            let due = Duration::from_micros(offs[id]);
                            let since = t0.elapsed();
                            if due > since {
                                std::thread::sleep(due - since);
                            }
                        }
                        let (rows, x) = request(cfg, id as u64);
                        let ts = Instant::now();
                        match server.submit(0, x, rows) {
                            Ok(_) => lats.push(ts.elapsed().as_secs_f64()),
                            Err(_) => errors += 1,
                        }
                        id += cfg.concurrency;
                    }
                    (lats, errors)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    let wall_secs = t0.elapsed().as_secs_f64().max(1e-9);
    let exec = server.shutdown().expect("first shutdown");

    let mut lats: Vec<f64> = per_client.iter().flat_map(|(l, _)| l.iter().copied()).collect();
    let errors: usize = per_client.iter().map(|(_, e)| *e).sum();
    lats.sort_by(|a, b| a.total_cmp(b));
    let served = lats.len();
    let mean_ms = if served == 0 {
        f64::NAN
    } else {
        lats.iter().sum::<f64>() / served as f64 * 1e3
    };
    Ok(BenchResult {
        label: label.to_string(),
        requests: cfg.requests,
        concurrency: cfg.concurrency,
        max_batch: policy.max_batch,
        deadline_us: policy.deadline_us,
        wall_secs,
        throughput_rps: served as f64 / wall_secs,
        rows_per_sec: exec.rows as f64 / wall_secs,
        mean_ms,
        p50_ms: percentile(&lats, 50.0) * 1e3,
        p95_ms: percentile(&lats, 95.0) * 1e3,
        p99_ms: percentile(&lats, 99.0) * 1e3,
        max_ms: lats.last().copied().unwrap_or(f64::NAN) * 1e3,
        errors,
        exec,
    })
}

/// Assemble the `BENCH_serve.json` artifact from the main run and the
/// optional `max_batch = 1` baseline.
pub fn bench_json(cfg: &LoadConfig, main: &BenchResult, baseline: Option<&BenchResult>) -> Json {
    let mut top = vec![
        ("bench".to_string(), Json::Str("serve".to_string())),
        (
            "config".to_string(),
            Json::Obj(vec![
                ("requests".to_string(), Json::Int(cfg.requests as i64)),
                ("concurrency".to_string(), Json::Int(cfg.concurrency as i64)),
                ("rows_min".to_string(), Json::Int(cfg.rows_min as i64)),
                ("rows_max".to_string(), Json::Int(cfg.rows_max as i64)),
                ("d".to_string(), Json::Int(cfg.d as i64)),
                ("n_groups".to_string(), Json::Int(cfg.n_groups as i64)),
                ("seed".to_string(), Json::Int(cfg.seed as i64)),
                (
                    "arrival".to_string(),
                    match cfg.arrival {
                        Arrival::Closed => Json::Str("closed".to_string()),
                        Arrival::Open { rate_rps } => Json::Obj(vec![(
                            "open_rate_rps".to_string(),
                            Json::Num(rate_rps),
                        )]),
                    },
                ),
                ("threads".to_string(), Json::Int(crate::util::parallel::default_threads() as i64)),
            ]),
        ),
    ];
    let mut results = vec![main.to_json()];
    if let Some(base) = baseline {
        results.push(base.to_json());
        top.push((
            "speedup_vs_max_batch_1".to_string(),
            Json::Num(main.throughput_rps / base.throughput_rps.max(1e-9)),
        ));
    }
    top.push(("results".to_string(), Json::Arr(results)));
    Json::Obj(top)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_payloads_are_deterministic_per_id() {
        let cfg = LoadConfig::default();
        let (r1, x1) = request(&cfg, 42);
        let (r2, x2) = request(&cfg, 42);
        assert_eq!(r1, r2);
        assert_eq!(x1, x2);
        assert!((cfg.rows_min..=cfg.rows_max).contains(&r1));
        let (_, other) = request(&cfg, 43);
        assert_ne!(x1, other);
    }

    #[test]
    fn open_schedule_is_deterministic_and_monotone() {
        let a = open_schedule(200, 5000.0, 3);
        let b = open_schedule(200, 5000.0, 3);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        // ~200 arrivals at 5k rps span ~40 ms; allow generous slack.
        let last = *a.last().unwrap();
        assert!((5_000..400_000).contains(&last), "{last}");
        assert_ne!(a, open_schedule(200, 5000.0, 4));
    }

    #[test]
    fn closed_loop_smoke_run_serves_everything() {
        let cfg = LoadConfig {
            requests: 40,
            concurrency: 4,
            d: 64,
            ..Default::default()
        };
        let res = run(&cfg, BatchPolicy { max_batch: 8, ..Default::default() }, "smoke").unwrap();
        assert_eq!(res.errors, 0);
        assert_eq!(res.exec.requests, 40);
        assert!(res.throughput_rps > 0.0);
        assert!(res.p50_ms <= res.p95_ms && res.p95_ms <= res.p99_ms);
        let hist_total: usize =
            res.exec.batch_hist.iter().enumerate().map(|(size, n)| size * n).sum();
        assert_eq!(hist_total, 40);
    }

    #[test]
    fn run_rejects_bad_dims() {
        let cfg = LoadConfig { d: 100, n_groups: 8, ..Default::default() };
        assert!(run(&cfg, BatchPolicy::default(), "bad").is_err());
    }

    #[test]
    fn bench_json_carries_speedup_field() {
        let cfg = LoadConfig { requests: 20, concurrency: 2, d: 64, ..Default::default() };
        let a = run(&cfg, BatchPolicy { max_batch: 8, ..Default::default() }, "a").unwrap();
        let b = run(&cfg, BatchPolicy { max_batch: 1, ..Default::default() }, "b").unwrap();
        let j = bench_json(&cfg, &a, Some(&b));
        assert!(j.get("speedup_vs_max_batch_1").is_some());
        assert_eq!(j.get("results").unwrap().as_arr().unwrap().len(), 2);
        // Round-trips through the parser (artifact is valid JSON).
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str(), Some("serve"));
    }
}
