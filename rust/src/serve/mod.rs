//! Dynamic micro-batching inference engine for the GR-KAN forward pass.
//!
//! FlashKAT's kernel-level lesson is that amortizing slow-memory traffic
//! across a tile is what unlocks throughput; this subsystem applies the
//! same principle one level up.  Individually served inference requests
//! pay the worker-pool wakeup, the queue round-trip, and the coefficient
//! traffic per *request*; coalescing concurrent requests into one
//! batched [`crate::rational::forward`] pays them per *batch*, while a
//! deadline keeps tail latency bounded.  Three layers (DESIGN.md §10):
//!
//! - [`batcher`] — the deterministic coalescing core: shape-keyed
//!   buckets, flush on max-batch / deadline / idle-executor, admission
//!   backpressure.  Pure (no threads, no wall clock), so coalescing is
//!   reproducible under a virtual clock.
//! - [`server`] — the threaded engine: blocking `submit`, one executor
//!   thread driving batches through the persistent worker pool, drain on
//!   shutdown.  Batched outputs are bit-identical to unbatched forwards.
//! - [`loadgen`] — seeded closed-/open-loop workload generation and the
//!   latency/throughput report behind `flashkat serve-bench` and the
//!   `BENCH_serve.json` artifact.

pub mod batcher;
pub mod loadgen;
pub mod server;

pub use batcher::{Batch, BatchPolicy, Batcher, FlushCause, ShapeKey, Ticket};
pub use loadgen::{Arrival, BenchResult, LoadConfig};
pub use server::{ExecStats, Model, Response, Server};
