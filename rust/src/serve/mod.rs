//! Dynamic micro-batching inference engine over named model executors.
//!
//! FlashKAT's kernel-level lesson is that amortizing slow-memory traffic
//! across a tile is what unlocks throughput; this subsystem applies the
//! same principle one level up.  Individually served inference requests
//! pay the worker-pool wakeup, the queue round-trip, and the model-state
//! traffic per *request*; coalescing concurrent requests into one
//! executor call pays them per *batch*, while a deadline keeps tail
//! latency bounded.  Four layers (DESIGN.md §§10-11):
//!
//! - [`batcher`] — the deterministic coalescing core: buckets keyed by
//!   registry index, flush on max-batch / deadline / idle-executor,
//!   admission backpressure.  Pure (no threads, no wall clock), so
//!   coalescing is reproducible under a virtual clock.
//! - [`executor`] — the execution abstraction: [`ModelExecutor`] maps
//!   `rows x d_in` to `rows x d_out`; [`RationalExecutor`] serves one
//!   GR-KAN layer (bit-identical to unbatched `rational::forward`),
//!   [`PipelineExecutor`] serves a whole AOT `<tag>_eval` model through
//!   the runtime's batched-rows adapter.
//! - [`server`] — the sharded threaded engine: the registry is
//!   partitioned across N executor shards (each with its own batcher
//!   and executor thread), so a slow model cannot head-of-line-block a
//!   fast one; blocking `submit` / non-blocking `try_submit` routed by
//!   model name, live per-model [`ExecStats`], drain on shutdown.
//! - [`loadgen`] — seeded multi-model workload generation, the
//!   latency/throughput report behind `flashkat serve-bench`, and the
//!   `(max_batch, deadline_us)` autotune sweep; both persist to the
//!   `BENCH_serve.json` record shape.
//!
//! Cross-cutting: every admission mints a [`crate::trace::SpanCtx`]
//! when a [`crate::trace::TraceCollector`] is attached
//! (`Server::start_sharded_traced`), and every [`Response`] carries a
//! [`crate::trace::Timing`] phase breakdown either way — the span/trace
//! machinery only ever *reads* clocks, so forwards stay bit-identical
//! with tracing on.

pub mod batcher;
pub mod cache;
pub mod executor;
pub mod loadgen;
pub mod server;

pub use batcher::{Batch, BatchPolicy, Batcher, FlushCause, ShapeKey, Ticket};
pub use cache::{CacheCounters, CacheStats, ForwardCache};
pub use executor::{
    ExecStats, ModelExecutor, ModelStats, PipelineExecutor, RationalExecutor, ServeStats,
};
pub use loadgen::{
    Arrival, AutotuneResult, BenchResult, CacheIdentity, CacheLeg, LoadConfig, ModelBench,
    ModelSpec, TraceRun, TransportBytes,
};
pub use server::{ModelMeta, Response, Server, SubmitError};
