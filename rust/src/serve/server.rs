//! Sharded, threaded micro-batching inference server over a registry of
//! named model executors.
//!
//! The registry is partitioned round-robin across `n_shards` **executor
//! shards**; each shard owns its own [`Batcher`], admission queue,
//! condvars, and executor thread, so a slow model's batch (a whole
//! [`super::PipelineExecutor`] forward, say) can never head-of-line-block
//! a fast rational model that lives on another shard — the serving-level
//! image of FlashKAT's "coordination overhead, not FLOPs" lesson.
//! Within a shard the engine is unchanged: the executor thread coalesces
//! admitted requests into batches keyed by shard-local registry index,
//! concatenates their rows into a single buffer, and hands the buffer to
//! the owning [`ModelExecutor`], so the pool wakeup, the queue
//! round-trip, and the model-state traffic are paid once per batch
//! instead of once per request.  A batched forward stays bit-identical
//! to its per-request reference (row independence; DESIGN.md §§11-12).
//!
//! Requests are routed by model *name* ([`Server::submit`]) or by global
//! registry index ([`Server::submit_at`]).  Admission control: `submit`
//! blocks while the shard's queue is at `queue_depth` (backpressure);
//! [`Server::try_submit`] instead fails fast with the typed
//! [`SubmitError::QueueFull`], which the HTTP frontend maps to
//! `429 Retry-After`.  An executor `Err` fails that batch's requests
//! without taking the server down.  Per-model counters are recorded
//! live after every batch ([`Server::stats`] — the `/metrics` feed);
//! shutdown stops admission, drains every pending request, and returns
//! the final [`ServeStats`].

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::batcher::{Batch, Batcher, BatchPolicy, FlushCause, ShapeKey};
use super::cache::{CacheStats, FlightValue, ForwardCache, Lookup};
use super::executor::{ExecStats, ModelExecutor, ModelStats, ServeStats};
use crate::trace::{AnnValue, CounterId, SpanCtx, Timing, TraceCollector, TraceEvent, TrackId};

/// A fulfilled request.
#[derive(Clone, Debug)]
pub struct Response {
    pub y: Vec<f32>,
    /// Requests coalesced into the batch that served this one.
    pub batch_size: usize,
    pub cause: FlushCause,
    /// Where this request's time went (always recorded; the marks are a
    /// handful of monotonic-clock reads per batch).
    pub timing: Timing,
    /// The request's span id when the server runs with a trace
    /// collector ([`Server::start_sharded_traced`]); `None` otherwise.
    pub span_id: Option<u64>,
}

/// Typed submission failure, so callers (the HTTP frontend above all)
/// can map outcomes to distinct actions without string matching:
/// `QueueFull` → 429 + Retry-After, `ShuttingDown` → 503,
/// `UnknownModel` → 404, `BadRequest` → 400, `Failed` → 500.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The target shard's admission queue is at `queue_depth`; the
    /// request was **not** admitted and may be retried.
    QueueFull {
        /// The depth it hit, for Retry-After style hints.
        queue_depth: usize,
    },
    /// Admission is closed; no further request will be served.
    ShuttingDown,
    /// No such model name / registry index.
    UnknownModel(String),
    /// The request itself is malformed (shape mismatch).
    BadRequest(String),
    /// Admitted, but the model's executor failed this batch (or the
    /// server dropped the response channel).
    Failed(String),
    /// Admitted, but the response did not arrive within
    /// [`TRY_RESPONSE_TIMEOUT`] — the non-blocking path gives its
    /// caller's thread back instead of waiting out a wedged executor.
    /// The request itself is still in flight and will be executed.
    ResponseTimeout,
}

/// Ceiling on how long a submitter waits for an admitted request's
/// response — and, for the blocking path, on its admission wait.
/// Batching delay is deadline-bounded, so this only triggers on an
/// executor wedged far beyond any sane batch duration.  It bounds
/// *every* submission path: `try_submit` so a slow model cannot pin
/// every HTTP handler thread (the frontend maps it to `503
/// Retry-After`), and the blocking `submit`/`submit_at` so a wedged
/// executor cannot pin in-process callers forever either.
pub const TRY_RESPONSE_TIMEOUT: Duration = Duration::from_secs(30);

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::QueueFull { queue_depth } => {
                write!(f, "admission queue full (depth {queue_depth})")
            }
            SubmitError::ShuttingDown => write!(f, "server is shutting down"),
            SubmitError::UnknownModel(what) => write!(f, "unknown model {what}"),
            SubmitError::BadRequest(msg) | SubmitError::Failed(msg) => write!(f, "{msg}"),
            SubmitError::ResponseTimeout => {
                write!(f, "timed out waiting for the model's response")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// Immutable registry-entry identity, kept on the shared side so
/// `submit` can validate and route without touching the executors (which
/// live on their shard's executor thread).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub d_in: usize,
    pub d_out: usize,
    /// Executor shard this model is pinned to.
    pub shard: usize,
}

struct Job {
    x: Vec<f32>,
    rows: u32,
    resp: mpsc::Sender<std::result::Result<Response, String>>,
    /// Span minted at this request's admission point (`Some` exactly
    /// when the server has a trace collector).  Rides with the payload
    /// — batcher tickets stay span-agnostic and the ticket id keys the
    /// two together.
    span: Option<SpanCtx>,
}

struct State {
    batcher: Batcher,
    /// Ticket id → payload for every admitted-but-unserved request.
    jobs: BTreeMap<u64, Job>,
    shutdown: bool,
    peak_queued: usize,
}

/// One executor shard: its own admission queue, condvars, and live
/// counters.  The executor thread owns the shard's executors; everything
/// here is the shared side.
struct Shard {
    state: Mutex<State>,
    /// Submitters waiting for queue space.
    space: Condvar,
    /// Executor waiting for work or a deadline.
    work: Condvar,
    /// Live per-executor counters (shard-local registry order), updated
    /// once per executed batch — the `/metrics` feed.
    stats: Mutex<Vec<ExecStats>>,
    /// Requests popped from the queue but not yet replied to (the batch
    /// currently inside the executor).  Together with the live queue
    /// depth this is the load signal `StatsResponse` v2 exports for the
    /// router's least-loaded policy; an atomic so readers never touch
    /// the state mutex on the executor hot path.
    in_flight: AtomicUsize,
}

/// The two trace tracks owned by one shard: batch slices on one, the
/// per-request slices of those batches on a companion track (slices on
/// a single Perfetto track must nest, and a batch's requests overlap
/// their batch but not each other's parents).
#[derive(Clone, Copy)]
struct ShardTracks {
    batch: TrackId,
    req: TrackId,
}

/// The counter tracks owned by one shard (Perfetto COUNTER TrackEvents,
/// kept in the collector's counter registry so slice-track consumers
/// like [`TraceCollector::snapshot`] never see them): admission-queue
/// depth sampled at every batch pop, and cumulative executed payload
/// bytes sampled after every batch.
#[derive(Clone, Copy)]
struct ShardCounters {
    queue: CounterId,
    traffic: CounterId,
}

struct Shared {
    shards: Vec<Shard>,
    /// Global registry order (= `submit_at` index order).
    meta: Vec<ModelMeta>,
    /// Global registry index → (shard, shard-local index).
    route: Vec<(u32, u32)>,
    /// Clock epoch for every µs timestamp (ticket enqueue, batch
    /// release, span marks).  When a tracer is attached this is *its*
    /// epoch, so server and handler timestamps share one timeline.
    epoch: Instant,
    tracer: Option<Arc<TraceCollector>>,
    /// Per-shard trace tracks; empty without a tracer.
    shard_tracks: Vec<ShardTracks>,
    /// Per-shard counter tracks; empty without a tracer.
    shard_counters: Vec<ShardCounters>,
    /// Content-addressed result cache + singleflight ([`super::cache`]);
    /// `None` (the default) leaves the submit path exactly as before.
    cache: Option<Arc<ForwardCache>>,
    /// Track for cache hit/coalesced slices (`Some` exactly when both a
    /// tracer and a cache are attached).  Cached requests never reach a
    /// shard's request track, so they get their own.
    cache_track: Option<TrackId>,
    /// Counter track for cache occupancy bytes (`Some` exactly when both
    /// a tracer and a cache are attached).
    cache_counter: Option<CounterId>,
}

fn now_us(shared: &Shared) -> u64 {
    shared.epoch.elapsed().as_micros() as u64
}

pub struct Server {
    shared: Arc<Shared>,
    exec: Mutex<Option<Vec<std::thread::JoinHandle<()>>>>,
}

impl Server {
    /// Single-shard server: one executor thread drives the whole
    /// registry (the PR-2/PR-3 behavior, unchanged).
    pub fn start(executors: Vec<Box<dyn ModelExecutor>>, policy: BatchPolicy) -> Result<Server> {
        Self::start_sharded(executors, policy, 1)
    }

    /// Validate the registry, partition it round-robin across
    /// `min(n_shards, registry len)` executor shards, spawn one executor
    /// thread per shard, and start serving.  Fails (instead of
    /// panicking) on an empty registry, duplicate model names, or
    /// thread-spawn failure.  Each shard applies `policy` independently
    /// (its own batcher and `queue_depth`).
    pub fn start_sharded(
        executors: Vec<Box<dyn ModelExecutor>>,
        policy: BatchPolicy,
        n_shards: usize,
    ) -> Result<Server> {
        Self::start_sharded_traced(executors, policy, n_shards, None)
    }

    /// [`Self::start_sharded`] with an optional trace collector.  With
    /// `Some`, every submission gets a [`SpanCtx`] (minted here or
    /// passed in by a frontend via [`Self::try_submit_span`]), each
    /// shard registers a batch track and a request track, and the
    /// server's clock epoch is the collector's, so all timestamps share
    /// one timeline.  Forwards stay bit-identical either way: tracing
    /// only reads clocks and appends to per-shard ring buffers.
    pub fn start_sharded_traced(
        executors: Vec<Box<dyn ModelExecutor>>,
        policy: BatchPolicy,
        n_shards: usize,
        tracer: Option<Arc<TraceCollector>>,
    ) -> Result<Server> {
        Self::start_configured(executors, policy, n_shards, tracer, 0)
    }

    /// The full constructor: [`Self::start_sharded_traced`] plus an
    /// optional content-addressed result cache of `cache_bytes` capacity
    /// (0 = off — every other constructor delegates here with 0, so the
    /// default submit path is byte-for-byte the pre-cache code).  With a
    /// cache, submissions are probed first: verified hits return the
    /// stored rows without touching a shard, identical in-flight
    /// requests coalesce onto one executor submission (singleflight),
    /// and cold results are inserted when their leader's batch replies.
    /// Bit-identity is unaffected — the cache only ever replays rows the
    /// executor itself produced for the exact same `(model, row bytes)`.
    pub fn start_configured(
        executors: Vec<Box<dyn ModelExecutor>>,
        policy: BatchPolicy,
        n_shards: usize,
        tracer: Option<Arc<TraceCollector>>,
        cache_bytes: usize,
    ) -> Result<Server> {
        if executors.is_empty() {
            bail!("server needs at least one executor");
        }
        if executors.len() > u32::MAX as usize {
            bail!("registry too large for ShapeKey's u32 index");
        }
        let n_shards = n_shards.clamp(1, executors.len());
        let mut meta = Vec::with_capacity(executors.len());
        let mut route = Vec::with_capacity(executors.len());
        let mut locals: Vec<u32> = vec![0; n_shards];
        for (i, e) in executors.iter().enumerate() {
            let shard = i % n_shards;
            meta.push(ModelMeta {
                name: e.name().to_string(),
                d_in: e.d_in(),
                d_out: e.d_out(),
                shard,
            });
            route.push((shard as u32, locals[shard]));
            locals[shard] += 1;
        }
        for (i, m) in meta.iter().enumerate() {
            if m.d_in == 0 || m.d_out == 0 {
                bail!("model {:?} has degenerate width {}x{}", m.name, m.d_in, m.d_out);
            }
            if meta[..i].iter().any(|o| o.name == m.name) {
                bail!("duplicate model name {:?} in registry", m.name);
            }
        }
        let shards: Vec<Shard> = locals
            .iter()
            .map(|&n| Shard {
                state: Mutex::new(State {
                    batcher: Batcher::new(policy),
                    jobs: BTreeMap::new(),
                    shutdown: false,
                    peak_queued: 0,
                }),
                space: Condvar::new(),
                work: Condvar::new(),
                stats: Mutex::new(vec![ExecStats::default(); n as usize]),
                in_flight: AtomicUsize::new(0),
            })
            .collect();
        let shard_tracks = match &tracer {
            Some(t) => (0..n_shards)
                .map(|s| ShardTracks {
                    batch: t.register_track(&format!("shard {s}")),
                    req: t.register_track(&format!("shard {s} req")),
                })
                .collect(),
            None => Vec::new(),
        };
        let shard_counters = match &tracer {
            Some(t) => (0..n_shards)
                .map(|s| ShardCounters {
                    queue: t.register_counter_track(&format!("shard {s} queue")),
                    traffic: t.register_counter_track(&format!("shard {s} traffic bytes")),
                })
                .collect(),
            None => Vec::new(),
        };
        let epoch = tracer.as_ref().map(|t| t.epoch()).unwrap_or_else(Instant::now);
        let cache = (cache_bytes > 0)
            .then(|| ForwardCache::new(cache_bytes, meta.iter().map(|m| m.name.clone()).collect()));
        let cache_track = match (&tracer, &cache) {
            (Some(t), Some(_)) => Some(t.register_track("cache")),
            _ => None,
        };
        let cache_counter = match (&tracer, &cache) {
            (Some(t), Some(_)) => Some(t.register_counter_track("cache bytes")),
            _ => None,
        };
        let shared = Arc::new(Shared {
            shards,
            meta,
            route,
            epoch,
            tracer,
            shard_tracks,
            shard_counters,
            cache,
            cache_track,
            cache_counter,
        });

        // Hand each shard its slice of the registry, preserving
        // shard-local order (global index i lives at local slot i / n).
        let mut per_shard: Vec<Vec<Box<dyn ModelExecutor>>> =
            (0..n_shards).map(|_| Vec::new()).collect();
        for (i, e) in executors.into_iter().enumerate() {
            per_shard[i % n_shards].push(e);
        }
        let mut threads = Vec::with_capacity(n_shards);
        for (s, execs) in per_shard.into_iter().enumerate() {
            let worker = Arc::clone(&shared);
            let spawned = std::thread::Builder::new()
                .name(format!("flashkat-serve-{s}"))
                .spawn(move || executor_loop(&worker, s, execs));
            match spawned {
                Ok(handle) => threads.push(handle),
                Err(e) => {
                    // Already-spawned shards would otherwise park forever
                    // on their work condvars: shut them down before
                    // reporting the failure.
                    for shard in &shared.shards {
                        let mut st = shard.state.lock().unwrap();
                        st.shutdown = true;
                        shard.work.notify_one();
                    }
                    for t in threads {
                        let _ = t.join();
                    }
                    bail!("spawning serve executor thread {s}: {e}");
                }
            }
        }
        Ok(Server { shared, exec: Mutex::new(Some(threads)) })
    }

    /// Registry metadata, in global registry (= `submit_at` index) order.
    pub fn models(&self) -> &[ModelMeta] {
        &self.shared.meta
    }

    /// Executor shard count.
    pub fn shards(&self) -> usize {
        self.shared.shards.len()
    }

    /// The trace collector this server was started with, if any.  The
    /// network frontends use it to register their handler tracks and
    /// mint spans at *their* admission points.
    pub fn tracer(&self) -> Option<&Arc<TraceCollector>> {
        self.shared.tracer.as_ref()
    }

    /// Mint a span at an outer admission point (HTTP route, wire
    /// handler) so `t_admit_us` covers the frontend's own work.  `None`
    /// without a collector — spans cost nothing when tracing is off.
    pub fn mint_span(&self, model: &str, rows: u32) -> Option<SpanCtx> {
        self.shared.tracer.as_ref().map(|t| t.mint(model, rows))
    }

    /// Registry index of a model name.
    pub fn model_index(&self, name: &str) -> Option<u32> {
        self.shared.meta.iter().position(|m| m.name == name).map(|i| i as u32)
    }

    /// Admitted-but-unserved request count across all shards (diagnostic).
    pub fn queued(&self) -> usize {
        self.shared
            .shards
            .iter()
            .map(|s| s.state.lock().unwrap().batcher.queued())
            .sum()
    }

    /// Live `(queue_depth, in_flight)` per shard: requests admitted but
    /// not yet popped, and requests inside the executor but not yet
    /// replied to.  This is the load signal `StatsResponse` carries in
    /// its v2 tail (the router's `--policy least-loaded` input) and the
    /// `/metrics` queue-depth/in-flight gauges.
    pub fn shard_loads(&self) -> Vec<(usize, usize)> {
        self.shared
            .shards
            .iter()
            .map(|s| {
                let queued = s.state.lock().unwrap().batcher.queued();
                (queued, s.in_flight.load(Ordering::Relaxed))
            })
            .collect()
    }

    /// Live counter snapshot: per-model stats recorded after every
    /// executed batch, plus each shard's peak queue depth.  Safe to call
    /// at any time (the `/metrics` endpoint does, per scrape); after
    /// [`Self::shutdown`] it returns the same final totals.
    pub fn stats(&self) -> ServeStats {
        let shared = &self.shared;
        // One lock round-trip per shard (these mutexes sit on the
        // executor hot path), then assemble per_model from the copies.
        let per_shard: Vec<Vec<ExecStats>> = shared
            .shards
            .iter()
            .map(|s| s.stats.lock().unwrap().clone())
            .collect();
        let per_model = shared
            .meta
            .iter()
            .enumerate()
            .map(|(i, m)| {
                let (s, l) = shared.route[i];
                let stats = per_shard[s as usize][l as usize].clone();
                ModelStats { name: m.name.clone(), d_in: m.d_in, d_out: m.d_out, stats }
            })
            .collect();
        let shard_peaks: Vec<usize> = shared
            .shards
            .iter()
            .map(|s| s.state.lock().unwrap().peak_queued)
            .collect();
        ServeStats {
            per_model,
            peak_queued: shard_peaks.iter().copied().max().unwrap_or(0),
            shard_peaks,
        }
    }

    /// Submit one request to the named model and block until served.
    pub fn submit(&self, model: &str, x: Vec<f32>, rows: u32) -> Result<Response> {
        let idx = self
            .model_index(model)
            .with_context(|| format!("unknown model {model:?}"))?;
        self.submit_at(idx, x, rows)
    }

    /// Submit by global registry index.  Blocks at admission while the
    /// shard's queue is at depth (backpressure), then until the response
    /// is computed; fails fast on a shape mismatch, once shutdown has
    /// begun, or when the model's executor reports an error for this
    /// batch.
    pub fn submit_at(&self, model: u32, x: Vec<f32>, rows: u32) -> Result<Response> {
        self.submit_inner(model, x, rows, true, None).map_err(|e| anyhow!("{e}"))
    }

    /// Non-blocking admission to the named model: where [`Self::submit`]
    /// would wait for queue space, this returns
    /// [`SubmitError::QueueFull`] immediately (load shedding — the HTTP
    /// 429 path).  Once admitted it still waits for the response, which
    /// is the part with a deadline-bounded latency.
    pub fn try_submit(
        &self,
        model: &str,
        x: Vec<f32>,
        rows: u32,
    ) -> std::result::Result<Response, SubmitError> {
        self.try_submit_span(model, x, rows, None)
    }

    /// [`Self::try_submit`] carrying a span minted earlier at an outer
    /// admission point (the HTTP route / wire handler), so the span's
    /// `t_admit_us` includes the frontend's parse time.  `None` behaves
    /// exactly like `try_submit` (a span is minted here if the server
    /// has a collector).
    pub fn try_submit_span(
        &self,
        model: &str,
        x: Vec<f32>,
        rows: u32,
        span: Option<SpanCtx>,
    ) -> std::result::Result<Response, SubmitError> {
        let idx = self
            .model_index(model)
            .ok_or_else(|| SubmitError::UnknownModel(format!("{model:?}")))?;
        self.submit_inner(idx, x, rows, false, span)
    }

    /// [`Self::try_submit`] by global registry index.
    pub fn try_submit_at(
        &self,
        model: u32,
        x: Vec<f32>,
        rows: u32,
    ) -> std::result::Result<Response, SubmitError> {
        self.submit_inner(model, x, rows, false, None)
    }

    fn submit_inner(
        &self,
        model: u32,
        x: Vec<f32>,
        rows: u32,
        block: bool,
        span: Option<SpanCtx>,
    ) -> std::result::Result<Response, SubmitError> {
        let m = self
            .shared
            .meta
            .get(model as usize)
            .ok_or_else(|| SubmitError::UnknownModel(format!("index {model}")))?;
        if x.len() != rows as usize * m.d_in {
            return Err(SubmitError::BadRequest(format!(
                "request shape mismatch for {:?}: {} values for {} rows of d_in={}",
                m.name,
                x.len(),
                rows,
                m.d_in
            )));
        }
        // Mint here (the in-process admission point) unless a frontend
        // already minted at its own, earlier one.
        let span = span.or_else(|| self.shared.tracer.as_ref().map(|t| t.mint(&m.name, rows)));
        let Some(cache) = &self.shared.cache else {
            return self.submit_cold(model, x, rows, block, span);
        };
        match cache.lookup(model, &x) {
            Lookup::Hit(y) => {
                // Verified hit: the stored rows are bit-exact replays of
                // an earlier executor reply for this same key.  No batch
                // exists, so the timing breakdown is all-zero and the
                // cause says so.
                self.record_cache_event(&span, "hit");
                Ok(Response {
                    y,
                    batch_size: 1,
                    cause: FlushCause::Cache,
                    timing: Timing::default(),
                    span_id: span.as_ref().map(|s| s.span_id),
                })
            }
            Lookup::Join(rx) => {
                // An identical request is already executing; park on the
                // leader's completion.  The leader's typed error (or its
                // drop-guard failure) fans out here — followers never
                // wedge.
                let outcome = rx.recv_timeout(TRY_RESPONSE_TIMEOUT).map_err(|e| match e {
                    mpsc::RecvTimeoutError::Timeout => SubmitError::ResponseTimeout,
                    mpsc::RecvTimeoutError::Disconnected => {
                        SubmitError::Failed("cache leader dropped the flight".to_string())
                    }
                })?;
                let v = outcome?;
                self.record_cache_event(&span, "coalesced");
                Ok(Response {
                    y: v.y,
                    batch_size: v.batch_size,
                    cause: v.cause,
                    timing: v.timing,
                    span_id: span.as_ref().map(|s| s.span_id),
                })
            }
            Lookup::Lead(token) => {
                let res = self.submit_cold(model, x, rows, block, span);
                match &res {
                    Ok(r) => token.publish(Ok(FlightValue {
                        y: r.y.clone(),
                        batch_size: r.batch_size,
                        cause: r.cause,
                        timing: r.timing,
                    })),
                    Err(e) => token.publish(Err(e.clone())),
                }
                // Occupancy moved (insert and possibly evictions):
                // sample the cache-bytes counter track.
                self.sample_cache_bytes();
                res
            }
            // Hash-slot collision with a different key: execute without
            // publishing (verification keeps collisions harmless).
            Lookup::Solo => self.submit_cold(model, x, rows, block, span),
        }
    }

    /// The pre-cache submit path: route to the model's shard, admit into
    /// its batcher (blocking or shedding per `block`), and wait for the
    /// executed batch's reply.
    fn submit_cold(
        &self,
        model: u32,
        x: Vec<f32>,
        rows: u32,
        block: bool,
        span: Option<SpanCtx>,
    ) -> std::result::Result<Response, SubmitError> {
        let m = &self.shared.meta[model as usize];
        let (s, local) = self.shared.route[model as usize];
        let shard = &self.shared.shards[s as usize];
        let key = ShapeKey { model: local, d: m.d_in as u32 };
        let (tx, rx) = mpsc::channel();
        // The blocking path's backpressure wait is bounded too: against
        // a wedged executor nothing ever frees queue space, and an
        // unbounded wait would pin in-process callers forever while
        // HTTP/wire callers shed with a 503.  Expiry is a truthful
        // `QueueFull` — the request was never admitted and may retry.
        let admit_deadline = Instant::now() + TRY_RESPONSE_TIMEOUT;
        {
            let mut st = shard.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return Err(SubmitError::ShuttingDown);
                }
                let now = now_us(&self.shared);
                if let Some(ticket) = st.batcher.admit(key, now) {
                    st.jobs.insert(ticket.id, Job { x, rows, resp: tx, span });
                    st.peak_queued = st.peak_queued.max(st.batcher.queued());
                    break;
                }
                let queue_full = SubmitError::QueueFull {
                    queue_depth: st.batcher.policy().queue_depth,
                };
                if !block {
                    return Err(queue_full);
                }
                let left = admit_deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    return Err(queue_full);
                }
                st = shard.space.wait_timeout(st, left).unwrap().0;
            }
            shard.work.notify_one();
        }
        // Once admitted, every path bounds its response wait the same
        // way: batching delay is deadline-bounded, so only a wedged
        // executor reaches the timeout.  The request stays in flight
        // and will still be executed.
        let outcome = rx.recv_timeout(TRY_RESPONSE_TIMEOUT).map_err(|e| match e {
            mpsc::RecvTimeoutError::Timeout => SubmitError::ResponseTimeout,
            mpsc::RecvTimeoutError::Disconnected => {
                SubmitError::Failed("server dropped the request".to_string())
            }
        });
        match outcome? {
            Ok(resp) => Ok(resp),
            Err(msg) => Err(SubmitError::Failed(format!("model {:?}: {msg}", m.name))),
        }
    }

    /// Cache occupancy + per-model hit/miss/coalesced counters; `None`
    /// when the server runs without a cache.  Valid at any time,
    /// including after [`Self::shutdown`] (the bench reads the final
    /// numbers then).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.shared.cache.as_ref().map(|c| c.stats())
    }

    /// Sample the cache's current occupancy onto its counter track;
    /// no-op unless both a tracer and a cache are attached.
    fn sample_cache_bytes(&self) {
        let (Some(tracer), Some(counter), Some(cache)) =
            (&self.shared.tracer, self.shared.cache_counter, &self.shared.cache)
        else {
            return;
        };
        tracer.record_counter(counter, tracer.now_us(), cache.stats().bytes as u64);
    }

    /// Emit a slice on the cache track for a request served off the
    /// cache path (it never reaches a shard's request track).  The
    /// `cause` annotation distinguishes verified hits from coalesced
    /// followers.
    fn record_cache_event(&self, span: &Option<SpanCtx>, cause: &'static str) {
        let (Some(tracer), Some(track), Some(span)) =
            (&self.shared.tracer, self.shared.cache_track, span)
        else {
            return;
        };
        let t1 = tracer.now_us().max(span.t_admit_us);
        tracer.record(TraceEvent {
            track,
            name: format!("cache {}", span.model),
            t0_us: span.t_admit_us,
            t1_us: t1,
            args: vec![
                ("span_id", AnnValue::U64(span.span_id)),
                ("rows", AnnValue::U64(u64::from(span.rows))),
                ("cause", AnnValue::Str(cause.to_string())),
            ],
        });
    }

    /// Stop admission on every shard, drain pending requests, and join
    /// the executor threads.  Returns `None` if a previous call already
    /// collected the stats.
    pub fn shutdown(&self) -> Option<ServeStats> {
        let threads = self.exec.lock().unwrap().take()?;
        for shard in &self.shared.shards {
            let mut st = shard.state.lock().unwrap();
            st.shutdown = true;
            shard.work.notify_one();
            shard.space.notify_all();
        }
        for t in threads {
            t.join().expect("serve executor panicked");
        }
        Some(self.stats())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Batch-local buffers, reused across batches so the steady-state hot
/// path allocates only the per-request response vectors.
#[derive(Default)]
struct Scratch {
    xcat: Vec<f32>,
    ycat: Vec<f32>,
}

fn executor_loop(shared: &Shared, shard_idx: usize, mut executors: Vec<Box<dyn ModelExecutor>>) {
    let shard = &shared.shards[shard_idx];
    let mut scratch = Scratch::default();
    let mut st = shard.state.lock().unwrap();
    loop {
        let now = now_us(shared);
        if let Some(batch) = st.batcher.pop(now, true) {
            // Queue-depth counter sample: depth *after* this batch left
            // the queue, read while the lock is still held so the value
            // and its timestamp are consistent.
            let queued = st.batcher.queued() as u64;
            let jobs = detach_jobs(&mut st, &batch);
            drop(st);
            shard.space.notify_all();
            if let (Some(t), Some(c)) = (&shared.tracer, shared.shard_counters.get(shard_idx)) {
                t.record_counter(c.queue, now, queued);
            }
            execute(shared, shard_idx, &mut executors, &batch, jobs, &mut scratch);
            st = shard.state.lock().unwrap();
            continue;
        }
        if st.shutdown {
            // `pop` came back empty; with a non-eager policy requests may
            // still be waiting on deadlines — drain them unconditionally.
            let batches = st.batcher.drain(now);
            let drained: Vec<(Batch, Vec<Job>)> = batches
                .into_iter()
                .map(|b| {
                    let jobs = detach_jobs(&mut st, &b);
                    (b, jobs)
                })
                .collect();
            drop(st);
            shard.space.notify_all();
            for (batch, jobs) in drained {
                execute(shared, shard_idx, &mut executors, &batch, jobs, &mut scratch);
            }
            return;
        }
        st = match st.batcher.next_deadline_us() {
            // Partial buckets pending (non-eager policy): sleep until the
            // earliest deadline, then loop to flush it.
            Some(due) => {
                let wait = Duration::from_micros(due.saturating_sub(now_us(shared)));
                shard.work.wait_timeout(st, wait).unwrap().0
            }
            None => shard.work.wait(st).unwrap(),
        };
    }
}

fn detach_jobs(st: &mut State, batch: &Batch) -> Vec<Job> {
    batch
        .tickets
        .iter()
        .map(|t| st.jobs.remove(&t.id).expect("payload for admitted ticket"))
        .collect()
}

/// Run one coalesced batch through its model's executor, record the
/// outcome (including each request's timing breakdown) in the shard's
/// live counters, fan the rows back out to the requesters, and — when
/// a tracer is attached — emit the batch slice and one request slice
/// per member onto the shard's tracks.
fn execute(
    shared: &Shared,
    shard_idx: usize,
    executors: &mut [Box<dyn ModelExecutor>],
    batch: &Batch,
    jobs: Vec<Job>,
    scratch: &mut Scratch,
) {
    let shard = &shared.shards[shard_idx];
    // In-flight gauge: covers the whole executor occupancy, assembly
    // through reply fan-out (queue depth stops counting these at pop).
    shard.in_flight.fetch_add(jobs.len(), Ordering::Relaxed);
    let idx = batch.key.model as usize;
    let exec = &mut executors[idx];
    let d_in = exec.d_in();
    let d_out = exec.d_out();
    let total_rows: usize = jobs.iter().map(|j| j.rows as usize).sum();

    scratch.xcat.clear();
    scratch.xcat.reserve(total_rows * d_in);
    for job in &jobs {
        scratch.xcat.extend_from_slice(&job.x);
    }
    // Span marks: release → exec0 is batch formation (the assembly
    // above), exec0 → exec1 is the executor call.  All subtractions
    // saturate — a ticket admitted between the pop's `now` capture and
    // here can carry `enq_us` a hair past `released_us`.
    let t_exec0 = now_us(shared);
    let t0 = Instant::now();
    // Executors are documented never to panic, but a third-party
    // implementation (or an FFI abort surfacing as a panic) must not
    // unwind this thread: that would strand every queued and future
    // submitter on a channel nobody serves.  Contain it to this batch.
    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        exec.run(&scratch.xcat, total_rows, &mut scratch.ycat)
    }))
    .unwrap_or_else(|_| Err(anyhow::anyhow!("executor panicked")));
    let busy = t0.elapsed().as_secs_f64();
    let t_exec1 = now_us(shared);
    let batch_form_us = t_exec0.saturating_sub(batch.released_us);
    let exec_us = t_exec1.saturating_sub(t_exec0);

    let size = jobs.len();
    let failure = match run {
        Ok(()) if scratch.ycat.len() == total_rows * d_out => None,
        Ok(()) => Some(format!(
            "executor returned {} values, expected {} ({total_rows} rows x d_out={d_out})",
            scratch.ycat.len(),
            total_rows * d_out
        )),
        Err(e) => Some(format!("{e:#}")),
    };
    let shard_traffic;
    {
        let stats_vec = &mut *shard.stats.lock().unwrap();
        let stats = &mut stats_vec[idx];
        stats.record(size, total_rows, batch.cause, busy);
        if failure.is_some() {
            stats.failed += size;
        } else {
            // Per-request timing samples (served requests only): queue
            // wait is admission → release, exec is the batch's run.
            for ticket in &batch.tickets {
                stats.record_request_timing(
                    batch.released_us.saturating_sub(ticket.enq_us),
                    exec_us,
                );
            }
            // Payload traffic for the /metrics feed and the shard's
            // cumulative traffic counter track: rows actually executed
            // times each side's f32 row width.
            stats.record_traffic((total_rows * d_in * 4) as u64, (total_rows * d_out * 4) as u64);
        }
        // Cumulative bytes moved by this shard (all its models), read
        // under the same lock that just updated it.
        shard_traffic = stats_vec.iter().map(|s| s.bytes_in + s.bytes_out).sum::<u64>();
    }
    if let (Some(t), Some(c)) = (&shared.tracer, shared.shard_counters.get(shard_idx)) {
        t.record_counter(c.traffic, t_exec1, shard_traffic);
    }

    let tracer = shared.tracer.as_ref();
    let mut events: Vec<TraceEvent> = Vec::new();
    if tracer.is_some() {
        let tracks = &shared.shard_tracks[shard_idx];
        let mut args = vec![
            ("cause", AnnValue::Str(batch.cause.label().to_string())),
            ("batch_size", AnnValue::U64(size as u64)),
            ("rows", AnnValue::U64(total_rows as u64)),
        ];
        if failure.is_some() {
            args.push(("failed", AnnValue::U64(size as u64)));
        }
        events.push(TraceEvent {
            track: tracks.batch,
            name: format!("batch {}", exec.name()),
            t0_us: batch.released_us,
            t1_us: t_exec1,
            args,
        });
    }

    if let Some(msg) = failure {
        for job in jobs {
            // A requester that gave up is not an executor error.
            let _ = job.resp.send(Err(msg.clone()));
        }
        if let Some(t) = tracer {
            t.record_many(events);
        }
        shard.in_flight.fetch_sub(size, Ordering::Relaxed);
        return;
    }

    let mut off = 0usize;
    for (ticket, job) in batch.tickets.iter().zip(jobs) {
        let n = job.rows as usize * d_out;
        let y = scratch.ycat[off..off + n].to_vec();
        off += n;
        let t_reply = now_us(shared);
        let timing = Timing {
            queue_wait_us: batch.released_us.saturating_sub(ticket.enq_us),
            batch_form_us,
            exec_us,
            reply_us: t_reply.saturating_sub(t_exec1),
        };
        // `shard_tracks` is non-empty exactly when a tracer is attached
        // (a caller-supplied span on an untraced server records nothing).
        if let (Some(span), Some(tracks)) = (&job.span, shared.shard_tracks.get(shard_idx)) {
            // Request slices share the batch's exec start and end at
            // their reply, so slices of one batch nest on the request
            // track; the wait breakdown rides as annotations.
            events.push(TraceEvent {
                track: tracks.req,
                name: format!("req {}", span.model),
                t0_us: t_exec0,
                t1_us: t_reply,
                args: vec![
                    ("span_id", AnnValue::U64(span.span_id)),
                    ("rows", AnnValue::U64(u64::from(job.rows))),
                    ("admit_us", AnnValue::U64(span.t_admit_us)),
                    ("queue_wait_us", AnnValue::U64(timing.queue_wait_us)),
                    ("batch_form_us", AnnValue::U64(timing.batch_form_us)),
                    ("exec_us", AnnValue::U64(timing.exec_us)),
                    ("reply_us", AnnValue::U64(timing.reply_us)),
                ],
            });
        }
        let span_id = job.span.as_ref().map(|s| s.span_id);
        let _ = job
            .resp
            .send(Ok(Response { y, batch_size: size, cause: batch.cause, timing, span_id }));
    }
    shard.in_flight.fetch_sub(size, Ordering::Relaxed);
    if let Some(t) = tracer {
        t.record_many(events);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rational::{forward, Coeffs};
    use crate::serve::executor::RationalExecutor;
    use crate::util::rng::Pcg64;

    const D: usize = 64;
    const GROUPS: usize = 8;

    fn model(seed: u64) -> (Box<dyn ModelExecutor>, Coeffs<f32>) {
        let mut rng = Pcg64::new(seed);
        let coeffs = Coeffs::<f32>::randn(GROUPS, 6, 4, &mut rng);
        (Box::new(RationalExecutor::new("grkan", D, coeffs.clone()).unwrap()), coeffs)
    }

    fn request(seed: u64, id: u64) -> (u32, Vec<f32>) {
        let mut rng = Pcg64::with_stream(seed, id);
        let rows = 1 + rng.below(4) as u32;
        let x = (0..rows as usize * D).map(|_| rng.normal_f32()).collect();
        (rows, x)
    }

    #[test]
    fn batched_output_matches_unbatched_forward() {
        let (m, coeffs) = model(5);
        let server = Server::start(
            vec![m],
            BatchPolicy { max_batch: 8, deadline_us: 500, queue_depth: 64, eager: true },
        )
        .unwrap();
        std::thread::scope(|s| {
            for client in 0..4u64 {
                let server = &server;
                let coeffs = &coeffs;
                s.spawn(move || {
                    for i in 0..25u64 {
                        let (rows, x) = request(5, client * 100 + i);
                        let want = forward(&x, rows as usize, D, coeffs);
                        let resp = server.submit("grkan", x, rows).expect("served");
                        assert_eq!(resp.y, want, "batched != unbatched for req {client}/{i}");
                        assert!(resp.batch_size >= 1);
                    }
                });
            }
        });
        let stats = server.shutdown().expect("first shutdown collects stats");
        let total = stats.total();
        assert_eq!(total.requests, 100);
        assert_eq!(total.failed, 0);
        assert!(total.rows > 0);
        let hist_total: usize =
            total.batch_hist.iter().enumerate().map(|(size, n)| size * n).sum();
        assert_eq!(hist_total, 100, "histogram accounts for every request");
        // Single-model registry: the per-model split IS the total.
        assert_eq!(stats.per_model.len(), 1);
        assert_eq!(stats.per_model[0].name, "grkan");
        assert_eq!(stats.per_model[0].stats, total);
        assert_eq!(stats.shard_peaks.len(), 1, "single shard by default");
    }

    #[test]
    fn routes_by_name_with_per_model_stats() {
        let mut rng = Pcg64::new(31);
        let cw = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
        let cn = Coeffs::<f32>::randn(4, 6, 4, &mut rng);
        let server = Server::start(
            vec![
                Box::new(RationalExecutor::new("wide", 64, cw.clone()).unwrap()),
                Box::new(RationalExecutor::new("narrow", 16, cn.clone()).unwrap()),
            ],
            BatchPolicy { max_batch: 8, deadline_us: 300, queue_depth: 64, eager: true },
        )
        .unwrap();
        assert_eq!(server.model_index("narrow"), Some(1));
        assert_eq!(server.model_index("nope"), None);
        std::thread::scope(|s| {
            for client in 0..4u64 {
                let server = &server;
                let (cw, cn) = (&cw, &cn);
                s.spawn(move || {
                    for i in 0..10u64 {
                        let mut rng = Pcg64::with_stream(31, client * 100 + i);
                        let (name, d, c): (&str, usize, &Coeffs<f32>) =
                            if (client + i) % 2 == 0 { ("wide", 64, cw) } else { ("narrow", 16, cn) };
                        let rows = 1 + rng.below(3);
                        let x: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
                        let want = forward(&x, rows, d, c);
                        let got = server.submit(name, x, rows as u32).expect("served").y;
                        assert_eq!(got, want, "{name} {client}/{i}");
                    }
                });
            }
        });
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.per_model.len(), 2);
        let total = stats.total();
        assert_eq!(total.requests, 40);
        let wide = stats.model("wide").unwrap();
        let narrow = stats.model("narrow").unwrap();
        assert_eq!(wide.stats.requests, 20);
        assert_eq!(narrow.stats.requests, 20);
        assert_eq!((wide.d_in, narrow.d_in), (64, 16));
        assert_eq!(wide.stats.requests + narrow.stats.requests, total.requests);
        assert_eq!(wide.stats.rows + narrow.stats.rows, total.rows);
        assert_eq!(wide.stats.batches + narrow.stats.batches, total.batches);
    }

    /// The same mixed workload served sharded: every output still
    /// bit-identical, per-model stats still sum to totals, and the
    /// shard layout is round-robin by registry index.
    #[test]
    fn sharded_server_routes_and_splits_stats() {
        let mut rng = Pcg64::new(33);
        let cw = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
        let cn = Coeffs::<f32>::randn(4, 6, 4, &mut rng);
        let server = Server::start_sharded(
            vec![
                Box::new(RationalExecutor::new("wide", 64, cw.clone()).unwrap()),
                Box::new(RationalExecutor::new("narrow", 16, cn.clone()).unwrap()),
            ],
            BatchPolicy { max_batch: 8, deadline_us: 300, queue_depth: 64, eager: true },
            2,
        )
        .unwrap();
        assert_eq!(server.shards(), 2);
        assert_eq!(server.models()[0].shard, 0);
        assert_eq!(server.models()[1].shard, 1);
        std::thread::scope(|s| {
            for client in 0..4u64 {
                let server = &server;
                let (cw, cn) = (&cw, &cn);
                s.spawn(move || {
                    for i in 0..10u64 {
                        let mut rng = Pcg64::with_stream(33, client * 100 + i);
                        let (name, d, c): (&str, usize, &Coeffs<f32>) =
                            if (client + i) % 2 == 0 { ("wide", 64, cw) } else { ("narrow", 16, cn) };
                        let rows = 1 + rng.below(3);
                        let x: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
                        let want = forward(&x, rows, d, c);
                        let got = server.submit(name, x, rows as u32).expect("served").y;
                        assert_eq!(got, want, "{name} {client}/{i}");
                    }
                });
            }
        });
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.shard_peaks.len(), 2);
        let total = stats.total();
        assert_eq!(total.requests, 40);
        assert_eq!(total.failed, 0);
        assert_eq!(stats.model("wide").unwrap().stats.requests, 20);
        assert_eq!(stats.model("narrow").unwrap().stats.requests, 20);
        assert!(stats.peak_queued <= 64);
    }

    /// Shared boolean + condvar (the test's wedge/release signal).
    type Flag = Arc<(Mutex<bool>, Condvar)>;

    /// An executor that blocks inside `run` until released, and reports
    /// when it has entered — the deterministic way to hold a shard busy.
    struct Gate {
        name: &'static str,
        entered: Flag,
        release: Flag,
    }

    impl Gate {
        fn pair(name: &'static str) -> (Box<dyn ModelExecutor>, Flag, Flag) {
            let entered: Flag = Arc::new((Mutex::new(false), Condvar::new()));
            let release: Flag = Arc::new((Mutex::new(false), Condvar::new()));
            (
                Box::new(Gate { name, entered: entered.clone(), release: release.clone() }),
                entered,
                release,
            )
        }

        fn wait_entered(entered: &Flag) {
            let (lock, cv) = &**entered;
            let mut e = lock.lock().unwrap();
            while !*e {
                e = cv.wait(e).unwrap();
            }
        }

        fn open(release: &Flag) {
            let (lock, cv) = &**release;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
    }

    impl ModelExecutor for Gate {
        fn name(&self) -> &str {
            self.name
        }
        fn d_in(&self) -> usize {
            4
        }
        fn d_out(&self) -> usize {
            4
        }
        fn run(&mut self, x: &[f32], _rows: usize, out: &mut Vec<f32>) -> Result<()> {
            {
                let (lock, cv) = &*self.entered;
                *lock.lock().unwrap() = true;
                cv.notify_all();
            }
            let (lock, cv) = &*self.release;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
            out.clear();
            out.extend_from_slice(x);
            Ok(())
        }
    }

    /// The sharding acceptance property, deterministically: with the
    /// slow model's shard wedged inside `run`, a fast model on the other
    /// shard still completes.  (On a single shard the fast request
    /// could not be served until the gate opened.)
    #[test]
    fn slow_shard_does_not_head_of_line_block_fast_shard() {
        let (gate, entered, release) = Gate::pair("slow");
        let (fast, coeffs) = model(44);
        let server = Server::start_sharded(
            vec![gate, fast],
            BatchPolicy { max_batch: 4, deadline_us: 100, queue_depth: 16, eager: true },
            2,
        )
        .unwrap();
        std::thread::scope(|s| {
            let server = &server;
            s.spawn(move || {
                let resp = server.submit("slow", vec![1.0; 4], 1).expect("served after release");
                assert_eq!(resp.y, vec![1.0; 4]);
            });
            // The slow shard is now provably wedged inside `run`.
            Gate::wait_entered(&entered);
            // A fast-model request must complete while it is wedged.
            let (rows, x) = request(44, 0);
            let want = forward(&x, rows as usize, D, &coeffs);
            assert_eq!(server.submit("grkan", x, rows).unwrap().y, want);
            // Live stats see the fast batch before the slow one finishes.
            let live = server.stats();
            assert_eq!(live.model("grkan").unwrap().stats.requests, 1);
            assert_eq!(live.model("slow").unwrap().stats.requests, 0);
            Gate::open(&release);
        });
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.total().requests, 2);
        assert_eq!(stats.total().failed, 0);
    }

    /// `try_submit` sheds load when the queue is saturated while `submit`
    /// keeps blocking: wedge the executor, fill the queue to depth, then
    /// observe the typed refusal and the blocking path's completion.
    #[test]
    fn try_submit_sheds_load_where_submit_blocks() {
        let (gate, entered, release) = Gate::pair("slow");
        let depth = 2;
        let server = Server::start_sharded(
            vec![gate],
            BatchPolicy { max_batch: 1, deadline_us: 50, queue_depth: depth, eager: true },
            1,
        )
        .unwrap();
        std::thread::scope(|s| {
            let server = &server;
            // First request is popped into a batch and wedges the executor.
            s.spawn(move || {
                server.submit("slow", vec![0.0; 4], 1).expect("served after release");
            });
            Gate::wait_entered(&entered);
            // Fill the admission queue to depth (these block for their
            // responses on their own threads).
            for _ in 0..depth {
                s.spawn(move || {
                    server.submit("slow", vec![0.0; 4], 1).expect("served after release");
                });
            }
            while server.queued() < depth {
                std::thread::sleep(Duration::from_millis(1));
            }
            // Non-blocking admission now refuses with the typed error...
            match server.try_submit("slow", vec![0.0; 4], 1) {
                Err(SubmitError::QueueFull { queue_depth }) => assert_eq!(queue_depth, depth),
                other => panic!("expected QueueFull, got {other:?}"),
            }
            // ...and `queued` is unchanged: the request was never admitted.
            assert_eq!(server.queued(), depth);
            // A blocking submit waits instead: start one, prove it is
            // still waiting, then release the gate and watch it finish.
            let blocked = s.spawn(move || {
                server.submit("slow", vec![0.0; 4], 1).expect("served after release")
            });
            std::thread::sleep(Duration::from_millis(20));
            assert!(!blocked.is_finished(), "submit must block, not error");
            Gate::open(&release);
            blocked.join().expect("blocked submit eventually served");
        });
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.total().requests, 2 + depth);
        assert!(stats.peak_queued <= depth);
    }

    #[test]
    fn try_submit_rejects_bad_requests_with_typed_errors() {
        let (m, _) = model(45);
        let server = Server::start(vec![m], BatchPolicy::default()).unwrap();
        assert!(matches!(
            server.try_submit("nope", vec![0.0; D], 1),
            Err(SubmitError::UnknownModel(_))
        ));
        assert!(matches!(
            server.try_submit_at(7, vec![0.0; D], 1),
            Err(SubmitError::UnknownModel(_))
        ));
        assert!(matches!(
            server.try_submit("grkan", vec![0.0; D - 1], 1),
            Err(SubmitError::BadRequest(_))
        ));
        server.shutdown();
        assert!(matches!(
            server.try_submit("grkan", vec![0.0; D], 1),
            Err(SubmitError::ShuttingDown)
        ));
    }

    #[test]
    fn registry_validation_rejects_bad_configs() {
        let (a, _) = model(40);
        let (b, _) = model(41);
        // Duplicate names: both executors are called "grkan".
        assert!(Server::start(vec![a, b], BatchPolicy::default()).is_err());
        assert!(Server::start(vec![], BatchPolicy::default()).is_err(), "empty registry");
        // Shard counts are clamped, not errors: 0 → 1, huge → registry len.
        let (c, _) = model(40);
        let s = Server::start_sharded(vec![c], BatchPolicy::default(), 0).unwrap();
        assert_eq!(s.shards(), 1);
        let (d, _) = model(40);
        let s = Server::start_sharded(vec![d], BatchPolicy::default(), 99).unwrap();
        assert_eq!(s.shards(), 1);
    }

    /// An executor whose `run` always fails: the batch's submitters get
    /// errors, the counters record the failure, and the server survives.
    struct Exploding;
    impl ModelExecutor for Exploding {
        fn name(&self) -> &str {
            "boom"
        }
        fn d_in(&self) -> usize {
            4
        }
        fn d_out(&self) -> usize {
            4
        }
        fn run(&mut self, _x: &[f32], _rows: usize, _out: &mut Vec<f32>) -> Result<()> {
            bail!("synthetic failure")
        }
    }

    #[test]
    fn executor_failure_is_an_error_not_a_crash() {
        let (m, coeffs) = model(42);
        let server = Server::start(vec![m, Box::new(Exploding)], BatchPolicy::default()).unwrap();
        let err = server.submit("boom", vec![0.0; 4], 1).unwrap_err().to_string();
        assert!(err.contains("synthetic failure"), "{err}");
        // The healthy model still serves after the failure.
        let (rows, x) = request(42, 0);
        let want = forward(&x, rows as usize, D, &coeffs);
        assert_eq!(server.submit("grkan", x, rows).unwrap().y, want);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.model("boom").unwrap().stats.failed, 1);
        assert_eq!(stats.model("grkan").unwrap().stats.failed, 0);
        assert_eq!(stats.total().failed, 1);
    }

    /// A panicking executor (contract violation) must be contained to
    /// its batch: submitters get errors, the thread survives, other
    /// models keep serving, shutdown still returns stats.
    struct Panicking;
    impl ModelExecutor for Panicking {
        fn name(&self) -> &str {
            "panicky"
        }
        fn d_in(&self) -> usize {
            4
        }
        fn d_out(&self) -> usize {
            4
        }
        fn run(&mut self, _x: &[f32], _rows: usize, _out: &mut Vec<f32>) -> Result<()> {
            panic!("synthetic executor panic")
        }
    }

    #[test]
    fn executor_panic_fails_the_batch_not_the_server() {
        let (m, coeffs) = model(43);
        let server =
            Server::start(vec![m, Box::new(Panicking)], BatchPolicy::default()).unwrap();
        let err = server.submit("panicky", vec![0.0; 4], 1).unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
        let (rows, x) = request(43, 0);
        let want = forward(&x, rows as usize, D, &coeffs);
        assert_eq!(server.submit("grkan", x, rows).unwrap().y, want);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.model("panicky").unwrap().stats.failed, 1);
        assert_eq!(stats.total().failed, 1);
    }

    #[test]
    fn lone_request_is_flushed_by_the_deadline() {
        let (m, _) = model(6);
        // Non-eager policy and a huge max_batch: only the deadline can
        // release this request.
        let server = Server::start(
            vec![m],
            BatchPolicy { max_batch: 64, deadline_us: 2_000, queue_depth: 64, eager: false },
        )
        .unwrap();
        let (rows, x) = request(6, 0);
        let resp = server.submit("grkan", x, rows).expect("served");
        assert_eq!(resp.cause, FlushCause::Deadline);
        assert_eq!(resp.batch_size, 1);
    }

    #[test]
    fn backpressure_never_exceeds_queue_depth() {
        let (m, _) = model(7);
        let depth = 4;
        let server = Server::start(
            vec![m],
            BatchPolicy { max_batch: 4, deadline_us: 200, queue_depth: depth, eager: true },
        )
        .unwrap();
        std::thread::scope(|s| {
            for client in 0..16u64 {
                let server = &server;
                s.spawn(move || {
                    for i in 0..10u64 {
                        let (rows, x) = request(7, client * 100 + i);
                        server.submit("grkan", x, rows).expect("served");
                    }
                });
            }
        });
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.total().requests, 160);
        assert!(
            stats.peak_queued <= depth,
            "queue grew to {} despite depth {depth}",
            stats.peak_queued
        );
    }

    #[test]
    fn shutdown_drains_pending_requests() {
        let (m, _) = model(8);
        // Deadline far in the future and non-eager: requests can only be
        // served by the shutdown drain.
        let server = Server::start(
            vec![m],
            BatchPolicy { max_batch: 64, deadline_us: 10_000_000, queue_depth: 64, eager: false },
        )
        .unwrap();
        std::thread::scope(|s| {
            for i in 0..3u64 {
                let server = &server;
                s.spawn(move || {
                    let (rows, x) = request(8, i);
                    let resp = server.submit("grkan", x, rows).expect("drained at shutdown");
                    assert_eq!(resp.cause, FlushCause::Drain);
                });
            }
            // Wait for all three to be admitted, then drain.
            while server.queued() < 3 {
                std::thread::sleep(Duration::from_millis(1));
            }
            let stats = server.shutdown().unwrap();
            let total = stats.total();
            assert_eq!(total.requests, 3);
            assert_eq!(total.causes[FlushCause::Drain.index()], 1);
        });
    }

    #[test]
    fn bad_requests_fail_fast() {
        let (m, _) = model(9);
        let server = Server::start(vec![m], BatchPolicy::default()).unwrap();
        assert!(server.submit("nope", vec![0.0; D], 1).is_err(), "unknown model name");
        assert!(server.submit_at(1, vec![0.0; D], 1).is_err(), "unknown model index");
        assert!(server.submit("grkan", vec![0.0; D - 1], 1).is_err(), "shape mismatch");
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.total().requests, 0);
    }

    /// A traced server mints a span per request, reports its timing on
    /// the response, and records exactly one request slice per served
    /// request — with the slice's marks properly nested (admit ≤
    /// release ≤ exec start ≤ reply).
    #[test]
    fn traced_server_spans_every_request_exactly_once() {
        let (m, coeffs) = model(11);
        let tracer = Arc::new(TraceCollector::new());
        let server = Server::start_sharded_traced(
            vec![m],
            BatchPolicy { max_batch: 8, deadline_us: 500, queue_depth: 64, eager: true },
            1,
            Some(tracer.clone()),
        )
        .unwrap();
        let mut span_ids = Vec::new();
        for i in 0..20u64 {
            let (rows, x) = request(11, i);
            let want = forward(&x, rows as usize, D, &coeffs);
            let resp = server.submit("grkan", x, rows).expect("served");
            assert_eq!(resp.y, want, "tracing must not perturb outputs");
            span_ids.push(resp.span_id.expect("traced server sets span ids"));
        }
        let stats = server.shutdown().unwrap();
        span_ids.sort_unstable();
        span_ids.dedup();
        assert_eq!(span_ids.len(), 20, "span ids must be unique");

        let snapshot = tracer.snapshot();
        assert_eq!(snapshot.len(), 2, "batch + request track for one shard");
        let batches = &snapshot[0].1;
        let reqs = &snapshot[1].1;
        assert_eq!(batches.len(), stats.total().batches);
        assert_eq!(reqs.len(), 20, "one request slice per served request");
        let mut seen: Vec<u64> = Vec::new();
        for ev in reqs {
            assert!(ev.t0_us <= ev.t1_us);
            let arg = |name: &str| {
                ev.args
                    .iter()
                    .find_map(|(k, v)| match v {
                        AnnValue::U64(u) if *k == name => Some(*u),
                        _ => None,
                    })
                    .unwrap_or_else(|| panic!("missing annotation {name}"))
            };
            // admit ≤ exec start (slice t0) and the slice covers the
            // exec + reply phases exactly.
            assert!(arg("admit_us") <= ev.t0_us);
            assert_eq!(ev.t1_us - ev.t0_us, arg("exec_us") + arg("reply_us"));
            seen.push(arg("span_id"));
        }
        seen.sort_unstable();
        assert_eq!(seen, span_ids, "trace spans = responded spans, each exactly once");
        // The dump renders to a well-formed trace.
        let st = crate::trace::stat(&tracer.render()).unwrap();
        assert_eq!(st.slice_begins, st.slice_ends);
        assert!(st.packets > 0);
    }

    /// A traced server samples its per-shard counter tracks — queue
    /// depth at every batch pop, cumulative payload bytes after every
    /// batch — and the traffic counter's last sample equals the
    /// per-model byte totals from the stats snapshot.
    #[test]
    fn traced_server_samples_shard_counter_tracks() {
        let (m, _) = model(13);
        let tracer = Arc::new(TraceCollector::new());
        let server = Server::start_sharded_traced(
            vec![m],
            BatchPolicy { max_batch: 8, deadline_us: 500, queue_depth: 64, eager: true },
            1,
            Some(tracer.clone()),
        )
        .unwrap();
        for i in 0..8u64 {
            let (rows, x) = request(13, i);
            server.submit("grkan", x, rows).expect("served");
        }
        let stats = server.shutdown().unwrap();
        let total = stats.total();
        assert_eq!(total.bytes_in, total.rows as u64 * D as u64 * 4);
        assert_eq!(total.bytes_out, total.rows as u64 * D as u64 * 4);

        let counters = tracer.counters_snapshot();
        let series = |name: &str| {
            counters
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, s)| s.as_slice())
                .unwrap_or_else(|| panic!("counter track {name:?} registered"))
        };
        let queue = series("shard 0 queue");
        assert_eq!(queue.len(), total.batches, "one depth sample per batch pop");
        let traffic = series("shard 0 traffic bytes");
        assert_eq!(traffic.len(), total.batches, "one traffic sample per batch");
        let last = traffic.iter().max_by_key(|(t, _)| *t).unwrap().1;
        assert_eq!(last, total.bytes_in + total.bytes_out);
        // The rendered trace carries the counter packets.
        let st = crate::trace::stat(&tracer.render()).unwrap();
        assert_eq!(st.counters as usize, queue.len() + traffic.len());
        assert_eq!(st.slice_begins, st.slice_ends);
    }

    /// An untraced server reports timing but no spans, and records no
    /// trace events anywhere.
    #[test]
    fn untraced_server_has_timing_but_no_spans() {
        let (m, _) = model(12);
        let server = Server::start(vec![m], BatchPolicy::default()).unwrap();
        let (rows, x) = request(12, 0);
        let resp = server.submit("grkan", x, rows).expect("served");
        assert!(resp.span_id.is_none());
        // The exec phase really ran, so the breakdown is populated
        // (exec time can round to 0µs only on a pathologically fast
        // clock; queue/batch/reply may legitimately be 0).
        let t = resp.timing;
        assert!(t.queue_wait_us < 60_000_000, "sane magnitude: {t:?}");
        assert!(server.tracer().is_none());
        assert!(server.mint_span("grkan", 1).is_none());
    }

    #[test]
    fn second_shutdown_returns_none() {
        let (m, _) = model(10);
        let server = Server::start(vec![m], BatchPolicy::default()).unwrap();
        assert!(server.shutdown().is_some());
        assert!(server.shutdown().is_none());
        assert!(server.submit("grkan", vec![0.0; D], 1).is_err(), "admission closed");
    }

    /// A cached server serves a repeated payload from the cache —
    /// bit-identical rows, `FlushCause::Cache`, zero timing — and the
    /// executor only ever sees the first copy.  Without `cache_bytes`
    /// there is no cache at all.
    #[test]
    fn cached_server_serves_repeats_without_reexecution() {
        let (m, coeffs) = model(50);
        let server = Server::start_configured(
            vec![m],
            BatchPolicy { max_batch: 8, deadline_us: 200, queue_depth: 64, eager: true },
            1,
            None,
            1 << 20,
        )
        .unwrap();
        let (rows, x) = request(50, 0);
        let want = forward(&x, rows as usize, D, &coeffs);
        let cold = server.submit("grkan", x.clone(), rows).expect("cold");
        assert_eq!(cold.y, want);
        assert_ne!(cold.cause, FlushCause::Cache, "first copy must execute");
        for _ in 0..3 {
            let hit = server.submit("grkan", x.clone(), rows).expect("hit");
            assert!(hit.y.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
            assert_eq!(hit.cause, FlushCause::Cache);
            assert_eq!(hit.batch_size, 1);
            assert_eq!(hit.timing, Timing::default(), "no batch, no phases");
        }
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.total().requests, 1, "executor saw only the cold copy");
        let cs = server.cache_stats().expect("cache attached");
        assert_eq!(cs.total.hits, 3);
        assert_eq!(cs.total.misses, 1);
        assert_eq!(cs.total.coalesced, 0);
        assert_eq!(cs.total.inserts, 1);
        assert_eq!(cs.total.requests(), 4);
        assert_eq!(cs.model("grkan").unwrap(), &cs.total);

        let plain = {
            let (m, _) = model(50);
            Server::start(vec![m], BatchPolicy::default()).unwrap()
        };
        assert!(plain.cache_stats().is_none(), "cache off by default");
    }

    /// With a tracer attached, cached requests record slices on the
    /// dedicated "cache" track (never on a shard's request track) with
    /// the hit/coalesced cause annotation, and still carry span ids.
    #[test]
    fn traced_cached_server_records_cache_slices() {
        let (m, _) = model(51);
        let tracer = Arc::new(TraceCollector::new());
        let server = Server::start_configured(
            vec![m],
            BatchPolicy { max_batch: 8, deadline_us: 200, queue_depth: 64, eager: true },
            1,
            Some(tracer.clone()),
            1 << 20,
        )
        .unwrap();
        let (rows, x) = request(51, 0);
        let cold = server.submit("grkan", x.clone(), rows).expect("cold");
        let hit = server.submit("grkan", x, rows).expect("hit");
        assert!(hit.span_id.is_some(), "cached responses keep their own span ids");
        assert_ne!(hit.span_id, cold.span_id, "each request minted its own span");
        server.shutdown();
        let snapshot = tracer.snapshot();
        let cache_events = snapshot
            .iter()
            .find(|(name, _)| name == "cache")
            .map(|(_, ev)| ev.as_slice())
            .expect("cache track registered");
        assert_eq!(cache_events.len(), 1, "one slice per cache-served request");
        let ev = &cache_events[0];
        assert!(ev.t0_us <= ev.t1_us);
        assert!(ev.args.iter().any(|(k, v)| *k == "cause"
            && matches!(v, AnnValue::Str(s) if s == "hit")));
        let req_events: usize = snapshot
            .iter()
            .filter(|(name, _)| name.ends_with(" req"))
            .map(|(_, ev)| ev.len())
            .sum();
        assert_eq!(req_events, 1, "only the cold request reached the executor track");
    }
}
