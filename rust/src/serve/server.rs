//! Threaded micro-batching inference server for the GR-KAN forward pass.
//!
//! One executor thread owns the [`Batcher`]: it coalesces admitted
//! requests into shape-keyed batches, concatenates their rows into a
//! single buffer, and runs one [`crate::rational::forward`] per batch on
//! the persistent worker pool (`util::parallel`), so the pool wakeup,
//! the queue round-trip, and the coefficient traffic are paid once per
//! batch instead of once per request.  Because the forward is strictly
//! elementwise per row, a coalesced batch is **bit-identical** to
//! serving each request alone — batching is purely a scheduling
//! decision (enforced by `batched_output_matches_unbatched_forward`).
//!
//! Admission control: `submit` blocks while the queue is at
//! `queue_depth` (backpressure), then blocks until its response is
//! computed.  Shutdown stops admission, drains every pending request,
//! and returns the executor's counters.

use std::collections::BTreeMap;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::batcher::{Batch, Batcher, BatchPolicy, FlushCause, ShapeKey};
use crate::rational::{forward_into, Coeffs};

/// One served model: grouped PAU coefficients for inputs of width `d`.
pub struct Model {
    pub name: String,
    pub d: usize,
    pub coeffs: Coeffs<f32>,
}

/// A fulfilled request.
#[derive(Clone, Debug)]
pub struct Response {
    pub y: Vec<f32>,
    /// Requests coalesced into the batch that served this one.
    pub batch_size: usize,
    pub cause: FlushCause,
}

/// Executor-side counters, returned by [`Server::shutdown`].
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub batches: usize,
    pub requests: usize,
    pub rows: usize,
    /// `batch_hist[k]` = number of batches that coalesced `k` requests.
    pub batch_hist: Vec<usize>,
    /// Batches by [`FlushCause::index`].
    pub causes: [usize; 4],
    /// Wall time inside the batched forward (executor busy time).
    pub busy_secs: f64,
    /// Peak queue depth observed — must never exceed the policy's
    /// `queue_depth` (the backpressure invariant).
    pub peak_queued: usize,
}

impl ExecStats {
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

struct Job {
    x: Vec<f32>,
    rows: u32,
    resp: mpsc::Sender<Response>,
}

struct State {
    batcher: Batcher,
    /// Ticket id → payload for every admitted-but-unserved request.
    jobs: BTreeMap<u64, Job>,
    shutdown: bool,
    peak_queued: usize,
}

struct Shared {
    state: Mutex<State>,
    /// Submitters waiting for queue space.
    space: Condvar,
    /// Executor waiting for work or a deadline.
    work: Condvar,
    models: Vec<Model>,
    epoch: Instant,
}

fn now_us(shared: &Shared) -> u64 {
    shared.epoch.elapsed().as_micros() as u64
}

pub struct Server {
    shared: Arc<Shared>,
    exec: Mutex<Option<std::thread::JoinHandle<ExecStats>>>,
}

impl Server {
    /// Spawn the executor thread and start serving.
    pub fn start(models: Vec<Model>, policy: BatchPolicy) -> Server {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                batcher: Batcher::new(policy),
                jobs: BTreeMap::new(),
                shutdown: false,
                peak_queued: 0,
            }),
            space: Condvar::new(),
            work: Condvar::new(),
            models,
            epoch: Instant::now(),
        });
        let worker = Arc::clone(&shared);
        let exec = std::thread::Builder::new()
            .name("flashkat-serve".into())
            .spawn(move || executor(&worker))
            .expect("spawn serve executor");
        Server { shared, exec: Mutex::new(Some(exec)) }
    }

    /// Admitted-but-unserved request count (diagnostic).
    pub fn queued(&self) -> usize {
        self.shared.state.lock().unwrap().batcher.queued()
    }

    /// Submit one request and block until it is served.  Blocks at
    /// admission while the queue is at depth (backpressure); fails fast
    /// on a shape mismatch or once shutdown has begun.
    pub fn submit(&self, model: u32, x: Vec<f32>, rows: u32) -> Result<Response> {
        let m = self
            .shared
            .models
            .get(model as usize)
            .with_context(|| format!("unknown model {model}"))?;
        if x.len() != rows as usize * m.d {
            bail!("request shape mismatch: {} values for {} rows of d={}", x.len(), rows, m.d);
        }
        let key = ShapeKey { model, d: m.d as u32 };
        let (tx, rx) = mpsc::channel();
        {
            let mut st = self.shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    bail!("server is shutting down");
                }
                let now = now_us(&self.shared);
                if let Some(ticket) = st.batcher.admit(key, now) {
                    st.jobs.insert(ticket.id, Job { x, rows, resp: tx });
                    st.peak_queued = st.peak_queued.max(st.batcher.queued());
                    break;
                }
                st = self.shared.space.wait(st).unwrap();
            }
            self.shared.work.notify_one();
        }
        rx.recv().map_err(|_| anyhow!("server dropped the request"))
    }

    /// Stop admission, drain pending requests, and join the executor.
    /// Returns `None` if a previous call already collected the stats.
    pub fn shutdown(&self) -> Option<ExecStats> {
        let handle = self.exec.lock().unwrap().take()?;
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_one();
            self.shared.space.notify_all();
        }
        Some(handle.join().expect("serve executor panicked"))
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Batch-local buffers, reused across batches so the steady-state hot
/// path allocates only the per-request response vectors.
#[derive(Default)]
struct Scratch {
    xcat: Vec<f32>,
    ycat: Vec<f32>,
}

fn executor(shared: &Shared) -> ExecStats {
    let mut stats = ExecStats::default();
    let mut scratch = Scratch::default();
    let mut st = shared.state.lock().unwrap();
    loop {
        let now = now_us(shared);
        if let Some(batch) = st.batcher.pop(now, true) {
            let jobs = detach_jobs(&mut st, &batch);
            drop(st);
            shared.space.notify_all();
            execute(shared, &batch, jobs, &mut stats, &mut scratch);
            st = shared.state.lock().unwrap();
            continue;
        }
        if st.shutdown {
            // `pop` came back empty; with a non-eager policy requests may
            // still be waiting on deadlines — drain them unconditionally.
            let batches = st.batcher.drain();
            let drained: Vec<(Batch, Vec<Job>)> = batches
                .into_iter()
                .map(|b| {
                    let jobs = detach_jobs(&mut st, &b);
                    (b, jobs)
                })
                .collect();
            stats.peak_queued = st.peak_queued;
            drop(st);
            shared.space.notify_all();
            for (batch, jobs) in drained {
                execute(shared, &batch, jobs, &mut stats, &mut scratch);
            }
            return stats;
        }
        st = match st.batcher.next_deadline_us() {
            // Partial buckets pending (non-eager policy): sleep until the
            // earliest deadline, then loop to flush it.
            Some(due) => {
                let wait = Duration::from_micros(due.saturating_sub(now_us(shared)));
                shared.work.wait_timeout(st, wait).unwrap().0
            }
            None => shared.work.wait(st).unwrap(),
        };
    }
}

fn detach_jobs(st: &mut State, batch: &Batch) -> Vec<Job> {
    batch
        .tickets
        .iter()
        .map(|t| st.jobs.remove(&t.id).expect("payload for admitted ticket"))
        .collect()
}

/// Run one coalesced batch and fan the rows back out to the requesters.
fn execute(
    shared: &Shared,
    batch: &Batch,
    jobs: Vec<Job>,
    stats: &mut ExecStats,
    scratch: &mut Scratch,
) {
    let model = &shared.models[batch.key.model as usize];
    let d = model.d;
    let total_rows: usize = jobs.iter().map(|j| j.rows as usize).sum();

    let t0 = Instant::now();
    scratch.xcat.clear();
    scratch.xcat.reserve(total_rows * d);
    for job in &jobs {
        scratch.xcat.extend_from_slice(&job.x);
    }
    // Elementwise per row, so this equals per-request forward calls bit
    // for bit — the accumulation order of each output element is
    // unchanged by coalescing.
    forward_into(&scratch.xcat, total_rows, d, &model.coeffs, &mut scratch.ycat);
    stats.busy_secs += t0.elapsed().as_secs_f64();

    let size = jobs.len();
    stats.batches += 1;
    stats.requests += size;
    stats.rows += total_rows;
    stats.causes[batch.cause.index()] += 1;
    if stats.batch_hist.len() <= size {
        stats.batch_hist.resize(size + 1, 0);
    }
    stats.batch_hist[size] += 1;

    let mut off = 0usize;
    for job in jobs {
        let n = job.rows as usize * d;
        let y = scratch.ycat[off..off + n].to_vec();
        off += n;
        // A requester that gave up is not an executor error.
        let _ = job.resp.send(Response { y, batch_size: size, cause: batch.cause });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rational::forward;
    use crate::util::rng::Pcg64;

    const D: usize = 64;
    const GROUPS: usize = 8;

    fn model(seed: u64) -> (Model, Coeffs<f32>) {
        let mut rng = Pcg64::new(seed);
        let coeffs = Coeffs::<f32>::randn(GROUPS, 6, 4, &mut rng);
        (Model { name: "grkan".into(), d: D, coeffs: coeffs.clone() }, coeffs)
    }

    fn request(seed: u64, id: u64) -> (u32, Vec<f32>) {
        let mut rng = Pcg64::with_stream(seed, id);
        let rows = 1 + rng.below(4) as u32;
        let x = (0..rows as usize * D).map(|_| rng.normal_f32()).collect();
        (rows, x)
    }

    #[test]
    fn batched_output_matches_unbatched_forward() {
        let (m, coeffs) = model(5);
        let server = Server::start(
            vec![m],
            BatchPolicy { max_batch: 8, deadline_us: 500, queue_depth: 64, eager: true },
        );
        std::thread::scope(|s| {
            for client in 0..4u64 {
                let server = &server;
                let coeffs = &coeffs;
                s.spawn(move || {
                    for i in 0..25u64 {
                        let (rows, x) = request(5, client * 100 + i);
                        let want = forward(&x, rows as usize, D, coeffs);
                        let resp = server.submit(0, x, rows).expect("served");
                        assert_eq!(resp.y, want, "batched != unbatched for req {client}/{i}");
                        assert!(resp.batch_size >= 1);
                    }
                });
            }
        });
        let stats = server.shutdown().expect("first shutdown collects stats");
        assert_eq!(stats.requests, 100);
        assert!(stats.rows > 0);
        let hist_total: usize =
            stats.batch_hist.iter().enumerate().map(|(size, n)| size * n).sum();
        assert_eq!(hist_total, 100, "histogram accounts for every request");
    }

    #[test]
    fn lone_request_is_flushed_by_the_deadline() {
        let (m, _) = model(6);
        // Non-eager policy and a huge max_batch: only the deadline can
        // release this request.
        let server = Server::start(
            vec![m],
            BatchPolicy { max_batch: 64, deadline_us: 2_000, queue_depth: 64, eager: false },
        );
        let (rows, x) = request(6, 0);
        let resp = server.submit(0, x, rows).expect("served");
        assert_eq!(resp.cause, FlushCause::Deadline);
        assert_eq!(resp.batch_size, 1);
    }

    #[test]
    fn backpressure_never_exceeds_queue_depth() {
        let (m, _) = model(7);
        let depth = 4;
        let server = Server::start(
            vec![m],
            BatchPolicy { max_batch: 4, deadline_us: 200, queue_depth: depth, eager: true },
        );
        std::thread::scope(|s| {
            for client in 0..16u64 {
                let server = &server;
                s.spawn(move || {
                    for i in 0..10u64 {
                        let (rows, x) = request(7, client * 100 + i);
                        server.submit(0, x, rows).expect("served");
                    }
                });
            }
        });
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 160);
        assert!(
            stats.peak_queued <= depth,
            "queue grew to {} despite depth {depth}",
            stats.peak_queued
        );
    }

    #[test]
    fn shutdown_drains_pending_requests() {
        let (m, _) = model(8);
        // Deadline far in the future and non-eager: requests can only be
        // served by the shutdown drain.
        let server = Server::start(
            vec![m],
            BatchPolicy { max_batch: 64, deadline_us: 10_000_000, queue_depth: 64, eager: false },
        );
        std::thread::scope(|s| {
            for i in 0..3u64 {
                let server = &server;
                s.spawn(move || {
                    let (rows, x) = request(8, i);
                    let resp = server.submit(0, x, rows).expect("drained at shutdown");
                    assert_eq!(resp.cause, FlushCause::Drain);
                });
            }
            // Wait for all three to be admitted, then drain.
            while server.queued() < 3 {
                std::thread::sleep(Duration::from_millis(1));
            }
            let stats = server.shutdown().unwrap();
            assert_eq!(stats.requests, 3);
            assert_eq!(stats.causes[FlushCause::Drain.index()], 1);
        });
    }

    #[test]
    fn bad_requests_fail_fast() {
        let (m, _) = model(9);
        let server = Server::start(vec![m], BatchPolicy::default());
        assert!(server.submit(1, vec![0.0; D], 1).is_err(), "unknown model");
        assert!(server.submit(0, vec![0.0; D - 1], 1).is_err(), "shape mismatch");
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests, 0);
    }

    #[test]
    fn second_shutdown_returns_none() {
        let (m, _) = model(10);
        let server = Server::start(vec![m], BatchPolicy::default());
        assert!(server.shutdown().is_some());
        assert!(server.shutdown().is_none());
        assert!(server.submit(0, vec![0.0; D], 1).is_err(), "admission closed");
    }
}
