//! Threaded micro-batching inference server over a registry of named
//! model executors.
//!
//! One executor thread owns the [`Batcher`] and the executor registry:
//! it coalesces admitted requests into batches keyed by registry index,
//! concatenates their rows into a single buffer, and hands the buffer to
//! the owning [`ModelExecutor`], so the pool wakeup, the queue
//! round-trip, and the model-state traffic are paid once per batch
//! instead of once per request.  The server itself knows nothing about
//! model internals — a [`super::RationalExecutor`] batch is bit-identical
//! to unbatched `rational::forward` calls, and a
//! [`super::PipelineExecutor`] batch is bit-identical to per-request
//! adapter calls (row independence; DESIGN.md §11).
//!
//! Requests are routed by model *name* ([`Server::submit`]) or by
//! registry index ([`Server::submit_at`]).  Admission control: `submit`
//! blocks while the queue is at `queue_depth` (backpressure), then
//! blocks until its response is computed.  An executor `Err` fails that
//! batch's requests without taking the server down.  Shutdown stops
//! admission, drains every pending request, and returns per-model
//! counters ([`ServeStats`]).

use std::collections::BTreeMap;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::batcher::{Batch, Batcher, BatchPolicy, FlushCause, ShapeKey};
use super::executor::{ExecStats, ModelExecutor, ModelStats, ServeStats};

/// A fulfilled request.
#[derive(Clone, Debug)]
pub struct Response {
    pub y: Vec<f32>,
    /// Requests coalesced into the batch that served this one.
    pub batch_size: usize,
    pub cause: FlushCause,
}

/// Immutable registry-entry identity, kept on the shared side so
/// `submit` can validate and route without touching the executors (which
/// live on the executor thread).
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub d_in: usize,
    pub d_out: usize,
}

struct Job {
    x: Vec<f32>,
    rows: u32,
    resp: mpsc::Sender<std::result::Result<Response, String>>,
}

struct State {
    batcher: Batcher,
    /// Ticket id → payload for every admitted-but-unserved request.
    jobs: BTreeMap<u64, Job>,
    shutdown: bool,
    peak_queued: usize,
}

struct Shared {
    state: Mutex<State>,
    /// Submitters waiting for queue space.
    space: Condvar,
    /// Executor waiting for work or a deadline.
    work: Condvar,
    meta: Vec<ModelMeta>,
    epoch: Instant,
}

fn now_us(shared: &Shared) -> u64 {
    shared.epoch.elapsed().as_micros() as u64
}

pub struct Server {
    shared: Arc<Shared>,
    exec: Mutex<Option<std::thread::JoinHandle<ServeStats>>>,
}

impl Server {
    /// Validate the registry, spawn the executor thread, and start
    /// serving.  Fails (instead of panicking) on an empty registry,
    /// duplicate model names, or thread-spawn failure.
    pub fn start(executors: Vec<Box<dyn ModelExecutor>>, policy: BatchPolicy) -> Result<Server> {
        if executors.is_empty() {
            bail!("server needs at least one executor");
        }
        if executors.len() > u32::MAX as usize {
            bail!("registry too large for ShapeKey's u32 index");
        }
        let meta: Vec<ModelMeta> = executors
            .iter()
            .map(|e| ModelMeta {
                name: e.name().to_string(),
                d_in: e.d_in(),
                d_out: e.d_out(),
            })
            .collect();
        for (i, m) in meta.iter().enumerate() {
            if m.d_in == 0 || m.d_out == 0 {
                bail!("model {:?} has degenerate width {}x{}", m.name, m.d_in, m.d_out);
            }
            if meta[..i].iter().any(|o| o.name == m.name) {
                bail!("duplicate model name {:?} in registry", m.name);
            }
        }
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                batcher: Batcher::new(policy),
                jobs: BTreeMap::new(),
                shutdown: false,
                peak_queued: 0,
            }),
            space: Condvar::new(),
            work: Condvar::new(),
            meta,
            epoch: Instant::now(),
        });
        let worker = Arc::clone(&shared);
        let exec = std::thread::Builder::new()
            .name("flashkat-serve".into())
            .spawn(move || executor_loop(&worker, executors))
            .context("spawning serve executor thread")?;
        Ok(Server { shared, exec: Mutex::new(Some(exec)) })
    }

    /// Registry metadata, in registry (= `ShapeKey.model` index) order.
    pub fn models(&self) -> &[ModelMeta] {
        &self.shared.meta
    }

    /// Registry index of a model name.
    pub fn model_index(&self, name: &str) -> Option<u32> {
        self.shared.meta.iter().position(|m| m.name == name).map(|i| i as u32)
    }

    /// Admitted-but-unserved request count (diagnostic).
    pub fn queued(&self) -> usize {
        self.shared.state.lock().unwrap().batcher.queued()
    }

    /// Submit one request to the named model and block until served.
    pub fn submit(&self, model: &str, x: Vec<f32>, rows: u32) -> Result<Response> {
        let idx = self
            .model_index(model)
            .with_context(|| format!("unknown model {model:?}"))?;
        self.submit_at(idx, x, rows)
    }

    /// Submit by registry index.  Blocks at admission while the queue is
    /// at depth (backpressure), then until the response is computed;
    /// fails fast on a shape mismatch, once shutdown has begun, or when
    /// the model's executor reports an error for this batch.
    pub fn submit_at(&self, model: u32, x: Vec<f32>, rows: u32) -> Result<Response> {
        let m = self
            .shared
            .meta
            .get(model as usize)
            .with_context(|| format!("unknown model index {model}"))?;
        if x.len() != rows as usize * m.d_in {
            bail!(
                "request shape mismatch for {:?}: {} values for {} rows of d_in={}",
                m.name,
                x.len(),
                rows,
                m.d_in
            );
        }
        let key = ShapeKey { model, d: m.d_in as u32 };
        let (tx, rx) = mpsc::channel();
        {
            let mut st = self.shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    bail!("server is shutting down");
                }
                let now = now_us(&self.shared);
                if let Some(ticket) = st.batcher.admit(key, now) {
                    st.jobs.insert(ticket.id, Job { x, rows, resp: tx });
                    st.peak_queued = st.peak_queued.max(st.batcher.queued());
                    break;
                }
                st = self.shared.space.wait(st).unwrap();
            }
            self.shared.work.notify_one();
        }
        match rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(msg)) => Err(anyhow!("model {:?}: {msg}", m.name)),
            Err(_) => Err(anyhow!("server dropped the request")),
        }
    }

    /// Stop admission, drain pending requests, and join the executor.
    /// Returns `None` if a previous call already collected the stats.
    pub fn shutdown(&self) -> Option<ServeStats> {
        let handle = self.exec.lock().unwrap().take()?;
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_one();
            self.shared.space.notify_all();
        }
        Some(handle.join().expect("serve executor panicked"))
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Batch-local buffers, reused across batches so the steady-state hot
/// path allocates only the per-request response vectors.
#[derive(Default)]
struct Scratch {
    xcat: Vec<f32>,
    ycat: Vec<f32>,
}

fn executor_loop(shared: &Shared, mut executors: Vec<Box<dyn ModelExecutor>>) -> ServeStats {
    let mut per: Vec<ExecStats> = vec![ExecStats::default(); executors.len()];
    let mut scratch = Scratch::default();
    let mut st = shared.state.lock().unwrap();
    loop {
        let now = now_us(shared);
        if let Some(batch) = st.batcher.pop(now, true) {
            let jobs = detach_jobs(&mut st, &batch);
            drop(st);
            shared.space.notify_all();
            execute(&mut executors, &batch, jobs, &mut per, &mut scratch);
            st = shared.state.lock().unwrap();
            continue;
        }
        if st.shutdown {
            // `pop` came back empty; with a non-eager policy requests may
            // still be waiting on deadlines — drain them unconditionally.
            let batches = st.batcher.drain();
            let drained: Vec<(Batch, Vec<Job>)> = batches
                .into_iter()
                .map(|b| {
                    let jobs = detach_jobs(&mut st, &b);
                    (b, jobs)
                })
                .collect();
            let peak_queued = st.peak_queued;
            drop(st);
            shared.space.notify_all();
            for (batch, jobs) in drained {
                execute(&mut executors, &batch, jobs, &mut per, &mut scratch);
            }
            return ServeStats {
                per_model: shared
                    .meta
                    .iter()
                    .zip(per)
                    .map(|(m, stats)| ModelStats {
                        name: m.name.clone(),
                        d_in: m.d_in,
                        d_out: m.d_out,
                        stats,
                    })
                    .collect(),
                peak_queued,
            };
        }
        st = match st.batcher.next_deadline_us() {
            // Partial buckets pending (non-eager policy): sleep until the
            // earliest deadline, then loop to flush it.
            Some(due) => {
                let wait = Duration::from_micros(due.saturating_sub(now_us(shared)));
                shared.work.wait_timeout(st, wait).unwrap().0
            }
            None => shared.work.wait(st).unwrap(),
        };
    }
}

fn detach_jobs(st: &mut State, batch: &Batch) -> Vec<Job> {
    batch
        .tickets
        .iter()
        .map(|t| st.jobs.remove(&t.id).expect("payload for admitted ticket"))
        .collect()
}

/// Run one coalesced batch through its model's executor and fan the rows
/// back out to the requesters.
fn execute(
    executors: &mut [Box<dyn ModelExecutor>],
    batch: &Batch,
    jobs: Vec<Job>,
    per: &mut [ExecStats],
    scratch: &mut Scratch,
) {
    let idx = batch.key.model as usize;
    let exec = &mut executors[idx];
    let d_in = exec.d_in();
    let d_out = exec.d_out();
    let total_rows: usize = jobs.iter().map(|j| j.rows as usize).sum();

    let t0 = Instant::now();
    scratch.xcat.clear();
    scratch.xcat.reserve(total_rows * d_in);
    for job in &jobs {
        scratch.xcat.extend_from_slice(&job.x);
    }
    // Executors are documented never to panic, but a third-party
    // implementation (or an FFI abort surfacing as a panic) must not
    // unwind this thread: that would strand every queued and future
    // submitter on a channel nobody serves.  Contain it to this batch.
    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        exec.run(&scratch.xcat, total_rows, &mut scratch.ycat)
    }))
    .unwrap_or_else(|_| Err(anyhow::anyhow!("executor panicked")));
    let busy = t0.elapsed().as_secs_f64();

    let size = jobs.len();
    let stats = &mut per[idx];
    stats.record(size, total_rows, batch.cause, busy);

    let failure = match run {
        Ok(()) if scratch.ycat.len() == total_rows * d_out => None,
        Ok(()) => Some(format!(
            "executor returned {} values, expected {} ({total_rows} rows x d_out={d_out})",
            scratch.ycat.len(),
            total_rows * d_out
        )),
        Err(e) => Some(format!("{e:#}")),
    };
    if let Some(msg) = failure {
        stats.failed += size;
        for job in jobs {
            // A requester that gave up is not an executor error.
            let _ = job.resp.send(Err(msg.clone()));
        }
        return;
    }

    let mut off = 0usize;
    for job in jobs {
        let n = job.rows as usize * d_out;
        let y = scratch.ycat[off..off + n].to_vec();
        off += n;
        let _ = job.resp.send(Ok(Response { y, batch_size: size, cause: batch.cause }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rational::{forward, Coeffs};
    use crate::serve::executor::RationalExecutor;
    use crate::util::rng::Pcg64;

    const D: usize = 64;
    const GROUPS: usize = 8;

    fn model(seed: u64) -> (Box<dyn ModelExecutor>, Coeffs<f32>) {
        let mut rng = Pcg64::new(seed);
        let coeffs = Coeffs::<f32>::randn(GROUPS, 6, 4, &mut rng);
        (Box::new(RationalExecutor::new("grkan", D, coeffs.clone()).unwrap()), coeffs)
    }

    fn request(seed: u64, id: u64) -> (u32, Vec<f32>) {
        let mut rng = Pcg64::with_stream(seed, id);
        let rows = 1 + rng.below(4) as u32;
        let x = (0..rows as usize * D).map(|_| rng.normal_f32()).collect();
        (rows, x)
    }

    #[test]
    fn batched_output_matches_unbatched_forward() {
        let (m, coeffs) = model(5);
        let server = Server::start(
            vec![m],
            BatchPolicy { max_batch: 8, deadline_us: 500, queue_depth: 64, eager: true },
        )
        .unwrap();
        std::thread::scope(|s| {
            for client in 0..4u64 {
                let server = &server;
                let coeffs = &coeffs;
                s.spawn(move || {
                    for i in 0..25u64 {
                        let (rows, x) = request(5, client * 100 + i);
                        let want = forward(&x, rows as usize, D, coeffs);
                        let resp = server.submit("grkan", x, rows).expect("served");
                        assert_eq!(resp.y, want, "batched != unbatched for req {client}/{i}");
                        assert!(resp.batch_size >= 1);
                    }
                });
            }
        });
        let stats = server.shutdown().expect("first shutdown collects stats");
        let total = stats.total();
        assert_eq!(total.requests, 100);
        assert_eq!(total.failed, 0);
        assert!(total.rows > 0);
        let hist_total: usize =
            total.batch_hist.iter().enumerate().map(|(size, n)| size * n).sum();
        assert_eq!(hist_total, 100, "histogram accounts for every request");
        // Single-model registry: the per-model split IS the total.
        assert_eq!(stats.per_model.len(), 1);
        assert_eq!(stats.per_model[0].name, "grkan");
        assert_eq!(stats.per_model[0].stats, total);
    }

    #[test]
    fn routes_by_name_with_per_model_stats() {
        let mut rng = Pcg64::new(31);
        let cw = Coeffs::<f32>::randn(8, 6, 4, &mut rng);
        let cn = Coeffs::<f32>::randn(4, 6, 4, &mut rng);
        let server = Server::start(
            vec![
                Box::new(RationalExecutor::new("wide", 64, cw.clone()).unwrap()),
                Box::new(RationalExecutor::new("narrow", 16, cn.clone()).unwrap()),
            ],
            BatchPolicy { max_batch: 8, deadline_us: 300, queue_depth: 64, eager: true },
        )
        .unwrap();
        assert_eq!(server.model_index("narrow"), Some(1));
        assert_eq!(server.model_index("nope"), None);
        std::thread::scope(|s| {
            for client in 0..4u64 {
                let server = &server;
                let (cw, cn) = (&cw, &cn);
                s.spawn(move || {
                    for i in 0..10u64 {
                        let mut rng = Pcg64::with_stream(31, client * 100 + i);
                        let (name, d, c): (&str, usize, &Coeffs<f32>) =
                            if (client + i) % 2 == 0 { ("wide", 64, cw) } else { ("narrow", 16, cn) };
                        let rows = 1 + rng.below(3);
                        let x: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
                        let want = forward(&x, rows, d, c);
                        let got = server.submit(name, x, rows as u32).expect("served").y;
                        assert_eq!(got, want, "{name} {client}/{i}");
                    }
                });
            }
        });
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.per_model.len(), 2);
        let total = stats.total();
        assert_eq!(total.requests, 40);
        let wide = stats.model("wide").unwrap();
        let narrow = stats.model("narrow").unwrap();
        assert_eq!(wide.stats.requests, 20);
        assert_eq!(narrow.stats.requests, 20);
        assert_eq!((wide.d_in, narrow.d_in), (64, 16));
        assert_eq!(wide.stats.requests + narrow.stats.requests, total.requests);
        assert_eq!(wide.stats.rows + narrow.stats.rows, total.rows);
        assert_eq!(wide.stats.batches + narrow.stats.batches, total.batches);
    }

    #[test]
    fn registry_validation_rejects_bad_configs() {
        let (a, _) = model(40);
        let (b, _) = model(41);
        // Duplicate names: both executors are called "grkan".
        assert!(Server::start(vec![a, b], BatchPolicy::default()).is_err());
        assert!(Server::start(vec![], BatchPolicy::default()).is_err(), "empty registry");
    }

    /// An executor whose `run` always fails: the batch's submitters get
    /// errors, the counters record the failure, and the server survives.
    struct Exploding;
    impl ModelExecutor for Exploding {
        fn name(&self) -> &str {
            "boom"
        }
        fn d_in(&self) -> usize {
            4
        }
        fn d_out(&self) -> usize {
            4
        }
        fn run(&mut self, _x: &[f32], _rows: usize, _out: &mut Vec<f32>) -> Result<()> {
            bail!("synthetic failure")
        }
    }

    #[test]
    fn executor_failure_is_an_error_not_a_crash() {
        let (m, coeffs) = model(42);
        let server = Server::start(vec![m, Box::new(Exploding)], BatchPolicy::default()).unwrap();
        let err = server.submit("boom", vec![0.0; 4], 1).unwrap_err().to_string();
        assert!(err.contains("synthetic failure"), "{err}");
        // The healthy model still serves after the failure.
        let (rows, x) = request(42, 0);
        let want = forward(&x, rows as usize, D, &coeffs);
        assert_eq!(server.submit("grkan", x, rows).unwrap().y, want);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.model("boom").unwrap().stats.failed, 1);
        assert_eq!(stats.model("grkan").unwrap().stats.failed, 0);
        assert_eq!(stats.total().failed, 1);
    }

    /// A panicking executor (contract violation) must be contained to
    /// its batch: submitters get errors, the thread survives, other
    /// models keep serving, shutdown still returns stats.
    struct Panicking;
    impl ModelExecutor for Panicking {
        fn name(&self) -> &str {
            "panicky"
        }
        fn d_in(&self) -> usize {
            4
        }
        fn d_out(&self) -> usize {
            4
        }
        fn run(&mut self, _x: &[f32], _rows: usize, _out: &mut Vec<f32>) -> Result<()> {
            panic!("synthetic executor panic")
        }
    }

    #[test]
    fn executor_panic_fails_the_batch_not_the_server() {
        let (m, coeffs) = model(43);
        let server =
            Server::start(vec![m, Box::new(Panicking)], BatchPolicy::default()).unwrap();
        let err = server.submit("panicky", vec![0.0; 4], 1).unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
        let (rows, x) = request(43, 0);
        let want = forward(&x, rows as usize, D, &coeffs);
        assert_eq!(server.submit("grkan", x, rows).unwrap().y, want);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.model("panicky").unwrap().stats.failed, 1);
        assert_eq!(stats.total().failed, 1);
    }

    #[test]
    fn lone_request_is_flushed_by_the_deadline() {
        let (m, _) = model(6);
        // Non-eager policy and a huge max_batch: only the deadline can
        // release this request.
        let server = Server::start(
            vec![m],
            BatchPolicy { max_batch: 64, deadline_us: 2_000, queue_depth: 64, eager: false },
        )
        .unwrap();
        let (rows, x) = request(6, 0);
        let resp = server.submit("grkan", x, rows).expect("served");
        assert_eq!(resp.cause, FlushCause::Deadline);
        assert_eq!(resp.batch_size, 1);
    }

    #[test]
    fn backpressure_never_exceeds_queue_depth() {
        let (m, _) = model(7);
        let depth = 4;
        let server = Server::start(
            vec![m],
            BatchPolicy { max_batch: 4, deadline_us: 200, queue_depth: depth, eager: true },
        )
        .unwrap();
        std::thread::scope(|s| {
            for client in 0..16u64 {
                let server = &server;
                s.spawn(move || {
                    for i in 0..10u64 {
                        let (rows, x) = request(7, client * 100 + i);
                        server.submit("grkan", x, rows).expect("served");
                    }
                });
            }
        });
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.total().requests, 160);
        assert!(
            stats.peak_queued <= depth,
            "queue grew to {} despite depth {depth}",
            stats.peak_queued
        );
    }

    #[test]
    fn shutdown_drains_pending_requests() {
        let (m, _) = model(8);
        // Deadline far in the future and non-eager: requests can only be
        // served by the shutdown drain.
        let server = Server::start(
            vec![m],
            BatchPolicy { max_batch: 64, deadline_us: 10_000_000, queue_depth: 64, eager: false },
        )
        .unwrap();
        std::thread::scope(|s| {
            for i in 0..3u64 {
                let server = &server;
                s.spawn(move || {
                    let (rows, x) = request(8, i);
                    let resp = server.submit("grkan", x, rows).expect("drained at shutdown");
                    assert_eq!(resp.cause, FlushCause::Drain);
                });
            }
            // Wait for all three to be admitted, then drain.
            while server.queued() < 3 {
                std::thread::sleep(Duration::from_millis(1));
            }
            let stats = server.shutdown().unwrap();
            let total = stats.total();
            assert_eq!(total.requests, 3);
            assert_eq!(total.causes[FlushCause::Drain.index()], 1);
        });
    }

    #[test]
    fn bad_requests_fail_fast() {
        let (m, _) = model(9);
        let server = Server::start(vec![m], BatchPolicy::default()).unwrap();
        assert!(server.submit("nope", vec![0.0; D], 1).is_err(), "unknown model name");
        assert!(server.submit_at(1, vec![0.0; D], 1).is_err(), "unknown model index");
        assert!(server.submit("grkan", vec![0.0; D - 1], 1).is_err(), "shape mismatch");
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.total().requests, 0);
    }

    #[test]
    fn second_shutdown_returns_none() {
        let (m, _) = model(10);
        let server = Server::start(vec![m], BatchPolicy::default()).unwrap();
        assert!(server.shutdown().is_some());
        assert!(server.shutdown().is_none());
        assert!(server.submit("grkan", vec![0.0; D], 1).is_err(), "admission closed");
    }
}
