//! Prefetching batch loader: a background worker generates batches ahead
//! of the training loop so data generation overlaps device execution (the
//! paper excludes data-loader time from throughput; we overlap it instead
//! and *measure* both).

use std::sync::mpsc;
use std::thread::JoinHandle;

use super::{SynthDataset, SynthSpec};

/// A ready batch: images (B,H,W,C) flat + integer labels.
pub struct Batch {
    pub index: u64,
    pub images: Vec<f32>,
    pub labels: Vec<usize>,
}

pub struct Prefetcher {
    rx: Option<mpsc::Receiver<Batch>>,
    worker: Option<JoinHandle<()>>,
    stop: mpsc::Sender<()>,
}

impl Prefetcher {
    /// Start a worker producing batches of `batch` samples, `depth` ahead.
    pub fn new(spec: SynthSpec, batch: usize, depth: usize) -> Self {
        let (tx, rx) = mpsc::sync_channel::<Batch>(depth.max(1));
        let (stop_tx, stop_rx) = mpsc::channel::<()>();
        let worker = std::thread::spawn(move || {
            let ds = SynthDataset::new(spec);
            let mut idx = 0u64;
            let mut step = 0u64;
            loop {
                if stop_rx.try_recv().is_ok() {
                    return;
                }
                let (images, labels) = ds.batch(idx, batch);
                let b = Batch { index: step, images, labels };
                if tx.send(b).is_err() {
                    return; // receiver dropped
                }
                idx += batch as u64;
                step += 1;
            }
        });
        Self { rx: Some(rx), worker: Some(worker), stop: stop_tx }
    }

    /// Blocking fetch of the next batch.
    pub fn next(&self) -> Batch {
        self.rx.as_ref().expect("receiver alive").recv().expect("prefetch worker alive")
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        let _ = self.stop.send(());
        // Dropping the receiver makes any blocked send() fail, so the
        // worker exits either via the stop signal or the send error.
        drop(self.rx.take());
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetcher_produces_sequential_batches() {
        let p = Prefetcher::new(SynthSpec::default(), 8, 2);
        let b0 = p.next();
        let b1 = p.next();
        assert_eq!(b0.index, 0);
        assert_eq!(b1.index, 1);
        assert_eq!(b0.labels, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(b1.labels, vec![8 % 10, 9 % 10, 0, 1, 2, 3, 4, 5]);
        assert_eq!(b0.images.len(), 8 * 32 * 32 * 3);
    }

    #[test]
    fn prefetcher_matches_direct_generation() {
        let p = Prefetcher::new(SynthSpec::default(), 4, 2);
        let b = p.next();
        let ds = SynthDataset::new(SynthSpec::default());
        let (images, labels) = ds.batch(0, 4);
        assert_eq!(b.images, images);
        assert_eq!(b.labels, labels);
    }

    #[test]
    fn drop_terminates_worker() {
        let p = Prefetcher::new(SynthSpec::default(), 4, 1);
        let _ = p.next();
        drop(p); // must not hang
    }
}
