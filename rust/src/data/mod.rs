//! Synthetic dataset substrate (ImageNet-1K substitution, DESIGN.md §3).
//!
//! Class-conditional structured images: each class places Gaussian blobs at
//! class-determined positions with class-dependent colors and a sinusoidal
//! texture, plus per-sample jitter and noise.  Deterministic per
//! (seed, index), infinite, and learnable by a small ViT — throughput
//! numbers (the paper's metric) never depend on image content.

pub mod loader;

use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub img_size: usize,
    pub channels: usize,
    pub n_classes: usize,
    pub seed: u64,
    /// Gaussian pixel noise added on top of the class pattern.
    pub noise: f32,
}

impl Default for SynthSpec {
    fn default() -> Self {
        Self { img_size: 32, channels: 3, n_classes: 10, seed: 0, noise: 0.15 }
    }
}

/// One generated sample: image in HWC f32 (z-scored-ish range) + label.
#[derive(Clone, Debug)]
pub struct Sample {
    pub image: Vec<f32>,
    pub label: usize,
}

#[derive(Clone, Debug)]
pub struct SynthDataset {
    pub spec: SynthSpec,
    /// Per-class blob layout: (cy, cx, sigma, amplitude per channel).
    blobs: Vec<Vec<(f32, f32, f32, [f32; 3])>>,
    /// Per-class texture frequency/phase.
    texture: Vec<(f32, f32, f32)>,
}

impl SynthDataset {
    pub fn new(spec: SynthSpec) -> Self {
        let mut rng = Pcg64::with_stream(spec.seed, 0x5eed);
        let blobs = (0..spec.n_classes)
            .map(|_| {
                let k = 2 + rng.below(3); // 2-4 blobs per class
                (0..k)
                    .map(|_| {
                        (
                            rng.uniform_range(0.2, 0.8) as f32,
                            rng.uniform_range(0.2, 0.8) as f32,
                            rng.uniform_range(0.08, 0.22) as f32,
                            [
                                rng.uniform_range(-1.5, 1.5) as f32,
                                rng.uniform_range(-1.5, 1.5) as f32,
                                rng.uniform_range(-1.5, 1.5) as f32,
                            ],
                        )
                    })
                    .collect()
            })
            .collect();
        let texture = (0..spec.n_classes)
            .map(|_| {
                (
                    rng.uniform_range(1.0, 6.0) as f32,
                    rng.uniform_range(0.0, std::f64::consts::TAU) as f32,
                    rng.uniform_range(0.1, 0.5) as f32,
                )
            })
            .collect();
        Self { spec, blobs, texture }
    }

    pub fn image_elements(&self) -> usize {
        self.spec.img_size * self.spec.img_size * self.spec.channels
    }

    /// Deterministic sample `index` (label cycles through classes).
    pub fn sample(&self, index: u64) -> Sample {
        let label = (index % self.spec.n_classes as u64) as usize;
        let mut rng = Pcg64::with_stream(self.spec.seed ^ 0xda7a, index);
        let s = self.spec.img_size;
        let c = self.spec.channels;
        let mut img = vec![0f32; s * s * c];

        // per-sample geometric jitter
        let dy = rng.uniform_range(-0.06, 0.06) as f32;
        let dx = rng.uniform_range(-0.06, 0.06) as f32;
        let gain = rng.uniform_range(0.8, 1.2) as f32;

        let (freq, phase, amp) = self.texture[label];
        for y in 0..s {
            for x in 0..s {
                let fy = y as f32 / s as f32;
                let fx = x as f32 / s as f32;
                let tex = amp * (freq * std::f32::consts::TAU * (fy + fx) + phase).sin();
                for ch in 0..c.min(3) {
                    let mut v = tex;
                    for &(cy, cx, sig, ref col) in &self.blobs[label] {
                        let r2 = (fy - cy - dy).powi(2) + (fx - cx - dx).powi(2);
                        v += col[ch] * (-r2 / (2.0 * sig * sig)).exp();
                    }
                    img[(y * s + x) * c + ch] = gain * v + self.spec.noise * rng.normal_f32();
                }
            }
        }
        Sample { image: img, label }
    }

    /// Fill a batch buffer: images (B,H,W,C) flat + labels.
    pub fn batch(&self, start_index: u64, batch: usize) -> (Vec<f32>, Vec<usize>) {
        let n = self.image_elements();
        let mut images = vec![0f32; batch * n];
        let mut labels = vec![0usize; batch];
        for b in 0..batch {
            let s = self.sample(start_index + b as u64);
            images[b * n..(b + 1) * n].copy_from_slice(&s.image);
            labels[b] = s.label;
        }
        (images, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_index() {
        let ds = SynthDataset::new(SynthSpec::default());
        let a = ds.sample(42);
        let b = ds.sample(42);
        assert_eq!(a.image, b.image);
        assert_eq!(a.label, b.label);
        let c = ds.sample(43);
        assert_ne!(a.image, c.image);
    }

    #[test]
    fn labels_cycle_through_classes() {
        let ds = SynthDataset::new(SynthSpec::default());
        for i in 0..20 {
            assert_eq!(ds.sample(i).label, (i % 10) as usize);
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean inter-class image distance must exceed intra-class distance:
        // otherwise the E2E training task would be unlearnable.
        let ds = SynthDataset::new(SynthSpec::default());
        let a0 = ds.sample(0).image; // class 0
        let a1 = ds.sample(10).image; // class 0 again
        let b0 = ds.sample(1).image; // class 1
        let dist = |x: &[f32], y: &[f32]| -> f32 {
            x.iter().zip(y).map(|(a, b)| (a - b).powi(2)).sum::<f32>().sqrt()
        };
        let intra = dist(&a0, &a1);
        let inter = dist(&a0, &b0);
        assert!(inter > 1.2 * intra, "inter {inter} vs intra {intra}");
    }

    #[test]
    fn batch_layout() {
        let ds = SynthDataset::new(SynthSpec::default());
        let (images, labels) = ds.batch(5, 4);
        assert_eq!(images.len(), 4 * ds.image_elements());
        assert_eq!(labels, vec![5, 6, 7, 8]);
        // first image in batch == direct sample
        let direct = ds.sample(5);
        assert_eq!(&images[..ds.image_elements()], direct.image.as_slice());
    }

    #[test]
    fn values_bounded() {
        let ds = SynthDataset::new(SynthSpec::default());
        let s = ds.sample(7);
        for &v in &s.image {
            assert!(v.is_finite() && v.abs() < 10.0);
        }
    }
}
