//! Minimal CLI argument parser (offline environment: no clap).
//!
//! Grammar: `flashkat <command> [positional...] [--flag value | --flag]`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut args = Args { command, ..Default::default() };
        while let Some(a) = it.next() {
            if let Some(flag) = a.strip_prefix("--") {
                if flag.is_empty() {
                    bail!("empty flag");
                }
                if let Some((k, v)) = flag.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.flags.insert(flag.to_string(), v);
                } else {
                    args.flags.insert(flag.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_bool(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    /// Like [`Self::flag_u64`] but range-checked into `u32`: a value that
    /// does not fit is an error, not a silent `as u32` truncation.
    pub fn flag_u32(&self, name: &str, default: u32) -> Result<u32> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a 32-bit unsigned integer, got {v:?}")),
        }
    }

    /// Range-checked into `u16` — the port-flag parser: `--port 70000`
    /// is an error, not a silent wraparound onto some other port
    /// (mirrors the [`Self::flag_u32`] fix).
    pub fn flag_u16(&self, name: &str, default: u16) -> Result<u16> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                anyhow!("--{name} expects an integer in 0..=65535, got {v:?}")
            }),
        }
    }

    pub fn flag_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn flag_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    /// Comma-separated list flag: `--models a:256,b:64` →
    /// `["a:256", "b:64"]`; empty items are dropped, an absent flag is
    /// an empty list.
    pub fn flag_list(&self, name: &str) -> Vec<String> {
        self.flag(name)
            .map(|v| {
                v.split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(str::to_string)
                    .collect()
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn commands_positional_flags() {
        let a = parse("report table3 --gpu h200 --b-sim=32 --verbose");
        assert_eq!(a.command, "report");
        assert_eq!(a.positional, vec!["table3"]);
        assert_eq!(a.flag("gpu"), Some("h200"));
        assert_eq!(a.flag_usize("b-sim", 8).unwrap(), 32);
        assert!(a.flag_bool("verbose"));
        assert!(!a.flag_bool("quiet"));
        assert_eq!(a.flag_str("missing", "dflt"), "dflt");
    }

    #[test]
    fn flag_value_styles_equivalent() {
        let a = parse("t --x=1");
        let b = parse("t --x 1");
        assert_eq!(a.flag("x"), b.flag("x"));
    }

    #[test]
    fn numeric_errors() {
        let a = parse("t --n abc");
        assert!(a.flag_usize("n", 0).is_err());
        assert!(a.flag_f64("n", 0.0).is_err());
    }

    #[test]
    fn flag_u32_rejects_out_of_range_instead_of_truncating() {
        let a = parse("t --loops 7");
        assert_eq!(a.flag_u32("loops", 1).unwrap(), 7);
        assert_eq!(a.flag_u32("absent", 3).unwrap(), 3);
        // 2^32 used to truncate to 0 through `flag_u64(..) as u32`.
        let big = parse("t --loops 4294967296");
        assert!(big.flag_u32("loops", 1).is_err());
        assert!(parse("t --loops -1").flag_u32("loops", 1).is_err());
    }

    #[test]
    fn flag_u16_rejects_out_of_range_ports() {
        let a = parse("t --port 8080");
        assert_eq!(a.flag_u16("port", 80).unwrap(), 8080);
        assert_eq!(a.flag_u16("absent", 80).unwrap(), 80);
        assert_eq!(parse("t --port 0").flag_u16("port", 80).unwrap(), 0, "0 = ephemeral");
        assert_eq!(parse("t --port 65535").flag_u16("port", 80).unwrap(), 65535);
        // 65536 used to be truncatable to 0 through a wider parse.
        assert!(parse("t --port 65536").flag_u16("port", 80).is_err());
        assert!(parse("t --port -1").flag_u16("port", 80).is_err());
        assert!(parse("t --port http").flag_u16("port", 80).is_err());
    }

    #[test]
    fn flag_list_splits_and_trims() {
        let a = parse("t --models grkan:256:8,small:64");
        assert_eq!(a.flag_list("models"), vec!["grkan:256:8", "small:64"]);
        assert!(a.flag_list("absent").is_empty());
        let b = parse("t --models ,,x,");
        assert_eq!(b.flag_list("models"), vec!["x"]);
    }

    #[test]
    fn empty_args() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.command, "");
    }

    #[test]
    fn negative_numbers_not_eaten_as_flags() {
        let a = parse("t --lr 0.5 pos1");
        assert_eq!(a.flag_f64("lr", 0.0).unwrap(), 0.5);
        assert_eq!(a.positional, vec!["pos1"]);
    }
}
