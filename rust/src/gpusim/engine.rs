//! Reservation-based discrete-event engine.
//!
//! Model: every warp executes its instruction stream in order (the rational
//! kernels are dependent chains).  Contended resources — the SM issue port,
//! the SM LSU, per-level bandwidth, and per-address atomic serialization —
//! are modeled as *work-conserving accumulators*: a resource tracks the
//! time at which its queued work drains, a request at warp-time `t`
//! starts at `max(t, drain_time)`, and for temporally-ordered arrivals
//! the accumulator is advanced past idle gaps so unused cycles are never
//! banked (see [`Resource::acquire`]).  This stays order-insensitive (warps
//! are simulated sequentially, not in temporal order) while still
//! enforcing both the latency bound (dependent chains) and the throughput
//! bound (total work / rate) — the two regimes the paper's analysis
//! distinguishes.  Warp residency per SM is capped at `warp_slots`; a new
//! warp starts when the earliest resident warp completes, which self-paces
//! request arrival the way a real warp scheduler does.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::config::GpuConfig;
use super::stats::{SimReport, WarpState};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemLevel {
    Shared,
    L1,
    L2,
    Hbm,
}

/// One warp-level instruction.
#[derive(Clone, Copy, Debug)]
pub enum Instr {
    /// `n` dependent ALU ops (each `lat_compute` cycles), `flops` counted.
    Compute { n: u32, flops: u32 },
    /// Dependent load: `bytes` moved from `level` (coalesced warp access).
    Load { level: MemLevel, bytes: u32 },
    /// Software-pipelined (prefetched) load: bandwidth is charged but the
    /// dependent chain does not stall — models Triton's pipelined tile
    /// loads in the FlashKAT kernel (one dependent fill at loop entry,
    /// async thereafter).
    LoadAsync { level: MemLevel, bytes: u32 },
    /// Fire-and-forget store (bandwidth charged, no dependency stall).
    Store { level: MemLevel, bytes: u32 },
    /// Atomic read-modify-write: `lanes` serialized updates to `addr`.
    Atomic { addr: u32, lanes: u32, bytes: u32 },
    /// Block barrier (fixed cost approximation).
    Barrier,
}

/// A kernel launch: a grid of blocks, each with `warps_per_block` warps
/// whose instruction streams the trace generator writes into `out`.
pub trait Kernel {
    fn name(&self) -> String;
    fn num_blocks(&self) -> u64;
    fn warps_per_block(&self) -> u32;
    /// Write warp `(block, warp)`'s instruction stream into `out`
    /// (cleared by the engine between calls).
    fn warp_program(&self, block: u64, warp: u32, out: &mut Vec<Instr>);
    /// Number of distinct atomic addresses used (sizing the queue table).
    fn atomic_addresses(&self) -> u32 {
        0
    }

    /// Equivalence class of warp `(block, warp)`'s program, or `None` if
    /// every warp is distinct.  Warps in the same class MUST emit
    /// identical instruction streams; the engine then generates each class
    /// once and replays it (§Perf: 3-4x engine speedup on the rational
    /// kernels, whose programs only vary by group).
    fn warp_class(&self, _block: u64, _warp: u32) -> Option<u32> {
        None
    }
}

/// Work-conserving resource accumulator (see module docs).
#[derive(Clone, Copy, Debug, Default)]
struct Resource {
    busy: f64,
    /// Latest request time seen, gating the idle credit below.
    last_t: u64,
}

impl Resource {
    /// Enqueue `work` cycles of service requested at warp-time `t`.
    /// Returns the service start time.
    ///
    /// Idle-gap crediting: when requests arrive in temporal order
    /// (`t >= last_t`) and the backlog has drained (`busy < t`), the
    /// accumulator is advanced to `t` before the new work is added — a
    /// resource cannot bank unused cycles, so a late request queues only
    /// behind work that is actually still in flight, and a burst arriving
    /// after an idle gap serializes properly instead of packing at `t`
    /// for free until the stale work sum catches up (the seed behavior,
    /// which under-serialized bursty traces and conversely billed late
    /// requests against long-finished work).
    ///
    /// The `t >= last_t` gate matters: warps are simulated sequentially,
    /// NOT in temporal order, so a temporally-concurrent warp visited
    /// later re-issues requests at small `t` after its predecessor's
    /// chain reached large `t`.  Crediting unconditionally would bake the
    /// predecessor's wall-clock positions into `busy` and serialize
    /// overlapping warps behind each other's latency; out-of-order
    /// requests therefore fall back to the pure work-sum rule.
    #[inline]
    fn acquire(&mut self, t: u64, work: f64) -> u64 {
        if t >= self.last_t && self.busy < t as f64 {
            self.busy = t as f64;
        }
        self.last_t = self.last_t.max(t);
        let start = (self.busy.ceil() as u64).max(t);
        self.busy += work;
        start
    }
}

struct SmState {
    issue: Resource,
    lsu: Resource,
    l1: Resource,
    /// Completion times of resident warps.
    resident: BinaryHeap<Reverse<u64>>,
    elapsed: u64,
}

pub fn simulate(cfg: &GpuConfig, kernel: &dyn Kernel) -> SimReport {
    let mut sms: Vec<SmState> = (0..cfg.num_sms)
        .map(|_| SmState {
            issue: Resource::default(),
            lsu: Resource::default(),
            l1: Resource::default(),
            resident: BinaryHeap::new(),
            elapsed: 0,
        })
        .collect();

    let mut l2 = Resource::default();
    let mut hbm = Resource::default();
    let mut atomics: Vec<Resource> = vec![Resource::default(); kernel.atomic_addresses() as usize];

    let mut rep = SimReport { kernel: kernel.name(), ..Default::default() };

    let mut prog: Vec<Instr> = Vec::with_capacity(1024);
    let mut class_cache: std::collections::HashMap<u32, Vec<Instr>> =
        std::collections::HashMap::new();
    let n_blocks = kernel.num_blocks();
    let wpb = kernel.warps_per_block();

    for block in 0..n_blocks {
        let sm_idx = (block % cfg.num_sms as u64) as usize;
        for w in 0..wpb {
            // Program generation, memoized by warp class when available.
            let prog: &[Instr] = match kernel.warp_class(block, w) {
                Some(class) => class_cache.entry(class).or_insert_with(|| {
                    let mut p = Vec::new();
                    kernel.warp_program(block, w, &mut p);
                    p
                }),
                None => {
                    prog.clear();
                    kernel.warp_program(block, w, &mut prog);
                    &prog
                }
            };

            let sm = &mut sms[sm_idx];
            // Residency: start when a slot frees up.
            let start = if sm.resident.len() < cfg.warp_slots {
                0
            } else {
                sm.resident.pop().unwrap().0
            };

            let mut t = start;
            for &instr in prog.iter() {
                // Issue-port: one instruction per cycle per SM.
                let issue = sm.issue.acquire(t, 1.0);
                rep.state_cycles[WarpState::NotSelected.index()] += issue - t;
                rep.state_cycles[WarpState::Selected.index()] += 1;
                rep.instructions += 1;

                match instr {
                    Instr::Compute { n, flops } => {
                        let done = issue + n as u64 * cfg.lat_compute;
                        rep.state_cycles[WarpState::Wait.index()] += done - issue;
                        rep.flops += flops as u64;
                        t = done;
                    }
                    Instr::Barrier => {
                        let done = issue + cfg.barrier_cost;
                        rep.state_cycles[WarpState::Barrier.index()] += done - issue;
                        t = done;
                    }
                    Instr::Load { level, bytes }
                    | Instr::LoadAsync { level, bytes }
                    | Instr::Store { level, bytes } => {
                        let is_async =
                            matches!(instr, Instr::Store { .. } | Instr::LoadAsync { .. });
                        // LSU: one memory instruction per `lsu_interval`.
                        let lsu = sm.lsu.acquire(issue, cfg.lsu_interval as f64);
                        rep.state_cycles[WarpState::LgThrottle.index()] += lsu - issue;

                        let (svc_start, lat, state) = match level {
                            MemLevel::Shared => {
                                rep.bytes_shared += bytes as u64;
                                (lsu, cfg.lat_shared, WarpState::ShortScoreboard)
                            }
                            MemLevel::L1 => {
                                rep.bytes_l1 += bytes as u64;
                                let s = sm.l1.acquire(lsu, bytes as f64 / cfg.bw_l1_per_sm);
                                (s, cfg.lat_l1, WarpState::ShortScoreboard)
                            }
                            MemLevel::L2 => {
                                rep.bytes_l1 += bytes as u64;
                                rep.bytes_l2 += bytes as u64;
                                let s = l2.acquire(lsu, bytes as f64 / cfg.bw_l2);
                                (s, cfg.lat_l2, WarpState::LongScoreboard)
                            }
                            MemLevel::Hbm => {
                                rep.bytes_l1 += bytes as u64;
                                rep.bytes_l2 += bytes as u64;
                                rep.bytes_hbm += bytes as u64;
                                let s = hbm.acquire(lsu, bytes as f64 / cfg.bw_hbm);
                                (s, cfg.lat_hbm, WarpState::LongScoreboard)
                            }
                        };
                        if is_async {
                            // Stores (write buffer) and prefetched loads
                            // don't stall the dependent chain; a small
                            // drain cost models queue occupancy.
                            rep.state_cycles[WarpState::Drain.index()] += 2;
                            t = lsu + 2;
                        } else {
                            let done = svc_start + lat;
                            rep.state_cycles[state.index()] += done - issue;
                            t = done;
                        }
                    }
                    Instr::Atomic { addr, lanes, bytes } => {
                        let lsu = sm.lsu.acquire(issue, cfg.lsu_interval as f64);
                        rep.state_cycles[WarpState::LgThrottle.index()] += lsu - issue;
                        // Atomics resolve at L2: bandwidth + per-address
                        // RMW serialization (the contention mechanism).
                        rep.bytes_l2 += (bytes as u64) * lanes as u64;
                        let work = lanes as u64 * cfg.atomic_service;
                        let bw_start = l2.acquire(lsu, (bytes * lanes) as f64 / cfg.bw_l2);
                        let svc = atomics[addr as usize].acquire(bw_start, work as f64);
                        let done = svc + work;
                        rep.atomic_lanes += lanes as u64;
                        rep.state_cycles[WarpState::LongScoreboard.index()] += done - issue;
                        t = done;
                    }
                }
            }

            rep.warp_cycles += t - start;
            let sm = &mut sms[sm_idx];
            sm.resident.push(Reverse(t));
            sm.elapsed = sm.elapsed.max(t);
        }
    }

    rep.elapsed_cycles = sms.iter().map(|s| s.elapsed).max().unwrap_or(0);
    rep.elapsed_secs = cfg.cycles_to_secs(rep.elapsed_cycles);

    let denom = (rep.elapsed_cycles.max(1) * cfg.num_sms as u64) as f64;
    rep.sm_thp = 100.0 * rep.instructions as f64 / denom;
    rep.l1_thp = 100.0 * rep.bytes_l1 as f64 / (denom * cfg.bw_l1_per_sm);
    rep.l2_thp = 100.0 * rep.bytes_l2 as f64 / (rep.elapsed_cycles.max(1) as f64 * cfg.bw_l2);
    rep.hbm_thp = 100.0 * rep.bytes_hbm as f64 / (rep.elapsed_cycles.max(1) as f64 * cfg.bw_hbm);
    rep.sm_thp = rep.sm_thp.min(100.0);
    rep.l1_thp = rep.l1_thp.min(100.0);
    rep.l2_thp = rep.l2_thp.min(100.0);
    rep.hbm_thp = rep.hbm_thp.min(100.0);
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A toy kernel: `blocks` blocks of one warp, each doing `loads` HBM
    /// loads, one compute instruction, `atomics` atomic adds, one store.
    struct Toy {
        blocks: u64,
        loads: u32,
        comp: u32,
        atomics: u32,
        addrs: u32,
    }

    impl Kernel for Toy {
        fn name(&self) -> String {
            "toy".into()
        }
        fn num_blocks(&self) -> u64 {
            self.blocks
        }
        fn warps_per_block(&self) -> u32 {
            1
        }
        fn warp_program(&self, _b: u64, _w: u32, out: &mut Vec<Instr>) {
            for _ in 0..self.loads {
                out.push(Instr::Load { level: MemLevel::Hbm, bytes: 128 });
            }
            if self.comp > 0 {
                out.push(Instr::Compute { n: self.comp, flops: self.comp * 32 });
            }
            for i in 0..self.atomics {
                out.push(Instr::Atomic { addr: i % self.addrs, lanes: 32, bytes: 4 });
            }
            out.push(Instr::Store { level: MemLevel::Hbm, bytes: 128 });
        }
        fn atomic_addresses(&self) -> u32 {
            self.addrs
        }
    }

    fn cfg() -> GpuConfig {
        GpuConfig::rtx4060ti()
    }

    #[test]
    fn idle_gaps_are_credited_for_ordered_arrivals() {
        let mut r = Resource::default();
        // busy [0, 10): first request starts immediately.
        assert_eq!(r.acquire(0, 10.0), 0);
        // The 10..1000 idle gap is credited: a late request starts at its
        // own arrival time, not at the stale work sum.
        assert_eq!(r.acquire(1000, 10.0), 1000);
        // ...and a second request at the same instant queues behind the
        // in-flight 10 cycles (the seed let both start at t=1000).
        assert_eq!(r.acquire(1000, 10.0), 1010);
        // An out-of-order request (a later-simulated concurrent warp)
        // must NOT see the predecessors' wall-clock positions as banked
        // idle; it falls back to the work-sum rule and queues behind the
        // 30 enqueued cycles (1020), not behind t=1000 + credit.
        assert_eq!(r.acquire(100, 10.0), 1020);
    }

    #[test]
    fn empty_kernel() {
        let r = simulate(&cfg(), &Toy { blocks: 0, loads: 0, comp: 0, atomics: 0, addrs: 1 });
        assert_eq!(r.elapsed_cycles, 0);
        assert_eq!(r.instructions, 0);
    }

    #[test]
    fn single_warp_latency_chain() {
        let r = simulate(&cfg(), &Toy { blocks: 1, loads: 2, comp: 5, atomics: 0, addrs: 1 });
        // 2 dependent HBM loads + 5-deep ALU chain + store.
        assert_eq!(r.instructions, 4); // 2 loads, 1 compute, 1 store
        assert!(r.elapsed_cycles >= 2 * 466 + 5 * 4, "{}", r.elapsed_cycles);
        assert!(r.elapsed_cycles < 2 * 466 + 5 * 4 + 50);
        assert_eq!(r.bytes_hbm, 3 * 128);
    }

    #[test]
    fn warps_overlap_under_residency() {
        // 100 independent warps across 34 SMs must overlap: elapsed should
        // be close to a single warp's chain, not 100x it.
        let one = simulate(&cfg(), &Toy { blocks: 1, loads: 4, comp: 2, atomics: 0, addrs: 1 });
        let many = simulate(&cfg(), &Toy { blocks: 100, loads: 4, comp: 2, atomics: 0, addrs: 1 });
        // Bound is 3x (was 2x): where arrivals are temporally ordered,
        // idle-crediting serializes same-cycle bursts the seed accumulator
        // let overlap for free, stretching shared-HBM queueing slightly.
        assert!(many.elapsed_cycles < 3 * one.elapsed_cycles, "{} vs {}", many.elapsed_cycles, one.elapsed_cycles);
    }

    #[test]
    fn bandwidth_bounds_streaming() {
        // Many warps streaming HBM: elapsed ~ total_bytes / bw_hbm.
        let blocks = 20_000;
        let r = simulate(&cfg(), &Toy { blocks, loads: 4, comp: 2, atomics: 0, addrs: 1 });
        let ideal = r.bytes_hbm as f64 / cfg().bw_hbm;
        let ratio = r.elapsed_cycles as f64 / ideal;
        // Slightly looser than the seed's 1.5 / 60%: for in-order arrival
        // runs the idle-credited accumulator no longer lets bursts absorb
        // their queueing for free, so some warm-up serialization shows up.
        assert!(ratio < 1.7, "elapsed {} vs ideal {}", r.elapsed_cycles, ideal);
        assert!(r.hbm_thp > 55.0, "{}", r.hbm_thp);
    }

    #[test]
    fn atomic_contention_serializes() {
        // Same work, but all warps hammer one address with atomics.
        let with = simulate(&cfg(), &Toy { blocks: 2000, loads: 1, comp: 2, atomics: 4, addrs: 1 });
        let without = simulate(&cfg(), &Toy { blocks: 2000, loads: 1, comp: 2, atomics: 0, addrs: 1 });
        // 2000 warps x 4 atomics x 32 lanes x `atomic_service` cycles on
        // ONE address is pure serialization; the 30-cycle floor below is a
        // conservative lower bound (the preset service interval is 120).
        assert!(with.elapsed_cycles >= 2000 * 4 * 32 * 30);
        assert!(with.elapsed_cycles > 10 * without.elapsed_cycles);
        // and the stall signature flips to Long Scoreboard.
        assert!(with.lsb_over_selected() > 10.0);
    }

    #[test]
    fn more_addresses_less_contention() {
        let few = simulate(&cfg(), &Toy { blocks: 4000, loads: 1, comp: 2, atomics: 8, addrs: 1 });
        let many = simulate(&cfg(), &Toy { blocks: 4000, loads: 1, comp: 2, atomics: 8, addrs: 8 });
        assert!(many.elapsed_cycles < few.elapsed_cycles);
    }

    #[test]
    fn flops_insensitivity_when_memory_bound() {
        // Paper Table 2: scaling compute 8x doesn't change elapsed time
        // when the kernel is memory/atomic-bound.
        let base = simulate(&cfg(), &Toy { blocks: 3000, loads: 2, comp: 8, atomics: 6, addrs: 4 });
        let scaled = simulate(&cfg(), &Toy { blocks: 3000, loads: 2, comp: 64, atomics: 6, addrs: 4 });
        assert_eq!(scaled.flops, base.flops * 8);
        let ratio = scaled.elapsed_cycles as f64 / base.elapsed_cycles as f64;
        assert!(ratio < 1.15, "ratio {ratio}");
    }

    #[test]
    fn warp_cycles_exceed_elapsed_with_parallelism() {
        let r = simulate(&cfg(), &Toy { blocks: 5000, loads: 3, comp: 4, atomics: 0, addrs: 1 });
        assert!(r.warp_cycles > r.elapsed_cycles);
    }

    #[test]
    fn throughputs_bounded() {
        let r = simulate(&cfg(), &Toy { blocks: 3000, loads: 3, comp: 4, atomics: 2, addrs: 2 });
        for v in [r.sm_thp, r.l1_thp, r.l2_thp, r.hbm_thp] {
            assert!((0.0..=100.0).contains(&v), "{v}");
        }
    }
}
