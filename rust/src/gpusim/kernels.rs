//! Kernel trace generators: paper Algorithm 1, Algorithm 2, the forward
//! kernel, plus GEMM / streaming kernels used to compose whole-model cost
//! estimates (Fig 1 / Table 4).
//!
//! Instruction budgets per element follow the actual rational math
//! (Horner P: 5 fma, A: 4 ops, derivatives, power ladders) — see
//! `rational::backward_elem` for the arithmetic being modeled.

use super::engine::{Instr, Kernel, MemLevel};

/// Problem dims for the rational kernels (the paper's microbenchmark is
/// B=1024, N=197, d=768, 8 groups, m+1=6, n=4).
#[derive(Clone, Copy, Debug)]
pub struct RationalDims {
    pub batch: u64,
    pub seq: u64,
    pub d: u64,
    pub n_groups: u32,
    pub m1: u32,
    pub n: u32,
    /// Artificial FLOP multiplier (paper Table 2's "Loops" column).
    pub flop_loops: u32,
}

impl RationalDims {
    pub fn paper() -> Self {
        Self { batch: 1024, seq: 197, d: 768, n_groups: 8, m1: 6, n: 4, flop_loops: 1 }
    }

    pub fn elements(&self) -> u64 {
        self.batch * self.seq * self.d
    }

    pub fn coeffs_per_group(&self) -> u32 {
        self.m1 + self.n
    }

    /// FLOPs per element of the forward rational evaluation.
    pub fn fwd_flops_per_elem(&self) -> u32 {
        (2 * (self.m1 - 1) + 2 * self.n + 3) * self.flop_loops
    }

    /// FLOPs per element of the backward (dx + dA + dB contributions).
    pub fn bwd_flops_per_elem(&self) -> u32 {
        (6 * (self.m1 - 1) + 6 * self.n + 12) * self.flop_loops
    }
}

const WARP: u64 = 32;
const LANE_BYTES: u32 = 4; // f32

// ---------------------------------------------------------------------------
// Forward kernel: 1-D grid, streaming, no accumulation.
// ---------------------------------------------------------------------------

pub struct RationalFwdKernel {
    pub dims: RationalDims,
    pub block_threads: u64,
}

impl RationalFwdKernel {
    pub fn new(dims: RationalDims) -> Self {
        Self { dims, block_threads: 256 }
    }
}

impl Kernel for RationalFwdKernel {
    fn name(&self) -> String {
        format!("rational_fwd(loops={})", self.dims.flop_loops)
    }

    fn warp_class(&self, _block: u64, _warp: u32) -> Option<u32> {
        Some(0) // identical program for every warp
    }

    fn num_blocks(&self) -> u64 {
        self.dims.elements().div_ceil(self.block_threads)
    }

    fn warps_per_block(&self) -> u32 {
        (self.block_threads / WARP) as u32
    }

    fn warp_program(&self, _block: u64, _warp: u32, out: &mut Vec<Instr>) {
        let d = &self.dims;
        // X tile for this warp.
        out.push(Instr::Load { level: MemLevel::Hbm, bytes: (WARP as u32) * LANE_BYTES });
        // Coefficient rows (tiny, L1-resident after first touch).
        out.push(Instr::Load { level: MemLevel::L1, bytes: d.coeffs_per_group() * LANE_BYTES });
        // Horner chains: ~12 dependent ALU ops per element, x flop_loops.
        out.push(Instr::Compute {
            n: 12 * d.flop_loops,
            flops: d.fwd_flops_per_elem() * WARP as u32,
        });
        out.push(Instr::Store { level: MemLevel::Hbm, bytes: (WARP as u32) * LANE_BYTES });
    }
}

// ---------------------------------------------------------------------------
// Algorithm 1 (KAT baseline backward): per-element atomic accumulation.
// ---------------------------------------------------------------------------

pub struct RationalBwdKatKernel {
    pub dims: RationalDims,
    pub block_threads: u64,
}

impl RationalBwdKatKernel {
    pub fn new(dims: RationalDims) -> Self {
        Self { dims, block_threads: 256 }
    }
}

impl Kernel for RationalBwdKatKernel {
    fn name(&self) -> String {
        format!("kat_bwd(loops={})", self.dims.flop_loops)
    }

    fn num_blocks(&self) -> u64 {
        self.dims.elements().div_ceil(self.block_threads)
    }

    fn warps_per_block(&self) -> u32 {
        (self.block_threads / WARP) as u32
    }

    fn atomic_addresses(&self) -> u32 {
        self.dims.n_groups * self.dims.coeffs_per_group()
    }

    fn warp_class(&self, block: u64, warp: u32) -> Option<u32> {
        // Program varies only with the group (atomic base address).
        let d = &self.dims;
        let flat = block * self.block_threads + warp as u64 * WARP;
        Some(((flat % d.d) / (d.d / d.n_groups as u64)) as u32)
    }

    fn warp_program(&self, block: u64, warp: u32, out: &mut Vec<Instr>) {
        let d = &self.dims;
        // Which group does this warp's first lane belong to?
        let flat = block * self.block_threads + warp as u64 * WARP;
        let g = ((flat % d.d) / (d.d / d.n_groups as u64)) as u32;

        out.push(Instr::Load { level: MemLevel::Hbm, bytes: (WARP as u32) * LANE_BYTES }); // X
        out.push(Instr::Load { level: MemLevel::Hbm, bytes: (WARP as u32) * LANE_BYTES }); // dO
        out.push(Instr::Load { level: MemLevel::L1, bytes: d.coeffs_per_group() * LANE_BYTES });
        out.push(Instr::Compute {
            n: 30 * d.flop_loops,
            flops: d.bwd_flops_per_elem() * WARP as u32,
        });
        // THE bottleneck: one atomic RMW per coefficient per element.
        // All 32 lanes of the warp hit the same address (same group) and
        // the hardware serializes them.
        let base = g * d.coeffs_per_group();
        for i in 0..d.coeffs_per_group() {
            out.push(Instr::Atomic { addr: base + i, lanes: WARP as u32, bytes: LANE_BYTES });
        }
        out.push(Instr::Store { level: MemLevel::Hbm, bytes: (WARP as u32) * LANE_BYTES }); // dX
    }
}

// ---------------------------------------------------------------------------
// Algorithm 2 (FlashKAT backward): 2-D grid, block-local reduction,
// one atomic per coefficient per BLOCK.
// ---------------------------------------------------------------------------

pub struct RationalBwdFlashKernel {
    pub dims: RationalDims,
    /// Rows per block (paper's S_block).
    pub s_block: u64,
}

impl RationalBwdFlashKernel {
    pub fn new(dims: RationalDims) -> Self {
        Self { dims, s_block: 128 }
    }

    fn d_g(&self) -> u64 {
        self.dims.d / self.dims.n_groups as u64
    }

    fn tile_elems(&self) -> u64 {
        self.s_block * self.d_g()
    }
}

impl Kernel for RationalBwdFlashKernel {
    fn name(&self) -> String {
        format!("flash_bwd(loops={},S={})", self.dims.flop_loops, self.s_block)
    }

    fn num_blocks(&self) -> u64 {
        let rows = self.dims.batch * self.dims.seq;
        rows.div_ceil(self.s_block) * self.dims.n_groups as u64
    }

    fn warps_per_block(&self) -> u32 {
        self.tile_elems().div_ceil(WARP) as u32
    }

    fn atomic_addresses(&self) -> u32 {
        self.dims.n_groups * self.dims.coeffs_per_group()
    }

    fn warp_class(&self, block: u64, warp: u32) -> Option<u32> {
        // Program varies with the group and with warp 0 vs the rest.
        let g = (block % self.dims.n_groups as u64) as u32;
        Some(g * 2 + u32::from(warp == 0))
    }

    fn warp_program(&self, block: u64, warp: u32, out: &mut Vec<Instr>) {
        let d = &self.dims;
        let g = (block % d.n_groups as u64) as u32;

        // Triton software-pipelines the tile loads: only the loop-entry
        // fill is a dependent stall, steady-state loads are prefetched.
        if warp == 0 {
            out.push(Instr::Load { level: MemLevel::Hbm, bytes: (WARP as u32) * LANE_BYTES });
        } else {
            out.push(Instr::LoadAsync { level: MemLevel::Hbm, bytes: (WARP as u32) * LANE_BYTES });
        }
        out.push(Instr::LoadAsync { level: MemLevel::Hbm, bytes: (WARP as u32) * LANE_BYTES }); // dO
        if warp == 0 {
            // One coefficient fetch per block (reused from registers/smem).
            out.push(Instr::Load { level: MemLevel::L1, bytes: d.coeffs_per_group() * LANE_BYTES });
        }
        out.push(Instr::Compute {
            n: 30 * d.flop_loops,
            flops: d.bwd_flops_per_elem() * WARP as u32,
        });
        // Block-local tree reduction through shared memory.
        out.push(Instr::Store { level: MemLevel::Shared, bytes: d.coeffs_per_group() * LANE_BYTES });
        out.push(Instr::Barrier);
        if warp == 0 {
            // Final warp reduces partials and issues ONE single-lane atomic
            // per coefficient for the whole block.
            let rounds = (self.warps_per_block() as f64).log2().ceil() as u32;
            out.push(Instr::Load {
                level: MemLevel::Shared,
                bytes: d.coeffs_per_group() * LANE_BYTES,
            });
            out.push(Instr::Compute { n: rounds.max(1), flops: rounds * d.coeffs_per_group() });
            let base = g * d.coeffs_per_group();
            for i in 0..d.coeffs_per_group() {
                out.push(Instr::Atomic { addr: base + i, lanes: 1, bytes: LANE_BYTES });
            }
        }
        out.push(Instr::Store { level: MemLevel::Hbm, bytes: (WARP as u32) * LANE_BYTES }); // dX
    }
}

// ---------------------------------------------------------------------------
// GEMM kernel: tiled matmul cost model for the non-rational model ops.
// ---------------------------------------------------------------------------

pub struct GemmKernel {
    pub m: u64,
    pub n: u64,
    pub k: u64,
    /// How many independent GEMMs of this shape (batched attention heads).
    pub count: u64,
}

const TILE: u64 = 128;

impl Kernel for GemmKernel {
    fn name(&self) -> String {
        format!("gemm({}x{}x{}x{})", self.count, self.m, self.n, self.k)
    }

    fn warp_class(&self, _block: u64, _warp: u32) -> Option<u32> {
        Some(0)
    }

    fn num_blocks(&self) -> u64 {
        self.count * self.m.div_ceil(TILE) * self.n.div_ceil(TILE)
    }

    fn warps_per_block(&self) -> u32 {
        8
    }

    fn warp_program(&self, _block: u64, _warp: u32, out: &mut Vec<Instr>) {
        // Each block computes a 128x128 tile: per k-step of 32, load A/B
        // sub-tiles and run the MAC pipeline.  Per warp: 1/8 of the tile.
        let steps = self.k.div_ceil(32);
        for _ in 0..steps {
            // A and B tiles: 128x32 f32 each per block -> 2*16KB/8 warps.
            out.push(Instr::Load { level: MemLevel::Hbm, bytes: 2048 });
            out.push(Instr::Load { level: MemLevel::Shared, bytes: 2048 });
            // 128x128x32 MACs / 8 warps / 32 lanes = 2048 MACs per lane,
            // pipelined ~8 dependent steps.
            out.push(Instr::Compute { n: 8, flops: 2 * 128 * 128 * 32 / 8 });
        }
        out.push(Instr::Store { level: MemLevel::Hbm, bytes: (TILE * TILE * 4 / 8) as u32 });
    }
}

// ---------------------------------------------------------------------------
// Streaming kernel: layernorm / softmax / residual adds / elementwise.
// ---------------------------------------------------------------------------

pub struct StreamKernel {
    pub label: String,
    pub bytes_read: u64,
    pub bytes_write: u64,
    pub alu_per_elem: u32,
}

impl Kernel for StreamKernel {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn warp_class(&self, _block: u64, _warp: u32) -> Option<u32> {
        Some(0)
    }

    fn num_blocks(&self) -> u64 {
        let elems = (self.bytes_read + self.bytes_write) / 4;
        elems.div_ceil(256).max(1)
    }

    fn warps_per_block(&self) -> u32 {
        8
    }

    fn warp_program(&self, _block: u64, _warp: u32, out: &mut Vec<Instr>) {
        let frac_read = self.bytes_read as f64 / (self.bytes_read + self.bytes_write).max(1) as f64;
        let rd = (128.0 * frac_read).round() as u32;
        if rd > 0 {
            out.push(Instr::Load { level: MemLevel::Hbm, bytes: rd });
        }
        out.push(Instr::Compute { n: self.alu_per_elem.max(1), flops: self.alu_per_elem * 32 });
        if rd < 128 {
            out.push(Instr::Store { level: MemLevel::Hbm, bytes: 128 - rd });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{simulate, GpuConfig};

    fn small() -> RationalDims {
        RationalDims { batch: 8, seq: 197, d: 768, n_groups: 8, m1: 6, n: 4, flop_loops: 1 }
    }

    #[test]
    fn flash_vs_kat_backward_orders_of_magnitude() {
        // Paper Table 3: 140.5x kernel speedup.  At scaled dims the ratio
        // should still be >= 2 orders of magnitude in elapsed cycles.
        let cfg = GpuConfig::rtx4060ti();
        let kat = simulate(&cfg, &RationalBwdKatKernel::new(small()));
        let flash = simulate(&cfg, &RationalBwdFlashKernel::new(small()));
        let speedup = kat.elapsed_cycles as f64 / flash.elapsed_cycles as f64;
        assert!(speedup > 20.0, "speedup only {speedup:.1}x");
        // Atomic lane counts differ by ~S_block*d_g (paper's reduction factor).
        assert!(kat.atomic_lanes > 1000 * flash.atomic_lanes.max(1));
    }

    #[test]
    fn kat_bwd_stall_signature() {
        // Paper Figure 2: Long Scoreboard >> Selected for Algorithm 1.
        let cfg = GpuConfig::rtx4060ti();
        let r = simulate(&cfg, &RationalBwdKatKernel::new(small()));
        assert!(r.lsb_over_selected() > 50.0, "{}", r.lsb_over_selected());
        // And memory throughput is LOW despite being memory-bound.
        assert!(r.hbm_thp < 20.0, "{}", r.hbm_thp);
    }

    #[test]
    fn flash_bwd_healthy_signature() {
        // Paper Figure 3 / Table 3: stalls shrink, HBM throughput rises.
        let cfg = GpuConfig::rtx4060ti();
        let r = simulate(&cfg, &RationalBwdFlashKernel::new(small()));
        assert!(r.lsb_over_selected() < 50.0, "{}", r.lsb_over_selected());
        assert!(r.hbm_thp > 30.0, "{}", r.hbm_thp);
    }

    #[test]
    fn fwd_is_bandwidth_bound() {
        // Paper Table 2 fwd: HBM ~89%, time insensitive to FLOP loops.
        let cfg = GpuConfig::rtx4060ti();
        let r1 = simulate(&cfg, &RationalFwdKernel::new(small()));
        assert!(r1.hbm_thp > 50.0, "{}", r1.hbm_thp);
        let mut d8 = small();
        d8.flop_loops = 8;
        let r8 = simulate(&cfg, &RationalFwdKernel::new(d8));
        let ratio = r8.elapsed_cycles as f64 / r1.elapsed_cycles as f64;
        assert!(ratio < 1.6, "fwd loops ratio {ratio}");
        assert_eq!(r8.flops, r1.flops * 8);
    }

    #[test]
    fn kat_bwd_flops_insensitive() {
        // Paper Table 2 bwd: cycles identical across 1x..8x FLOPs.
        let cfg = GpuConfig::rtx4060ti();
        let r1 = simulate(&cfg, &RationalBwdKatKernel::new(small()));
        let mut d8 = small();
        d8.flop_loops = 8;
        let r8 = simulate(&cfg, &RationalBwdKatKernel::new(d8));
        let ratio = r8.elapsed_cycles as f64 / r1.elapsed_cycles as f64;
        assert!((0.95..1.1).contains(&ratio), "bwd loops ratio {ratio}");
    }

    #[test]
    fn gemm_cost_model_sane() {
        let cfg = GpuConfig::rtx4060ti();
        let r = simulate(&cfg, &GemmKernel { m: 2048, n: 768, k: 768, count: 1 });
        assert!(r.flops > 2 * 2048 * 768 * 768 * 9 / 10); // ~2mnk
        // Tiled GEMM with tile reuse: traffic well below mnk scaling but
        // above the single-pass minimum.
        let min_bytes = (2048 * 768 + 768 * 768 + 2048 * 768) * 4;
        assert!(r.bytes_hbm as u64 > min_bytes);
        assert!((r.bytes_hbm as u64) < 20 * min_bytes);
    }

    #[test]
    fn stream_kernel_balances_bytes() {
        let cfg = GpuConfig::rtx4060ti();
        let r = simulate(
            &cfg,
            &StreamKernel { label: "ln".into(), bytes_read: 1 << 20, bytes_write: 1 << 20, alu_per_elem: 4 },
        );
        let total = r.bytes_hbm as f64;
        assert!((total - 2.0 * (1 << 20) as f64).abs() / total < 0.2, "{total}");
    }

    #[test]
    fn flash_access_reduction_matches_paper_formula() {
        // Atomic reduction factor = S_block * d_g (paper Section 4).
        let dims = small();
        let kat = RationalBwdKatKernel::new(dims);
        let flash = RationalBwdFlashKernel::new(dims);
        let kat_atomics: u64 = dims.elements() * dims.coeffs_per_group() as u64;
        let flash_atomics: u64 = flash.num_blocks() * dims.coeffs_per_group() as u64;
        let reduction = kat_atomics as f64 / flash_atomics as f64;
        let expected = (flash.s_block * (dims.d / dims.n_groups as u64)) as f64;
        // ceil-division block remainders allow ~10% slack at small dims.
        assert!((reduction / expected - 1.0).abs() < 0.10, "{reduction} vs {expected}");
        let _ = kat; // (kat kernel asserts the same count implicitly in sim tests)
    }
}
