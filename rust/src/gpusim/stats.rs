//! Warp-state accounting and the simulation report.

use crate::util::stats::{human_count, human_time};

/// Nsight Compute warp-state vocabulary (the paper's Figures 2-3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WarpState {
    /// "Computing - Selected": the warp issues an instruction.
    Selected,
    /// Waiting on a global-memory (L2/HBM) dependency — the paper's
    /// dominant stall for KAT's backward pass.
    LongScoreboard,
    /// Waiting on a shared-memory dependency.
    ShortScoreboard,
    /// Waiting on a fixed-latency ALU dependency.
    Wait,
    /// Ready but another warp was selected (issue-port contention).
    NotSelected,
    /// Issue blocked because the load/store unit queue is full.
    LgThrottle,
    /// Memory-IO pipe throttled (we fold texture/special into this).
    MioThrottle,
    /// Waiting to drain stores at exit.
    Drain,
    /// Waiting at a block barrier.
    Barrier,
}

pub const ALL_STATES: [WarpState; 9] = [
    WarpState::Selected,
    WarpState::LongScoreboard,
    WarpState::ShortScoreboard,
    WarpState::Wait,
    WarpState::NotSelected,
    WarpState::LgThrottle,
    WarpState::MioThrottle,
    WarpState::Drain,
    WarpState::Barrier,
];

impl WarpState {
    pub fn label(&self) -> &'static str {
        match self {
            WarpState::Selected => "Computing - Selected",
            WarpState::LongScoreboard => "Stall Long Scoreboard",
            WarpState::ShortScoreboard => "Stall Short Scoreboard",
            WarpState::Wait => "Stall Wait",
            WarpState::NotSelected => "Stall Not Selected",
            WarpState::LgThrottle => "Stall LG Throttle",
            WarpState::MioThrottle => "Stall MIO Throttle",
            WarpState::Drain => "Stall Drain",
            WarpState::Barrier => "Stall Barrier",
        }
    }

    pub fn index(&self) -> usize {
        ALL_STATES.iter().position(|s| s == self).unwrap()
    }
}

/// Aggregate simulation result for one kernel launch.
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    pub kernel: String,
    /// Wall clock of the launch (max SM completion), cycles and seconds.
    pub elapsed_cycles: u64,
    pub elapsed_secs: f64,
    /// Sum over warps of resident cycles — the Nsight-style "Cycles"
    /// aggregate the paper reports (2.4T for KAT bwd).
    pub warp_cycles: u64,
    pub instructions: u64,
    pub flops: u64,
    /// Cycles spent per warp state (summed over warps).
    pub state_cycles: [u64; 9],
    /// Bytes that transited each level.
    pub bytes_l1: u64,
    pub bytes_l2: u64,
    pub bytes_hbm: u64,
    pub bytes_shared: u64,
    /// Count of atomic lane-updates (serialized RMWs).
    pub atomic_lanes: u64,
    /// Throughput utilization (0-100%).
    pub sm_thp: f64,
    pub l1_thp: f64,
    pub l2_thp: f64,
    pub hbm_thp: f64,
}

impl SimReport {
    /// Average cycles each warp spends in `state` per issued instruction —
    /// the y-axis of the paper's Figures 2-3.
    pub fn cycles_per_instr(&self, state: WarpState) -> f64 {
        if self.instructions == 0 {
            return 0.0;
        }
        self.state_cycles[state.index()] as f64 / self.instructions as f64
    }

    /// Ratio of Long-Scoreboard stall to Selected (paper quotes 412x).
    pub fn lsb_over_selected(&self) -> f64 {
        let sel = self.state_cycles[WarpState::Selected.index()].max(1);
        self.state_cycles[WarpState::LongScoreboard.index()] as f64 / sel as f64
    }

    pub fn table_row(&self) -> String {
        format!(
            "{:<22} {:>8} {:>10} {:>8.2} {:>8.2} {:>8.2} {:>8.2}",
            self.kernel,
            human_count(self.warp_cycles as f64),
            human_time(self.elapsed_secs),
            self.sm_thp,
            self.l1_thp,
            self.l2_thp,
            self.hbm_thp,
        )
    }

    pub fn warp_state_figure(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("warp states for {} (cycles per issued instruction)\n", self.kernel));
        let max = ALL_STATES
            .iter()
            .map(|s| self.cycles_per_instr(*s))
            .fold(0.0f64, f64::max)
            .max(1e-9);
        for s in ALL_STATES {
            let v = self.cycles_per_instr(s);
            let bar = "#".repeat(((v / max) * 50.0).round() as usize);
            out.push_str(&format!("  {:<24} {:>10.2} |{}\n", s.label(), v, bar));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_indexing_consistent() {
        for (i, s) in ALL_STATES.iter().enumerate() {
            assert_eq!(s.index(), i);
        }
    }

    #[test]
    fn cycles_per_instr_and_ratio() {
        let mut r = SimReport::default();
        r.instructions = 100;
        r.state_cycles[WarpState::Selected.index()] = 100;
        r.state_cycles[WarpState::LongScoreboard.index()] = 41_200;
        assert!((r.cycles_per_instr(WarpState::Selected) - 1.0).abs() < 1e-12);
        assert!((r.lsb_over_selected() - 412.0).abs() < 1e-9);
    }

    #[test]
    fn figure_renders_all_states() {
        let mut r = SimReport::default();
        r.kernel = "k".into();
        r.instructions = 10;
        r.state_cycles = [10, 20, 0, 5, 1, 0, 0, 0, 0];
        let fig = r.warp_state_figure();
        for s in ALL_STATES {
            assert!(fig.contains(s.label()));
        }
    }
}
