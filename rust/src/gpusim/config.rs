//! Simulated-GPU configuration.
//!
//! Latencies follow Luo et al. 2024 ("Benchmarking and dissecting the
//! Nvidia Hopper GPU architecture"), the source the paper cites: shared
//! 29.0, L1 37.9, L2 261.5, HBM 466.3 cycles.  Bandwidths and the atomic
//! same-address service interval are calibration constants chosen to land
//! in the regime the paper measures; the *ratios* between algorithms are
//! what the reproduction targets.

#[derive(Clone, Debug)]
pub struct GpuConfig {
    pub name: &'static str,
    pub num_sms: usize,
    /// Resident warp slots per SM (occupancy ceiling).
    pub warp_slots: usize,
    pub clock_ghz: f64,

    // Dependency latencies (cycles).
    pub lat_shared: u64,
    pub lat_l1: u64,
    pub lat_l2: u64,
    pub lat_hbm: u64,
    /// Latency of one ALU op in a dependent chain.
    pub lat_compute: u64,

    // Bandwidth (bytes per cycle).
    pub bw_l1_per_sm: f64,
    pub bw_l2: f64,
    pub bw_hbm: f64,

    /// Cycles between two memory instructions issued by one SM's LSU.
    pub lsu_interval: u64,
    /// Serialization cost per *lane* of an atomic RMW to one address.
    /// Same-address float atomics on NVIDIA hardware sustain roughly one
    /// update per ~10^2 cycles once fully contended (L2 round-trip +
    /// replay); 120 calibrates Algorithm 1's elapsed time to the ~1 s the
    /// paper measures at B=1024, N=197, d=768 on a 4060 Ti.
    pub atomic_service: u64,
    /// Fixed cost of a block-level barrier (__syncthreads).
    pub barrier_cost: u64,
}

impl GpuConfig {
    /// RTX 4060 Ti-class part: 34 SMs, ~2.3 GHz, 288 GB/s GDDR6.
    /// The paper's kernel microbenchmarks (Tables 2-3, Figs 2-3) used this.
    pub fn rtx4060ti() -> Self {
        Self {
            name: "sim-4060ti",
            num_sms: 34,
            warp_slots: 48,
            clock_ghz: 2.3,
            lat_shared: 29,
            lat_l1: 38,
            lat_l2: 262,
            lat_hbm: 466,
            lat_compute: 4,
            bw_l1_per_sm: 32.0,
            bw_l2: 550.0,  // ~1.3 TB/s @ 2.3 GHz
            bw_hbm: 125.0, // 288 GB/s @ 2.3 GHz
            lsu_interval: 2,
            atomic_service: 120,
            barrier_cost: 40,
        }
    }

    /// H200-class part: 132 SMs, ~1.8 GHz, 4.8 TB/s HBM3e.
    /// Used for the paper's end-to-end training measurements (Fig 1, Tab 4).
    pub fn h200() -> Self {
        Self {
            name: "sim-h200",
            num_sms: 132,
            warp_slots: 64,
            clock_ghz: 1.8,
            lat_shared: 29,
            lat_l1: 38,
            lat_l2: 262,
            lat_hbm: 466,
            lat_compute: 4,
            bw_l1_per_sm: 64.0,
            bw_l2: 4500.0,  // ~8 TB/s
            bw_hbm: 2650.0, // 4.8 TB/s @ 1.8 GHz
            lsu_interval: 2,
            atomic_service: 120,
            barrier_cost: 40,
        }
    }

    pub fn cycles_to_secs(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.clock_ghz * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_sane() {
        for cfg in [GpuConfig::rtx4060ti(), GpuConfig::h200()] {
            assert!(cfg.num_sms > 0 && cfg.warp_slots > 0);
            assert!(cfg.lat_shared < cfg.lat_l1);
            assert!(cfg.lat_l1 < cfg.lat_l2);
            assert!(cfg.lat_l2 < cfg.lat_hbm);
            assert!(cfg.bw_hbm < cfg.bw_l2);
        }
    }

    #[test]
    fn cycle_time_conversion() {
        let cfg = GpuConfig::rtx4060ti();
        let s = cfg.cycles_to_secs(2_300_000_000);
        assert!((s - 1.0).abs() < 1e-9);
    }
}
