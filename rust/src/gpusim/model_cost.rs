//! Whole-model training-step cost composition (paper Fig 1 and Table 4).
//!
//! A transformer train step is decomposed into kernel launches (GEMMs,
//! streaming ops, and the rational kernels), each simulated once per
//! distinct shape and summed.  Backward GEMMs cost ~2x forward (dX and dW);
//! the rational backward uses Algorithm 1 or Algorithm 2 per the variant.
//!
//! To keep simulation affordable the batch is scaled down to `b_sim` and
//! elapsed time scaled back linearly — valid because every regime involved
//! (HBM bandwidth, atomic serialization, issue throughput) is linear in
//! the element count at these sizes; the latency floor is negligible.

use super::config::GpuConfig;
use super::engine::{simulate, Kernel};
use super::kernels::{
    GemmKernel, RationalBwdFlashKernel, RationalBwdKatKernel, RationalDims, RationalFwdKernel,
    StreamKernel,
};

/// Which feed-forward the model uses, and (for GR-KAN) which backward.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ffn {
    Mlp,
    GrkanKat,
    GrkanFlash,
}

/// Transformer shape for cost estimation (paper Table 6 variants).
#[derive(Clone, Copy, Debug)]
pub struct ModelShape {
    pub name: &'static str,
    pub batch: u64,
    pub tokens: u64,
    pub d: u64,
    pub depth: u64,
    pub heads: u64,
    pub mlp_ratio: u64,
    pub n_groups: u32,
    pub ffn: Ffn,
}

impl ModelShape {
    pub fn kat(name: &'static str, d: u64, heads: u64, ffn: Ffn) -> Self {
        Self { name, batch: 1024, tokens: 197, d, depth: 12, heads, mlp_ratio: 4, n_groups: 8, ffn }
    }
}

/// The six Fig-1 models plus the FlashKAT variants of Table 4.
pub fn paper_models() -> Vec<ModelShape> {
    vec![
        ModelShape::kat("vit-t", 192, 3, Ffn::Mlp),
        ModelShape::kat("kat-t", 192, 3, Ffn::GrkanKat),
        ModelShape::kat("flashkat-t", 192, 3, Ffn::GrkanFlash),
        ModelShape::kat("vit-s", 384, 6, Ffn::Mlp),
        ModelShape::kat("kat-s", 384, 6, Ffn::GrkanKat),
        ModelShape::kat("flashkat-s", 384, 6, Ffn::GrkanFlash),
        ModelShape::kat("vit-b", 768, 12, Ffn::Mlp),
        ModelShape::kat("kat-b", 768, 12, Ffn::GrkanKat),
        ModelShape::kat("flashkat-b", 768, 12, Ffn::GrkanFlash),
    ]
}

/// Per-op cost line.
#[derive(Clone, Debug)]
pub struct OpCost {
    pub label: String,
    pub secs: f64,
}

/// Full train-step estimate (forward + backward, optimizer excluded like
/// the paper's Fwd+Bwd measurement).
#[derive(Clone, Debug)]
pub struct StepCost {
    pub model: &'static str,
    pub fwd_secs: f64,
    pub bwd_secs: f64,
    pub ops: Vec<OpCost>,
}

impl StepCost {
    pub fn total_secs(&self) -> f64 {
        self.fwd_secs + self.bwd_secs
    }

    /// Training throughput in images/second (paper Table 4's metric).
    pub fn throughput(&self, batch: u64) -> f64 {
        batch as f64 / self.total_secs()
    }
}

struct Estimator<'a> {
    cfg: &'a GpuConfig,
    scale: f64,
    ops: Vec<OpCost>,
    fwd: f64,
    bwd: f64,
}

impl<'a> Estimator<'a> {
    fn sim(&mut self, label: &str, kernel: &dyn Kernel, reps: f64, is_fwd: bool) -> f64 {
        let r = simulate(self.cfg, kernel);
        let secs = r.elapsed_secs * self.scale * reps;
        self.ops.push(OpCost { label: format!("{label} x{reps:.0}"), secs });
        if is_fwd {
            self.fwd += secs;
        } else {
            self.bwd += secs;
        }
        secs
    }
}

/// Estimate one fwd+bwd step of `shape` on `cfg`, simulating at batch
/// `b_sim` and scaling elapsed time by `batch / b_sim`.
pub fn train_step_cost(cfg: &GpuConfig, shape: &ModelShape, b_sim: u64) -> StepCost {
    let b_sim = b_sim.min(shape.batch).max(1);
    let scale = shape.batch as f64 / b_sim as f64;
    let mut est = Estimator { cfg, scale, ops: Vec::new(), fwd: 0.0, bwd: 0.0 };

    let (bn, d, n, h) = (b_sim * shape.tokens, shape.d, shape.tokens, shape.heads);
    let dh = d / h;
    let d_ff = d * shape.mlp_ratio;
    let depth = shape.depth as f64;
    let f32b = 4;

    // --- attention + norms, per layer (identical for all variants) ---
    // LayerNorm x2 per layer, fwd and bwd.
    let ln = StreamKernel {
        label: "layernorm".into(),
        bytes_read: bn * d * f32b,
        bytes_write: bn * d * f32b,
        alu_per_elem: 8,
    };
    est.sim("ln fwd", &ln, 2.0 * depth, true);
    est.sim("ln bwd", &ln, 2.0 * depth, false);

    // QKV projection (one fused gemm), output projection.
    let qkv = GemmKernel { m: bn, n: 3 * d, k: d, count: 1 };
    let proj = GemmKernel { m: bn, n: d, k: d, count: 1 };
    est.sim("qkv fwd", &qkv, depth, true);
    est.sim("qkv bwd", &qkv, 2.0 * depth, false);
    est.sim("proj fwd", &proj, depth, true);
    est.sim("proj bwd", &proj, 2.0 * depth, false);

    // Attention scores and weighted sum (batched over B*heads).
    let scores = GemmKernel { m: n, n, k: dh, count: b_sim * h };
    let av = GemmKernel { m: n, n: dh, k: n, count: b_sim * h };
    est.sim("scores fwd", &scores, depth, true);
    est.sim("scores bwd", &scores, 2.0 * depth, false);
    est.sim("attn-v fwd", &av, depth, true);
    est.sim("attn-v bwd", &av, 2.0 * depth, false);
    let softmax = StreamKernel {
        label: "softmax".into(),
        bytes_read: b_sim * h * n * n * f32b,
        bytes_write: b_sim * h * n * n * f32b,
        alu_per_elem: 12,
    };
    est.sim("softmax fwd", &softmax, depth, true);
    est.sim("softmax bwd", &softmax, depth, false);

    // --- feed-forward ---
    let fc1 = GemmKernel { m: bn, n: d_ff, k: d, count: 1 };
    let fc2 = GemmKernel { m: bn, n: d, k: d_ff, count: 1 };
    est.sim("fc1 fwd", &fc1, depth, true);
    est.sim("fc1 bwd", &fc1, 2.0 * depth, false);
    est.sim("fc2 fwd", &fc2, depth, true);
    est.sim("fc2 bwd", &fc2, 2.0 * depth, false);

    match shape.ffn {
        Ffn::Mlp => {
            let gelu = StreamKernel {
                label: "gelu".into(),
                bytes_read: bn * d_ff * f32b,
                bytes_write: bn * d_ff * f32b,
                alu_per_elem: 16,
            };
            est.sim("gelu fwd", &gelu, depth, true);
            est.sim("gelu bwd", &gelu, depth, false);
        }
        Ffn::GrkanKat | Ffn::GrkanFlash => {
            // Two rationals per block: on d (pre-fc1) and on d_ff (pre-fc2).
            for (label, width) in [("rational(d)", d), ("rational(4d)", d_ff)] {
                let dims = RationalDims {
                    batch: b_sim,
                    seq: shape.tokens,
                    d: width,
                    n_groups: shape.n_groups,
                    m1: 6,
                    n: 4,
                    flop_loops: 1,
                };
                est.sim(&format!("{label} fwd"), &RationalFwdKernel::new(dims), depth, true);
                if shape.ffn == Ffn::GrkanKat {
                    est.sim(
                        &format!("{label} bwd[alg1]"),
                        &RationalBwdKatKernel::new(dims),
                        depth,
                        false,
                    );
                } else {
                    est.sim(
                        &format!("{label} bwd[alg2]"),
                        &RationalBwdFlashKernel::new(dims),
                        depth,
                        false,
                    );
                }
            }
        }
    }

    StepCost { model: shape.name, fwd_secs: est.fwd, bwd_secs: est.bwd, ops: est.ops }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn h200() -> GpuConfig {
        GpuConfig::h200()
    }

    #[test]
    fn kat_orders_of_magnitude_slower_than_vit() {
        // Paper Fig 1: KAT-T is ~102x slower than ViT-T per fwd+bwd step.
        let cfg = h200();
        let vit = train_step_cost(&cfg, &ModelShape::kat("vit-t", 192, 3, Ffn::Mlp), 16);
        let kat = train_step_cost(&cfg, &ModelShape::kat("kat-t", 192, 3, Ffn::GrkanKat), 16);
        let ratio = kat.total_secs() / vit.total_secs();
        assert!(ratio > 20.0, "ratio {ratio:.1}");
    }

    #[test]
    fn flashkat_closes_most_of_the_gap() {
        // Paper Table 4 / Limitations: FlashKAT within ~25-50% of ViT.
        let cfg = h200();
        let vit = train_step_cost(&cfg, &ModelShape::kat("vit-t", 192, 3, Ffn::Mlp), 16);
        let flash = train_step_cost(&cfg, &ModelShape::kat("fk-t", 192, 3, Ffn::GrkanFlash), 16);
        let ratio = flash.total_secs() / vit.total_secs();
        assert!(ratio < 3.0, "ratio {ratio:.2}");
        assert!(ratio > 1.0, "FlashKAT shouldn't be faster than ViT ({ratio:.2})");
    }

    #[test]
    fn backward_dominates_kat_step() {
        // Paper Insight 3: the backward pass dominates KAT training time.
        let cfg = h200();
        let kat = train_step_cost(&cfg, &ModelShape::kat("kat-t", 192, 3, Ffn::GrkanKat), 16);
        assert!(kat.bwd_secs > 10.0 * kat.fwd_secs);
    }

    #[test]
    fn bigger_models_slower() {
        let cfg = h200();
        let t = train_step_cost(&cfg, &ModelShape::kat("vit-t", 192, 3, Ffn::Mlp), 8);
        let b = train_step_cost(&cfg, &ModelShape::kat("vit-b", 768, 12, Ffn::Mlp), 8);
        assert!(b.total_secs() > 2.0 * t.total_secs());
    }

    #[test]
    fn throughput_metric() {
        let sc = StepCost { model: "x", fwd_secs: 0.05, bwd_secs: 0.05, ops: vec![] };
        assert!((sc.throughput(1024) - 10240.0).abs() < 1e-6);
    }

    #[test]
    fn batch_scaling_roughly_linear() {
        // The b_sim scaling assumption: per-image cost stable across b_sim.
        let cfg = h200();
        let a = train_step_cost(&cfg, &ModelShape::kat("kat-t", 192, 3, Ffn::GrkanKat), 8);
        let b = train_step_cost(&cfg, &ModelShape::kat("kat-t", 192, 3, Ffn::GrkanKat), 32);
        let ratio = a.total_secs() / b.total_secs();
        assert!((0.7..1.4).contains(&ratio), "{ratio}");
    }
}
