//! FlashKAT leader binary.
//!
//! Subcommands:
//!   report <fig1|table1|table2|fig2|fig3|table3|table4|table5|configs|all>
//!          [--gpu 4060ti|h200] [--batch N] [--b-sim N] [--rows N] [--passes N]
//!   train  [--model kat_micro|vit_micro|kat_micro_katbwd] [--steps N]
//!          [--seed N] [--ckpt PATH] [--artifacts DIR]
//!   profile [--kernel fwd|kat|flash] [--loops N] [--gpu 4060ti|h200] [--batch N]
//!   selfcheck [--artifacts DIR]   -- runtime vs Rust-oracle numerics
//!   flops
//!
//! See DESIGN.md §5 for the table/figure -> command mapping.

use anyhow::{bail, Context, Result};

use flashkat::cli::Args;
use flashkat::config::TrainConfig;
use flashkat::coordinator::Trainer;
use flashkat::gpusim::kernels::{
    RationalBwdFlashKernel, RationalBwdKatKernel, RationalDims, RationalFwdKernel,
};
use flashkat::gpusim::{simulate, GpuConfig};
use flashkat::rational::experiment::RoundingConfig;
use flashkat::report;
use flashkat::runtime::Runtime;

fn gpu_from(args: &Args) -> Result<GpuConfig> {
    Ok(match args.flag_str("gpu", "4060ti") {
        "4060ti" => GpuConfig::rtx4060ti(),
        "h200" => GpuConfig::h200(),
        other => bail!("unknown --gpu {other:?} (4060ti|h200)"),
    })
}

fn dims_from(args: &Args) -> Result<RationalDims> {
    let mut d = RationalDims::paper();
    d.batch = args.flag_u64("batch", d.batch)?;
    Ok(d)
}

fn cmd_report(args: &Args) -> Result<()> {
    let which = args.positional.first().map(String::as_str).unwrap_or("all");
    let gpu = gpu_from(args)?;
    let b_sim = args.flag_u64("b-sim", 32)?;
    let dims = dims_from(args)?;
    let rounding = RoundingConfig {
        rows: args.flag_usize("rows", 32 * 768)?,
        passes: args.flag_usize("passes", 5)?,
        ..Default::default()
    };
    let all = which == "all";
    if all || which == "table1" {
        print!("{}", report::table1());
    }
    if all || which == "fig1" {
        print!("{}", report::fig1(&GpuConfig::h200(), b_sim.min(16)));
    }
    if all || which == "table2" {
        print!("{}", report::table2(&gpu, dims));
    }
    if all || which == "fig2" || which == "fig3" {
        print!("{}", report::fig2_fig3(&gpu, dims));
    }
    if all || which == "table3" {
        print!("{}", report::table3(&gpu, dims));
    }
    if all || which == "table4" {
        print!("{}", report::table4(&GpuConfig::h200(), b_sim.min(16)));
    }
    if all || which == "table5" {
        print!("{}", report::table5(&rounding));
    }
    if all || which == "configs" {
        print!("{}", report::configs());
    }
    if !all
        && !matches!(
            which,
            "table1" | "fig1" | "table2" | "fig2" | "fig3" | "table3" | "table4" | "table5"
                | "configs"
        )
    {
        bail!("unknown report {which:?}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let tag = args.flag_str("model", "kat_micro").to_string();
    let mut cfg = TrainConfig { model: tag.clone(), ..Default::default() };
    cfg.steps = args.flag_usize("steps", cfg.steps)?;
    cfg.seed = args.flag_u64("seed", cfg.seed)?;
    cfg.log_every = args.flag_usize("log-every", cfg.log_every)?;
    let artifacts = args.flag_str("artifacts", "artifacts");
    let rt = Runtime::cpu(artifacts)?;
    eprintln!("platform: {}", rt.platform());
    let trainer = Trainer::new(&rt, &tag, cfg).context("loading artifacts")?;
    eprintln!(
        "model {tag}: {} parameter leaves, batch {}",
        trainer.param_leaves(),
        trainer.batch_size()
    );
    let ckpt = args.flag("ckpt").map(std::path::PathBuf::from);
    let rep = trainer.train(ckpt.as_deref())?;
    println!(
        "{}: {} steps, loss {:.4} -> {:.4}, {:.1} (± {:.1}) img/s, host overhead {:.1}%, eval acc {:.3} (EMA {:.3})",
        rep.tag,
        rep.steps,
        rep.first_loss(),
        rep.final_loss(),
        rep.throughput_mean,
        rep.throughput_ci95,
        100.0 * rep.host_overhead,
        rep.final_eval_acc.unwrap_or(f64::NAN),
        rep.ema_eval_acc.unwrap_or(f64::NAN)
    );
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let gpu = gpu_from(args)?;
    let mut dims = dims_from(args)?;
    dims.flop_loops = args.flag_u64("loops", 1)? as u32;
    let rep = match args.flag_str("kernel", "kat") {
        "fwd" => simulate(&gpu, &RationalFwdKernel::new(dims)),
        "kat" => simulate(&gpu, &RationalBwdKatKernel::new(dims)),
        "flash" => simulate(&gpu, &RationalBwdFlashKernel::new(dims)),
        other => bail!("unknown --kernel {other:?} (fwd|kat|flash)"),
    };
    println!("kernel                    cycles       time   SM%      L1%      L2%     HBM%");
    println!("{}", rep.table_row());
    print!("{}", rep.warp_state_figure());
    Ok(())
}

/// Runtime integration check: run the standalone rational kernels through
/// PJRT and compare against the Rust-side oracle.
fn cmd_selfcheck(args: &Args) -> Result<()> {
    use flashkat::rational::accumulate::{backward, Strategy};
    use flashkat::rational::Coeffs;
    use flashkat::runtime::HostTensor;
    use flashkat::util::rng::Pcg64;

    let artifacts = args.flag_str("artifacts", "artifacts");
    let rt = Runtime::cpu(artifacts)?;
    println!("platform: {}", rt.platform());

    let m = rt.load("rational_fwd")?;
    let dims: Vec<usize> = m
        .manifest
        .raw
        .get("dims")
        .and_then(|d| d.as_arr())
        .context("dims meta")?
        .iter()
        .map(|v| v.as_usize().unwrap())
        .collect();
    let (b, n, d) = (dims[0], dims[1], dims[2]);
    let rows = b * n;
    let mut rng = Pcg64::new(7);
    let x: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
    let coeffs = Coeffs::<f32>::randn(8, 6, 4, &mut rng);

    let inputs = [
        HostTensor::F32 { shape: vec![b, n, d], data: x.clone() },
        HostTensor::F32 { shape: vec![8, 6], data: coeffs.a.clone() },
        HostTensor::F32 { shape: vec![8, 4], data: coeffs.b.clone() },
    ];
    let outs = m.execute(&inputs)?;
    let got = outs[0].as_f32()?;
    let want = flashkat::rational::forward(&x, rows, d, &coeffs);
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0, f64::max);
    println!(
        "rational_fwd: max |pallas - rust oracle| = {max_err:.3e} over {} elements",
        got.len()
    );
    if max_err > 1e-3 {
        bail!("forward mismatch");
    }

    let mb = rt.load("rational_bwd_flash")?;
    let dout: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32()).collect();
    let inputs = [
        HostTensor::F32 { shape: vec![b, n, d], data: x.clone() },
        HostTensor::F32 { shape: vec![b, n, d], data: dout.clone() },
        HostTensor::F32 { shape: vec![8, 6], data: coeffs.a.clone() },
        HostTensor::F32 { shape: vec![8, 4], data: coeffs.b.clone() },
    ];
    let outs = mb.execute(&inputs)?;
    let (_, da_r, db_r) =
        backward(&x, &dout, rows, d, &coeffs, Strategy::BlockTree { s_block: 128 });
    let da = outs[1].as_f32()?;
    let db = outs[2].as_f32()?;
    let scale = da_r.iter().map(|v| v.abs() as f64).fold(1.0, f64::max);
    let err_a =
        da.iter().zip(&da_r).map(|(a, b)| (a - b).abs() as f64).fold(0.0, f64::max) / scale;
    let scale_b = db_r.iter().map(|v| v.abs() as f64).fold(1.0, f64::max);
    let err_b =
        db.iter().zip(&db_r).map(|(a, b)| (a - b).abs() as f64).fold(0.0, f64::max) / scale_b;
    println!("rational_bwd_flash: rel dA err {err_a:.3e}, rel dB err {err_b:.3e}");
    if err_a > 1e-3 || err_b > 1e-3 {
        bail!("backward mismatch");
    }
    println!("selfcheck OK");
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.command.as_str() {
        "report" => cmd_report(&args),
        "train" => cmd_train(&args),
        "profile" => cmd_profile(&args),
        "selfcheck" => cmd_selfcheck(&args),
        "flops" => {
            print!("{}", report::table1());
            Ok(())
        }
        "" | "help" | "--help" => {
            println!(
                "flashkat — FlashKAT reproduction (see DESIGN.md)\n\n\
                 usage: flashkat <report|train|profile|selfcheck|flops> [flags]\n\
                 \x20 report <fig1|table1|table2|fig2|fig3|table3|table4|table5|configs|all>\n\
                 \x20 train  [--model kat_micro|vit_micro|kat_micro_katbwd] [--steps N] [--ckpt PATH]\n\
                 \x20 profile [--kernel fwd|kat|flash] [--loops N] [--gpu 4060ti|h200]\n\
                 \x20 selfcheck [--artifacts DIR]"
            );
            Ok(())
        }
        other => bail!("unknown command {other:?} — try `flashkat help`"),
    }
}
